"""L1 Pallas kernel: tiled Gram product f(X) = X·Xᵀ.

The paper's running worker task (§V-A). TPU schedule: the output grid is
(r/T, r/T) tiles; each program holds two (T × d) row-panels of X in VMEM
and issues one (T×d)·(d×T) contraction on the MXU, accumulating in f32.
For the AOT shapes (T = 64, d ≤ 512) the VMEM footprint is
2·T·d·4B ≤ 256 KiB — far under the ~16 MiB budget, leaving room for
double-buffering the HBM→VMEM streams.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so correctness runs through the interpreter and the real-TPU
efficiency is estimated analytically (DESIGN.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile edge. 64 keeps (64 × d) panels VMEM-resident and feeds the
# 128×128 MXU with well-shaped operands after internal vectorization.
TILE = 64


def _gram_kernel(xi_ref, xj_ref, o_ref):
    """o = Xᵢ · Xⱼᵀ for two row-panels of X."""
    xi = xi_ref[...]  # (tile, d)
    xj = xj_ref[...]  # (tile, d)
    o_ref[...] = jax.lax.dot_general(
        xi,
        xj,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def gram(x: jnp.ndarray) -> jnp.ndarray:
    """X (r, d) → X·Xᵀ (r, r), tiled at TILE when divisible."""
    r, d = x.shape
    tile = TILE if r % TILE == 0 else r
    grid = (r // tile, r // tile)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        interpret=True,
    )(x, x)
