"""L1 Pallas kernel: Berrut weighted block combination.

The SPACDC encode (Eq. (17)) evaluates ``u(αⱼ) = Σᵢ wᵢ(αⱼ)·Bᵢ`` for each
worker j — a weighted sum of the K+T data/mask blocks. On a real TPU this
is a VMEM-resident reduction: the grid walks row-tiles of the output; each
program streams the matching tile of all n source blocks through VMEM and
accumulates with the scalar weights (held in SMEM-like full residency).

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls);
the BlockSpec structure below is the TPU schedule the DESIGN.md
§Hardware-Adaptation section analyzes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height: one VMEM tile of each source block per grid step.
# 8 sublanes × f32 is the TPU-native minimum; 64 keeps the tile MXU/VPU
# friendly while bounding VMEM at n_blocks × 64 × c × 4 bytes.
TILE_ROWS = 64


def _berrut_kernel(w_ref, blocks_ref, o_ref):
    """One row-tile: o = Σᵢ wᵢ · blocksᵢ  (accumulate in f32)."""
    blocks = blocks_ref[...]  # (n, tile_rows, c)
    w = w_ref[...]  # (n,)
    # Weighted reduction over the leading axis. tensordot lowers to a
    # single (1×n)·(n×tile·c) contraction — MXU-shaped on real hardware.
    o_ref[...] = jnp.tensordot(w, blocks, axes=1)


@functools.partial(jax.jit, static_argnames=())
def berrut_combine(blocks: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Σᵢ wᵢ·Bᵢ for blocks (n, r, c), weights (n,) → (r, c).

    Rows are tiled at TILE_ROWS when divisible (the AOT shapes are);
    otherwise the kernel falls back to a single-program grid.
    """
    n, r, c = blocks.shape
    tile = TILE_ROWS if r % TILE_ROWS == 0 else r
    grid = (r // tile,)
    return pl.pallas_call(
        _berrut_kernel,
        grid=grid,
        in_specs=[
            # Weights: full residency every step.
            pl.BlockSpec((n,), lambda i: (0,)),
            # Blocks: all n sources, one row-tile, all columns.
            pl.BlockSpec((n, tile, c), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), blocks.dtype),
        interpret=True,
    )(weights, blocks)


def berrut_combine_stacked(
    stacked: jnp.ndarray, weights: jnp.ndarray, n_blocks: int
) -> jnp.ndarray:
    """2-D interop wrapper for the Rust runtime: ``stacked`` is the n
    blocks concatenated by rows ((n·r) × c); ``weights`` is (n, 1).

    The PJRT bridge moves plain 2-D f32 matrices, so the AOT artifact is
    lowered through this wrapper.
    """
    total_rows, c = stacked.shape
    r = total_rows // n_blocks
    blocks = stacked.reshape(n_blocks, r, c)
    return berrut_combine(blocks, weights.reshape(n_blocks))
