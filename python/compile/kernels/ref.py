"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every kernel in this package is checked against these references by
``python/tests`` (pytest + hypothesis). The references are deliberately
written in the most obvious jnp form — no tiling, no tricks — so a
mismatch always indicts the kernel.
"""

import jax.numpy as jnp


def berrut_combine_ref(blocks: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Σᵢ wᵢ·Bᵢ over stacked blocks (n, r, c) with weights (n,).

    This is the inner operation of the SPACDC/BACC encode (paper
    Eq. (17)) and decode (Eq. (18)): a weighted combination of the K+T
    data/mask blocks at one evaluation node.
    """
    return jnp.tensordot(weights, blocks, axes=1)


def gram_ref(x: jnp.ndarray) -> jnp.ndarray:
    """f(X) = X Xᵀ — the paper's running worker task (§V-A)."""
    return x @ x.T


def rightmul_ref(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """f(X) = X·V — the SPACDC-DL coded gradient op (Eq. (23))."""
    return x @ v


def mlp_forward_ref(params, x: jnp.ndarray) -> jnp.ndarray:
    """Forward pass of the §VI-A DNN: ReLU hiddens, softmax output.

    ``params`` is a list of (W, b) with W (out, in) and b (out, 1);
    ``x`` is (features, batch); returns class probabilities
    (classes, batch).
    """
    a = x
    for i, (w, b) in enumerate(params):
        tau = w @ a + b
        if i + 1 == len(params):
            a = jnp.exp(tau - tau.max(axis=0, keepdims=True))
            a = a / a.sum(axis=0, keepdims=True)
        else:
            a = jnp.maximum(tau, 0.0)
    return a
