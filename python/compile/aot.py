"""AOT driver: lower the L2 functions (with their L1 Pallas kernels) to
HLO text and write ``artifacts/`` + ``manifest.txt``.

HLO *text* is the interchange format — jax ≥ 0.5 serializes protos with
64-bit instruction ids that the Rust side's xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Manifest line format (consumed by ``rust/src/runtime/pjrt.rs``)::

    <key> <file> <out_rows> <out_cols>

Artifact keys mirror ``runtime::pjrt::artifact_key``:
``gram_{r}x{c}``, ``rightmul_{r}x{k}x{c}``, ``berrut_{n}x{r}x{c}``,
``mlp_fwd_{batch}``.

Run via ``make artifacts`` (idempotent: skips when outputs are newer than
inputs thanks to make's dependency check).
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Default DL geometry — must match rust SystemConfig::default():
# layers 784-256-128-10, batch 64, K=4 partitions, T=3 masks.
LAYERS = [784, 256, 128, 10]
BATCH = 64
K_PARTITIONS = 4
T_MASKS = 3


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_entry(fn, args):
    return to_hlo_text(jax.jit(fn).lower(*args))


def artifact_plan():
    """(key, file, out_shape, thunk) for every artifact."""
    plan = []

    def add(key, out_shape, fn, args):
        plan.append((key, f"{key}.hlo.txt", out_shape, lambda: lower_entry(fn, args)))

    # Worker Gram tasks: the quickstart share shape and a small test shape.
    for r, c in [(128, 256), (64, 64)]:
        add(f"gram_{r}x{c}", (r, r), model.gram_task, (f32(r, c),))

    # SPACDC-DL backward products (Eq. 23): Θᵀ row-blocks × δ, for the
    # default net at K=4, batch 64.
    #   layer 2: Θ₂ᵀ (128×10) → blocks 32×10, δ (10×64)
    #   layer 1: Θ₁ᵀ (256×128) → blocks 64×128, δ (128×64)
    for r, k, c in [(32, 10, BATCH), (64, 128, BATCH)]:
        add(
            f"rightmul_{r}x{k}x{c}",
            (r, c),
            model.rightmul_task,
            (f32(r, k), f32(k, c)),
        )

    # Master-side Berrut encode (Eq. 17) for the same layer blocks:
    # K+T = 7 stacked blocks → one encoded share.
    n = K_PARTITIONS + T_MASKS
    for r, c in [(64, 128), (32, 10)]:
        fn = functools.partial(model.berrut_encode_task, n_blocks=n)
        add(
            f"berrut_{n}x{r}x{c}",
            (r, c),
            fn,
            (f32(n * r, c), f32(n, 1)),
        )

    # Full DNN forward for PJRT-served evaluation.
    l0, l1, l2, l3 = LAYERS
    add(
        f"mlp_fwd_{BATCH}",
        (l3, BATCH),
        model.mlp_forward,
        (
            f32(l1, l0),
            f32(l1, 1),
            f32(l2, l1),
            f32(l2, 1),
            f32(l3, l2),
            f32(l3, 1),
            f32(l0, BATCH),
        ),
    )
    return plan


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = ["# key file out_rows out_cols"]
    for key, fname, out_shape, thunk in artifact_plan():
        text = thunk()
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{key} {fname} {out_shape[0]} {out_shape[1]}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines) - 1} artifacts")


if __name__ == "__main__":
    main()
