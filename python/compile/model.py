"""L2: the JAX compute graph — the worker tasks and the §VI-A DNN.

Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text once (``make artifacts``) and the Rust coordinator executes the
compiled artifacts through PJRT. Python never runs on the request path.

The worker tasks call the L1 Pallas kernels so the kernels lower into the
same HLO module the Rust side loads.
"""

import jax.numpy as jnp

from compile.kernels.berrut import berrut_combine_stacked
from compile.kernels.gram import gram as gram_kernel


def gram_task(x: jnp.ndarray):
    """Worker task f(X̃) = X̃·X̃ᵀ (§V-A), via the L1 Pallas kernel.

    Returned as a 1-tuple: the AOT path lowers with ``return_tuple=True``
    and the Rust loader unwraps with ``to_tuple1``.
    """
    return (gram_kernel(x),)


def rightmul_task(x: jnp.ndarray, v: jnp.ndarray):
    """Worker task f(X̃) = X̃·V — the Eq. (23) coded gradient product."""
    return (jnp.dot(x, v, preferred_element_type=jnp.float32),)


def berrut_encode_task(stacked: jnp.ndarray, weights: jnp.ndarray, n_blocks: int):
    """Master-side SPACDC encode step (Eq. (17)) at one node: weighted
    combination of the K+T stacked blocks, via the L1 Pallas kernel."""
    return (berrut_combine_stacked(stacked, weights, n_blocks),)


def mlp_forward(w0, b0, w1, b1, w2, b2, x):
    """Forward pass of the default 784-256-128-10 DNN (Eq. (19)):
    ReLU hiddens, softmax output. Biases are (out, 1) so every operand is
    a plain 2-D f32 matrix on the PJRT bridge.
    """
    a1 = jnp.maximum(w0 @ x + b0, 0.0)
    a2 = jnp.maximum(w1 @ a1 + b1, 0.0)
    tau = w2 @ a2 + b2
    e = jnp.exp(tau - tau.max(axis=0, keepdims=True))
    return (e / e.sum(axis=0, keepdims=True),)
