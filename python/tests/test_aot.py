"""AOT path checks: every artifact in the plan lowers to parseable HLO
text with the declared output shape, and numerics survive the round trip
through the XlaComputation conversion.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_plan_covers_expected_keys(self):
        keys = {k for k, _, _, _ in aot.artifact_plan()}
        assert "gram_128x256" in keys
        assert "rightmul_64x128x64" in keys
        assert "rightmul_32x10x64" in keys
        assert "berrut_7x64x128" in keys
        assert "mlp_fwd_64" in keys

    def test_hlo_text_is_emitted(self):
        text = aot.lower_entry(model.gram_task, (aot.f32(64, 64),))
        assert "HloModule" in text
        assert len(text) > 200

    def test_manifest_shapes_match_declared(self):
        # Lower one small entry and sanity-check the declared output
        # shape appears in the HLO root.
        for key, _, out_shape, thunk in aot.artifact_plan():
            if key == "gram_64x64":
                text = thunk()
                assert f"f32[{out_shape[0]},{out_shape[1]}]" in text

    def test_rightmul_lowering_numerics(self):
        """jit-compile the same function the artifact captures and compare
        against the reference — guards against lowering-time shape bugs."""
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 10), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(1), (10, 64), jnp.float32)
        (got,) = jax.jit(model.rightmul_task)(x, v)
        np.testing.assert_allclose(got, x @ v, rtol=1e-4, atol=1e-5)

    def test_gram_task_jit_matches_eager(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (64, 64), jnp.float32)
        (eager,) = model.gram_task(x)
        (jitted,) = jax.jit(model.gram_task)(x)
        np.testing.assert_allclose(eager, jitted, rtol=1e-5, atol=1e-5)


class TestArtifactFiles:
    """Validate artifacts on disk when `make artifacts` has run."""

    @pytest.fixture
    def artifacts_dir(self):
        import os

        d = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        if not os.path.exists(os.path.join(d, "manifest.txt")):
            pytest.skip("artifacts not built (run `make artifacts`)")
        return d

    def test_manifest_lines_well_formed(self, artifacts_dir):
        import os

        with open(os.path.join(artifacts_dir, "manifest.txt")) as f:
            lines = [
                l.strip()
                for l in f
                if l.strip() and not l.startswith("#")
            ]
        assert len(lines) >= 6
        for line in lines:
            key, fname, rows, cols = line.split()
            assert int(rows) > 0 and int(cols) > 0
            path = os.path.join(artifacts_dir, fname)
            assert os.path.exists(path), f"missing artifact file {fname}"
            with open(path) as f:
                head = f.read(4096)
            assert "HloModule" in head, f"{fname} is not HLO text"
