"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; fixed tests pin the exact AOT
shapes the Rust runtime loads.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.berrut import berrut_combine, berrut_combine_stacked
from compile.kernels.gram import gram
from compile.kernels.ref import (
    berrut_combine_ref,
    gram_ref,
    mlp_forward_ref,
    rightmul_ref,
)


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestBerrutKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 9),
        r=st.integers(1, 96),
        c=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, n, r, c, seed):
        blocks = rand(seed, n, r, c)
        weights = rand(seed + 1, n)
        got = berrut_combine(blocks, weights)
        want = berrut_combine_ref(blocks, weights)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_aot_shape_64x128(self):
        # The exact artifact shape: K+T=7 blocks of 64×128.
        blocks = rand(1, 7, 64, 128)
        weights = rand(2, 7)
        np.testing.assert_allclose(
            berrut_combine(blocks, weights),
            berrut_combine_ref(blocks, weights),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_tiled_path_multiple_of_tile(self):
        # 128 rows → 2 grid steps at TILE_ROWS=64.
        blocks = rand(3, 4, 128, 16)
        weights = rand(4, 4)
        np.testing.assert_allclose(
            berrut_combine(blocks, weights),
            berrut_combine_ref(blocks, weights),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_stacked_wrapper_matches_3d(self):
        blocks = rand(5, 7, 32, 10)
        weights = rand(6, 7)
        stacked = blocks.reshape(7 * 32, 10)
        np.testing.assert_allclose(
            berrut_combine_stacked(stacked, weights.reshape(7, 1), 7),
            berrut_combine_ref(blocks, weights),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_single_block_identity_weight(self):
        blocks = rand(7, 1, 8, 8)
        out = berrut_combine(blocks, jnp.ones((1,)))
        np.testing.assert_allclose(out, blocks[0], rtol=1e-6)

    def test_weights_summing_to_one_preserve_constant(self):
        # Partition-of-unity weights on identical blocks: exact identity.
        blocks = jnp.stack([jnp.full((16, 4), 3.25)] * 5)
        w = jnp.array([0.4, 0.25, 0.2, 0.1, 0.05])
        out = berrut_combine(blocks, w)
        np.testing.assert_allclose(out, jnp.full((16, 4), 3.25), rtol=1e-5)


class TestGramKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        r=st.integers(1, 80),
        d=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, r, d, seed):
        x = rand(seed, r, d)
        np.testing.assert_allclose(gram(x), gram_ref(x), rtol=1e-4, atol=1e-4)

    def test_aot_shape_128x256(self):
        x = rand(11, 128, 256)
        np.testing.assert_allclose(gram(x), gram_ref(x), rtol=1e-4, atol=1e-4)

    def test_output_is_symmetric_psd_diagonal(self):
        x = rand(12, 64, 32)
        g = np.asarray(gram(x))
        np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-5)
        assert (np.diag(g) >= -1e-5).all()

    def test_dtype_is_f32(self):
        x = rand(13, 64, 16)
        assert gram(x).dtype == jnp.float32


class TestModelFunctions:
    def test_rightmul_matches_ref(self):
        from compile import model

        x = rand(20, 64, 128)
        v = rand(21, 128, 64)
        (got,) = model.rightmul_task(x, v)
        np.testing.assert_allclose(got, rightmul_ref(x, v), rtol=1e-4, atol=1e-4)

    def test_mlp_forward_matches_ref(self):
        from compile import model

        params = [
            (rand(30, 256, 784, scale=0.05), rand(31, 256, 1, scale=0.01)),
            (rand(32, 128, 256, scale=0.05), rand(33, 128, 1, scale=0.01)),
            (rand(34, 10, 128, scale=0.05), rand(35, 10, 1, scale=0.01)),
        ]
        x = jax.random.uniform(jax.random.PRNGKey(36), (784, 64), jnp.float32)
        (got,) = model.mlp_forward(
            params[0][0], params[0][1],
            params[1][0], params[1][1],
            params[2][0], params[2][1],
            x,
        )
        want = mlp_forward_ref(params, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        # Probabilities: columns sum to 1.
        np.testing.assert_allclose(np.asarray(got).sum(axis=0), 1.0, rtol=1e-5)

    def test_berrut_encode_task_matches_ref(self):
        from compile import model

        blocks = rand(40, 7, 64, 128)
        w = rand(41, 7)
        (got,) = model.berrut_encode_task(
            blocks.reshape(7 * 64, 128), w.reshape(7, 1), n_blocks=7
        )
        np.testing.assert_allclose(
            got, berrut_combine_ref(blocks, w), rtol=1e-5, atol=1e-5
        )

    def test_gram_task_wraps_kernel(self):
        from compile import model

        x = rand(42, 64, 64)
        (got,) = model.gram_task(x)
        np.testing.assert_allclose(got, gram_ref(x), rtol=1e-4, atol=1e-4)


class TestHypothesisProperties:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 8))
    def test_berrut_linearity(self, seed, n):
        """combine(B, w1 + w2) == combine(B, w1) + combine(B, w2)."""
        blocks = rand(seed, n, 32, 8)
        w1 = rand(seed + 1, n)
        w2 = rand(seed + 2, n)
        lhs = berrut_combine(blocks, w1 + w2)
        rhs = berrut_combine(blocks, w1) + berrut_combine(blocks, w2)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 100.0))
    def test_gram_scale_quadratic(self, seed, scale):
        """gram(s·X) == s²·gram(X)."""
        x = rand(seed, 32, 16)
        lhs = gram(scale * x)
        rhs = (scale**2) * gram(x)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)
