#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh smoke-bench JSON against the
committed baseline and fail on a >tolerance throughput regression.

Usage:
    compare_bench.py BASELINE.json CURRENT.json [--tolerance 0.25]

Gated metrics (higher is better):
  * best GEMM GFLOP/s across the measured sizes
  * MEA-ECC seal MB/s
  * MEA-ECC open MB/s
  * per-kernel SIMD throughput (``simd`` block, when present): dispatched
    GEMM row-panel GFLOP/s, keystream XOR MB/s, axpy GB/s, Fp61 add Mops
    — so a broken dispatch that silently falls back to scalar shows up
    as a regression even if end-to-end numbers stay within tolerance
  * multi-tenant saturation (``saturation`` block, when present):
    aggregate rounds/s of 4 concurrent tenants through one fleet — a
    serving-front-end scheduling regression shows up here even when the
    per-kernel numbers hold
  * weighted fairness (``saturation.weighted`` block, when present):
    proportionality of a 2:1-weighted lane pair's bandwidth split
    (1.0 = perfect) — a broken deficit-round-robin weighting drags it
    toward the 0.75 an equal split scores

The default tolerance is 25% — smoke benches on shared CI runners are
noisy, so the gate only catches real regressions (a botched GEMM kernel,
an accidentally quadratic seal path), not jitter.

Bootstrapping: the repo ships a placeholder baseline (``"placeholder":
true``) because the baseline must be *measured on CI hardware*, not
authored by hand. While the placeholder is in place the gate prints the
current numbers and passes; replace ``BENCH_BASELINE.json`` with the
``bench`` job's ``BENCH.json`` artifact from a trusted run to arm it.
"""

import argparse
import json
import sys


def metrics(bench: dict) -> dict:
    """Extract the gated metrics from a microbench JSON."""
    out = {}
    gemm = bench.get("gemm") or []
    gflops = [row["gflops"] for row in gemm if "gflops" in row]
    if gflops:
        out["gemm_gflops"] = max(gflops)
    seal = bench.get("seal") or {}
    if "seal_mb_s" in seal:
        out["seal_mb_s"] = seal["seal_mb_s"]
    if "open_mb_s" in seal:
        out["open_mb_s"] = seal["open_mb_s"]
    simd = bench.get("simd") or {}
    for kernel, field, name in (
        ("gemm", "simd_gflops", "simd_gemm_gflops"),
        ("keystream", "simd_mb_s", "simd_keystream_mb_s"),
        ("axpy", "simd_gb_s", "simd_axpy_gb_s"),
        ("fp61", "simd_add_mops", "simd_fp61_add_mops"),
    ):
        value = (simd.get(kernel) or {}).get(field)
        if value is not None:
            out[name] = value
    saturation = bench.get("saturation") or {}
    if "rounds_per_s" in saturation:
        out["saturation_rounds_per_s"] = saturation["rounds_per_s"]
    weighted = saturation.get("weighted") or {}
    if "fairness" in weighted:
        # Proportionality of the 2:1 weighted split (1.0 = perfect).
        # Higher is better like every other gated metric: a weighted-
        # scheduler regression drags the split toward equal shares.
        out["saturation_weighted_fairness"] = weighted["fairness"]
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)

    cur = metrics(current)
    if not cur:
        print("error: current bench JSON carries no gated metrics", file=sys.stderr)
        return 1
    print("current bench metrics:")
    for k, v in sorted(cur.items()):
        print(f"  {k:<22} {v:.3f}")

    if baseline.get("placeholder"):
        print("\nbaseline is a placeholder — gate not armed yet.")
        print("To arm it, commit this run's BENCH.json as BENCH_BASELINE.json.")
        return 0

    base = metrics(baseline)
    failed = False
    print(f"\nvs baseline (tolerance {args.tolerance:.0%}):")
    for key, base_v in sorted(base.items()):
        cur_v = cur.get(key)
        if cur_v is None:
            print(f"  {key:<22} MISSING from current run")
            failed = True
            continue
        floor = base_v * (1.0 - args.tolerance)
        delta = (cur_v - base_v) / base_v
        verdict = "ok" if cur_v >= floor else "REGRESSION"
        print(f"  {key:<22} {base_v:.3f} -> {cur_v:.3f} ({delta:+.1%})  {verdict}")
        if cur_v < floor:
            failed = True

    if failed:
        print("\nbench gate FAILED: throughput regressed beyond tolerance", file=sys.stderr)
        return 1
    print("\nbench gate passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
