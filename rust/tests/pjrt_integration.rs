//! PJRT integration: the Rust runtime loads the AOT artifacts produced by
//! `make artifacts` and executes them with correct numerics.
//!
//! All tests skip cleanly when `artifacts/manifest.txt` is absent so
//! `cargo test` stays green before the Python step has run.

use spacdc::matrix::{gram, matmul, Matrix};
use spacdc::metrics::{names, MetricsRegistry};
use spacdc::rng::rng_from_seed;
use spacdc::runtime::{Executor, RuntimeService, WorkerOp};
use std::path::Path;
use std::sync::Arc;

fn artifacts_dir() -> Option<&'static Path> {
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the `xla` feature (PJRT stub engine)");
        return None;
    }
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn service_loads_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::start(dir).expect("runtime service");
    let keys = svc.handle().keys();
    assert!(keys.iter().any(|k| k == "gram_128x256"), "keys: {keys:?}");
    assert!(keys.iter().any(|k| k == "mlp_fwd_64"), "keys: {keys:?}");
    assert!(keys.len() >= 6);
}

#[test]
fn gram_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::start(dir).expect("runtime service");
    let mut rng = rng_from_seed(1);
    let x = Matrix::random_gaussian(128, 256, 0.0, 1.0, &mut rng);
    let out = svc
        .handle()
        .execute("gram_128x256", vec![x.clone()])
        .expect("execute");
    let expect = gram(&x);
    assert_eq!(out.shape(), (128, 128));
    assert!(
        out.rel_error(&expect) < 1e-4,
        "PJRT vs native gram: {}",
        out.rel_error(&expect)
    );
}

#[test]
fn rightmul_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::start(dir).expect("runtime service");
    let mut rng = rng_from_seed(2);
    let x = Matrix::random_gaussian(64, 128, 0.0, 1.0, &mut rng);
    let v = Matrix::random_gaussian(128, 64, 0.0, 1.0, &mut rng);
    let out = svc
        .handle()
        .execute("rightmul_64x128x64", vec![x.clone(), v.clone()])
        .expect("execute");
    assert!(out.rel_error(&matmul(&x, &v)) < 1e-4);
}

#[test]
fn berrut_encode_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::start(dir).expect("runtime service");
    let mut rng = rng_from_seed(3);
    // 7 stacked blocks of 64×128 + weights (7×1).
    let stacked = Matrix::random_gaussian(7 * 64, 128, 0.0, 1.0, &mut rng);
    let weights = Matrix::random_uniform(7, 1, -1.0, 1.0, &mut rng);
    let out = svc
        .handle()
        .execute("berrut_7x64x128", vec![stacked.clone(), weights.clone()])
        .expect("execute");
    // Native: Σ wᵢ · blockᵢ.
    let mut expect = Matrix::zeros(64, 128);
    for i in 0..7 {
        expect.axpy(weights.get(i, 0), &stacked.rows_slice(i * 64, 64));
    }
    assert!(out.rel_error(&expect) < 1e-4, "err {}", out.rel_error(&expect));
}

#[test]
fn mlp_forward_artifact_produces_probabilities() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::start(dir).expect("runtime service");
    let mut rng = rng_from_seed(4);
    let inputs = vec![
        Matrix::random_gaussian(256, 784, 0.0, 0.05, &mut rng), // w0
        Matrix::zeros(256, 1),                                  // b0
        Matrix::random_gaussian(128, 256, 0.0, 0.05, &mut rng), // w1
        Matrix::zeros(128, 1),                                  // b1
        Matrix::random_gaussian(10, 128, 0.0, 0.05, &mut rng),  // w2
        Matrix::zeros(10, 1),                                   // b2
        Matrix::random_uniform(784, 64, 0.0, 1.0, &mut rng),    // x
    ];
    let out = svc.handle().execute("mlp_fwd_64", inputs).expect("execute");
    assert_eq!(out.shape(), (10, 64));
    for c in 0..64 {
        let s: f32 = (0..10).map(|r| out.get(r, c)).sum();
        assert!((s - 1.0).abs() < 1e-4, "column {c} sums to {s}");
    }
}

#[test]
fn executor_prefers_pjrt_for_matching_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::start(dir).expect("runtime service");
    let metrics = Arc::new(MetricsRegistry::new());
    let exec = Executor::with_runtime(svc.handle(), Arc::clone(&metrics));
    let mut rng = rng_from_seed(5);

    // Matching shape → PJRT.
    let x = Matrix::random_gaussian(128, 256, 0.0, 1.0, &mut rng);
    let out = exec.run(&WorkerOp::Gram, &[x.clone()]);
    assert!(out.rel_error(&gram(&x)) < 1e-4);
    assert_eq!(metrics.get(names::PJRT_EXECUTIONS), 1);
    assert_eq!(metrics.get(names::NATIVE_EXECUTIONS), 0);

    // Non-matching shape → native fallback.
    let y = Matrix::random_gaussian(33, 17, 0.0, 1.0, &mut rng);
    let out = exec.run(&WorkerOp::Gram, &[y.clone()]);
    assert!(out.rel_error(&gram(&y)) < 1e-5);
    assert_eq!(metrics.get(names::NATIVE_EXECUTIONS), 1);
}

#[test]
fn executor_shared_across_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::start(dir).expect("runtime service");
    let metrics = Arc::new(MetricsRegistry::new());
    let exec = Executor::with_runtime(svc.handle(), Arc::clone(&metrics));
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let exec = exec.clone();
            std::thread::spawn(move || {
                let mut rng = rng_from_seed(100 + t);
                let x = Matrix::random_gaussian(128, 256, 0.0, 1.0, &mut rng);
                let out = exec.run(&WorkerOp::Gram, &[x.clone()]);
                assert!(out.rel_error(&gram(&x)) < 1e-4);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(metrics.get(names::PJRT_EXECUTIONS), 4);
}
