//! Adversarial integration tests: the paper's security (§IV) and privacy
//! (Theorems 2–3) claims exercised against live adversaries.

use spacdc::coding::{BlockCode, CodeParams, CodedTask, Spacdc};
use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::coordinator::MasterBuilder;
use spacdc::ecc::{secp256k1, sim_curve, KeyPair, MaskMode, MeaEcc};
use spacdc::matrix::{split_rows, Matrix};
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;
use spacdc::sim::{correlation_of, CollusionPool, EavesdropLog};
use std::sync::Arc;

fn base_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 12;
    cfg.partitions = 3;
    cfg.colluders = 2;
    cfg.stragglers = 2;
    cfg.delay.base_service_s = 0.0;
    cfg.seed = 0x5EC;
    cfg
}

#[test]
fn eavesdropper_learns_nothing_under_mea_ecc() {
    let tap = Arc::new(EavesdropLog::new());
    let mut cfg = base_cfg();
    cfg.scheme = SchemeKind::Bacc; // deterministic shares, reproducible
    cfg.security = TransportSecurity::MeaEcc;
    let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
    let mut rng = rng_from_seed(1);
    let x = Matrix::random_gaussian(24, 16, 0.0, 1.0, &mut rng);
    master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();

    let scheme = spacdc::coding::Bacc::new(CodeParams::new(12, 3, 0));
    let enc = scheme.encode_blocks(&x, 1, &mut rng_from_seed(0)).unwrap();
    let corr = tap.downlink_correlation(&enc.shares);
    assert!(corr < 0.15, "sealed wire correlates with shares: {corr}");
    assert!(tap.count() >= 12 + 10, "tap should see both directions");
}

#[test]
fn eavesdropper_reads_everything_in_plain_mode() {
    let tap = Arc::new(EavesdropLog::new());
    let mut cfg = base_cfg();
    cfg.scheme = SchemeKind::Bacc;
    cfg.security = TransportSecurity::Plain;
    let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
    let mut rng = rng_from_seed(2);
    let x = Matrix::random_gaussian(24, 16, 0.0, 1.0, &mut rng);
    master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
    let scheme = spacdc::coding::Bacc::new(CodeParams::new(12, 3, 0));
    let enc = scheme.encode_blocks(&x, 1, &mut rng_from_seed(0)).unwrap();
    let corr = tap.downlink_correlation(&enc.shares);
    assert!(corr > 0.95, "plain wire must match the shares: {corr}");
}

#[test]
fn collusion_pool_collects_only_member_shares_through_coordinator() {
    let coalition = Arc::new(CollusionPool::new(vec![0, 5]));
    let mut cfg = base_cfg();
    cfg.scheme = SchemeKind::Spacdc;
    let mut master = MasterBuilder::new(cfg).collusion(Arc::clone(&coalition)).build().unwrap();
    let mut rng = rng_from_seed(3);
    let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
    master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
    // The members see exactly their decrypted shares, nothing else.
    let gathered = coalition.gathered();
    let members: std::collections::BTreeSet<usize> =
        gathered.iter().map(|(w, _)| *w).collect();
    assert!(members.iter().all(|w| [0usize, 5].contains(w)), "members {members:?}");
    assert!(!gathered.is_empty());
}

#[test]
fn colluder_leakage_drops_with_mask_amplitude() {
    // The ℝ-instantiation privacy law (DESIGN.md §3): the best
    // single-share inversion degrades as mask_scale grows.
    let attack = |scale: f32| -> f64 {
        let k = 3;
        let t = 2;
        let scheme = Spacdc::with_mask_scale(CodeParams::new(12, k, t), scale);
        let mut rng = rng_from_seed(4);
        let mut acc = 0.0;
        let trials = 10;
        for _ in 0..trials {
            let x = Matrix::random_gaussian(12, 6, 0.0, 1.0, &mut rng);
            let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
            let (blocks, _) = split_rows(&x, k);
            let (data_pos, _) = Spacdc::node_layout(k, t);
            let betas = scheme.betas();
            let signs: Vec<u32> = (0..(k + t) as u32).collect();
            let mut best = f64::INFINITY;
            for j in 0..t {
                let w = spacdc::coding::interp::berrut_weights(
                    &betas,
                    &signs,
                    enc.ctx.alphas[j],
                );
                for (b, block) in blocks.iter().enumerate() {
                    let wb = w[data_pos[b]];
                    if wb.abs() > 1e-6 {
                        best = best
                            .min(enc.shares[j].scale(1.0 / wb as f32).rel_error(block));
                    }
                }
            }
            acc += best;
        }
        acc / trials as f64
    };
    let weak = attack(0.25);
    let strong = attack(4.0);
    assert!(
        strong > 3.0 * weak,
        "mask amplitude must control leakage: {weak} vs {strong}"
    );
}

#[test]
fn mea_ecc_cross_curve_consistency() {
    // The same MEA-ECC protocol over both curve instantiations.
    let mut rng = rng_from_seed(5);
    let m = Matrix::random_gaussian(8, 8, 0.0, 1.0, &mut rng);

    let sim = sim_curve();
    let kp1 = KeyPair::generate(&sim, &mut rng);
    let mea1 = MeaEcc::new(sim, MaskMode::Keystream);
    let sealed1 = mea1.encrypt(&m, &kp1.public(), &mut rng);
    assert_eq!(mea1.decrypt(&sealed1, &kp1), m);

    let secp = secp256k1();
    let kp2 = KeyPair::generate(&secp, &mut rng);
    let mea2 = MeaEcc::new(secp, MaskMode::Keystream);
    let sealed2 = mea2.encrypt(&m, &kp2.public(), &mut rng);
    assert_eq!(mea2.decrypt(&sealed2, &kp2), m);
}

#[test]
fn sealed_result_path_hides_worker_outputs_too() {
    // Uplink (worker→master) payloads must also decorrelate from the
    // true results under MEA-ECC — Theorem 3's transport analogue.
    let tap = Arc::new(EavesdropLog::new());
    let mut cfg = base_cfg();
    cfg.scheme = SchemeKind::Bacc;
    cfg.security = TransportSecurity::MeaEcc;
    let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
    let mut rng = rng_from_seed(6);
    let x = Matrix::random_gaussian(24, 16, 0.0, 1.0, &mut rng);
    master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
    // For the identity op the true uplink payloads are the shares.
    let scheme = spacdc::coding::Bacc::new(CodeParams::new(12, 3, 0));
    let enc = scheme.encode_blocks(&x, 1, &mut rng_from_seed(0)).unwrap();
    let mut worst: f64 = 0.0;
    for msg in tap.messages().iter().filter(|m| !m.downlink) {
        let r = &enc.shares[msg.worker];
        if r.shape() == msg.payload.shape() {
            worst = worst.max(correlation_of(r, &msg.payload).abs());
        }
    }
    assert!(worst < 0.25, "uplink leaks worker results: {worst}");
}
