//! Multi-tenant serving-front-end integration tests (DESIGN.md §12):
//! the tenant-isolation contract (a tenant's decoded bits are identical
//! solo or interleaved, on any fabric and pool width), the digest pin
//! for the shipped `tenants` scenario across the execution matrix, and
//! deficit-round-robin fairness under a greedy tenant.

use spacdc::coding::CodedTask;
use spacdc::config::{SchemeKind, SystemConfig, TransportKind};
use spacdc::coordinator::{Master, ServiceConfig, SessionOptions};
use spacdc::matrix::Matrix;
use spacdc::rng::{derive_seed, rng_from_seed};
use spacdc::runtime::WorkerOp;
use spacdc::sim::{run_scenario, run_scenario_with, Scenario};

/// The CI matrix in miniature: both fabrics, serial and wide pools.
const MATRIX: [(TransportKind, usize); 4] = [
    (TransportKind::InProc, 1),
    (TransportKind::InProc, 8),
    (TransportKind::Tcp, 1),
    (TransportKind::Tcp, 8),
];

/// Straggler-free cluster: decode waits for every dispatched worker, so
/// each tenant's decode set — and therefore its bits — is pinned by the
/// schedule alone (the precondition scenario validation enforces for
/// multi-tenant soaks).
fn cluster(transport: TransportKind, threads: usize) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 8;
    cfg.partitions = 4;
    cfg.colluders = 2;
    cfg.stragglers = 0;
    cfg.scheme = SchemeKind::Spacdc;
    cfg.transport = transport;
    cfg.threads = threads;
    cfg.delay.base_service_s = 0.0;
    cfg
}

/// A tenant's task list, drawn from its own seed stream (the same
/// per-round derivation the scenario runner uses).
fn tenant_tasks(seed: u64, rounds: usize) -> Vec<CodedTask> {
    (1..=rounds as u64)
        .map(|r| {
            let mut rng = rng_from_seed(derive_seed(seed, 0xDA7A_0000 + r));
            let x = Matrix::random_gaussian(24, 12, 0.0, 1.0, &mut rng);
            CodedTask::block_map(WorkerOp::Gram, x)
        })
        .collect()
}

/// Run one tenant alone on a fresh fleet and return its decoded blocks
/// in task order.
fn solo_blocks(
    transport: TransportKind,
    threads: usize,
    seed: u64,
    rounds: usize,
) -> Vec<Vec<Matrix>> {
    let mut master = Master::from_config(cluster(transport, threads)).unwrap();
    let mut svc = master.service(ServiceConfig { global_inflight: 16, speculate: false });
    let sid = svc.open_iter(
        "solo",
        SessionOptions { inflight: 16, seed: Some(seed), ..Default::default() },
        tenant_tasks(seed, rounds).into_iter(),
    );
    let mut out = svc.run();
    out.rounds[sid]
        .drain(..)
        .map(|r| r.outcome.expect("solo round must decode").blocks)
        .collect()
}

#[test]
fn tenant_bits_are_identical_solo_or_interleaved() {
    // Three tenants share one fleet at inflight 16 each; every tenant's
    // decoded bits must equal its solo run exactly — per seed stream,
    // per round, per f32 bit — on every fabric and pool width.
    const ROUNDS: usize = 5;
    let seeds = [0xA11C_E001u64, 0xB0B0_0002, 0xCAFE_0003];
    for (transport, threads) in MATRIX {
        let solo: Vec<Vec<Vec<Matrix>>> = seeds
            .iter()
            .map(|&s| solo_blocks(transport, threads, s, ROUNDS))
            .collect();

        let mut master = Master::from_config(cluster(transport, threads)).unwrap();
        let mut svc = master.service(ServiceConfig { global_inflight: 16, speculate: false });
        let sids: Vec<usize> = seeds
            .iter()
            .enumerate()
            .map(|(t, &s)| {
                svc.open_iter(
                    &format!("tenant-{t}"),
                    SessionOptions { inflight: 16, seed: Some(s), ..Default::default() },
                    tenant_tasks(s, ROUNDS).into_iter(),
                )
            })
            .collect();
        let mut out = svc.run();
        assert_eq!(out.decoded(), seeds.len() * ROUNDS);
        for (t, &sid) in sids.iter().enumerate() {
            let interleaved: Vec<Vec<Matrix>> = out.rounds[sid]
                .drain(..)
                .map(|r| r.outcome.expect("interleaved round must decode").blocks)
                .collect();
            assert_eq!(
                interleaved, solo[t],
                "tenant {t} bits diverged from its solo run at \
                 transport={} threads={threads}",
                transport.name()
            );
        }
    }
}

#[test]
fn tenants_scenario_digest_pins_across_transports_and_widths() {
    let mut sc = Scenario::builtin("tenants").unwrap();
    sc.rounds = 4; // keep the matrix cheap; same scenario for every combo
    let mut reports = Vec::new();
    for (transport, threads) in MATRIX {
        let report = run_scenario(&sc, transport, threads).unwrap();
        assert_eq!(report.tenants, 4);
        assert_eq!(report.tenant_stats.len(), 4);
        assert_eq!(report.rounds, 4 * sc.rounds, "rounds aggregates all tenants");
        assert_eq!(report.recovery_hit_rate, 1.0, "fault-free soak decodes every round");
        assert!(
            report.occupancy_max <= sc.inflight,
            "the global cap binds: {} > {}",
            report.occupancy_max,
            sc.inflight
        );
        for t in &report.tenant_stats {
            assert_eq!(t.decoded, sc.rounds, "tenant {} must decode every round", t.tenant);
            assert_eq!(t.failed, 0);
            assert!(t.occupancy_max <= sc.tenant_inflight);
        }
        reports.push((transport.name(), threads, report));
    }
    let first = &reports[0].2;
    for (transport, threads, report) in &reports {
        assert_eq!(
            report.digest, first.digest,
            "digest diverged at transport={transport} threads={threads}"
        );
        for (t, stat) in report.tenant_stats.iter().enumerate() {
            assert_eq!(
                stat.digest, first.tenant_stats[t].digest,
                "tenant {t} digest diverged at transport={transport} threads={threads}"
            );
        }
    }
    // Distinct seed streams: no two tenants may produce the same bits.
    for t in 1..first.tenant_stats.len() {
        assert_ne!(first.tenant_stats[0].digest, first.tenant_stats[t].digest);
    }
}

#[test]
fn greedy_tenant_cannot_starve_a_polite_one() {
    // A greedy 16-wide lane with 3× the work shares the fleet with a
    // polite 1-wide lane. Deficit round-robin must keep serving the
    // polite lane throughout: its rounds interleave with the greedy
    // stream instead of queueing behind it, and its tail latency stays
    // within a small factor of the greedy lane's.
    let mut master = Master::from_config(cluster(TransportKind::InProc, 0)).unwrap();
    let mut svc = master.service(ServiceConfig { global_inflight: 16, speculate: false });
    let greedy = svc.open_iter(
        "greedy",
        SessionOptions { inflight: 16, seed: Some(0x92EE_D000), ..Default::default() },
        tenant_tasks(0x92EE_D000, 24).into_iter(),
    );
    let polite = svc.open_iter(
        "polite",
        SessionOptions { inflight: 1, seed: Some(0x9011_7E00), ..Default::default() },
        tenant_tasks(0x9011_7E00, 8).into_iter(),
    );
    let out = svc.run();
    assert_eq!(out.tenants[greedy].decoded, 24);
    assert_eq!(out.tenants[polite].decoded, 8, "the polite lane must finish all its work");
    // Starvation would push the polite lane's submissions past the
    // greedy lane's 24: round ids are global and monotone in dispatch
    // order, so fairness shows up as interleaved ids.
    let polite_last = out.rounds[polite].iter().map(|r| r.round).max().unwrap();
    assert!(
        polite_last <= 24,
        "polite lane starved: its last dispatch was global round {polite_last} of 32"
    );
    let (g99, p99) = (out.tenants[greedy].p99_ms, out.tenants[polite].p99_ms);
    assert!(
        p99 <= g99 * 4.0 + 50.0,
        "polite p99 {p99:.2} ms vs greedy p99 {g99:.2} ms — tail blew out"
    );
}

#[test]
fn weighted_lanes_share_bandwidth_in_weight_proportion() {
    // A 2:1-weighted pair of saturated lanes must split dispatch
    // bandwidth 2:1 while both are busy. Round ids are global and
    // monotone in dispatch order, so the heavy lane's last dispatch
    // marks how much of the merged stream it consumed: its 40 rounds
    // should sit inside a ~60-round contention window (share 2/3,
    // within 10%).
    const TASKS: usize = 40;
    let mut master = Master::from_config(cluster(TransportKind::InProc, 0)).unwrap();
    let mut svc = master.service(ServiceConfig { global_inflight: 4, speculate: false });
    let heavy = svc.open_iter(
        "heavy",
        SessionOptions { inflight: 4, weight: 2, seed: Some(0x3EA0_0001), ..Default::default() },
        tenant_tasks(0x3EA0_0001, TASKS).into_iter(),
    );
    let light = svc.open_iter(
        "light",
        SessionOptions { inflight: 4, weight: 1, seed: Some(0x3EA0_0002), ..Default::default() },
        tenant_tasks(0x3EA0_0002, TASKS).into_iter(),
    );
    let out = svc.run();
    assert_eq!(out.tenants[heavy].decoded, TASKS as u64);
    assert_eq!(out.tenants[light].decoded, TASKS as u64);
    let heavy_last = out.rounds[heavy].iter().map(|r| r.round).max().unwrap();
    let share = TASKS as f64 / heavy_last as f64;
    let want = 2.0 / 3.0;
    assert!(
        (share - want).abs() <= want * 0.10,
        "heavy lane bandwidth share {share:.3} is off its 2/3 weight share \
         (exhausted at global round {heavy_last} of {})",
        2 * TASKS
    );
}

#[test]
fn tenants_faults_soak_pins_digests_with_adversity_composed() {
    // The composition contract the re-keyed fault plan exists for
    // (DESIGN.md §13): four tenants share a fleet while worker 2
    // crashes and respawns and worker 5 forges about half its rounds —
    // and still one scenario digest and one digest per tenant hold
    // across both fabrics, both pool widths, and both global-cap
    // widths. Faults key on lane streams and wall-rounds-served, not
    // on the global round ids the interleaving reassigns, and
    // speculation re-covers every written-off share so each round
    // decodes the full fleet.
    let sc = Scenario::builtin("tenants-faults").unwrap();
    let mut reports = Vec::new();
    for (transport, threads) in MATRIX {
        for inflight in [1usize, 4] {
            let report =
                run_scenario_with(&sc, transport, threads, Some(inflight), None).unwrap();
            assert_eq!(report.crashes, 1, "the scheduled crash must fire");
            assert_eq!(report.respawns, 1, "the crashed incarnation must rejoin");
            assert_eq!(report.final_generations[2], 1, "worker 2 rejoined as generation 1");
            assert!(
                report.verify_forged_detected > 0,
                "the seeded forgery schedule must fire at least once"
            );
            assert_eq!(report.recovery_hit_rate, 1.0, "every round must still decode");
            assert_eq!(
                report.degraded_rounds, 0,
                "speculation must re-cover every written-off share"
            );
            assert_eq!(report.tenant_stats.len(), 4);
            for t in &report.tenant_stats {
                assert_eq!(t.decoded, sc.rounds, "tenant {} must decode every round", t.tenant);
                assert_eq!(t.failed, 0);
                assert_eq!(t.degraded, 0);
            }
            reports.push((transport.name(), threads, inflight, report));
        }
    }
    let first = &reports[0].3;
    for (transport, threads, inflight, report) in &reports {
        assert_eq!(
            report.digest, first.digest,
            "digest diverged at transport={transport} threads={threads} inflight={inflight}"
        );
        for (t, stat) in report.tenant_stats.iter().enumerate() {
            assert_eq!(
                stat.digest, first.tenant_stats[t].digest,
                "tenant {t} digest diverged at transport={transport} \
                 threads={threads} inflight={inflight}"
            );
        }
    }
}
