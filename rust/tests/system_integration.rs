//! Cross-module integration: coordinator + coding + ECC + sim working
//! together across schemes, scenarios, and failure patterns — all through
//! the unified `Master::run(CodedTask)` pipeline.

use spacdc::coding::{BlockCode, CodeParams, CodedTask, MatDot, Spacdc};
use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::coordinator::Master;
use spacdc::dl::{train, TrainerOptions};
use spacdc::matrix::{gram, matmul, split_rows, stack_rows, Matrix};
use spacdc::metrics::names;
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;
use std::sync::Arc;

fn cfg(scheme: SchemeKind) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 16;
    cfg.partitions = 4;
    cfg.colluders = 2;
    cfg.stragglers = 3;
    cfg.scheme = scheme;
    cfg.delay.base_service_s = 0.0;
    cfg.seed = 0x5151;
    cfg
}

#[test]
fn every_scheme_completes_a_linear_round() {
    let mut rng = rng_from_seed(1);
    let x = Matrix::random_gaussian(32, 12, 0.0, 1.0, &mut rng);
    let v = Arc::new(Matrix::random_gaussian(12, 8, 0.0, 1.0, &mut rng));
    for scheme in [
        SchemeKind::Spacdc,
        SchemeKind::Bacc,
        SchemeKind::Mds,
        SchemeKind::Polynomial,
        SchemeKind::Lcc,
        SchemeKind::SecPoly,
        SchemeKind::Uncoded,
    ] {
        let mut c = cfg(scheme);
        if scheme == SchemeKind::Uncoded {
            c.partitions = c.workers;
        }
        let mut master = Master::from_config(c).unwrap();
        let out = master
            .run(CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone()))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        assert!(!out.blocks.is_empty(), "{scheme:?}");
        // Exact schemes must be near-exact; approximate ones bounded.
        let k = out.blocks.len();
        let (blocks, _) = split_rows(&x, k);
        let worst = out
            .blocks
            .iter()
            .zip(&blocks)
            .map(|(d, b)| d.rel_error(&matmul(b, &v)))
            .fold(0.0f64, f64::max);
        let bound = match scheme {
            SchemeKind::Spacdc | SchemeKind::Bacc => 0.6,
            _ => 1e-2,
        };
        assert!(worst < bound, "{scheme:?}: worst {worst}");
    }
}

#[test]
fn matdot_end_to_end_with_sealed_transport() {
    let mut c = cfg(SchemeKind::MatDot);
    c.security = TransportSecurity::MeaEcc;
    let mut master = Master::from_config(c).unwrap();
    let mut rng = rng_from_seed(2);
    let a = Matrix::random_gaussian(10, 12, 0.0, 1.0, &mut rng);
    let b = Matrix::random_gaussian(12, 10, 0.0, 1.0, &mut rng);
    let out = master.run(CodedTask::pair_product(a.clone(), b.clone())).unwrap();
    // MatDot decode solves a degree-(2K−2) Vandermonde system over f32
    // payloads; conditioning bounds accuracy at ~1e-2 for clustered
    // return subsets (see matdot.rs docs).
    assert!(out.blocks[0].rel_error(&matmul(&a, &b)) < 0.05);
}

#[test]
fn transport_modes_agree_on_decoded_output() {
    // MEA-ECC keystream decrypt is bit-exact, so with a deterministic
    // scheme (BACC) and no stragglers (wait-for-all ⇒ fixed return set)
    // the decode results must be identical between Plain and MeaEcc.
    let mut rng = rng_from_seed(3);
    let x = Matrix::random_gaussian(32, 8, 0.0, 1.0, &mut rng);
    let run_with = |security: TransportSecurity| -> Vec<Matrix> {
        let mut c = cfg(SchemeKind::Bacc);
        c.stragglers = 0; // flexible wait count = N ⇒ deterministic set
        c.security = security;
        let mut master = Master::from_config(c).unwrap();
        master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap().blocks
    };
    let plain = run_with(TransportSecurity::Plain);
    let sealed = run_with(TransportSecurity::MeaEcc);
    for (p, s) in plain.iter().zip(&sealed) {
        assert_eq!(p.as_slice(), s.as_slice(), "transport must be transparent");
    }
}

#[test]
fn straggler_injection_delays_but_does_not_break_rounds() {
    let mut c = cfg(SchemeKind::Spacdc);
    c.delay.base_service_s = 0.005;
    c.delay.straggler_factor = 8.0;
    c.stragglers = 4;
    let mut master = Master::from_config(c).unwrap();
    let mut rng = rng_from_seed(4);
    let x = Matrix::random_gaussian(32, 8, 0.0, 1.0, &mut rng);
    let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
    // Waited for N−S = 12 fast results; round should finish well before
    // a straggler's 40ms service time.
    assert_eq!(out.results_used, 12);
    assert!(
        out.wall.as_secs_f64() < 0.035,
        "round waited for stragglers: {:?}",
        out.wall
    );
}

#[test]
fn late_results_are_accounted() {
    let mut c = cfg(SchemeKind::Spacdc);
    c.delay.base_service_s = 0.002;
    c.stragglers = 4;
    let mut master = Master::from_config(c).unwrap();
    let mut rng = rng_from_seed(5);
    let x = Matrix::random_gaussian(32, 8, 0.0, 1.0, &mut rng);
    for _ in 0..3 {
        master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
    }
    // Let stragglers land, then trigger a drain with one more round.
    std::thread::sleep(std::time::Duration::from_millis(80));
    master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
    let late = master.metrics().get(names::RESULTS_LATE);
    assert!(late > 0, "straggler results should have been counted late");
}

#[test]
fn coded_training_is_deterministic() {
    // Uncoded waits for every worker, so the return set — and therefore
    // the whole training trajectory — is deterministic bit-for-bit.
    let mut c = cfg(SchemeKind::Uncoded);
    c.partitions = c.workers;
    c.stragglers = 0;
    c.dl.layers = vec![16, 12, 4];
    c.dl.batch_size = 16;
    c.dl.epochs = 1;
    c.dl.train_examples = 64;
    c.dl.test_examples = 32;
    let a = train(&TrainerOptions::new(c.clone())).unwrap();
    let b = train(&TrainerOptions::new(c)).unwrap();
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert!((ea.loss - eb.loss).abs() < 1e-9, "coded training must be deterministic");
    }
}

#[test]
fn spacdc_decode_quality_improves_with_returns() {
    // System-level check of the accuracy-vs-returns trade-off.
    let params = CodeParams::new(24, 3, 2);
    let scheme = Spacdc::new(params);
    let mut rng = rng_from_seed(6);
    let x = Matrix::random_gaussian(30, 10, 0.0, 1.0, &mut rng);
    let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
    let (blocks, spec) = split_rows(&x, 3);
    let err_at = |count: usize| -> f64 {
        let results: Vec<(usize, Matrix)> =
            (0..count).map(|i| (i, enc.shares[i].clone())).collect();
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
        stack_rows(&decoded, &spec).rel_error(&stack_rows(&blocks, &spec))
    };
    let e_full = err_at(24);
    let e_half = err_at(12);
    assert!(e_full < e_half, "more returns must not hurt: {e_full} vs {e_half}");
}

#[test]
fn gram_round_through_coordinator_matches_direct_computation() {
    let mut c = cfg(SchemeKind::Bacc);
    c.stragglers = 0;
    let mut master = Master::from_config(c).unwrap();
    let mut rng = rng_from_seed(7);
    let x = Matrix::random_gaussian(32, 16, 0.0, 1.0, &mut rng);
    let out = master.run(CodedTask::block_map(WorkerOp::Gram, x.clone())).unwrap();
    let (blocks, _) = split_rows(&x, 4);
    for (d, b) in out.blocks.iter().zip(&blocks) {
        assert!(d.rel_error(&gram(b)) < 0.15);
    }
}

#[test]
fn matdot_pair_code_from_library_and_coordinator_agree() {
    let mut rng = rng_from_seed(8);
    let a = Matrix::random_gaussian(8, 9, 0.0, 1.0, &mut rng);
    let b = Matrix::random_gaussian(9, 8, 0.0, 1.0, &mut rng);
    // Library-level decode.
    let code = MatDot::new(16, 4).unwrap();
    let enc = code.encode_pair(&a, &b).unwrap();
    let results: Vec<(usize, Matrix)> = (0..7)
        .map(|i| (i, MatDot::worker_compute(&enc.shares[i])))
        .collect();
    let lib = code.decode_pair(&enc, &results).unwrap();
    // Coordinator-level decode (different return subset ⇒ agreement is
    // bounded by the Vandermonde conditioning, not bit-exact).
    let mut master = Master::from_config(cfg(SchemeKind::MatDot)).unwrap();
    let coord = master.run(CodedTask::pair_product(a.clone(), b.clone())).unwrap();
    assert!(lib.rel_error(&coord.blocks[0]) < 0.05);
}
