//! Scenario-engine integration tests: the determinism contract (one
//! digest per scenario across `{inproc, tcp} × {threads 1, 8}`), the
//! crash → respawn → rejoin lifecycle observed end-to-end through the
//! live system, and the file ≡ builtin pin for the shipped scenarios.

use spacdc::config::TransportKind;
use spacdc::rng::{derive_seed, rng_from_seed};
use spacdc::sim::{
    run_scenario, run_scenario_with, CrashEvent, FaultCoords, FaultKey, FaultPlan, RoundStatus,
    Scenario, ScenarioOp,
};

/// The CI matrix in miniature: both fabrics, serial and wide pools.
const MATRIX: [(TransportKind, usize); 4] = [
    (TransportKind::InProc, 1),
    (TransportKind::InProc, 8),
    (TransportKind::Tcp, 1),
    (TransportKind::Tcp, 8),
];

#[test]
fn shipped_scenario_files_match_their_builtins() {
    for name in Scenario::builtin_names() {
        let from_file = Scenario::from_file(&format!("scenarios/{name}.toml"))
            .unwrap_or_else(|e| panic!("scenarios/{name}.toml: {e}"));
        let builtin = Scenario::builtin(name).unwrap();
        assert_eq!(from_file, builtin, "scenarios/{name}.toml drifted from the builtin");
        // And the loader prefers exactly that file.
        assert_eq!(Scenario::load(name).unwrap(), builtin);
    }
    assert!(Scenario::load("no-such-scenario").is_err());
}

#[test]
fn baseline_digest_pins_across_transports_and_widths() {
    let mut sc = Scenario::builtin("baseline").unwrap();
    sc.rounds = 4; // keep the matrix cheap; same scenario for every combo
    let mut digests = Vec::new();
    for (transport, threads) in MATRIX {
        let report = run_scenario(&sc, transport, threads).unwrap();
        assert_eq!(report.recovery_hit_rate, 1.0, "baseline must decode every round");
        assert!(report.records.iter().all(|r| r.results_used == sc.workers));
        assert!(report.records.iter().all(|r| !r.degraded));
        assert!(report.bytes_tx > 0 && report.bytes_rx > 0);
        digests.push((transport.name(), threads, report.digest));
    }
    let first = digests[0].2.clone();
    for (transport, threads, digest) in &digests {
        assert_eq!(
            digest,
            &first,
            "digest diverged at transport={transport} threads={threads}: {digests:?}"
        );
    }
}

#[test]
fn crash_respawn_soak_is_bit_identical_across_the_matrix() {
    let sc = Scenario::builtin("crash-respawn").unwrap();
    let mut digests = Vec::new();
    for (transport, threads) in MATRIX {
        let report = run_scenario(&sc, transport, threads).unwrap();
        assert_eq!(report.crashes, 2, "both scheduled crashes must be observed");
        assert_eq!(report.respawns, 2, "both incarnations must rejoin");
        assert_eq!(report.final_generations[2], 1, "worker 2 rejoined as generation 1");
        assert_eq!(report.final_generations[5], 1, "worker 5 rejoined as generation 1");
        assert!(
            report.degraded_rounds >= 2,
            "crash rounds must degrade to decode-from-what-arrived, got {}",
            report.degraded_rounds
        );
        assert_eq!(report.recovery_hit_rate, 1.0, "every round must still decode");
        // The crash rounds lose exactly the crashed worker (plus any
        // scheduled corruption) yet still decode.
        let r3 = &report.records[2];
        assert_eq!(r3.status, RoundStatus::Ok);
        assert!(r3.degraded && r3.results_used < sc.workers);
        digests.push((transport.name(), threads, report.digest));
    }
    let first = digests[0].2.clone();
    for (transport, threads, digest) in &digests {
        assert_eq!(
            digest,
            &first,
            "digest diverged at transport={transport} threads={threads}: {digests:?}"
        );
    }
}

#[test]
fn colluders_and_stragglers_ride_the_flexible_threshold() {
    let mut sc = Scenario::builtin("colluders-stragglers").unwrap();
    sc.rounds = 4;
    let report = run_scenario(&sc, TransportKind::InProc, 0).unwrap();
    assert_eq!(report.recovery_hit_rate, 1.0);
    // The wait policy takes the N − S fast returns; the stragglers'
    // results land as wasted work.
    assert!(report.records.iter().all(|r| r.results_used == sc.workers - sc.stragglers));
    assert!(
        report.downlink_leak < 0.2,
        "sealed payloads must not correlate with the plaintext blocks: {}",
        report.downlink_leak
    );
    // The Berrut decode of a degree-2 f from N − S returns is an
    // approximation; precise error-vs-returns bounds live in the
    // coding-layer tests — here it must simply be a sane finite value.
    assert!(report.records.iter().all(|r| {
        let e = r.rel_err.unwrap();
        e.is_finite() && e < 5.0
    }));
}

#[test]
fn colluding_workers_gather_exactly_their_shares() {
    // S = 0 so every worker (colluders included) deposits before the
    // round completes: the coalition's haul is exact, not a race —
    // 3 colluders × 1 share × rounds.
    let mut sc = Scenario::builtin("colluders-stragglers").unwrap();
    sc.rounds = 3;
    sc.stragglers = 0;
    let report = run_scenario(&sc, TransportKind::InProc, 0).unwrap();
    assert_eq!(report.colluder_shares, sc.colluder_set.len() * sc.rounds as usize);
    assert!(report.records.iter().all(|r| r.results_used == sc.workers));
}

#[test]
fn forged_rounds_recover_verified_and_pin_one_digest() {
    // The Byzantine soak's acceptance bar: every forged round decodes
    // correctly from honest copies — never silently wrong — and the
    // digest is bit-identical across both fabrics, both pool widths,
    // and inflight ∈ {1, 4, 16}.
    let sc = Scenario::builtin("forgers").unwrap();
    let mut digests = Vec::new();
    for (transport, threads) in MATRIX {
        for inflight in [1usize, 4, 16] {
            let report =
                run_scenario_with(&sc, transport, threads, Some(inflight), None).unwrap();
            assert!(
                report.verify_forged_detected > 0,
                "the seeded schedule must fire at least one forgery"
            );
            assert_eq!(report.recovery_hit_rate, 1.0, "every forged round must still decode");
            for r in &report.records {
                assert_eq!(r.status, RoundStatus::Ok);
                assert_eq!(
                    r.results_used, sc.workers,
                    "round {}: the proxy copy must restore the full wait policy",
                    r.round
                );
                assert!(!r.degraded, "a fully recovered round is not degraded");
                let e = r.rel_err.unwrap();
                assert!(
                    e.is_finite() && e < 1.0,
                    "round {}: a forged result poisoned the decode (rel_err {e})",
                    r.round
                );
            }
            // Each booked forgery was re-dispatched and its proxy's
            // result recovered; the forged copy lost the race at the
            // commitment check, quarantining its sender at least once,
            // and a later honest result rehabilitated a suspect.
            assert_eq!(report.spec_recovered, report.verify_forged_detected);
            assert!(report.spec_redispatched >= report.verify_forged_detected);
            assert!(report.verify_checked > 0, "the collector must verify commitments");
            assert!(report.verify_quarantined >= 1, "a caught forger must be quarantined");
            assert!(
                report.verify_rehabilitated >= 1,
                "an honest round must rehabilitate a suspect"
            );
            digests.push((transport.name(), threads, inflight, report.digest));
        }
    }
    let first = digests[0].3.clone();
    for (transport, threads, inflight, digest) in &digests {
        assert_eq!(
            digest, &first,
            "digest diverged at transport={transport} threads={threads} inflight={inflight}"
        );
    }
}

#[test]
fn unrecoverable_forgeries_refuse_the_round_typed_never_silently_wrong() {
    // MDS needs exactly K = 3 of N = 4. Two forgers at rate 1.0 with
    // speculation off leave only two verifiable results per round:
    // every round must fail as `forged` — the typed refusal — and
    // never decode wrong.
    let mut sc = Scenario::builtin("forgers").unwrap();
    sc.name = "forged-hopeless-mds".into();
    sc.rounds = 3;
    sc.workers = 4;
    sc.partitions = 3;
    sc.colluders = 0;
    sc.stragglers = 0;
    sc.scheme = spacdc::config::SchemeKind::Mds;
    sc.security = spacdc::config::TransportSecurity::Plain;
    sc.op = ScenarioOp::Identity;
    sc.forger_set = vec![0, 1];
    sc.forge_rate = 0.999_999; // validate() wants [0, 1): forge every round
    sc.inflight = 1;
    sc.speculate = false;
    sc.validate().unwrap();
    let t0 = std::time::Instant::now();
    let report = run_scenario(&sc, TransportKind::InProc, 1).unwrap();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(15),
        "forged-hopeless rounds must not ride the 30s deadline"
    );
    // The invariant under any mix of forgery coins: a round either
    // refuses with the typed `forged` status and publishes nothing, or
    // decodes exactly from verified results — never silently wrong.
    for r in &report.records {
        match r.status {
            RoundStatus::Forged => {
                assert!(r.rel_err.is_none(), "a refused round publishes no decode");
            }
            // A round where only one forger's coin fired degrades to
            // the three honest results and still decodes exactly.
            RoundStatus::Ok => {
                let e = r.rel_err.unwrap();
                assert!(e < 1e-2, "round {}: wrong decode slipped through ({e})", r.round);
            }
            other => panic!("round {}: unexpected status {other:?}", r.round),
        }
    }
    assert!(
        report.records.iter().any(|r| r.status == RoundStatus::Forged),
        "at a ~1.0 forge rate some round must be refused as forged"
    );
    assert!(report.verify_forged_detected >= sc.rounds, "both forgers fire most rounds");
}

#[test]
fn hopeless_rounds_fail_fast_and_the_soak_continues() {
    // MDS needs exactly K = 3 of N = 4. Two unrecovered crashes
    // mid-round 2 doom that round (typed, immediate) and every round
    // after it cannot even dispatch — the soak records it all instead
    // of aborting.
    let mut sc = Scenario::builtin("baseline").unwrap();
    sc.name = "hopeless-mds".into();
    sc.rounds = 4;
    sc.workers = 4;
    sc.partitions = 3;
    sc.colluders = 0;
    sc.scheme = spacdc::config::SchemeKind::Mds;
    sc.security = spacdc::config::TransportSecurity::Plain;
    sc.op = ScenarioOp::Identity;
    sc.crashes = vec![
        CrashEvent { worker: 1, round: 2, respawn_after: None },
        CrashEvent { worker: 2, round: 2, respawn_after: None },
    ];
    sc.validate().unwrap();
    let t0 = std::time::Instant::now();
    let report = run_scenario(&sc, TransportKind::InProc, 1).unwrap();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(15),
        "hopeless rounds must not ride the 30s deadline"
    );
    let statuses: Vec<RoundStatus> = report.records.iter().map(|r| r.status).collect();
    assert_eq!(
        statuses,
        vec![
            RoundStatus::Ok,
            RoundStatus::Hopeless,
            RoundStatus::SubmitFailed,
            RoundStatus::SubmitFailed,
        ]
    );
    assert_eq!(report.recovery_hit_rate, 0.25);
    assert_eq!(report.crashes, 2);
    assert_eq!(report.respawns, 0);
}

#[test]
fn reports_serialize_with_digest_and_per_round_records() {
    let mut sc = Scenario::builtin("baseline").unwrap();
    sc.rounds = 2;
    let report = run_scenario(&sc, TransportKind::InProc, 1).unwrap();
    let json = report.to_json();
    for needle in [
        "\"schema\": \"scenario-report-v4\"",
        "\"scenario\": \"baseline\"",
        "\"digest\": \"",
        "\"per_round\": [",
        "\"lifecycle\": {",
        "\"stream\": {\"inflight\": 1, \"speculate\": false",
        "\"occupancy_mean\": ",
        "\"tenants\": {\"count\": 1, \"inflight\": 1, \"per_tenant\": []}",
        "\"speculation\": {\"redispatched\": 0, \"recovered\": 0, \"wasted\": 0}",
        "\"verify\": {\"checked\": ",
        "\"forged_detected\": 0, \"quarantined\": 0, \"rehabilitated\": 0}",
        "\"recovery_hit_rate\": 1.0000",
    ] {
        assert!(json.contains(needle), "report JSON missing {needle}:\n{json}");
    }
    assert_eq!(report.digest.len(), 16, "fnv64 digest is 16 hex chars");
    assert!(report.digest.chars().all(|c| c.is_ascii_hexdigit()));
}

#[test]
fn fault_key_global_reproduces_the_legacy_draw_streams() {
    // Before the re-keying, the engine drew corruption from
    // `derive_seed(seed, 0xC0_44_0000 ^ (round << 20) ^ worker)`,
    // forgery from the matching 0xF0_46_0000 stream, and matched
    // crash/respawn events on the global round id. `fault_key =
    // "global"` must reproduce all three bit-for-bit — that is what
    // keeps every pre-existing single-tenant scenario digest unchanged
    // when a config opts back into the legacy keying.
    let crashes = vec![
        CrashEvent { worker: 2, round: 3, respawn_after: Some(2) },
        CrashEvent { worker: 5, round: 4, respawn_after: Some(3) },
    ];
    let seed = 0x5CE1u64;
    let plan = FaultPlan::new(crashes.clone(), 0.06, seed)
        .with_forgers(vec![2, 5], 0.55)
        .with_key(FaultKey::Global);
    for worker in 0..10usize {
        for round in 1..=40u64 {
            let coords = FaultCoords::global(round);
            let legacy_crash = crashes.iter().any(|c| c.worker == worker && c.round == round);
            assert_eq!(plan.crashes_at(worker, &coords), legacy_crash);
            let legacy_corrupt = !legacy_crash && {
                let mut rng = rng_from_seed(derive_seed(
                    seed,
                    0xC0_44_0000 ^ (round << 20) ^ worker as u64,
                ));
                rng.next_f64() < 0.06
            };
            assert_eq!(
                plan.corrupts(worker, &coords),
                legacy_corrupt,
                "corruption stream moved at (worker {worker}, round {round})"
            );
            let legacy_forge = [2usize, 5].contains(&worker)
                && !legacy_crash
                && !legacy_corrupt
                && {
                    let mut rng = rng_from_seed(derive_seed(
                        seed,
                        0xF0_46_0000 ^ (round << 20) ^ worker as u64,
                    ));
                    rng.next_f64() < 0.55
                };
            assert_eq!(
                plan.forges_at(worker, &coords),
                legacy_forge,
                "forgery stream moved at (worker {worker}, round {round})"
            );
        }
    }
    // Legacy respawn arithmetic: due exactly at crash round + delay.
    assert_eq!(plan.respawns_due(5), vec![2]);
    assert_eq!(plan.respawns_due(7), vec![5]);
    assert!(plan.respawns_due(4).is_empty());
    // Under the global key the other coordinates are inert: only the
    // global round feeds the draw, however the order was laned.
    let weird = FaultCoords { round: 7, served: 3, lane: 9, lane_round: 1 };
    assert_eq!(plan.corrupts(0, &weird), plan.corrupts(0, &FaultCoords::global(7)));
    assert_eq!(plan.forges_at(5, &weird), plan.forges_at(5, &FaultCoords::global(7)));
}

#[test]
fn served_key_coincides_with_global_while_no_worker_dies() {
    // The scenario default flipped from global-round keying to
    // wall-rounds-served. With no crash in the plan every worker's
    // served count equals the global round, so the shipped crash-free
    // scenarios must digest identically under either key — the
    // back-compat half of the default flip.
    for name in ["baseline", "forgers"] {
        let mut sc = Scenario::builtin(name).unwrap();
        sc.rounds = sc.rounds.min(4);
        let mut digests = Vec::new();
        for key in [FaultKey::Global, FaultKey::Served] {
            sc.fault_key = key;
            digests.push(run_scenario(&sc, TransportKind::InProc, 2).unwrap().digest);
        }
        assert_eq!(digests[0], digests[1], "{name}: served keying moved off the legacy stream");
    }
}

#[test]
fn crash_respawn_under_the_global_key_still_pins_one_digest() {
    // Opting a crash scenario back into `fault_key = "global"` must
    // still produce one digest across fabrics — the legacy lifecycle
    // path (respawns computed from the plan, not the due ledger) stays
    // live and deterministic.
    let mut sc = Scenario::builtin("crash-respawn").unwrap();
    sc.fault_key = FaultKey::Global;
    let a = run_scenario(&sc, TransportKind::InProc, 1).unwrap();
    let b = run_scenario(&sc, TransportKind::Tcp, 8).unwrap();
    assert_eq!(a.crashes, 2);
    assert_eq!(a.respawns, 2);
    assert_eq!(a.digest, b.digest, "global-key digest diverged between fabrics");
}
