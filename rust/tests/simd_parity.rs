//! SIMD-vs-scalar parity — the PR-7 contract (DESIGN.md §10).
//!
//! The SIMD layer (`spacdc::simd`) promises that every dispatched
//! kernel — packed GEMM row×panel, MEA-ECC keystreams, the
//! `weighted_sum` axpy, batched Fp61 lanes — is *bit-identical* to its
//! scalar oracle at every level the running CPU can execute. This suite
//! pins that from outside the crate:
//!
//! * kernel sweeps run every `available_levels()` entry through the
//!   `*_at` entry points on ragged shapes and unaligned tails;
//! * the public hot paths (`matmul`/`gram`, seal/open, decode) are
//!   recomputed against scalar references at whatever level the process
//!   dispatched, so a vector kernel cannot drift without failing here;
//! * the full encode → seal → decode digest of all 8 schemes is pinned
//!   across thread counts at the ambient level.
//!
//! The `SPACDC_SIMD=off` vs auto axis cannot be toggled in-process (the
//! level is a `OnceLock`); the CI scenario matrix runs whole processes
//! under both values and asserts one digest, completing the contract.

use spacdc::coding::interp::weighted_sum_with;
use spacdc::coding::{make_scheme, CodeParams, CodedTask, Threshold};
use spacdc::config::SchemeKind;
use spacdc::coordinator::SealedPayload;
use spacdc::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use spacdc::field::fp61::{batch, P61};
use spacdc::matrix::{matmul_with, matvec, Matrix};
use spacdc::metrics::MetricsRegistry;
use spacdc::parallel::{self, ThreadPool};
use spacdc::rng::{derive_seed, rng_from_seed, Rng};
use spacdc::runtime::{Executor, WorkerOp};
use spacdc::simd::{self, axpy, fp61x, gemm, keystream, Level};
use std::sync::Arc;

fn fill_f32(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn dispatched_level_is_executable() {
    let l = simd::level();
    assert!(
        simd::available_levels().contains(&l),
        "dispatched level {} must be executable here",
        l.name()
    );
}

#[test]
fn gemm_row_panel_parity_on_ragged_shapes() {
    let mut rng = rng_from_seed(0x5101);
    for &k in &[1usize, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127] {
        for &cols in &[1usize, 2, 3, 4, 5, 6, 7, 8, 9, 13] {
            let arow = fill_f32(&mut rng, k);
            let panel = fill_f32(&mut rng, k * cols);
            let mut want = vec![0f32; cols];
            gemm::row_panel_scalar(&arow, &panel, k, &mut want);
            for level in simd::available_levels() {
                let mut got = vec![0f32; cols];
                gemm::row_panel_at(level, &arow, &panel, k, &mut got);
                assert_eq!(bits(&got), bits(&want), "level={} k={k} cols={cols}", level.name());
            }
        }
    }
}

#[test]
fn public_matmul_bit_matches_scalar_reference() {
    // Whatever level the process dispatched, the public product must
    // equal a from-scratch scalar-oracle recomputation, bit for bit.
    let mut rng = rng_from_seed(0x5102);
    let pool = ThreadPool::new(8);
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (33, 17, 65), (70, 129, 41)] {
        let a = Matrix::random_gaussian(m, k, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(k, n, 0.0, 1.0, &mut rng);
        let fast = matmul_with(&pool, &a, &b);
        let bt = b.transpose();
        let btd = bt.as_slice();
        let mut want = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                want[i * n + j] = gemm::dot_scalar(a.row(i), &btd[j * k..j * k + k]);
            }
        }
        assert_eq!(bits(fast.as_slice()), bits(&want), "({m},{k},{n})");
    }
}

#[test]
fn matvec_bit_matches_scalar_dots() {
    let mut rng = rng_from_seed(0x5103);
    let a = Matrix::random_gaussian(39, 23, 0.0, 1.0, &mut rng);
    let v = fill_f32(&mut rng, 23);
    let got = matvec(&a, &v);
    let want: Vec<f32> = (0..39).map(|i| gemm::dot_scalar(a.row(i), &v)).collect();
    assert_eq!(bits(&got), bits(&want));
}

#[test]
fn keystream_parity_on_unaligned_tails() {
    for &len in &[0usize, 1, 5, 8, 13, 31, 32, 33, 63, 64, 65, 97, 1000, 4097] {
        let plain: Vec<u8> = (0..len).map(|i| (i * 31 + 7) as u8).collect();
        let mut want = plain.clone();
        keystream::xor_in_place_at(Level::Scalar, &mut want, 0xC0FFEE);
        for level in simd::available_levels() {
            let mut got = plain.clone();
            keystream::xor_in_place_at(level, &mut got, 0xC0FFEE);
            assert_eq!(got, want, "xor level={} len={len}", level.name());
        }
        let fplain: Vec<f32> = (0..len).map(|i| i as f32 * 0.5 - 9.0).collect();
        let mut fwant = fplain.clone();
        keystream::mask_f32_in_place_at(Level::Scalar, &mut fwant, 0xC0FFEE);
        for level in simd::available_levels() {
            let mut fgot = fplain.clone();
            keystream::mask_f32_in_place_at(level, &mut fgot, 0xC0FFEE);
            assert_eq!(bits(&fgot), bits(&fwant), "mask level={} len={len}", level.name());
        }
    }
}

#[test]
fn weighted_sum_bit_matches_scalar_axpy_reference() {
    // Chunking only partitions elements; each element accumulates the
    // samples in input order, so whole-matrix scalar axpy passes are the
    // exact reference for any pool width and SIMD level.
    let mut rng = rng_from_seed(0x5104);
    let values: Vec<Matrix> =
        (0..7).map(|_| Matrix::random_gaussian(41, 29, 0.0, 1.0, &mut rng)).collect();
    let weights: Vec<f64> = (0..7).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let mut want = vec![0f32; 41 * 29];
    for (v, &w) in values.iter().zip(&weights) {
        axpy::axpy_at(Level::Scalar, &mut want, v.as_slice(), w as f32);
    }
    for threads in [1usize, 8] {
        let got = weighted_sum_with(&ThreadPool::new(threads), &values, &weights);
        assert_eq!(bits(got.as_slice()), bits(&want), "threads={threads}");
    }
}

#[test]
fn axpy_parity_on_ragged_lengths() {
    let mut rng = rng_from_seed(0x5105);
    for &len in &[0usize, 1, 7, 8, 15, 16, 17, 100, 4099] {
        let src = fill_f32(&mut rng, len);
        let base = fill_f32(&mut rng, len);
        let w = rng.uniform(-2.0, 2.0) as f32;
        let mut want = base.clone();
        axpy::axpy_at(Level::Scalar, &mut want, &src, w);
        for level in simd::available_levels() {
            let mut got = base.clone();
            axpy::axpy_at(level, &mut got, &src, w);
            assert_eq!(bits(&got), bits(&want), "level={} len={len}", level.name());
        }
    }
}

#[test]
fn fp61_batch_parity_across_levels() {
    let mut rng = rng_from_seed(0x5106);
    for &len in &[0usize, 1, 2, 3, 4, 5, 9, 100, 513] {
        let a: Vec<u64> = (0..len).map(|_| rng.next_u64() % P61).collect();
        let b: Vec<u64> = (0..len).map(|_| rng.next_u64() % P61).collect();
        let raw: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
        let mut add_want = a.clone();
        fp61x::add_assign_at(Level::Scalar, &mut add_want, &b);
        let mut red_want = raw.clone();
        fp61x::reduce_assign_at(Level::Scalar, &mut red_want);
        for level in simd::available_levels() {
            let mut add_got = a.clone();
            fp61x::add_assign_at(level, &mut add_got, &b);
            assert_eq!(add_got, add_want, "add level={} len={len}", level.name());
            let mut red_got = raw.clone();
            fp61x::reduce_assign_at(level, &mut red_got);
            assert_eq!(red_got, red_want, "reduce level={} len={len}", level.name());
        }
        // The public batch API (dispatched) against element-wise math.
        let mut sum = a.clone();
        batch::add_assign(&mut sum, &b);
        let mut prod = a.clone();
        batch::mul_assign(&mut prod, &b);
        for i in 0..len {
            assert_eq!(sum[i] as u128, (a[i] as u128 + b[i] as u128) % P61 as u128);
            assert_eq!(prod[i] as u128, (a[i] as u128 * b[i] as u128) % P61 as u128);
        }
    }
}

fn push_matrix(digest: &mut Vec<u8>, m: &Matrix) {
    digest.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    digest.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    for v in m.as_slice() {
        digest.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// One full coded round at the current global pool width, digested —
/// the `parallel_determinism` construction, reused to pin that the SIMD
/// dispatch level does not interact with the thread count.
fn pipeline_digest(kind: SchemeKind) -> Vec<u8> {
    let params = CodeParams::new(12, 3, 2);
    let scheme = make_scheme(kind, params);
    let mut rng = rng_from_seed(0x51D);
    let x = Matrix::random_gaussian(24, 18, 0.0, 1.0, &mut rng);
    let task = if kind == SchemeKind::MatDot {
        CodedTask::pair_product(x.clone(), x.transpose())
    } else {
        let v = Matrix::random_gaussian(18, 8, 0.0, 1.0, &mut rng);
        CodedTask::block_map(WorkerOp::RightMul(Arc::new(v)), x.clone())
    };
    let job = scheme.encode(&task, &mut rng).unwrap();
    let mut digest = Vec::new();
    for payloads in &job.payloads {
        for m in payloads {
            push_matrix(&mut digest, m);
        }
    }
    let curve = sim_curve();
    let mea = MeaEcc::new(curve, MaskMode::Keystream);
    let executor = Executor::native(Arc::new(MetricsRegistry::new()));
    let mut results: Vec<(usize, Matrix)> = Vec::new();
    for (w, payloads) in job.payloads.iter().enumerate() {
        let mut wrng = rng_from_seed(derive_seed(0x51D2, w as u64));
        let keys = KeyPair::generate(&curve, &mut wrng);
        let mut opened = Vec::new();
        for m in payloads {
            let sealed = SealedPayload::seal(&mea, m, &keys.public(), &mut wrng);
            digest.extend_from_slice(&sealed.sealed.bytes);
            let back = sealed.open_owned(&mea, &keys).unwrap();
            assert_eq!(&back, m, "seal/open must round-trip bit-exact");
            opened.push(back);
        }
        results.push((w, executor.run(&job.op, &opened)));
    }
    let selected: Vec<(usize, Matrix)> = match scheme.threshold(&task) {
        Threshold::Exact(k) => results.into_iter().take(k).collect(),
        Threshold::Flexible { .. } => {
            results.into_iter().filter(|(w, _)| *w != 2 && *w != 7).collect()
        }
    };
    let decoded = scheme.decode(&job.ctx, &selected).unwrap();
    for m in &decoded {
        push_matrix(&mut digest, m);
    }
    digest
}

#[test]
fn all_schemes_digest_stable_across_threads_at_dispatched_level() {
    for kind in SchemeKind::all() {
        parallel::configure(1);
        let baseline = pipeline_digest(kind);
        assert!(!baseline.is_empty());
        parallel::configure(8);
        let got = pipeline_digest(kind);
        assert_eq!(
            got,
            baseline,
            "{} digest must be identical at (threads=8, level={})",
            kind.name(),
            simd::level().name()
        );
    }
    parallel::configure(0); // restore auto width for later tests
}
