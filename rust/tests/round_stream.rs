//! Round-stream integration tests — the determinism contract for
//! windowed streams (DESIGN.md §8): one digest across `inflight ∈
//! {1, 4, 16}` on both transports, speculation as a pure recovery
//! mechanism (a healthy stream decodes identically with it on or off),
//! and crash-under-window soaks that must degrade or re-dispatch but
//! never deadlock.

use spacdc::coding::CodedTask;
use spacdc::config::{SchemeKind, SystemConfig, TransportKind};
use spacdc::coordinator::{Master, StreamConfig};
use spacdc::matrix::Matrix;
use spacdc::metrics::names;
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;
use spacdc::sim::{run_scenario_with, Scenario};

/// The CI stream matrix in miniature: both fabrics × three window
/// widths (threads are exercised by the scenario-engine tests).
const MATRIX: [(TransportKind, usize); 6] = [
    (TransportKind::InProc, 1),
    (TransportKind::InProc, 4),
    (TransportKind::InProc, 16),
    (TransportKind::Tcp, 1),
    (TransportKind::Tcp, 4),
    (TransportKind::Tcp, 16),
];

/// A faster cousin of the builtin `stream` scenario: same shape, same
/// crash/respawn + speculation story, service delay turned down so the
/// whole matrix stays cheap.
fn quick_stream() -> Scenario {
    let mut sc = Scenario::builtin("stream").unwrap();
    // Keep the full 12 rounds (the second respawn lands at round 11);
    // just turn the service delay down so the 6-way matrix stays cheap.
    sc.delay.base_service_s = 0.001;
    sc
}

#[test]
fn stream_digest_is_bit_identical_across_windows_and_transports() {
    let sc = quick_stream();
    let mut digests = Vec::new();
    for (transport, inflight) in MATRIX {
        let report = run_scenario_with(&sc, transport, 2, Some(inflight), None).unwrap();
        assert_eq!(
            report.recovery_hit_rate, 1.0,
            "every round must decode at transport={} inflight={inflight}",
            transport.name()
        );
        assert_eq!(
            report.spec_recovered, 2,
            "each scheduled crash loses exactly one share and speculation recovers it"
        );
        assert_eq!(
            report.degraded_rounds, 0,
            "a recovered round decodes at full policy, not degraded"
        );
        assert_eq!(report.respawns, 2, "both incarnations rejoin on schedule");
        assert_eq!(report.inflight, inflight);
        digests.push((transport.name(), inflight, report.digest));
    }
    let first = digests[0].2.clone();
    for (transport, inflight, digest) in &digests {
        assert_eq!(
            digest, &first,
            "digest diverged at transport={transport} inflight={inflight}: {digests:?}"
        );
    }
}

#[test]
fn speculation_is_invisible_on_a_healthy_stream() {
    // No crashes: speculation must change nothing — not one decoded
    // bit, not one byte of the comm accounting the digest folds.
    let mut sc = quick_stream();
    sc.crashes.clear();
    let off = run_scenario_with(&sc, TransportKind::InProc, 2, Some(4), Some(false)).unwrap();
    let on = run_scenario_with(&sc, TransportKind::InProc, 2, Some(4), Some(true)).unwrap();
    assert_eq!(off.digest, on.digest, "speculation perturbed a healthy stream");
    assert_eq!(on.spec_recovered, 0);
    assert_eq!(off.recovery_hit_rate, 1.0);
    for (a, b) in off.records.iter().zip(&on.records) {
        assert_eq!(a.results_used, b.results_used);
        assert_eq!(a.rel_err, b.rel_err, "round {}: decoded outputs differ", a.round);
    }
}

#[test]
fn speculation_turns_degraded_rounds_into_recovered_ones() {
    // Same crashing stream, speculation as the only difference: off
    // degrades the crash rounds, on recovers them to full policy.
    let sc = quick_stream();
    let off = run_scenario_with(&sc, TransportKind::InProc, 2, Some(4), Some(false)).unwrap();
    assert_eq!(off.recovery_hit_rate, 1.0, "flexible rounds ride out the crash either way");
    assert_eq!(off.degraded_rounds, 2, "without speculation, each crash degrades its round");
    assert_eq!(off.spec_recovered, 0);
    let on = run_scenario_with(&sc, TransportKind::InProc, 2, Some(4), Some(true)).unwrap();
    assert_eq!(on.degraded_rounds, 0);
    assert_eq!(on.spec_recovered, 2);
    // The recovered rounds decode from strictly more results.
    let crash_rounds = [4usize, 8];
    for r in crash_rounds {
        let (off_r, on_r) = (&off.records[r - 1], &on.records[r - 1]);
        assert!(
            on_r.results_used > off_r.results_used,
            "round {r}: speculation must add the recovered share \
             ({} vs {})",
            on_r.results_used,
            off_r.results_used
        );
    }
}

#[test]
fn speculation_survives_scheduled_wire_corruption() {
    // crash-respawn injects a 6% per-(worker, round) corruption coin.
    // A speculative copy must never be handed to an executor whose coin
    // is true for that round — the copy would be corrupted in transit
    // with nobody booking it lost, wedging the share in `pending` until
    // the 30 s deadline. With the executor filter, corruption-lost
    // shares are recovered (or degrade cleanly) and every round
    // decodes fast.
    let sc = Scenario::builtin("crash-respawn").unwrap();
    let t0 = std::time::Instant::now();
    let report = run_scenario_with(&sc, TransportKind::InProc, 2, Some(4), Some(true)).unwrap();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(20),
        "a wedged speculative share rode the deadline"
    );
    assert_eq!(report.recovery_hit_rate, 1.0, "every round must decode");
    assert!(
        report.spec_recovered >= 2,
        "the crashed workers' shares (at least) must be recovered, got {}",
        report.spec_recovered
    );
}

fn crash_under_window_cfg(transport: TransportKind, speculate: bool) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 6;
    cfg.partitions = 3;
    cfg.colluders = 2;
    cfg.stragglers = 0;
    cfg.scheme = SchemeKind::Spacdc;
    cfg.transport = transport;
    cfg.speculate = speculate;
    cfg.round_deadline_s = 20.0;
    // Slow enough that three submitted rounds are all still owed when
    // the crash lands, fast enough for a test.
    cfg.delay.base_service_s = 0.05;
    cfg.seed = 0xD1E;
    cfg
}

fn crash_under_window_check(transport: TransportKind, speculate: bool) {
    let mut master = Master::from_config(crash_under_window_cfg(transport, speculate)).unwrap();
    let mut rng = rng_from_seed(91);
    let tasks: Vec<Matrix> =
        (0..3).map(|_| Matrix::random_gaussian(12, 6, 0.0, 1.0, &mut rng)).collect();
    // Three rounds into the window, nothing waited on: worker 0 owes a
    // share to every one of them when the master writes it off.
    let handles: Vec<_> = tasks
        .iter()
        .map(|x| {
            master.submit(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap()
        })
        .collect();
    master.note_worker_crashed(0);
    let t0 = std::time::Instant::now();
    for h in handles {
        let out = master.wait(h).unwrap_or_else(|e| panic!("round must not fail: {e}"));
        if speculate {
            // The lost share is re-dispatched, the wait target restored:
            // full-policy decode. (The written-off worker is a zombie
            // whose own result races the speculative copy — both carry
            // identical bits, so first-wins keeps this deterministic.)
            assert_eq!(out.results_used, 6, "speculation must restore the full policy");
            assert!(!out.degraded);
        } else {
            // Degrade to what can still arrive; the zombie's result,
            // arriving early, simply takes one of the 5 slots.
            assert_eq!(out.results_used, 5, "the round must degrade, not deadlock");
            assert!(out.degraded);
        }
        assert_eq!(out.blocks.len(), 3);
    }
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(15),
        "crash-under-window must not ride the deadline"
    );
    if speculate {
        assert!(
            master.metrics().get(names::SPEC_REDISPATCHED) >= 3,
            "each in-flight round's lost share is re-dispatched"
        );
    }
    // The next round runs clean on the surviving workers.
    let out = master
        .run(CodedTask::block_map(WorkerOp::Identity, tasks[0].clone()))
        .unwrap();
    assert_eq!(out.results_used, 5, "the dead worker is skipped up front");
}

#[test]
fn crash_under_window_degrades_without_speculation_inproc() {
    crash_under_window_check(TransportKind::InProc, false);
}

#[test]
fn crash_under_window_recovers_with_speculation_inproc() {
    crash_under_window_check(TransportKind::InProc, true);
}

#[test]
fn crash_under_window_survives_on_tcp() {
    crash_under_window_check(TransportKind::Tcp, true);
}

#[test]
fn wider_windows_do_not_change_stream_outcomes_via_master_api() {
    // The API-level twin of the digest test: drive the same task list
    // through run_stream at three widths and require bit-identical
    // decoded blocks per round.
    let mut blocks_by_width: Vec<Vec<Vec<Matrix>>> = Vec::new();
    for inflight in [1usize, 4, 16] {
        let mut cfg = SystemConfig::default();
        cfg.workers = 8;
        cfg.partitions = 4;
        cfg.colluders = 2;
        cfg.stragglers = 2;
        cfg.scheme = SchemeKind::Spacdc;
        cfg.seed = 0xABCD;
        cfg.delay.base_service_s = 0.0;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(17);
        let tasks: Vec<CodedTask> = (0..6)
            .map(|_| {
                CodedTask::block_map(
                    WorkerOp::Gram,
                    Matrix::random_gaussian(16, 8, 0.0, 1.0, &mut rng),
                )
            })
            .collect();
        let out = master
            .run_stream(tasks, StreamConfig { inflight, speculate: false })
            .unwrap();
        assert_eq!(out.decoded(), 6);
        blocks_by_width
            .push(out.rounds.into_iter().map(|r| r.outcome.unwrap().blocks).collect());
    }
    for wider in &blocks_by_width[1..] {
        for (a, b) in blocks_by_width[0].iter().zip(wider) {
            assert_eq!(a, b, "decoded blocks moved with the window width");
        }
    }
}
