//! The unified coding surface: one `Master::run(CodedTask)` entry point
//! for all 8 schemes and both task shapes, plus the split-phase
//! `submit`/`wait` pipelining semantics (distinct round ids, no
//! cross-round result bleed, out-of-order waits).

use spacdc::coding::CodedTask;
use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::coordinator::Master;
use spacdc::matrix::{matmul, split_rows, stack_rows, Matrix};
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;
use std::sync::Arc;

fn cfg(scheme: SchemeKind) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 16;
    cfg.partitions = 4; // MatDot: 2K−1 = 7 ≤ 16; SPACDC: K+T = 6 ≤ 16
    cfg.colluders = 2;
    cfg.stragglers = 3;
    cfg.scheme = scheme;
    cfg.delay.base_service_s = 0.0;
    cfg.seed = 0xAB1F;
    if scheme == SchemeKind::Uncoded {
        cfg.partitions = cfg.workers;
    }
    cfg
}

/// Decode-error tolerance per scheme: exact codes must be near-exact,
/// the Berrut family is approximate under stragglers.
fn tolerance(scheme: SchemeKind) -> f64 {
    match scheme {
        SchemeKind::Spacdc | SchemeKind::Bacc => 0.6,
        SchemeKind::MatDot => 0.05,
        _ => 1e-2,
    }
}

#[test]
fn block_map_round_trip_across_all_supporting_schemes() {
    // Every scheme except MatDot (a pure pair code) serves block maps;
    // decoded blocks must match the uncoded per-block reference.
    let mut rng = rng_from_seed(11);
    let x = Matrix::random_gaussian(32, 10, 0.0, 1.0, &mut rng);
    let v = Arc::new(Matrix::random_gaussian(10, 6, 0.0, 1.0, &mut rng));
    for scheme in SchemeKind::all() {
        if scheme == SchemeKind::MatDot {
            continue;
        }
        let mut master = Master::from_config(cfg(scheme)).unwrap();
        let task = CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone());
        let out = master.run(task).unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        let (blocks, _) = split_rows(&x, out.blocks.len());
        let worst = out
            .blocks
            .iter()
            .zip(&blocks)
            .map(|(d, b)| d.rel_error(&matmul(b, &v)))
            .fold(0.0f64, f64::max);
        assert!(worst < tolerance(scheme), "{scheme:?}: block-map err {worst}");
    }
}

#[test]
fn pair_product_round_trip_across_all_eight_schemes() {
    // The same PairProduct task runs on every SchemeKind — MatDot with
    // its two-operand shares, the row-partition schemes by broadcast
    // right-multiply — and must decode to the single full product.
    let mut rng = rng_from_seed(12);
    let a = Matrix::random_gaussian(28, 12, 0.0, 1.0, &mut rng);
    let b = Matrix::random_gaussian(12, 9, 0.0, 1.0, &mut rng);
    let reference = matmul(&a, &b);
    for scheme in SchemeKind::all() {
        let mut master = Master::from_config(cfg(scheme)).unwrap();
        let task = CodedTask::pair_product(a.clone(), b.clone());
        let out = master.run(task).unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        assert_eq!(out.blocks.len(), 1, "{scheme:?}: pair product is one matrix");
        assert_eq!(out.blocks[0].shape(), (28, 9), "{scheme:?}");
        let err = out.blocks[0].rel_error(&reference);
        assert!(err < tolerance(scheme), "{scheme:?}: pair-product err {err}");
    }
}

#[test]
fn pair_product_round_trips_under_sealed_transport() {
    // The unified wire path carries 1 or 2 sealed payloads per worker
    // identically; spot-check both extremes under MEA-ECC.
    let mut rng = rng_from_seed(13);
    let a = Matrix::random_gaussian(20, 8, 0.0, 1.0, &mut rng);
    let b = Matrix::random_gaussian(8, 7, 0.0, 1.0, &mut rng);
    let reference = matmul(&a, &b);
    for scheme in [SchemeKind::MatDot, SchemeKind::Mds] {
        let mut c = cfg(scheme);
        c.security = TransportSecurity::MeaEcc;
        let mut master = Master::from_config(c).unwrap();
        let out = master.run(CodedTask::pair_product(a.clone(), b.clone())).unwrap();
        assert!(
            out.blocks[0].rel_error(&reference) < tolerance(scheme),
            "{scheme:?} sealed"
        );
    }
}

#[test]
fn matdot_rejects_block_maps_with_a_typed_error() {
    let mut master = Master::from_config(cfg(SchemeKind::MatDot)).unwrap();
    let err = master
        .run(CodedTask::block_map(WorkerOp::Identity, Matrix::ones(8, 4)))
        .unwrap_err();
    assert!(err.to_string().contains("block-map"), "got: {err}");
}

#[test]
fn submitted_rounds_have_distinct_ids_and_isolated_results() {
    // Two rounds in flight with *different* data, waited in reverse
    // order: each decode must reproduce its own round's input (identity
    // task ⇒ decode ≈ the round's blocks), proving results are routed by
    // round id rather than arrival order.
    let mut master = Master::from_config(cfg(SchemeKind::Spacdc)).unwrap();
    let mut rng = rng_from_seed(14);
    let x1 = Matrix::random_gaussian(16, 6, 0.0, 1.0, &mut rng);
    let x2 = Matrix::random_gaussian(16, 6, 0.0, 1.0, &mut rng);

    let h1 = master.submit(CodedTask::block_map(WorkerOp::Identity, x1.clone())).unwrap();
    let h2 = master.submit(CodedTask::block_map(WorkerOp::Identity, x2.clone())).unwrap();
    assert_ne!(h1.round_id(), h2.round_id(), "rounds must get distinct ids");

    let out2 = master.wait(h2).unwrap();
    let out1 = master.wait(h1).unwrap();

    let (_, spec) = split_rows(&x1, 4);
    let restored1 = stack_rows(&out1.blocks, &spec);
    let restored2 = stack_rows(&out2.blocks, &spec);
    let e11 = restored1.rel_error(&x1);
    let e22 = restored2.rel_error(&x2);
    assert!(e11 < 0.5, "round 1 should decode round 1's data: {e11}");
    assert!(e22 < 0.5, "round 2 should decode round 2's data: {e22}");
    // Cross-check: each output is far closer to its own input than to
    // the other round's input — the no-bleed property.
    let e12 = restored1.rel_error(&x2);
    let e21 = restored2.rel_error(&x1);
    assert!(e12 > 2.0 * e11, "round 1 output bleeds toward round 2 data: {e11} vs {e12}");
    assert!(e21 > 2.0 * e22, "round 2 output bleeds toward round 1 data: {e22} vs {e21}");
}

#[test]
fn many_rounds_in_flight_all_complete() {
    let mut master = Master::from_config(cfg(SchemeKind::Bacc)).unwrap();
    let mut rng = rng_from_seed(15);
    let inputs: Vec<Matrix> =
        (0..6).map(|_| Matrix::random_gaussian(16, 5, 0.0, 1.0, &mut rng)).collect();
    let handles: Vec<_> = inputs
        .iter()
        .map(|x| master.submit(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap())
        .collect();
    for (h, x) in handles.into_iter().zip(&inputs) {
        let out = master.wait(h).unwrap();
        let (_, spec) = split_rows(x, 4);
        let restored = stack_rows(&out.blocks, &spec);
        assert!(restored.rel_error(x) < 0.3, "err {}", restored.rel_error(x));
    }
}
