//! The wire + transport layers, end to end: property tests over the
//! frame codecs (round-trip, corruption, truncation) and the
//! transport-equivalence guarantee — a TCP round decodes bit-identically
//! to the same seeded in-proc round, across schemes and security modes.

use spacdc::coding::CodedTask;
use spacdc::config::{SchemeKind, SystemConfig, TransportKind, TransportSecurity};
use spacdc::coordinator::{Master, ResultMsg, SealedPayload, WirePayload, WorkOrder};
use spacdc::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use spacdc::matrix::Matrix;
use spacdc::metrics::names;
use spacdc::prop::{forall, prop_assert, Gen};
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;
use spacdc::wire;
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------- helpers

fn gen_matrix(g: &mut Gen) -> Matrix {
    let rows = g.usize_in(1..24);
    let cols = g.usize_in(1..24);
    Matrix::random_gaussian(rows, cols, 0.0, 3.0, g.rng())
}

/// A random payload: plain, or sealed to a throwaway key.
fn gen_payload(g: &mut Gen, mea: &MeaEcc<spacdc::field::Fp61>) -> WirePayload {
    let m = gen_matrix(g);
    if g.bool_with(0.5) {
        WirePayload::Plain(m)
    } else {
        let kp = KeyPair::generate(mea.curve(), g.rng());
        WirePayload::Sealed(SealedPayload::seal(mea, &m, &kp.public(), g.rng()))
    }
}

fn gen_op(g: &mut Gen) -> WorkerOp {
    match g.usize_in(0..4) {
        0 => WorkerOp::Gram,
        1 => WorkerOp::RightMul(Arc::new(gen_matrix(g))),
        2 => WorkerOp::PairProduct,
        _ => WorkerOp::Identity,
    }
}

fn gen_order(g: &mut Gen, mea: &MeaEcc<spacdc::field::Fp61>) -> WorkOrder {
    let arity = g.usize_in(1..3); // 1 or 2 operands, like the real schemes
    WorkOrder {
        round: g.u64(),
        worker: g.usize_in(0..64),
        lane: g.usize_in(0..1 << 16) as u32,
        lane_round: g.u64(),
        served: g.u64(),
        op: gen_op(g),
        payloads: (0..arity).map(|_| gen_payload(g, mea)).collect(),
        delay: Duration::from_nanos(g.u64() >> 20),
        commitment: g.u64(),
    }
}

fn gen_result(g: &mut Gen, mea: &MeaEcc<spacdc::field::Fp61>) -> ResultMsg {
    ResultMsg {
        round: g.u64(),
        worker: g.usize_in(0..64),
        executor: g.usize_in(0..64),
        payload: gen_payload(g, mea),
        commitment: g.u64(),
    }
}

fn payloads_eq(a: &WirePayload, b: &WirePayload) -> bool {
    match (a, b) {
        (WirePayload::Plain(x), WirePayload::Plain(y)) => {
            x.shape() == y.shape()
                && x.as_slice().iter().zip(y.as_slice()).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (WirePayload::Sealed(x), WirePayload::Sealed(y)) => {
            x.rows == y.rows
                && x.cols == y.cols
                && x.sealed.ephemeral == y.sealed.ephemeral
                && x.sealed.bytes == y.sealed.bytes
        }
        _ => false,
    }
}

fn ops_eq(a: &WorkerOp, b: &WorkerOp) -> bool {
    match (a, b) {
        (WorkerOp::Gram, WorkerOp::Gram)
        | (WorkerOp::PairProduct, WorkerOp::PairProduct)
        | (WorkerOp::Identity, WorkerOp::Identity) => true,
        (WorkerOp::RightMul(x), WorkerOp::RightMul(y)) => {
            x.shape() == y.shape() && x.as_slice() == y.as_slice()
        }
        _ => false,
    }
}

// --------------------------------------------------------- codec properties

#[test]
fn order_frames_round_trip_over_random_shapes_and_arities() {
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    forall(60, 0xF1A7, |g| {
        let order = gen_order(g, &mea);
        let frame = wire::encode_order(&order);
        let back = wire::decode_order(&frame).map_err(|e| e.to_string())?;
        prop_assert(back.round == order.round, "round id changed")?;
        prop_assert(back.worker == order.worker, "worker id changed")?;
        prop_assert(back.lane == order.lane, "lane changed")?;
        prop_assert(back.lane_round == order.lane_round, "lane round changed")?;
        prop_assert(back.served == order.served, "served count changed")?;
        prop_assert(back.delay == order.delay, "delay changed")?;
        prop_assert(back.commitment == order.commitment, "commitment changed")?;
        prop_assert(ops_eq(&back.op, &order.op), "op changed")?;
        prop_assert(back.payloads.len() == order.payloads.len(), "arity changed")?;
        for (p, q) in back.payloads.iter().zip(&order.payloads) {
            prop_assert(payloads_eq(p, q), "payload changed")?;
        }
        Ok(())
    });
}

#[test]
fn result_frames_round_trip_plain_and_sealed() {
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    forall(60, 0xF1A8, |g| {
        let msg = gen_result(g, &mea);
        let frame = wire::encode_result(&msg);
        let back = wire::decode_result(&frame).map_err(|e| e.to_string())?;
        prop_assert(back.round == msg.round, "round id changed")?;
        prop_assert(back.worker == msg.worker, "worker id changed")?;
        prop_assert(back.executor == msg.executor, "executor id changed")?;
        prop_assert(back.commitment == msg.commitment, "commitment changed")?;
        prop_assert(payloads_eq(&back.payload, &msg.payload), "payload changed")
    });
}

#[test]
fn any_single_byte_corruption_is_rejected() {
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    forall(80, 0xC0FF, |g| {
        let order = gen_order(g, &mea);
        let mut frame = wire::encode_order(&order);
        let pos = g.usize_in(0..frame.len());
        // Any nonzero flip at any position must surface as a WireError.
        let flip = (g.usize_in(1..256)) as u8;
        frame[pos] ^= flip;
        prop_assert(
            wire::decode_order(&frame).is_err(),
            format!("corruption at byte {pos} (flip {flip:#04x}) decoded anyway"),
        )
    });
}

// ---------------------------------------------- control frames (PR 4 wire)

/// A random lifecycle control message: the `Register`/`Crash` frames
/// worker incarnations and the fault injector exchange.
fn gen_control(g: &mut Gen) -> spacdc::coordinator::ControlMsg {
    use spacdc::coordinator::ControlMsg;
    if g.bool_with(0.4) {
        ControlMsg::Crash { worker: g.usize_in(0..256) }
    } else {
        let pk = if g.bool_with(0.15) {
            spacdc::ecc::Point::Infinity
        } else {
            let kp = KeyPair::generate(&sim_curve(), g.rng());
            kp.public()
        };
        ControlMsg::Register {
            worker: g.usize_in(0..256),
            generation: g.usize_in(0..1 << 16) as u32,
            pk,
        }
    }
}

#[test]
fn control_frames_round_trip_over_random_contents() {
    forall(80, 0xC7A1, |g| {
        let msg = gen_control(g);
        let frame = wire::encode_control(&msg);
        match wire::decode_message(&frame).map_err(|e| e.to_string())? {
            wire::WireMessage::Control(back) => {
                prop_assert(back == msg, format!("control changed: {back:?} vs {msg:?}"))?;
            }
            other => return Err(format!("control frame decoded as {}", other.kind_name())),
        }
        // A control frame must never pass for an order or a result.
        prop_assert(wire::decode_order(&frame).is_err(), "control decoded as order")?;
        prop_assert(wire::decode_result(&frame).is_err(), "control decoded as result")
    });
}

#[test]
fn any_control_frame_corruption_is_rejected() {
    // Every single-byte flip must fail to decode (CRC or structure) —
    // a corrupted registration must never install a wrong key, and a
    // corrupted kill must never fire.
    forall(120, 0xC7A2, |g| {
        let msg = gen_control(g);
        let mut frame = wire::encode_control(&msg);
        let pos = g.usize_in(0..frame.len());
        let flip = (g.usize_in(1..256)) as u8;
        frame[pos] ^= flip;
        prop_assert(
            wire::decode_message(&frame).is_err(),
            format!("corrupted control frame (byte {pos} ^ {flip:#04x}) decoded"),
        )
    });
}

#[test]
fn any_control_frame_truncation_is_rejected() {
    forall(80, 0xC7A3, |g| {
        let msg = gen_control(g);
        let frame = wire::encode_control(&msg);
        let cut = g.usize_in(0..frame.len());
        prop_assert(
            wire::decode_message(&frame[..cut]).is_err(),
            format!("{cut}-byte prefix of a {}-byte control frame decoded", frame.len()),
        )
    });
}

#[test]
fn control_frames_reject_trailing_garbage_and_bad_tags() {
    use spacdc::wire::{frame, MsgKind};
    // Unknown control tag byte.
    let bad_tag = frame(MsgKind::Control, &[9, 0, 0, 0, 0]);
    assert!(wire::decode_message(&bad_tag).is_err(), "unknown control tag accepted");
    // A structurally valid Crash body with trailing bytes.
    let mut body = vec![1u8];
    body.extend_from_slice(&7u32.to_le_bytes());
    body.push(0xEE);
    let trailing = frame(MsgKind::Control, &body);
    assert!(wire::decode_message(&trailing).is_err(), "trailing body bytes accepted");
    // An empty control body.
    let empty = frame(MsgKind::Control, &[]);
    assert!(wire::decode_message(&empty).is_err(), "empty control body accepted");
}

// ------------------------------------------------------------- router peeks

#[test]
fn router_peeks_agree_with_the_full_decoder() {
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    forall(60, 0xC7A4, |g| {
        match g.usize_in(0..3) {
            0 => {
                let order = gen_order(g, &mea);
                let f = wire::encode_order(&order);
                prop_assert(
                    wire::peek_kind(&f) == Some(spacdc::wire::MsgKind::Order),
                    "order peek",
                )?;
                prop_assert(wire::peek_result_round(&f).is_none(), "order has no result round")
            }
            1 => {
                let msg = gen_result(g, &mea);
                let f = wire::encode_result(&msg);
                prop_assert(
                    wire::peek_kind(&f) == Some(spacdc::wire::MsgKind::Result),
                    "result peek",
                )?;
                prop_assert(
                    wire::peek_result_round(&f) == Some(msg.round),
                    "peeked round must match the encoded round",
                )
            }
            _ => {
                let msg = gen_control(g);
                let f = wire::encode_control(&msg);
                prop_assert(
                    wire::peek_kind(&f) == Some(spacdc::wire::MsgKind::Control),
                    "control peek",
                )?;
                prop_assert(wire::peek_result_round(&f).is_none(), "control has no round")
            }
        }
    });
}

#[test]
fn any_truncation_is_rejected() {
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    forall(80, 0x7A11, |g| {
        let msg = gen_result(g, &mea);
        let frame = wire::encode_result(&msg);
        let cut = g.usize_in(0..frame.len());
        prop_assert(
            wire::decode_result(&frame[..cut]).is_err(),
            format!("{cut}-byte prefix of a {}-byte frame decoded", frame.len()),
        )
    });
}

// ------------------------------------------- commitment echo (wire v3)

#[test]
fn any_result_frame_corruption_is_rejected_commitment_included() {
    // The commitment u64 rides at the end of the result body; a flip
    // anywhere in the frame — payload, ids, or the echo itself — must
    // fail the CRC. An in-transit tamper therefore never reaches the
    // collector's commitment comparison: only a worker that *re-frames*
    // (a forger) can deliver a wrong echo, which is exactly the case the
    // collector's ledger check exists for.
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    forall(120, 0xF1A9, |g| {
        let msg = gen_result(g, &mea);
        let mut frame = wire::encode_result(&msg);
        let pos = g.usize_in(0..frame.len());
        let flip = (g.usize_in(1..256)) as u8;
        frame[pos] ^= flip;
        prop_assert(
            wire::decode_result(&frame).is_err(),
            format!("corrupted result frame (byte {pos} ^ {flip:#04x}) decoded"),
        )
    });
}

#[test]
fn a_reframed_tampered_commitment_survives_the_wire_but_not_the_ledger() {
    // A forger controls its own encoder: it can re-frame a result with a
    // valid CRC around a tampered echo. The wire layer must accept the
    // frame (it is well-formed) — detection belongs to the collector's
    // encode-time ledger, not the CRC.
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    forall(40, 0xF1AA, |g| {
        let msg = gen_result(g, &mea);
        let tamper = g.u64() | 1; // nonzero XOR → echo always differs
        let forged = ResultMsg { commitment: msg.commitment ^ tamper, ..msg.clone() };
        let back = wire::decode_result(&wire::encode_result(&forged))
            .map_err(|e| format!("well-formed forged frame rejected by the wire: {e}"))?;
        prop_assert(
            back.commitment != msg.commitment,
            "tampered echo must disagree with the encode-time commitment",
        )?;
        prop_assert(
            back.commitment == forged.commitment,
            "the forged echo itself must round-trip verbatim",
        )
    });
}

// ---------------------------------------------------- transport equivalence

fn round_cfg(
    scheme: SchemeKind,
    security: TransportSecurity,
    transport: TransportKind,
    stragglers: usize,
) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 12;
    cfg.partitions = if scheme == SchemeKind::Uncoded { 12 } else { 3 };
    cfg.colluders = 2;
    cfg.stragglers = stragglers;
    cfg.scheme = scheme;
    cfg.security = security;
    cfg.transport = transport;
    // With stragglers the return set must be deterministic for the
    // bit-identity check: give every task a real service time so the
    // S stragglers (5× slower) can never beat a fast worker home.
    cfg.delay.base_service_s = if stragglers > 0 { 0.04 } else { 0.0 };
    cfg.seed = 0x7C9;
    cfg
}

fn run_round(cfg: SystemConfig) -> (Vec<Matrix>, usize, u64, u64) {
    let mut master = Master::from_config(cfg).unwrap();
    let mut rng = rng_from_seed(99);
    let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
    let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
    let tx = master.metrics().get(names::BYTES_TX);
    let rx = master.metrics().get(names::BYTES_RX);
    (out.blocks, out.results_used, tx, rx)
}

#[test]
fn tcp_rounds_decode_bit_identically_to_inproc_across_schemes() {
    // Scheme × security grid with deterministic return sets:
    //  - SPACDC sealed, S=2 → flexible policy takes the 10 non-stragglers;
    //  - BACC plain, S=0    → flexible policy takes all 12;
    //  - CONV plain, S=0    → exact policy waits for all 12.
    let grid = [
        (SchemeKind::Spacdc, TransportSecurity::MeaEcc, 2usize),
        (SchemeKind::Bacc, TransportSecurity::Plain, 0),
        (SchemeKind::Uncoded, TransportSecurity::Plain, 0),
    ];
    for (scheme, security, stragglers) in grid {
        let (inproc, used_i, tx_i, rx_i) =
            run_round(round_cfg(scheme, security, TransportKind::InProc, stragglers));
        let (tcp, used_t, tx_t, rx_t) =
            run_round(round_cfg(scheme, security, TransportKind::Tcp, stragglers));
        assert_eq!(used_i, used_t, "{scheme:?}: results_used must match");
        assert_eq!(inproc.len(), tcp.len(), "{scheme:?}: block count must match");
        for (a, b) in inproc.iter().zip(&tcp) {
            assert_eq!(a.shape(), b.shape(), "{scheme:?}");
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{scheme:?}: decode must be bit-identical");
            }
        }
        // Identical frames moved on both fabrics, and bytes_rx reflects
        // exactly the (identical) decode inputs.
        assert_eq!(tx_i, tx_t, "{scheme:?}: bytes_tx must match across transports");
        assert_eq!(rx_i, rx_t, "{scheme:?}: bytes_rx must match across transports");
        assert!(tx_i > 0 && rx_i > 0, "{scheme:?}: byte counters live");
    }
}

#[test]
fn sealed_tcp_round_reports_more_bytes_than_symbols() {
    // 4 bytes per f32 symbol plus framing: the byte counters must
    // strictly dominate 4× the symbol counters.
    let (_, _, tx, _) = run_round(round_cfg(
        SchemeKind::Spacdc,
        TransportSecurity::MeaEcc,
        TransportKind::Tcp,
        0,
    ));
    let cfg = round_cfg(SchemeKind::Spacdc, TransportSecurity::MeaEcc, TransportKind::InProc, 0);
    let mut master = Master::from_config(cfg).unwrap();
    let mut rng = rng_from_seed(99);
    let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
    master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
    let symbols = master.metrics().get(names::SYMBOLS_TO_WORKERS);
    assert!(
        tx > 4 * symbols,
        "bytes_tx {tx} must exceed 4×symbols {symbols} (framing overhead)"
    );
}
