//! Determinism under parallelism — the PR-3 contract.
//!
//! The parallel subsystem (`spacdc::parallel`) promises that every hot
//! path — per-worker encode fan-out, MEA-ECC seal fan-out, packed GEMM,
//! row-chunked Berrut/Lagrange decode — produces *bit-identical* output
//! at any thread count. This suite pins that:
//!
//! * a full encode → seal → open → compute → decode pipeline, digested
//!   to bytes, is identical for `threads ∈ {1, 2, 8}` across all 8
//!   schemes;
//! * the packed GEMM matches the naive oracle on ragged shapes and is
//!   bit-identical across pool widths.
//!
//! The scheme pipeline runs against the process-global pool (the same
//! one `Master` configures), so the cross-width comparison lives in a
//! single `#[test]` to avoid races on the global width; the GEMM
//! properties use explicit `ThreadPool`s and parallelize freely.

use spacdc::coding::{make_scheme, CodeParams, CodedTask, Threshold};
use spacdc::config::SchemeKind;
use spacdc::coordinator::SealedPayload;
use spacdc::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use spacdc::matrix::{gram_with, matmul_naive, matmul_with, Matrix};
use spacdc::metrics::MetricsRegistry;
use spacdc::parallel::{self, ThreadPool};
use spacdc::rng::{derive_seed, rng_from_seed};
use spacdc::runtime::{Executor, WorkerOp};
use std::sync::Arc;

fn push_matrix(digest: &mut Vec<u8>, m: &Matrix) {
    digest.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    digest.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    for v in m.as_slice() {
        digest.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// One full coded round at the current global pool width, digested to
/// bytes: encoded shares, sealed wire ciphertexts, and decoded blocks.
/// Every RNG is seeded explicitly, so two calls differ only if some
/// stage's output depends on the thread count.
fn pipeline_digest(kind: SchemeKind) -> Vec<u8> {
    let params = CodeParams::new(12, 3, 2);
    let scheme = make_scheme(kind, params);
    let mut rng = rng_from_seed(0xD17);
    let x = Matrix::random_gaussian(24, 18, 0.0, 1.0, &mut rng);
    let task = if kind == SchemeKind::MatDot {
        CodedTask::pair_product(x.clone(), x.transpose())
    } else {
        let v = Matrix::random_gaussian(18, 8, 0.0, 1.0, &mut rng);
        CodedTask::block_map(WorkerOp::RightMul(Arc::new(v)), x.clone())
    };
    assert!(scheme.supports(&task), "{kind:?} must support the probe task");
    let job = scheme.encode(&task, &mut rng).unwrap();

    let mut digest = Vec::new();
    for payloads in &job.payloads {
        for m in payloads {
            push_matrix(&mut digest, m);
        }
    }

    // Seal → open per worker exactly as the wire does, with per-worker
    // derived RNGs (the same construction Master::submit uses), then run
    // the worker op on the opened operands.
    let curve = sim_curve();
    let mea = MeaEcc::new(curve, MaskMode::Keystream);
    let executor = Executor::native(Arc::new(MetricsRegistry::new()));
    let mut results: Vec<(usize, Matrix)> = Vec::new();
    for (w, payloads) in job.payloads.iter().enumerate() {
        let mut wrng = rng_from_seed(derive_seed(0xA11CE, w as u64));
        let keys = KeyPair::generate(&curve, &mut wrng);
        let mut opened = Vec::new();
        for m in payloads {
            let sealed = SealedPayload::seal(&mea, m, &keys.public(), &mut wrng);
            digest.extend_from_slice(&sealed.sealed.bytes);
            let back = sealed.open_owned(&mea, &keys).unwrap();
            assert_eq!(&back, m, "seal/open must round-trip bit-exact");
            opened.push(back);
        }
        results.push((w, executor.run(&job.op, &opened)));
    }

    // A deterministic result subset per the scheme's own semantics:
    // exact schemes decode from exactly their threshold, flexible ones
    // from a fixed straggler pattern.
    let selected: Vec<(usize, Matrix)> = match scheme.threshold(&task) {
        Threshold::Exact(k) => results.into_iter().take(k).collect(),
        Threshold::Flexible { .. } => {
            results.into_iter().filter(|(w, _)| *w != 2 && *w != 7).collect()
        }
    };
    let decoded = scheme.decode(&job.ctx, &selected).unwrap();
    for m in &decoded {
        push_matrix(&mut digest, m);
    }
    digest
}

#[test]
fn encode_seal_decode_bit_identical_across_thread_counts() {
    for kind in SchemeKind::all() {
        parallel::configure(1);
        let baseline = pipeline_digest(kind);
        assert!(!baseline.is_empty());
        for threads in [2usize, 8] {
            parallel::configure(threads);
            let got = pipeline_digest(kind);
            assert_eq!(
                got, baseline,
                "{} pipeline must be bit-identical at threads={threads}",
                kind.name()
            );
        }
    }
    parallel::configure(0); // restore auto for any later test in this binary
}

#[test]
fn packed_gemm_matches_naive_on_ragged_shapes() {
    let shapes = [
        (1usize, 1usize, 1usize), // minimal
        (1, 7, 1),                // single row/col, prime inner
        (7, 11, 13),              // all prime
        (3, 1, 5),                // inner dim 1
        (31, 37, 29),             // primes around the block sizes
        (257, 3, 65),             // tall & skinny, crosses ROW_BLOCK
        (2, 129, 2),              // long inner dim, tiny output
        (64, 64, 64),             // exactly one block each way
    ];
    let mut rng = rng_from_seed(0x6E44);
    let pool = ThreadPool::new(8);
    for &(m, k, n) in &shapes {
        let a = Matrix::random_gaussian(m, k, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(k, n, 0.0, 1.0, &mut rng);
        let fast = matmul_with(&pool, &a, &b);
        let slow = matmul_naive(&a, &b);
        assert!(
            fast.max_abs_diff(&slow) < 1e-3,
            "({m},{k},{n}): diff {}",
            fast.max_abs_diff(&slow)
        );
    }
}

#[test]
fn packed_gemm_bit_identical_across_pool_widths() {
    let mut rng = rng_from_seed(0x6E45);
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (33, 17, 65), (100, 40, 70)] {
        let a = Matrix::random_gaussian(m, k, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(k, n, 0.0, 1.0, &mut rng);
        let serial = matmul_with(&ThreadPool::new(1), &a, &b);
        for threads in [2usize, 8] {
            let par = matmul_with(&ThreadPool::new(threads), &a, &b);
            assert_eq!(
                serial.as_slice(),
                par.as_slice(),
                "({m},{k},{n}) at threads={threads}"
            );
        }
    }
}

#[test]
fn gram_bit_identical_across_pool_widths_and_symmetric() {
    let mut rng = rng_from_seed(0x6E46);
    let x = Matrix::random_gaussian(67, 41, 0.0, 1.0, &mut rng);
    let serial = gram_with(&ThreadPool::new(1), &x);
    for threads in [2usize, 8] {
        let par = gram_with(&ThreadPool::new(threads), &x);
        assert_eq!(serial.as_slice(), par.as_slice(), "threads={threads}");
    }
    for i in 0..67 {
        for j in 0..67 {
            assert_eq!(serial.get(i, j), serial.get(j, i), "gram must stay exactly symmetric");
        }
    }
}
