//! A minimal, dependency-free subset of the `anyhow` API.
//!
//! The build environment for this repo has no crates.io access, so this
//! vendored shim provides exactly the surface the crate uses: an opaque
//! [`Error`] convertible from any `std::error::Error`, the [`Result`]
//! alias, and the `anyhow!` / `bail!` / `ensure!` macros. Source-chaining
//! and backtraces are intentionally out of scope.

use std::fmt;

/// An opaque, type-erased error.
///
/// Deliberately does **not** implement `std::error::Error` so the blanket
/// `From<E: std::error::Error>` below does not overlap with the identity
/// `From` impl (the same design as the real `anyhow`).
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

/// A plain-message error (what `anyhow!("...")` produces).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for MessageError {}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Box::new(MessageError(message.to_string())))
    }

    /// The underlying boxed error.
    pub fn inner(&self) -> &(dyn std::error::Error + Send + Sync + 'static) {
        &*self.0
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints through Debug; show the
        // human-readable message there too.
        fmt::Display::fmt(&self.0, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(Box::new(e))
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message, a displayable value, or a
/// format string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broke: {}", 42)
    }

    #[test]
    fn macros_and_conversions() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke: 42");
        let io: Error = std::io::Error::new(std::io::ErrorKind::Other, "disk").into();
        assert_eq!(io.to_string(), "disk");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(from_string.to_string(), "plain");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }
}
