//! Fig. 6 — communication complexity vs m (|𝓕| = 10, K = 30,
//! m = 1..1000).
//!
//! Analytic curves from Table II plus *counted symbols* from real
//! coordinator rounds (the metrics registry records every f32 crossing a
//! master↔worker link) at a reduced grid.
//!
//! Paper shape: SPACDC ≈ BACC lowest; MatDot's worker→master upload
//! dominates everything (each worker returns a full m×m product).

use spacdc::analysis::CostModel;
use spacdc::bench::{banner, print_series};
use spacdc::coding::CodedTask;
use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::coordinator::MasterBuilder;
use spacdc::matrix::Matrix;
use spacdc::metrics::names;
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;

const F_RETURNED: usize = 10;
const K: usize = 30;
const MS_ANALYTIC: [usize; 5] = [100, 250, 500, 750, 1000];
const MS_MEASURED: [usize; 3] = [120, 360, 600];

fn measured_symbols(kind: SchemeKind, m: usize) -> Option<(f64, f64)> {
    let mut cfg = SystemConfig::default();
    cfg.workers = 36;
    cfg.partitions = if kind == SchemeKind::MatDot { 6 } else { K.min(m) };
    cfg.colluders = 2;
    cfg.stragglers = 4;
    cfg.scheme = kind;
    cfg.transport = TransportSecurity::Plain; // count raw symbols
    cfg.delay.base_service_s = 0.0;
    cfg.seed = 0xF166 + m as u64;
    let mut master = MasterBuilder::new(cfg).build().ok()?;
    let mut rng = rng_from_seed(1);
    let x = Matrix::random_gaussian(m, 64, 0.0, 1.0, &mut rng);
    let task = if kind == SchemeKind::MatDot {
        CodedTask::pair_product(x.clone(), x.transpose())
    } else {
        CodedTask::block_map(WorkerOp::Gram, x)
    };
    master.run(task).ok()?;
    Some((
        master.metrics().get(names::SYMBOLS_TO_WORKERS) as f64,
        master.metrics().get(names::SYMBOLS_TO_MASTER) as f64,
    ))
}

fn main() {
    banner("Fig. 6 — communication complexity vs m (|F|=10, K=30)");
    let schemes = [
        SchemeKind::Bacc,
        SchemeKind::Lcc,
        SchemeKind::Polynomial,
        SchemeKind::SecPoly,
        SchemeKind::MatDot,
        SchemeKind::Spacdc,
    ];

    println!("\nanalytic worker→master symbols (Table II):");
    print_series("m =", &MS_ANALYTIC.map(|m| m as f64));
    for kind in schemes {
        let series: Vec<f64> = MS_ANALYTIC
            .iter()
            .map(|&m| CostModel::new(m, m, K, 36, F_RETURNED).costs(kind).comm_to_master)
            .collect();
        print_series(kind.name(), &series);
    }

    println!("\ncounted symbols from live rounds (gram task, d=64):");
    println!("{:<12} {:>8} {:>16} {:>16}", "scheme", "m", "→workers", "→master");
    for kind in [SchemeKind::Spacdc, SchemeKind::Bacc, SchemeKind::Mds, SchemeKind::MatDot] {
        for &m in &MS_MEASURED {
            // MDS can't run a degree-2 gram; skip gracefully.
            if kind == SchemeKind::Mds {
                continue;
            }
            if let Some((down, up)) = measured_symbols(kind, m) {
                println!("{:<12} {:>8} {:>16.0} {:>16.0}", kind.name(), m, down, up);
            }
        }
    }
    println!(
        "\npaper shape: SPACDC ≈ BACC lowest upload; MatDot worst \
         (full m×m per worker)."
    );
}
