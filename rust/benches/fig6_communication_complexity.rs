//! Fig. 6 — communication complexity vs m (|𝓕| = 10, K = 30,
//! m = 1..1000).
//!
//! Three views, in decreasing abstraction:
//!
//! 1. analytic worker→master **symbol** curves from Table II;
//! 2. **counted symbols** from real coordinator rounds (the metrics
//!    registry records every f32 crossing a master↔worker link);
//! 3. **counted bytes** from the transport itself (`comm.bytes_tx` /
//!    `comm.bytes_rx`): every frame is serialized, so this is the honest
//!    wire load including framing, shapes, ops, and checksums — and it
//!    is identical whether the fabric is in-process channels or real
//!    localhost TCP sockets (the parity rows at the bottom).
//!
//! Paper shape: SPACDC ≈ BACC lowest; MatDot's worker→master upload
//! dominates everything (each worker returns a full m×m product).

use spacdc::analysis::CostModel;
use spacdc::bench::{banner, print_series};
use spacdc::coding::CodedTask;
use spacdc::config::{SchemeKind, SystemConfig, TransportKind, TransportSecurity};
use spacdc::coordinator::MasterBuilder;
use spacdc::matrix::Matrix;
use spacdc::metrics::names;
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;

const F_RETURNED: usize = 10;
const K: usize = 30;
const MS_ANALYTIC: [usize; 5] = [100, 250, 500, 750, 1000];
const MS_MEASURED: [usize; 3] = [120, 360, 600];

struct Measured {
    symbols_down: f64,
    symbols_up: f64,
    bytes_tx: f64,
    bytes_rx: f64,
}

fn measured_round(kind: SchemeKind, m: usize, transport: TransportKind) -> Option<Measured> {
    let mut cfg = SystemConfig::default();
    cfg.workers = 36;
    cfg.partitions = if kind == SchemeKind::MatDot { 6 } else { K.min(m) };
    cfg.colluders = 2;
    cfg.stragglers = 4;
    cfg.scheme = kind;
    cfg.transport = transport;
    cfg.security = TransportSecurity::Plain; // count raw symbols
    cfg.delay.base_service_s = 0.0;
    cfg.seed = 0xF166 + m as u64;
    let mut master = MasterBuilder::new(cfg).build().ok()?;
    let mut rng = rng_from_seed(1);
    let x = Matrix::random_gaussian(m, 64, 0.0, 1.0, &mut rng);
    let task = if kind == SchemeKind::MatDot {
        CodedTask::pair_product(x.clone(), x.transpose())
    } else {
        CodedTask::block_map(WorkerOp::Gram, x)
    };
    master.run(task).ok()?;
    let metrics = master.metrics();
    Some(Measured {
        symbols_down: metrics.get(names::SYMBOLS_TO_WORKERS) as f64,
        symbols_up: metrics.get(names::SYMBOLS_TO_MASTER) as f64,
        bytes_tx: metrics.get(names::BYTES_TX) as f64,
        bytes_rx: metrics.get(names::BYTES_RX) as f64,
    })
}

fn main() {
    banner("Fig. 6 — communication complexity vs m (|F|=10, K=30)");
    let schemes = [
        SchemeKind::Bacc,
        SchemeKind::Lcc,
        SchemeKind::Polynomial,
        SchemeKind::SecPoly,
        SchemeKind::MatDot,
        SchemeKind::Spacdc,
    ];

    println!("\nanalytic worker→master symbols (Table II):");
    print_series("m =", &MS_ANALYTIC.map(|m| m as f64));
    for kind in schemes {
        let series: Vec<f64> = MS_ANALYTIC
            .iter()
            .map(|&m| CostModel::new(m, m, K, 36, F_RETURNED).costs(kind).comm_to_master)
            .collect();
        print_series(kind.name(), &series);
    }

    println!("\ncounted from live rounds (gram task, d=64): symbols and transport bytes:");
    println!(
        "{:<12} {:>6} {:>14} {:>14} {:>14} {:>14}",
        "scheme", "m", "sym→workers", "sym→master", "bytes_tx", "bytes_rx"
    );
    for kind in [SchemeKind::Spacdc, SchemeKind::Bacc, SchemeKind::MatDot] {
        for &m in &MS_MEASURED {
            if let Some(r) = measured_round(kind, m, TransportKind::InProc) {
                println!(
                    "{:<12} {:>6} {:>14.0} {:>14.0} {:>14.0} {:>14.0}",
                    kind.name(),
                    m,
                    r.symbols_down,
                    r.symbols_up,
                    r.bytes_tx,
                    r.bytes_rx
                );
            }
        }
    }

    println!("\ntransport parity — identical frames over channels and TCP sockets:");
    println!("{:<12} {:>6} {:>14} {:>14}", "transport", "m", "bytes_tx", "bytes_rx");
    for transport in [TransportKind::InProc, TransportKind::Tcp] {
        if let Some(r) = measured_round(SchemeKind::Spacdc, 120, transport) {
            println!(
                "{:<12} {:>6} {:>14.0} {:>14.0}",
                transport.name(),
                120,
                r.bytes_tx,
                r.bytes_rx
            );
        }
    }

    println!(
        "\npaper shape: SPACDC ≈ BACC lowest upload; MatDot worst \
         (full m×m per worker). bytes_tx ≈ 4·symbols + framing; \
         bytes_rx counts exactly the results each decode consumed."
    );
}
