//! Table II — the full complexity comparison across the six schemes,
//! evaluated at a concrete parameter point plus empirical spot checks of
//! the two protection columns (security/privacy).

use spacdc::analysis::CostModel;
use spacdc::bench::banner;
use spacdc::coding::{make_scheme, CodeParams, CodedTask};
use spacdc::config::SchemeKind;
use spacdc::matrix::Matrix;
use spacdc::runtime::WorkerOp;

fn main() {
    banner("Table II — complexity comparison (m=d=1000, K=8, N=30, |F|=10)");
    let model = CostModel::new(1000, 1000, 8, 30, 10);
    println!(
        "\n{:<12} {:>12} {:>14} {:>14} {:>14} {:>14}  {:>4} {:>4}",
        "scheme", "encode", "decode", "→workers", "→master", "worker", "sec", "priv"
    );
    for kind in CostModel::table_ii_rows() {
        let c = model.costs(kind);
        println!(
            "{:<12} {:>12.3e} {:>14.3e} {:>14.3e} {:>14.3e} {:>14.3e}  {:>4} {:>4}",
            kind.name(),
            c.encoding,
            c.decoding,
            c.comm_to_workers,
            c.comm_to_master,
            c.worker_compute,
            if c.protects_security { "yes" } else { "no" },
            if c.protects_privacy { "yes" } else { "no" },
        );
    }

    println!("\nempirical protection columns (scheme implementations):");
    let params = CodeParams::new(30, 8, 3);
    let probe = CodedTask::block_map(WorkerOp::Identity, Matrix::ones(8, 8));
    for kind in [
        SchemeKind::Polynomial,
        SchemeKind::SecPoly,
        SchemeKind::Bacc,
        SchemeKind::Lcc,
        SchemeKind::Spacdc,
        SchemeKind::MatDot,
    ] {
        let s = make_scheme(kind, params);
        println!(
            "  {:<12} privacy masks: {}   threshold(deg1): {:?}",
            kind.name(),
            if s.is_private() { "yes (T blocks)" } else { "no" },
            s.threshold(&probe),
        );
    }
    println!(
        "\npaper row of interest: SPACDC matches BACC on every complexity \
         column while adding transmission security (MEA-ECC) and T-collusion \
         privacy — the only scheme with both 'yes' columns."
    );
}
