//! Fig. 3 — average training time of CONV-DL, MDS-DL, MATDOT-DL and
//! SPACDC-DL under N=30, T=3, S ∈ {0, 3, 5, 7}.
//!
//! The paper's claim: all four are comparable at S=0; as S grows the
//! baselines' training time climbs steeply (CONV waits for everyone,
//! MDS/MATDOT wait for their recovery thresholds against re-straggling
//! workers) while SPACDC-DL, which decodes from whatever returned, stays
//! nearly flat and wins by ≥ ~50% at S ≥ 5.
//!
//! Scaled to this testbed: thread workers with injected service delays
//! (base 2 ms, straggler factor 5×), a reduced step budget, and the
//! synthetic MNIST-like workload (DESIGN.md §3).

use spacdc::bench::banner;
use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::dl::{train, TrainerOptions};

fn scenario_cfg(scheme: SchemeKind, stragglers: usize) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 30;
    cfg.colluders = 3;
    cfg.stragglers = stragglers;
    cfg.partitions = 4;
    cfg.scheme = scheme;
    // Baselines run unencrypted (as in the paper); SPACDC pays for
    // MEA-ECC and still wins.
    cfg.security = if scheme == SchemeKind::Spacdc {
        TransportSecurity::MeaEcc
    } else {
        TransportSecurity::Plain
    };
    // Service time dominates master-local compute (the cluster regime
    // the paper measures): modest net + 4 ms worker service.
    cfg.delay.base_service_s = 0.004;
    cfg.delay.straggler_factor = 5.0;
    cfg.dl.layers = vec![256, 128, 64, 10];
    cfg.dl.batch_size = 64;
    cfg.dl.train_examples = 1024;
    cfg.dl.test_examples = 256;
    cfg.dl.epochs = 1;
    cfg.seed = 0xF1633;
    cfg
}

fn main() -> anyhow::Result<()> {
    banner("Fig. 3 — average training time vs stragglers (N=30, T=3)");
    let schemes = [
        SchemeKind::Uncoded,
        SchemeKind::Mds,
        SchemeKind::MatDot,
        SchemeKind::Spacdc,
    ];
    let scenarios = [0usize, 3, 5, 7];
    const STEPS: usize = 12;

    println!(
        "\n{:<12} {:>10} {:>10} {:>10} {:>10}   (seconds per {} steps)",
        "scheme", "S=0", "S=3", "S=5", "S=7", STEPS
    );
    let mut rows = Vec::new();
    for scheme in schemes {
        let mut row = Vec::new();
        for &s in &scenarios {
            let mut opts = TrainerOptions::new(scenario_cfg(scheme, s));
            opts.max_steps = Some(STEPS);
            opts.eval_each_epoch = false;
            let report = train(&opts)?;
            row.push(report.total_wall_s);
        }
        println!(
            "{:<12} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            scheme.name(),
            row[0],
            row[1],
            row[2],
            row[3]
        );
        rows.push((scheme, row));
    }

    // Paper-shape checks: SPACDC ≤ baselines for S ≥ 5; gap grows with S.
    let find = |k: SchemeKind| rows.iter().find(|(s, _)| *s == k).unwrap().1.clone();
    let spacdc = find(SchemeKind::Spacdc);
    let conv = find(SchemeKind::Uncoded);
    println!("\nSPACDC-DL saving vs CONV-DL:");
    for (i, &s) in scenarios.iter().enumerate() {
        let saving = 100.0 * (1.0 - spacdc[i] / conv[i]);
        println!("  S={s}: {saving:.1}%  (paper: ~52–65% at S ∈ {{5,7}})");
    }
    Ok(())
}
