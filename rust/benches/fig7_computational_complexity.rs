//! Fig. 7 — per-worker computational complexity vs K
//! (d = 1000, m = 5000, K = 1..36).
//!
//! Analytic Table II curves plus measured per-worker Gram time on the
//! actual share shapes each scheme hands its workers (scaled grid:
//! m = 1200, d = 128 keeps the bench under a minute).
//!
//! Paper shape: MatDot O(dm²/K) dominates everyone else's O(dm²/K²);
//! all the row-partition schemes coincide.

use spacdc::analysis::CostModel;
use spacdc::bench::{banner, black_box, print_series, run, BenchConfig};
use spacdc::config::SchemeKind;
use spacdc::matrix::{gram, matmul, Matrix};
use spacdc::rng::rng_from_seed;

const KS: [usize; 4] = [2, 4, 8, 16];
const M_MEAS: usize = 1200;
const D_MEAS: usize = 128;

fn main() {
    banner("Fig. 7 — per-worker computational complexity vs K (d=1000, m=5000)");
    let schemes = [
        SchemeKind::Bacc,
        SchemeKind::MatDot,
        SchemeKind::Polynomial,
        SchemeKind::Lcc,
        SchemeKind::SecPoly,
        SchemeKind::Spacdc,
    ];

    println!("\nanalytic per-worker ops (Table II):");
    print_series("K =", &KS.map(|k| k as f64));
    for kind in schemes {
        let series: Vec<f64> = KS
            .iter()
            .map(|&k| CostModel::new(5000, 1000, k, 30, 10).costs(kind).worker_compute)
            .collect();
        print_series(kind.name(), &series);
    }

    println!(
        "\nmeasured worker-task wall (ms) at m={M_MEAS}, d={D_MEAS} \
         (gram on the actual share shape):"
    );
    print_series("K =", &KS.map(|k| k as f64));
    // Row-partition schemes: share is (m/K × d); worker computes share·shareᵀ.
    let mut rng = rng_from_seed(0xF167);
    let row_series: Vec<f64> = KS
        .iter()
        .map(|&k| {
            let share = Matrix::random_gaussian(M_MEAS / k, D_MEAS, 0.0, 1.0, &mut rng);
            let r = run("gram", BenchConfig { warmup_iters: 1, iters: 3 }, |_| {
                black_box(gram(&share));
            });
            r.mean() * 1e3
        })
        .collect();
    print_series("row-partition (all)", &row_series);

    // MatDot: share pair is (m × d/K)·(d/K × m) → full m×m product.
    let matdot_series: Vec<f64> = KS
        .iter()
        .map(|&k| {
            let a = Matrix::random_gaussian(M_MEAS, D_MEAS / k, 0.0, 1.0, &mut rng);
            let b = Matrix::random_gaussian(D_MEAS / k, M_MEAS, 0.0, 1.0, &mut rng);
            let r = run("matdot", BenchConfig { warmup_iters: 1, iters: 3 }, |_| {
                black_box(matmul(&a, &b));
            });
            r.mean() * 1e3
        })
        .collect();
    print_series("MATDOT", &matdot_series);

    // Shape check: the MatDot/row-partition ratio should grow ~linearly
    // in K (O(dm²/K) vs O(dm²/K²)).
    println!("\nMATDOT / row-partition ratio (paper: grows ~K):");
    for (i, &k) in KS.iter().enumerate() {
        println!("  K={k}: {:.1}×", matdot_series[i] / row_series[i]);
    }
}
