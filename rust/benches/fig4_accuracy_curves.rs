//! Fig. 4 — test accuracy vs wall-clock for the four algorithms at
//! N=30, T=3, S ∈ {3, 5, 7}.
//!
//! Paper shape: SPACDC-DL reaches any given accuracy level in the least
//! wall-clock; CONV-DL is slowest; the gap widens with S. Reported here
//! as per-epoch (wall_s, accuracy) series plus the time-to-80% readout
//! the paper quotes.

use spacdc::bench::banner;
use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::dl::{train, TrainerOptions};

fn cfg_for(scheme: SchemeKind, stragglers: usize) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 30;
    cfg.colluders = 3;
    cfg.stragglers = stragglers;
    cfg.partitions = 4;
    cfg.scheme = scheme;
    cfg.security = if scheme == SchemeKind::Spacdc {
        TransportSecurity::MeaEcc
    } else {
        TransportSecurity::Plain
    };
    cfg.delay.base_service_s = 0.004;
    cfg.delay.straggler_factor = 5.0;
    // Smaller net so several epochs fit in bench time; the relative
    // per-step cost across schemes is what Fig. 4 measures.
    cfg.dl.layers = vec![256, 128, 64, 10];
    cfg.dl.batch_size = 64;
    cfg.dl.train_examples = 1024;
    cfg.dl.test_examples = 256;
    cfg.dl.epochs = 4;
    cfg.seed = 0xF164;
    cfg
}

fn main() -> anyhow::Result<()> {
    banner("Fig. 4 — test accuracy vs wall-clock (N=30, T=3)");
    let schemes = [
        SchemeKind::Uncoded,
        SchemeKind::Mds,
        SchemeKind::MatDot,
        SchemeKind::Spacdc,
    ];
    for s in [3usize, 5, 7] {
        println!("\n--- S = {s} ---");
        println!("{:<12} {}", "scheme", "(wall_s, accuracy) per epoch");
        let mut t80: Vec<(SchemeKind, Option<f64>)> = Vec::new();
        for scheme in schemes {
            let report = train(&TrainerOptions::new(cfg_for(scheme, s)))?;
            print!("{:<12}", scheme.name());
            for e in &report.epochs {
                print!(" ({:.2}, {:.3})", e.wall_s, e.accuracy);
            }
            println!();
            t80.push((scheme, report.time_to_accuracy(0.8)));
        }
        println!("time to 80% accuracy:");
        for (scheme, t) in &t80 {
            match t {
                Some(t) => println!("  {:<12} {t:.2}s", scheme.name()),
                None => println!("  {:<12} not reached", scheme.name()),
            }
        }
    }
    println!(
        "\npaper shape: SPACDC-DL fastest to any accuracy level; gap \
         widens with S (52–65% savings at S ∈ {{5,7}})."
    );
    Ok(())
}
