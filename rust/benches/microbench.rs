//! Microbenchmarks + ablations — the §Perf harness.
//!
//! Sections:
//! 1. Matrix substrate: naive vs packed/blocked matmul (the L3 hot-path
//!    optimization target).
//! 2. ECC layer: scalar multiplication, MEA-ECC seal/open throughput.
//! 3. Coding hot paths: SPACDC encode / decode at the DL shapes.
//! 4. Ablation: SPACDC mask_scale vs decode error and colluder leakage
//!    (the DESIGN.md §3 privacy/accuracy trade-off).

use spacdc::bench::{banner, black_box, header, run, BenchConfig};
use spacdc::coding::{BlockCode, CodeParams, Spacdc};
use spacdc::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use spacdc::matrix::{matmul, matmul_naive, split_rows, Matrix};
use spacdc::rng::rng_from_seed;

fn main() {
    banner("§Perf microbenchmarks");
    println!("{}", header());

    // ---- 1. matrix substrate -------------------------------------------
    let mut rng = rng_from_seed(0x3B);
    for n in [128usize, 256, 512] {
        let a = Matrix::random_gaussian(n, n, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(n, n, 0.0, 1.0, &mut rng);
        let naive = run(&format!("matmul_naive_{n}"), BenchConfig::quick(), |_| {
            black_box(matmul_naive(&a, &b));
        });
        let fast = run(&format!("matmul_packed_{n}"), BenchConfig::quick(), |_| {
            black_box(matmul(&a, &b));
        });
        println!("{}", naive.row());
        println!("{}", fast.row());
        println!(
            "  -> packed speedup at {n}: {:.2}x  (flops {:.2} GF/s)",
            naive.mean() / fast.mean(),
            2.0 * (n as f64).powi(3) / fast.mean() / 1e9
        );
    }

    // ---- 2. ECC / MEA-ECC ----------------------------------------------
    let curve = sim_curve();
    let master = KeyPair::generate(&curve, &mut rng);
    let worker = KeyPair::generate(&curve, &mut rng);
    let scalar_mul = run("ecc_scalar_mul_fp61", BenchConfig { warmup_iters: 3, iters: 30 }, |i| {
        black_box(curve.mul_u64(0x9E3779B9 + i as u64, &master.public()));
    });
    println!("{}", scalar_mul.row());

    let mea = MeaEcc::new(curve, MaskMode::Keystream);
    let payload = Matrix::random_gaussian(64, 128, 0.0, 1.0, &mut rng);
    let mut seal_rng = rng_from_seed(9);
    let seal = run("mea_seal_64x128", BenchConfig { warmup_iters: 2, iters: 20 }, |_| {
        black_box(mea.encrypt(&payload, &worker.public(), &mut seal_rng));
    });
    println!("{}", seal.row());
    let sealed = mea.encrypt(&payload, &worker.public(), &mut seal_rng);
    let open = run("mea_open_64x128", BenchConfig { warmup_iters: 2, iters: 20 }, |_| {
        black_box(mea.decrypt(&sealed, &worker));
    });
    println!("{}", open.row());
    println!(
        "  -> MEA-ECC throughput: seal {:.1} MB/s, open {:.1} MB/s",
        64.0 * 128.0 * 4.0 / seal.mean() / 1e6,
        64.0 * 128.0 * 4.0 / open.mean() / 1e6
    );

    // ---- 3. SPACDC encode/decode at the DL shapes ------------------------
    let scheme = Spacdc::new(CodeParams::new(30, 4, 3));
    let wt = Matrix::random_gaussian(256, 128, 0.0, 1.0, &mut rng);
    let mut enc_rng = rng_from_seed(10);
    let encode = run("spacdc_encode_256x128_n30", BenchConfig { warmup_iters: 2, iters: 15 }, |_| {
        black_box(scheme.encode_blocks(&wt, 1, &mut enc_rng).unwrap());
    });
    println!("{}", encode.row());
    let enc = scheme.encode_blocks(&wt, 1, &mut enc_rng).unwrap();
    let results: Vec<(usize, Matrix)> =
        (0..27).map(|i| (i, enc.shares[i].clone())).collect();
    let decode = run("spacdc_decode_27of30", BenchConfig { warmup_iters: 2, iters: 15 }, |_| {
        black_box(scheme.decode_blocks(&enc.ctx, &results).unwrap());
    });
    println!("{}", decode.row());

    // ---- 4. mask-scale ablation ------------------------------------------
    banner("ablation: SPACDC mask_scale vs decode error & colluder leakage");
    println!(
        "{:<12} {:>14} {:>22}",
        "mask_scale", "decode rel-err", "colluder attack err"
    );
    for &scale in &[0.25f32, 0.5, 1.0, 2.0, 4.0] {
        let scheme = Spacdc::with_mask_scale(CodeParams::new(30, 4, 3), scale);
        let mut rng = rng_from_seed(0xAB);
        let x = Matrix::random_gaussian(64, 32, 0.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let results: Vec<(usize, Matrix)> =
            (0..27).map(|i| (i, enc.shares[i].clone())).collect();
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
        let (blocks, _) = split_rows(&x, 4);
        let err = decoded
            .iter()
            .zip(&blocks)
            .map(|(d, b)| d.rel_error(b))
            .fold(0.0f64, f64::max);
        // Colluder attack: best single-share inversion toward block 0.
        let (data_pos, _) = Spacdc::node_layout(4, 3);
        let betas = scheme.betas();
        let signs: Vec<u32> = (0..7).collect();
        let mut attack = f64::INFINITY;
        for j in 0..3 {
            let w = spacdc::coding::interp::berrut_weights(&betas, &signs, enc.ctx.alphas[j]);
            let wb = w[data_pos[0]];
            if wb.abs() > 1e-6 {
                attack = attack.min(enc.shares[j].scale(1.0 / wb as f32).rel_error(&blocks[0]));
            }
        }
        println!("{scale:<12} {err:>14.4} {attack:>22.4}");
    }
    println!(
        "\nreading: error grows ~linearly with mask amplitude while the \
         best colluder attack degrades — pick mask_scale for the privacy \
         budget, not larger (DESIGN.md §3)."
    );
}
