//! Microbenchmarks + ablations — the §Perf harness, now machine-readable.
//!
//! Sections:
//! 1. Matrix substrate: naive vs packed/blocked/parallel matmul.
//! 2. ECC layer: scalar multiplication, MEA-ECC seal/open throughput
//!    (the wire's seal-the-bytes form).
//! 3. Coding hot paths: SPACDC encode / decode at the DL shapes.
//! 4. End-to-end sealed SPACDC round at n = 8 workers
//!    (encode + seal + worker compute + unseal + decode), serial
//!    (`threads = 1`) vs parallel (`threads = 8`), asserting the decode
//!    output is bit-identical across thread counts.
//! 5. SIMD kernels: each dispatched microkernel (GEMM row×panel,
//!    keystream XOR, weighted-sum axpy, batched Fp61 add) measured
//!    single-threaded at `Level::Scalar` vs the dispatched level via the
//!    `*_at` entry points — the per-kernel speedups the CI bench job
//!    gates at ≥ 2× on SIMD-capable hardware.
//! 6. Ablation: SPACDC mask_scale vs decode error and colluder leakage
//!    (full mode only).
//! 7. Saturation: 4 concurrent tenants streaming through one live
//!    8-worker fleet via the serving front end (DESIGN.md §12), each at
//!    a 4-wide session window under a 16-wide global cap, vs one tenant
//!    streaming the same total rounds at inflight 16 — aggregate
//!    `rounds_per_s` and per-tenant p99 land in BENCH.json and the CI
//!    bench job gates the aggregate against the self-arming baseline.
//!
//! Flags (after `cargo bench --bench microbench --`):
//! * `--smoke`        — small shapes / few iterations (the CI preset).
//! * `--json <path>`  — additionally write the measurements as JSON.
//!   CI runs `--smoke --json BENCH.json` and gates the job on the
//!   committed `BENCH_BASELINE.json` (see `ci/compare_bench.py`):
//!   GEMM GFLOP/s and seal/open MB/s may not regress more than 25%.

use spacdc::bench::{banner, black_box, header, run, BenchConfig};
use spacdc::coding::{BlockCode, CodeParams, CodedTask, Spacdc};
use spacdc::config::{SchemeKind, SystemConfig};
use spacdc::coordinator::{Master, SealedPayload, ServiceConfig, SessionOptions, StreamConfig};
use spacdc::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use spacdc::field::fp61::{batch, P61};
use spacdc::field::Fp61;
use spacdc::matrix::{gram, matmul, matmul_naive, split_rows, Matrix};
use spacdc::parallel;
use spacdc::rng::{derive_seed, rng_from_seed};
use spacdc::runtime::WorkerOp;
use spacdc::simd::{self, axpy, fp61x, gemm, keystream, Level};
use std::time::Instant;

struct GemmRow {
    n: usize,
    naive_ms: f64,
    packed_ms: f64,
    gflops: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    banner(if smoke { "§Perf microbenchmarks (smoke)" } else { "§Perf microbenchmarks" });
    parallel::configure(0); // auto width for the kernel benches
    println!("available cores: {cores}, pool width: {}", parallel::configured_threads());
    println!("{}", header());

    // ---- 1. matrix substrate -------------------------------------------
    let mut rng = rng_from_seed(0x3B);
    let gemm_sizes: &[usize] = if smoke { &[64, 128] } else { &[128, 256, 512] };
    let gemm_cfg =
        if smoke { BenchConfig { warmup_iters: 1, iters: 2 } } else { BenchConfig::quick() };
    let mut gemm_rows = Vec::new();
    for &n in gemm_sizes {
        let a = Matrix::random_gaussian(n, n, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(n, n, 0.0, 1.0, &mut rng);
        let naive = run(&format!("matmul_naive_{n}"), gemm_cfg, |_| {
            black_box(matmul_naive(&a, &b));
        });
        let fast = run(&format!("matmul_packed_{n}"), gemm_cfg, |_| {
            black_box(matmul(&a, &b));
        });
        println!("{}", naive.row());
        println!("{}", fast.row());
        let gflops = 2.0 * (n as f64).powi(3) / fast.mean() / 1e9;
        println!(
            "  -> packed speedup at {n}: {:.2}x  (flops {gflops:.2} GF/s)",
            naive.mean() / fast.mean(),
        );
        gemm_rows.push(GemmRow {
            n,
            naive_ms: naive.mean() * 1e3,
            packed_ms: fast.mean() * 1e3,
            gflops,
        });
    }

    // ---- 2. ECC / MEA-ECC ----------------------------------------------
    let curve = sim_curve();
    let master = KeyPair::generate(&curve, &mut rng);
    let worker = KeyPair::generate(&curve, &mut rng);
    let scalar_mul = run("ecc_scalar_mul_fp61", BenchConfig { warmup_iters: 3, iters: 30 }, |i| {
        black_box(curve.mul_u64(0x9E3779B9 + i as u64, &master.public()));
    });
    println!("{}", scalar_mul.row());

    let mea = MeaEcc::new(curve, MaskMode::Keystream);
    let (sr, sc) = if smoke { (128usize, 128usize) } else { (512usize, 512usize) };
    let payload = Matrix::random_gaussian(sr, sc, 0.0, 1.0, &mut rng);
    let seal_bytes = (sr * sc * 4) as f64;
    let mut seal_rng = rng_from_seed(9);
    let ecc_cfg = BenchConfig { warmup_iters: 2, iters: if smoke { 5 } else { 20 } };
    let seal = run(&format!("mea_seal_bytes_{sr}x{sc}"), ecc_cfg, |_| {
        black_box(SealedPayload::seal(&mea, &payload, &worker.public(), &mut seal_rng));
    });
    println!("{}", seal.row());
    let sealed = SealedPayload::seal(&mea, &payload, &worker.public(), &mut seal_rng);
    let open = run(&format!("mea_open_bytes_{sr}x{sc}"), ecc_cfg, |_| {
        black_box(sealed.open(&mea, &worker).unwrap());
    });
    println!("{}", open.row());
    let seal_mb_s = seal_bytes / seal.mean() / 1e6;
    let open_mb_s = seal_bytes / open.mean() / 1e6;
    println!("  -> MEA-ECC throughput: seal {seal_mb_s:.1} MB/s, open {open_mb_s:.1} MB/s");

    // ---- 3. SPACDC encode/decode at the DL shapes ------------------------
    let (dn, dk, dt, drows, dcols, drets) =
        if smoke { (12, 4, 2, 64, 64, 10) } else { (30, 4, 3, 256, 128, 27) };
    let scheme = Spacdc::new(CodeParams::new(dn, dk, dt));
    let wt = Matrix::random_gaussian(drows, dcols, 0.0, 1.0, &mut rng);
    let mut enc_rng = rng_from_seed(10);
    let code_cfg = BenchConfig { warmup_iters: 2, iters: if smoke { 5 } else { 15 } };
    let encode = run(&format!("spacdc_encode_{drows}x{dcols}_n{dn}"), code_cfg, |_| {
        black_box(scheme.encode_blocks(&wt, 1, &mut enc_rng).unwrap());
    });
    println!("{}", encode.row());
    let enc = scheme.encode_blocks(&wt, 1, &mut enc_rng).unwrap();
    let results: Vec<(usize, Matrix)> =
        (0..drets).map(|i| (i, enc.shares[i].clone())).collect();
    let decode = run(&format!("spacdc_decode_{drets}of{dn}"), code_cfg, |_| {
        black_box(scheme.decode_blocks(&enc.ctx, &results).unwrap());
    });
    println!("{}", decode.row());

    // ---- 4. end-to-end sealed round: serial vs parallel ------------------
    // Always the acceptance-criterion shape (512×512, n = 8) so the JSON
    // artifact measures the real thing even in smoke mode — one round is
    // ~100 ms serial, cheap enough for CI. Note the measured speedup is
    // bounded by the runner's core count (recorded as available_cores).
    banner("end-to-end sealed SPACDC round, n=8: threads=1 vs threads=8");
    let (rr, rc) = (512usize, 512usize);
    let round_iters = if smoke { 2 } else { 3 };
    let (serial_s, decoded_serial) = best_round(1, rr, rc, round_iters);
    let (parallel_s, decoded_parallel) = best_round(8, rr, rc, round_iters);
    parallel::configure(0);
    let bit_identical = decoded_serial.len() == decoded_parallel.len()
        && decoded_serial
            .iter()
            .zip(&decoded_parallel)
            .all(|(a, b)| a.as_slice().len() == b.as_slice().len()
                && a.as_slice().iter().zip(b.as_slice()).all(|(x, y)| x.to_bits() == y.to_bits()));
    let speedup = serial_s / parallel_s;
    println!(
        "round {rr}x{rc}: threads=1 {:.2}ms, threads=8 {:.2}ms  -> {speedup:.2}x, decode bit-identical: {bit_identical}",
        serial_s * 1e3,
        parallel_s * 1e3
    );
    assert!(bit_identical, "decode output must not depend on the thread count");

    // ---- 5. SIMD kernels: scalar oracle vs dispatched level --------------
    // Single-threaded, via the explicit-level `*_at` entry points, on
    // identical seeded inputs — the kernel speedup itself, with no pool
    // or curve work diluting it.
    let active = simd::level();
    banner(&format!(
        "SIMD kernels: scalar oracle vs dispatched level ({})",
        active.name()
    ));
    let simd_cfg = BenchConfig { warmup_iters: 2, iters: if smoke { 5 } else { 20 } };
    let gemm_scalar_gflops = bench_simd_gemm(Level::Scalar, simd_cfg);
    let gemm_simd_gflops = if active == Level::Scalar {
        gemm_scalar_gflops
    } else {
        bench_simd_gemm(active, simd_cfg)
    };
    let ks_scalar_mb_s = bench_simd_keystream(Level::Scalar, simd_cfg);
    let ks_simd_mb_s = if active == Level::Scalar {
        ks_scalar_mb_s
    } else {
        bench_simd_keystream(active, simd_cfg)
    };
    let axpy_scalar_gb_s = bench_simd_axpy(Level::Scalar, simd_cfg);
    let axpy_simd_gb_s = if active == Level::Scalar {
        axpy_scalar_gb_s
    } else {
        bench_simd_axpy(active, simd_cfg)
    };
    let fp61_scalar_mops = bench_simd_fp61_add(Level::Scalar, simd_cfg);
    let fp61_simd_mops = if active == Level::Scalar {
        fp61_scalar_mops
    } else {
        bench_simd_fp61_add(active, simd_cfg)
    };
    let fp61_mul_mops = bench_fp61_mul(simd_cfg);
    println!(
        "  -> {} vs scalar: gemm {:.2}x ({gemm_simd_gflops:.2} GF/s), keystream {:.2}x \
         ({ks_simd_mb_s:.0} MB/s), axpy {:.2}x, fp61-add {:.2}x (mul stays scalar: {fp61_mul_mops:.0} Mops)",
        active.name(),
        gemm_simd_gflops / gemm_scalar_gflops,
        ks_simd_mb_s / ks_scalar_mb_s,
        axpy_simd_gb_s / axpy_scalar_gb_s,
        fp61_simd_mops / fp61_scalar_mops,
    );

    // ---- 6. mask-scale ablation ------------------------------------------
    if !smoke {
        mask_scale_ablation();
    }

    // ---- 7. multi-tenant saturation --------------------------------------
    banner("saturation: 4 tenants × inflight 4 vs 1 tenant × inflight 16, one fleet");
    let sat = bench_saturation(smoke);
    let p99_worst = sat.p99_ms.iter().fold(0.0f64, |a, &b| a.max(b));
    println!(
        "{} rounds through 8 workers: single-tenant {:.2} rounds/s, 4 tenants {:.2} rounds/s \
         ({:.2}x), per-tenant p99 {:?} ms",
        sat.rounds,
        sat.single_rounds_per_s,
        sat.rounds_per_s,
        sat.rounds_per_s / sat.single_rounds_per_s,
        sat.p99_ms.iter().map(|p| (p * 100.0).round() / 100.0).collect::<Vec<_>>(),
    );
    println!(
        "weighted 2:1 pair under a 4-wide cap: heavy lane took {:.1}% of the merged \
         dispatch stream (ideal 66.7%, fairness {:.3})",
        sat.weighted_heavy_share * 100.0,
        sat.weighted_fairness,
    );

    // ---- JSON artifact ---------------------------------------------------
    if let Some(path) = json_path {
        let gemm_json: Vec<String> = gemm_rows
            .iter()
            .map(|g| {
                format!(
                    "{{\"n\": {}, \"naive_ms\": {:.4}, \"packed_ms\": {:.4}, \"speedup\": {:.3}, \"gflops\": {:.3}}}",
                    g.n,
                    g.naive_ms,
                    g.packed_ms,
                    g.naive_ms / g.packed_ms,
                    g.gflops
                )
            })
            .collect();
        let simd_json = format!(
            "{{\"active\": \"{}\", \
             \"gemm\": {{\"scalar_gflops\": {:.3}, \"simd_gflops\": {:.3}, \"speedup\": {:.3}}}, \
             \"keystream\": {{\"scalar_mb_s\": {:.2}, \"simd_mb_s\": {:.2}, \"speedup\": {:.3}}}, \
             \"axpy\": {{\"scalar_gb_s\": {:.3}, \"simd_gb_s\": {:.3}, \"speedup\": {:.3}}}, \
             \"fp61\": {{\"scalar_add_mops\": {:.1}, \"simd_add_mops\": {:.1}, \"speedup\": {:.3}, \"mul_mops\": {:.1}}}}}",
            active.name(),
            gemm_scalar_gflops,
            gemm_simd_gflops,
            gemm_simd_gflops / gemm_scalar_gflops,
            ks_scalar_mb_s,
            ks_simd_mb_s,
            ks_simd_mb_s / ks_scalar_mb_s,
            axpy_scalar_gb_s,
            axpy_simd_gb_s,
            axpy_simd_gb_s / axpy_scalar_gb_s,
            fp61_scalar_mops,
            fp61_simd_mops,
            fp61_simd_mops / fp61_scalar_mops,
            fp61_mul_mops,
        );
        let json = format!(
            "{{\n  \"schema\": \"spacdc-microbench-v1\",\n  \"smoke\": {smoke},\n  \"available_cores\": {cores},\n  \
             \"gemm\": [{}],\n  \
             \"seal\": {{\"rows\": {sr}, \"cols\": {sc}, \"seal_ms\": {:.4}, \"open_ms\": {:.4}, \"seal_mb_s\": {:.2}, \"open_mb_s\": {:.2}}},\n  \
             \"decode\": {{\"scheme\": \"spacdc\", \"workers\": {dn}, \"returns\": {drets}, \"rows\": {drows}, \"cols\": {dcols}, \"encode_ms\": {:.4}, \"decode_ms\": {:.4}}},\n  \
             \"round\": {{\"scheme\": \"spacdc\", \"workers\": 8, \"rows\": {rr}, \"cols\": {rc}, \"threads_1_ms\": {:.3}, \"threads_8_ms\": {:.3}, \"speedup\": {:.3}, \"decode_bit_identical\": {bit_identical}}},\n  \
             \"simd\": {simd_json},\n  \
             \"saturation\": {{\"tenants\": {}, \"rounds\": {}, \"global_inflight\": 16, \
             \"tenant_inflight\": 4, \"rounds_per_s\": {:.3}, \"single_rounds_per_s\": {:.3}, \
             \"speedup\": {:.3}, \"p99_ms\": [{}], \"p99_worst_ms\": {:.3}, \
             \"weighted\": {{\"weights\": [2, 1], \"heavy_share\": {:.4}, \"fairness\": {:.4}}}}}\n}}\n",
            gemm_json.join(", "),
            seal.mean() * 1e3,
            open.mean() * 1e3,
            seal_mb_s,
            open_mb_s,
            encode.mean() * 1e3,
            decode.mean() * 1e3,
            serial_s * 1e3,
            parallel_s * 1e3,
            speedup,
            sat.tenants,
            sat.rounds,
            sat.rounds_per_s,
            sat.single_rounds_per_s,
            sat.rounds_per_s / sat.single_rounds_per_s,
            sat.p99_ms.iter().map(|p| format!("{p:.3}")).collect::<Vec<_>>().join(", "),
            p99_worst,
            sat.weighted_heavy_share,
            sat.weighted_fairness,
        );
        std::fs::write(&path, &json).expect("write bench JSON");
        println!("\nwrote {path}");
    }
}

/// GEMM row×panel kernel at one level, single-threaded: 64 A rows
/// against a 256-row packed panel at k = 256 (a COL_BLOCK-aligned
/// shape). Returns GFLOP/s.
fn bench_simd_gemm(level: Level, cfg: BenchConfig) -> f64 {
    let (r, k, c) = (64usize, 256usize, 256usize);
    let mut rng = rng_from_seed(0x51D0);
    let a = Matrix::random_gaussian(r, k, 0.0, 1.0, &mut rng);
    let panel = Matrix::random_gaussian(c, k, 0.0, 1.0, &mut rng);
    let mut out = vec![0f32; r * c];
    let res = run(&format!("simd_gemm_row_panel_{}", level.name()), cfg, |_| {
        for i in 0..r {
            gemm::row_panel_at(level, a.row(i), panel.as_slice(), k, &mut out[i * c..(i + 1) * c]);
        }
        black_box(&mut out);
    });
    println!("{}", res.row());
    2.0 * (r * k * c) as f64 / res.mean() / 1e9
}

/// Keystream byte-XOR over a 1 MiB buffer (the seal/open-the-bytes
/// kernel). Returns MB/s. Each iteration re-masks the same buffer —
/// identical work either way, since XOR is self-inverse.
fn bench_simd_keystream(level: Level, cfg: BenchConfig) -> f64 {
    let mut buf: Vec<u8> = (0..1usize << 20).map(|i| (i * 13 + 5) as u8).collect();
    let len = buf.len() as f64;
    let res = run(&format!("simd_keystream_xor_1mib_{}", level.name()), cfg, |_| {
        keystream::xor_in_place_at(level, &mut buf, 0x5EA1);
        black_box(&mut buf);
    });
    println!("{}", res.row());
    len / res.mean() / 1e6
}

/// Weighted-sum axpy over 256 Ki f32 (one decode chunk's worth of
/// accumulation, 64× over). Returns GB/s counting src read + out
/// read/write. Alternating weight sign keeps the accumulator bounded.
fn bench_simd_axpy(level: Level, cfg: BenchConfig) -> f64 {
    let n = 1usize << 18;
    let mut rng = rng_from_seed(0x51D1);
    let src: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let mut out = vec![0f32; n];
    let res = run(&format!("simd_axpy_256k_{}", level.name()), cfg, |i| {
        let w = if i % 2 == 0 { 0.5f32 } else { -0.5f32 };
        axpy::axpy_at(level, &mut out, &src, w);
        black_box(&mut out);
    });
    println!("{}", res.row());
    12.0 * n as f64 / res.mean() / 1e9
}

/// Batched Fp61 modular add over 64 Ki limbs. Returns Mops (field adds
/// per second / 1e6). Canonical values stay canonical, so the same
/// buffers feed every iteration.
fn bench_simd_fp61_add(level: Level, cfg: BenchConfig) -> f64 {
    let n = 1usize << 16;
    let mut rng = rng_from_seed(0x51D2);
    let mut a: Vec<u64> = (0..n).map(|_| rng.next_u64() % P61).collect();
    let b: Vec<u64> = (0..n).map(|_| rng.next_u64() % P61).collect();
    let res = run(&format!("simd_fp61_add_64k_{}", level.name()), cfg, |_| {
        fp61x::add_assign_at(level, &mut a, &b);
        black_box(&mut a);
    });
    println!("{}", res.row());
    n as f64 / res.mean() / 1e6
}

/// Batched Fp61 multiply (scalar at every level — recorded for the
/// record, not gated). Returns Mops.
fn bench_fp61_mul(cfg: BenchConfig) -> f64 {
    let n = 1usize << 16;
    let mut rng = rng_from_seed(0x51D3);
    let mut a: Vec<u64> = (0..n).map(|_| rng.next_u64() % P61).collect();
    let b: Vec<u64> = (0..n).map(|_| 1 + rng.next_u64() % (P61 - 1)).collect();
    let res = run("fp61_mul_64k_scalar", cfg, |_| {
        batch::mul_assign(&mut a, &b);
        black_box(&mut a);
    });
    println!("{}", res.row());
    n as f64 / res.mean() / 1e6
}

/// One full sealed SPACDC round at a fixed pool width, modeled exactly
/// like the live system: parallel encode fan-out, parallel per-worker
/// seal fan-out, the 8 workers in parallel (each worker's open → Gram →
/// re-seal runs on one pool thread; its inner kernels degrade to serial
/// there, as on a real worker node), serial collector-style unseal, and
/// the row-chunked parallel decode. All RNGs are derived, so the decode
/// output is a pure function of the inputs — compared bit-for-bit
/// between widths by the caller.
fn sealed_round(threads: usize, rows: usize, cols: usize) -> (f64, Vec<Matrix>) {
    parallel::configure(threads);
    let (n, k, t) = (8usize, 4usize, 2usize);
    let scheme = Spacdc::new(CodeParams::new(n, k, t));
    let curve = sim_curve();
    let mea = MeaEcc::new(curve, MaskMode::Keystream);
    let worker_keys: Vec<KeyPair<Fp61>> = (0..n)
        .map(|w| KeyPair::generate(&curve, &mut rng_from_seed(derive_seed(0xBEEF, w as u64))))
        .collect();
    let master_keys = KeyPair::generate(&curve, &mut rng_from_seed(0xAB));
    let x = Matrix::random_gaussian(rows, cols, 0.0, 1.0, &mut rng_from_seed(0x5EED));

    let t0 = Instant::now();
    // Master: encode (per-share fan-out) + seal (per-worker fan-out).
    let enc = scheme.encode_blocks(&x, 2, &mut rng_from_seed(1)).unwrap();
    let ctx = enc.ctx;
    let pool = parallel::global();
    let worker_pks: Vec<_> = worker_keys.iter().map(|kp| kp.public()).collect();
    let sealed: Vec<SealedPayload> = pool.map_vec(enc.shares, |w, share| {
        let mut srng = rng_from_seed(derive_seed(2, w as u64));
        SealedPayload::seal(&mea, &share, &worker_pks[w], &mut srng)
    });
    // Workers: open, compute f = Gram, re-seal to the master.
    let master_pk = master_keys.public();
    let result_payloads: Vec<SealedPayload> = pool.map_vec(sealed, |w, s| {
        let share = s.open_owned(&mea, &worker_keys[w]).unwrap();
        let y = gram(&share);
        let mut srng = rng_from_seed(derive_seed(3, w as u64));
        SealedPayload::seal(&mea, &y, &master_pk, &mut srng)
    });
    // Master: unseal results (serial, like the collector thread), decode.
    let results: Vec<(usize, Matrix)> = result_payloads
        .into_iter()
        .enumerate()
        .map(|(w, s)| (w, s.open_owned(&mea, &master_keys).unwrap()))
        .collect();
    let decoded = scheme.decode_blocks(&ctx, &results).unwrap();
    (t0.elapsed().as_secs_f64(), decoded)
}

/// Best-of-`iters` wall time for the sealed round at one width (plus one
/// untimed warmup); returns the decode output for the bit-identity check.
fn best_round(threads: usize, rows: usize, cols: usize, iters: usize) -> (f64, Vec<Matrix>) {
    let _ = sealed_round(threads, rows, cols); // warmup
    let mut best = f64::INFINITY;
    let mut out = Vec::new();
    for _ in 0..iters {
        let (secs, decoded) = sealed_round(threads, rows, cols);
        if secs < best {
            best = secs;
        }
        out = decoded;
    }
    (best, out)
}

struct SaturationRow {
    tenants: usize,
    rounds: usize,
    rounds_per_s: f64,
    single_rounds_per_s: f64,
    p99_ms: Vec<f64>,
    /// Measured dispatch-bandwidth share of a weight-2 lane racing a
    /// weight-1 lane (ideal: 2/3).
    weighted_heavy_share: f64,
    /// Proportionality of that split: `min(share, ideal) /
    /// max(share, ideal)` — 1.0 is a perfect 2:1 split, and a broken
    /// weighted scheduler drags it toward 0.5 (equal split) or below.
    weighted_fairness: f64,
}

/// Section 7: the same total round count through one live 8-worker
/// fleet, first as one `run_stream` tenant at inflight 16, then as 4
/// session tenants at inflight 4 under a 16-wide global cap — equal
/// total in-flight either way, so the aggregate throughput isolates
/// the serving front end's multiplexing cost (which the CI bench job
/// gates at ≥ 0.9× the single-tenant stream).
fn bench_saturation(smoke: bool) -> SaturationRow {
    parallel::configure(0);
    let tenants = 4usize;
    let per_tenant = if smoke { 4 } else { 16 };
    let total = tenants * per_tenant;
    let (rows, cols) = if smoke { (48usize, 24usize) } else { (128usize, 64usize) };
    let mut cfg = SystemConfig::default();
    cfg.workers = 8;
    cfg.partitions = 4;
    cfg.colluders = 2;
    cfg.stragglers = 0;
    cfg.scheme = SchemeKind::Spacdc;
    cfg.delay.base_service_s = 0.0;
    cfg.use_pjrt = false;
    let tasks = |seed: u64, n: usize| -> Vec<CodedTask> {
        let mut rng = rng_from_seed(seed);
        (0..n)
            .map(|_| {
                let x = Matrix::random_gaussian(rows, cols, 0.0, 1.0, &mut rng);
                CodedTask::block_map(WorkerOp::Gram, x)
            })
            .collect()
    };

    let mut master = Master::from_config(cfg.clone()).expect("saturation fleet");
    let single = master
        .run_stream(tasks(0x5A70, total), StreamConfig { inflight: 16, speculate: false })
        .expect("single-tenant stream");
    assert!(single.rounds.iter().all(|r| r.outcome.is_ok()));
    drop(master);

    let mut master = Master::from_config(cfg.clone()).expect("saturation fleet");
    let mut svc = master.service(ServiceConfig { global_inflight: 16, speculate: false });
    for t in 0..tenants {
        let seed = derive_seed(0x5A71, t as u64);
        svc.open_iter(
            &format!("tenant-{t}"),
            SessionOptions { inflight: 4, seed: Some(seed), ..Default::default() },
            tasks(seed, per_tenant).into_iter(),
        );
    }
    let out = svc.run();
    assert_eq!(out.decoded(), total, "every tenant round must decode");

    // Weighted leg: a 2:1 pair of saturated lanes under a tight global
    // cap. Round ids are global and monotone in dispatch order, so the
    // heavy lane's last dispatch measures its share of the merged
    // stream while both lanes were busy (ideal 2/3).
    let mut master = Master::from_config(cfg).expect("saturation fleet");
    let mut svc = master.service(ServiceConfig { global_inflight: 4, speculate: false });
    let per_lane = total / 2;
    let heavy = svc.open_iter(
        "heavy",
        SessionOptions { inflight: 4, weight: 2, seed: Some(0x5A72), ..Default::default() },
        tasks(0x5A72, per_lane).into_iter(),
    );
    svc.open_iter(
        "light",
        SessionOptions { inflight: 4, weight: 1, seed: Some(0x5A73), ..Default::default() },
        tasks(0x5A73, per_lane).into_iter(),
    );
    let weighted = svc.run();
    assert_eq!(weighted.decoded(), total, "every weighted round must decode");
    let heavy_last =
        weighted.rounds[heavy].iter().map(|r| r.round).max().unwrap_or(1).max(1) as f64;
    let heavy_share = per_lane as f64 / heavy_last;
    let ideal = 2.0 / 3.0;
    let fairness = (heavy_share.min(ideal)) / (heavy_share.max(ideal));

    SaturationRow {
        tenants,
        rounds: total,
        rounds_per_s: out.rounds_per_s,
        single_rounds_per_s: single.rounds_per_s,
        p99_ms: out.tenants.iter().map(|t| t.p99_ms).collect(),
        weighted_heavy_share: heavy_share,
        weighted_fairness: fairness,
    }
}

fn mask_scale_ablation() {
    banner("ablation: SPACDC mask_scale vs decode error & colluder leakage");
    println!(
        "{:<12} {:>14} {:>22}",
        "mask_scale", "decode rel-err", "colluder attack err"
    );
    for &scale in &[0.25f32, 0.5, 1.0, 2.0, 4.0] {
        let scheme = Spacdc::with_mask_scale(CodeParams::new(30, 4, 3), scale);
        let mut rng = rng_from_seed(0xAB);
        let x = Matrix::random_gaussian(64, 32, 0.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let results: Vec<(usize, Matrix)> =
            (0..27).map(|i| (i, enc.shares[i].clone())).collect();
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
        let (blocks, _) = split_rows(&x, 4);
        let err = decoded
            .iter()
            .zip(&blocks)
            .map(|(d, b)| d.rel_error(b))
            .fold(0.0f64, f64::max);
        // Colluder attack: best single-share inversion toward block 0.
        let (data_pos, _) = Spacdc::node_layout(4, 3);
        let betas = scheme.betas();
        let signs: Vec<u32> = (0..7).collect();
        let mut attack = f64::INFINITY;
        for j in 0..3 {
            let w = spacdc::coding::interp::berrut_weights(&betas, &signs, enc.ctx.alphas[j]);
            let wb = w[data_pos[0]];
            if wb.abs() > 1e-6 {
                attack = attack.min(enc.shares[j].scale(1.0 / wb as f32).rel_error(&blocks[0]));
            }
        }
        println!("{scale:<12} {err:>14.4} {attack:>22.4}");
    }
    println!(
        "\nreading: error grows ~linearly with mask amplitude while the \
         best colluder attack degrades — pick mask_scale for the privacy \
         budget, not larger (DESIGN.md §3)."
    );
}
