//! Fig. 5 — decoding complexity vs K (m = 1000, K = 1..36).
//!
//! Two views per scheme:
//! * the paper's closed-form curve (Table II, `analysis::complexity`);
//! * measured wall-clock of this repo's actual decoders at matching
//!   parameters (N = 40 workers, |𝓕| = N − 4 returns, d = 32).
//!
//! Paper shape: SPACDC ≈ BACC lowest and flat in K; LCC below the
//! polynomial-interpolation family; MatDot highest.

use spacdc::analysis::CostModel;
use spacdc::bench::{banner, black_box, print_series};
use spacdc::coding::{make_scheme, CodeParams, CodedTask, MatDot};
use spacdc::config::SchemeKind;
use spacdc::matrix::Matrix;
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;
use std::time::Instant;

const M: usize = 1000;
const D: usize = 32;
const N: usize = 40;
const KS: [usize; 5] = [2, 4, 8, 12, 16];

fn measured_decode_s(kind: SchemeKind, k: usize) -> Option<f64> {
    let mut rng = rng_from_seed(0xF165 + k as u64);
    let x = Matrix::random_gaussian(M, D, 0.0, 1.0, &mut rng);
    let returns = N - 4;
    if kind == SchemeKind::MatDot {
        let code = MatDot::new(N, k).ok()?;
        let enc = code.encode_pair(&x, &x.transpose()).ok()?;
        let results: Vec<(usize, Matrix)> = (0..code.recovery_threshold().min(returns))
            .map(|i| (i, MatDot::worker_compute(&enc.shares[i])))
            .collect();
        let t0 = Instant::now();
        black_box(code.decode_pair(&enc, &results).ok()?);
        return Some(t0.elapsed().as_secs_f64());
    }
    // Row-partition schemes through the unified task API: an identity
    // block map isolates decode cost from worker compute.
    let params = CodeParams::new(N, k, 2);
    let scheme = make_scheme(kind, params);
    let task = CodedTask::block_map(WorkerOp::Identity, x);
    let job = scheme.encode(&task, &mut rng).ok()?;
    let need = match scheme.threshold(&task) {
        spacdc::coding::Threshold::Exact(t) => t,
        spacdc::coding::Threshold::Flexible { .. } => returns,
    };
    if need > N {
        return None;
    }
    let results: Vec<(usize, Matrix)> =
        (0..need).map(|i| (i, job.payloads[i][0].clone())).collect();
    let t0 = Instant::now();
    black_box(scheme.decode(&job.ctx, &results).ok()?);
    Some(t0.elapsed().as_secs_f64())
}

fn main() {
    banner("Fig. 5 — decoding complexity vs K (m=1000)");
    let schemes = [
        SchemeKind::Bacc,
        SchemeKind::Lcc,
        SchemeKind::Polynomial,
        SchemeKind::SecPoly,
        SchemeKind::MatDot,
        SchemeKind::Spacdc,
    ];

    println!("\nanalytic (Table II formulas), ops:");
    print_series("K =", &KS.map(|k| k as f64));
    for kind in schemes {
        let series: Vec<f64> = KS
            .iter()
            .map(|&k| CostModel::new(M, M, k, N, N - 4).costs(kind).decoding)
            .collect();
        print_series(kind.name(), &series);
    }

    println!("\nmeasured decode wall-time (ms), this repo's decoders:");
    print_series("K =", &KS.map(|k| k as f64));
    for kind in schemes {
        let series: Vec<f64> = KS
            .iter()
            .map(|&k| measured_decode_s(kind, k).map(|s| s * 1e3).unwrap_or(f64::NAN))
            .collect();
        print_series(kind.name(), &series);
    }
    println!(
        "\npaper shape: SPACDC ≈ BACC lowest/flat; MatDot highest; \
         LCC < Polynomial/SecPoly."
    );
}
