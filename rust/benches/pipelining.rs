//! Round pipelining — the split-phase `submit`/`wait` API vs sequential
//! `run` calls.
//!
//! With one round in flight the master idles while workers serve their
//! (simulated) compute time, and workers idle while the master
//! encodes/seals/decodes. Submitting R rounds before waiting overlaps
//! the master-side work of round r+1 with the workers' service time of
//! round r, so R pipelined rounds finish in less wall-clock than the
//! same R rounds run back-to-back — the first step toward the batched /
//! async serving story.
//!
//! Setup: SPACDC, N=12 (S=2 stragglers at 5×), MEA-ECC sealed transport
//! (so the master-side seal/unseal cost is realistic), 10 ms simulated
//! worker service time, 512×256 data.

use spacdc::bench::banner;
use spacdc::coding::CodedTask;
use spacdc::config::{SchemeKind, SystemConfig};
use spacdc::coordinator::Master;
use spacdc::matrix::Matrix;
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;
use std::time::Instant;

fn cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.workers = 12;
    cfg.partitions = 3;
    cfg.colluders = 2;
    cfg.stragglers = 2;
    cfg.scheme = SchemeKind::Spacdc;
    cfg.delay.base_service_s = 0.010;
    cfg.delay.straggler_factor = 5.0;
    cfg.seed = 0x9199;
    cfg
}

fn task(x: &Matrix) -> CodedTask {
    CodedTask::block_map(WorkerOp::Identity, x.clone())
}

fn main() -> anyhow::Result<()> {
    banner("round pipelining: submit/wait overlap vs sequential run");
    let mut master = Master::from_config(cfg())?;
    let mut rng = rng_from_seed(7);
    let x = Matrix::random_gaussian(512, 256, 0.0, 1.0, &mut rng);

    // Warmup: touch every allocation/code path once.
    master.run(task(&x))?;

    println!(
        "\n{:<10} {:>16} {:>16} {:>10}",
        "rounds", "sequential(ms)", "pipelined(ms)", "speedup"
    );
    let mut speedup_at_2 = 0.0f64;
    for rounds in [2usize, 4, 8] {
        // Sequential: each round fully completes before the next starts.
        let t0 = Instant::now();
        for _ in 0..rounds {
            master.run(task(&x))?;
        }
        let seq = t0.elapsed().as_secs_f64();

        // Pipelined: all rounds in flight at once, then waited in order.
        let t0 = Instant::now();
        let handles: Vec<_> = (0..rounds)
            .map(|_| master.submit(task(&x)))
            .collect::<Result<_, _>>()?;
        for h in handles {
            master.wait(h)?;
        }
        let pipe = t0.elapsed().as_secs_f64();

        if rounds == 2 {
            speedup_at_2 = seq / pipe;
        }
        println!(
            "{:<10} {:>16.2} {:>16.2} {:>9.2}x",
            rounds,
            seq * 1e3,
            pipe * 1e3,
            seq / pipe
        );
    }

    println!(
        "\nreading: the pipelined column omits (R−1) master-side\n\
         encode+seal+decode stalls — the acceptance check is that ≥2\n\
         concurrently submitted rounds beat the same rounds run\n\
         sequentially (speedup > 1 in every row)."
    );
    anyhow::ensure!(
        speedup_at_2 > 1.0,
        "2 pipelined rounds must beat 2 sequential rounds (speedup {speedup_at_2:.3})"
    );
    Ok(())
}
