//! # SPACDC — Secure and Private Approximated Coded Distributed Computing
//!
//! A full-system reproduction of *"Approximated Coded Computing: Towards
//! Fast, Private and Secure Distributed Machine Learning"* (Qiu, Zhu,
//! Luong, Niyato — CS.DC 2024).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: a master/worker
//!   runtime that Berrut-encodes data with T privacy masks
//!   ([`coding::spacdc`]), seals every share with MEA-ECC ([`ecc::mea`]),
//!   dispatches to workers, and decodes an approximation of `f(Xᵢ)` from
//!   *any* subset of returned results ([`coordinator`]).
//! * **L2 (python/compile/model.py)** — the worker task `f(X̃)=X̃X̃ᵀ` and
//!   the DNN fwd/bwd of §VI, written in JAX and AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the Berrut
//!   encode combination and the tiled Gram product, lowered inside the L2
//!   functions.
//!
//! Every workload enters the system as a typed
//! [`CodedTask`](coding::CodedTask) through one coordinator pipeline:
//! the multi-tenant serving front end
//! ([`Master::service`](coordinator::Master::service) →
//! [`Service`](coordinator::Service)) multiplexes many concurrent
//! session lanes — iterator-, channel-, or manually-fed — over one
//! worker fleet with admission control and fair scheduling, while
//! [`Master::run`](coordinator::Master::run) (one synchronous round),
//! [`Master::submit`](coordinator::Master::submit) /
//! [`Master::wait`](coordinator::Master::wait) (explicit overlap), and
//! [`Master::run_stream`](coordinator::Master::run_stream) (one
//! windowed stream) remain as single-tenant entry points. All eight
//! schemes — MatDot included — implement the task-level
//! [`Scheme`](coding::Scheme) trait.
//!
//! Master and workers exchange *serialized frames* — a versioned,
//! checksummed binary format ([`wire`]) — over a pluggable fabric
//! ([`transport`]): in-process channels by default, localhost TCP
//! sockets with `transport = "tcp"`. A background collector thread on
//! the master routes results to their in-flight rounds, and the
//! transport feeds real `bytes_tx`/`bytes_rx` counters (the honest half
//! of the Fig. 6 communication accounting).
//!
//! The compiled artifacts are executed from Rust through the PJRT C API
//! ([`runtime`]); Python never runs on the request path.
//!
//! See `DESIGN.md` (repo root) for the system inventory, the task/job
//! API, the faithfulness notes, and the experiment index.

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod coding;
pub mod config;
pub mod coordinator;
pub mod dl;
pub mod ecc;
pub mod field;
pub mod matrix;
pub mod metrics;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod simd;
pub mod transport;
pub mod wire;
