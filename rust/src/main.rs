//! `spacdc` — the SPACDC coordinator CLI.
//!
//! Subcommands:
//! * `train`  — run SPACDC-DL (or a baseline) end to end and report the
//!   loss/accuracy curve (Algorithm 2).
//! * `round`  — run one coded Gram round and report decode error +
//!   communication accounting.
//! * `sweep`  — training-time sweep over straggler counts (the Fig. 3
//!   scenario grid) for one scheme.
//! * `scenario` — run a declarative adversity scenario (stragglers,
//!   crash/respawn churn, colluders, wire corruption) through the
//!   scenario engine and report per-round outcomes + the determinism
//!   digest (see also the dedicated `scenario_runner` bin).
//! * `worker` — run one worker as a standalone process: dial the
//!   master, send the `Register` handshake, then serve the normal
//!   worker loop over the versioned TCP wire protocol. Forked by the
//!   process fabric (`--transport proc`) and the `testbed` bin; usable
//!   by hand for ad-hoc cluster experiments (DESIGN.md §9).
//! * `info`   — print the resolved config, artifact registry, and the
//!   Table II complexity row for the chosen parameters.

use spacdc::analysis::CostModel;
use spacdc::cli::{parse, usage, ArgSpec};
use spacdc::coding::CodedTask;
use spacdc::config::{
    parse_threads_token, SchemeKind, SystemConfig, TransportKind, TransportSecurity,
};
use spacdc::coordinator::{MasterBuilder, WorkerHarness};
use spacdc::dl::{train, TrainerOptions};
use spacdc::matrix::{gram, split_rows, Matrix};
use spacdc::rng::rng_from_seed;
use spacdc::runtime::{Executor, RuntimeService, WorkerOp};
use spacdc::sim::{parse_crash, run_scenario_with, FaultKey, FaultPlan, Scenario};
use spacdc::transport::WorkerLink;
use std::path::Path;
use std::sync::Arc;

fn specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("config", "", "config file (TOML subset; optional)"),
        ArgSpec::opt("scheme", "spacdc", "uncoded|mds|matdot|polynomial|lcc|secpoly|bacc|spacdc"),
        ArgSpec::opt("workers", "30", "number of workers N"),
        ArgSpec::opt("stragglers", "3", "number of stragglers S"),
        ArgSpec::opt("colluders", "3", "number of colluders T"),
        ArgSpec::opt("partitions", "4", "number of data partitions K"),
        ArgSpec::opt("epochs", "10", "training epochs"),
        ArgSpec::opt("transport", "inproc", "worker link fabric: inproc|tcp|proc"),
        ArgSpec::opt("security", "mea-ecc", "payload sealing: plain|mea-ecc"),
        ArgSpec::opt("round-deadline-s", "60", "per-round result-collection deadline (s)"),
        ArgSpec::opt("threads", "auto", "master-side thread-pool width (auto = one per core)"),
        ArgSpec::opt("inflight", "", "round-stream window: rounds kept in flight (≥ 1)"),
        ArgSpec::opt("speculate", "", "re-dispatch outstanding shares: on|off"),
        ArgSpec::opt("scenario", "", "scenario name or file (scenario subcommand)"),
        ArgSpec::opt("tenants", "", "scenario override: concurrent session tenants (≥ 1)"),
        ArgSpec::opt("tenant-inflight", "", "scenario override: per-tenant session window"),
        ArgSpec::opt("seed", "49374", "experiment seed"),
        ArgSpec::opt("base-service-ms", "0", "injected per-task service time (ms)"),
        ArgSpec::opt("rows", "512", "data rows m (round subcommand)"),
        ArgSpec::opt("cols", "256", "data cols d (round subcommand)"),
        ArgSpec::flag("no-pjrt", "disable the PJRT artifact path"),
        ArgSpec::flag("help", "show usage"),
    ]
}

/// Arguments of the `worker` subcommand — a different vocabulary from
/// the master-side subcommands (no scheme/topology knobs: the master
/// owns those and ships work fully encoded), so it dispatches before
/// the main spec parse.
fn worker_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::required("connect", "master address host:port"),
        ArgSpec::required("worker", "worker id W"),
        ArgSpec::required("master-pk", "master public key (hex, from the forking fabric)"),
        ArgSpec::opt("generation", "0", "incarnation number (bumped on respawn)"),
        ArgSpec::opt("seed", "49374", "experiment seed (must match the master's)"),
        ArgSpec::opt("crashes", "", "crash schedule: comma-joined w@r[+d] tokens"),
        ArgSpec::opt("corrupt-rate", "0", "wire corruption probability per result"),
        ArgSpec::opt("forgers", "", "forger worker ids (comma-joined)"),
        ArgSpec::opt("forge-rate", "0", "forgery probability per (forger, round)"),
        ArgSpec::opt("fault-seed", "0", "fault-plan seed (must match the master's)"),
        ArgSpec::opt("fault-key", "global", "fault keying: global | served | lane"),
        ArgSpec::flag("help", "show usage"),
    ]
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) == Some("worker") {
        return cmd_worker(&args[1..]);
    }
    let specs = specs();
    let parsed = match parse(&args, &specs) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if parsed.has_flag("help") || parsed.positional.is_empty() {
        print!("{}", usage("spacdc <train|round|sweep|scenario|worker|info>", &specs));
        return Ok(());
    }

    let mut cfg = match parsed.get("config") {
        Some("") | None => SystemConfig::default(),
        Some(path) => SystemConfig::from_file(path)?,
    };
    cfg.scheme = SchemeKind::from_str_token(parsed.get_str("scheme"))
        .ok_or_else(|| anyhow::anyhow!("unknown scheme {}", parsed.get_str("scheme")))?;
    cfg.workers = parsed.get_usize("workers");
    cfg.stragglers = parsed.get_usize("stragglers");
    cfg.colluders = parsed.get_usize("colluders");
    cfg.partitions = parsed.get_usize("partitions");
    cfg.dl.epochs = parsed.get_usize("epochs");
    cfg.transport = TransportKind::from_str_token(parsed.get_str("transport"))
        .ok_or_else(|| anyhow::anyhow!("unknown transport {}", parsed.get_str("transport")))?;
    cfg.security = TransportSecurity::from_str_token(parsed.get_str("security"))
        .ok_or_else(|| anyhow::anyhow!("unknown security {}", parsed.get_str("security")))?;
    cfg.round_deadline_s = parsed.get_f64("round-deadline-s");
    cfg.threads = parse_threads_token(parsed.get_str("threads")).ok_or_else(|| {
        anyhow::anyhow!(
            "--threads {}: pool width must be ≥ 1, or 'auto'",
            parsed.get_str("threads")
        )
    })?;
    // `--inflight`/`--speculate` act as overrides: unset, a scenario's
    // own `[stream]` table wins (and plain runs stay synchronous).
    let inflight_flag: Option<usize> = match parsed.get("inflight").filter(|s| !s.is_empty()) {
        None => None,
        Some(raw) => {
            let n: usize =
                raw.parse().map_err(|_| anyhow::anyhow!("--inflight {raw}: not a number"))?;
            anyhow::ensure!(n >= 1, "--inflight {n}: stream window must be ≥ 1");
            Some(n)
        }
    };
    let speculate_flag = match parsed.get("speculate").filter(|s| !s.is_empty()) {
        None => None,
        Some("on" | "true" | "1" | "yes") => Some(true),
        Some("off" | "false" | "0" | "no") => Some(false),
        Some(other) => anyhow::bail!("--speculate {other}: expected on|off"),
    };
    cfg.inflight = inflight_flag.unwrap_or(cfg.inflight);
    cfg.speculate = speculate_flag.unwrap_or(cfg.speculate);
    let tenants_flag: Option<usize> = match parsed.get("tenants").filter(|s| !s.is_empty()) {
        None => None,
        Some(raw) => {
            Some(raw.parse().map_err(|_| anyhow::anyhow!("--tenants {raw}: not a number"))?)
        }
    };
    let tenant_inflight_flag: Option<usize> =
        match parsed.get("tenant-inflight").filter(|s| !s.is_empty()) {
            None => None,
            Some(raw) => Some(
                raw.parse()
                    .map_err(|_| anyhow::anyhow!("--tenant-inflight {raw}: not a number"))?,
            ),
        };
    if let Some(s) = parsed.get("scenario").filter(|s| !s.is_empty()) {
        cfg.scenario = s.to_string();
    }
    cfg.seed = parsed.get_u64("seed");
    cfg.delay.base_service_s = parsed.get_f64("base-service-ms") / 1e3;
    cfg.use_pjrt = !parsed.has_flag("no-pjrt");
    cfg.validate().map_err(|e| anyhow::anyhow!(e.to_string()))?;

    match parsed.positional[0].as_str() {
        "train" => cmd_train(&cfg),
        "round" => cmd_round(&cfg, parsed.get_usize("rows"), parsed.get_usize("cols")),
        "sweep" => cmd_sweep(&cfg),
        "scenario" => {
            cmd_scenario(&cfg, inflight_flag, speculate_flag, tenants_flag, tenant_inflight_flag)
        }
        "info" => cmd_info(&cfg),
        other => {
            eprintln!("unknown subcommand {other}");
            std::process::exit(2);
        }
    }
}

/// Attach the PJRT runtime when artifacts exist and it is enabled.
///
/// Returns the service together with the executor: the caller keeps the
/// service alive for as long as the executor is in use, and dropping it
/// joins the runtime thread cleanly (no `std::mem::forget` leak).
fn executor_for(cfg: &SystemConfig) -> Option<(RuntimeService, Executor)> {
    if !cfg.use_pjrt {
        return None;
    }
    let dir = Path::new(&cfg.artifacts_dir);
    match RuntimeService::start(dir) {
        Ok(svc) => {
            let metrics = Arc::new(spacdc::metrics::MetricsRegistry::new());
            let handle = svc.handle();
            Some((svc, Executor::with_runtime(handle, metrics)))
        }
        Err(e) => {
            eprintln!("note: PJRT runtime unavailable ({e}); using native kernels");
            None
        }
    }
}

fn cmd_train(cfg: &SystemConfig) -> anyhow::Result<()> {
    println!(
        "SPACDC-DL training: scheme={} N={} S={} T={} K={} layers={:?}",
        cfg.scheme.name(),
        cfg.workers,
        cfg.stragglers,
        cfg.colluders,
        cfg.partitions,
        cfg.dl.layers
    );
    let runtime = executor_for(cfg);
    let mut opts = TrainerOptions::new(cfg.clone());
    opts.executor = runtime.as_ref().map(|(_, e)| e.clone());
    let report = train(&opts)?;
    println!("epoch  loss      accuracy  wall(s)");
    for e in &report.epochs {
        println!("{:>5}  {:<8.4}  {:<8.4}  {:<8.2}", e.epoch, e.loss, e.accuracy, e.wall_s);
    }
    println!(
        "final accuracy {:.4} after {} steps in {:.2}s",
        report.final_accuracy, report.steps, report.total_wall_s
    );
    Ok(())
}

fn cmd_round(cfg: &SystemConfig, rows: usize, cols: usize) -> anyhow::Result<()> {
    println!(
        "one coded round: scheme={} transport={} f(X)=XXᵀ on {}x{} data",
        cfg.scheme.name(),
        cfg.transport.name(),
        rows,
        cols
    );
    let runtime = executor_for(cfg);
    let mut builder = MasterBuilder::new(cfg.clone());
    if let Some((_, exec)) = &runtime {
        builder = builder.executor(exec.clone());
    }
    let mut master = builder.build()?;
    let mut rng = rng_from_seed(cfg.seed);
    let x = Matrix::random_gaussian(rows, cols, 0.0, 1.0, &mut rng);
    // One entry point for every scheme: MatDot runs the Gram as its
    // native pair product; the row-partition schemes as a block map.
    let task = if cfg.scheme == SchemeKind::MatDot {
        CodedTask::pair_product(x.clone(), x.transpose())
    } else {
        CodedTask::block_map(WorkerOp::Gram, x.clone())
    };
    let out = master.run(task)?;
    // Decode-quality report.
    if cfg.scheme == SchemeKind::MatDot {
        let err = out.blocks[0].rel_error(&gram(&x));
        println!("full-product rel error: {err:.6}");
    } else {
        let (blocks, _) = split_rows(&x, master.config().partitions);
        for (i, (d, b)) in out.blocks.iter().zip(&blocks).enumerate() {
            println!("block {i}: rel error {:.6}", d.rel_error(&gram(b)));
        }
    }
    println!(
        "round wall {:.3}ms, {} results used",
        out.wall.as_secs_f64() * 1e3,
        out.results_used
    );
    println!("{}", master.metrics().report());
    Ok(())
}

fn cmd_sweep(cfg: &SystemConfig) -> anyhow::Result<()> {
    println!("training-time sweep over stragglers (scheme={})", cfg.scheme.name());
    println!("{:>3}  {:>10}  {:>9}", "S", "wall(s)", "accuracy");
    for s in [0usize, 3, 5, 7] {
        let mut c = cfg.clone();
        c.stragglers = s;
        c.dl.epochs = cfg.dl.epochs.min(3);
        let report = train(&TrainerOptions::new(c))?;
        println!("{s:>3}  {:>10.2}  {:>9.4}", report.total_wall_s, report.final_accuracy);
    }
    Ok(())
}

fn cmd_scenario(
    cfg: &SystemConfig,
    inflight: Option<usize>,
    speculate: Option<bool>,
    tenants: Option<usize>,
    tenant_inflight: Option<usize>,
) -> anyhow::Result<()> {
    if cfg.scenario.is_empty() {
        anyhow::bail!(
            "no scenario selected: pass --scenario <name|file> or set `scenario =` in the \
             config (builtins: {})",
            Scenario::builtin_names().join(", ")
        );
    }
    let mut scenario = Scenario::load(&cfg.scenario)?;
    // `--tenants`/`--tenant-inflight` override the scenario's
    // `[tenants]` table (validated again by the runner).
    if let Some(t) = tenants {
        scenario.tenants = t;
    }
    if let Some(w) = tenant_inflight {
        scenario.tenant_inflight = w;
    }
    let report = run_scenario_with(&scenario, cfg.transport, cfg.threads, inflight, speculate)?;
    print!("{}", report.render_table());
    std::fs::write("SCENARIO_REPORT.json", report.to_json())?;
    println!("wrote SCENARIO_REPORT.json");
    Ok(())
}

/// `spacdc worker` — one worker node as a standalone process.
///
/// Dials the master, then hands the socket to the same
/// [`WorkerHarness`] the in-proc fabrics run on a thread: the harness
/// sends the `Register { worker, generation, pk }` handshake and serves
/// orders until the socket closes (master gone → clean exit). The fault
/// plan arrives on the command line, re-serialized by the process
/// fabric from the scenario, so a child crashes on exactly the rounds
/// the in-proc run would. A *crashed* process parks instead of exiting
/// — the supervisor's SIGKILL must be the actual cause of death so the
/// exit log proves the fault ran at the OS level (DESIGN.md §9).
fn cmd_worker(args: &[String]) -> anyhow::Result<()> {
    let specs = worker_specs();
    let parsed = match parse(args, &specs) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if parsed.has_flag("help") {
        print!("{}", usage("spacdc worker --connect <host:port>", &specs));
        return Ok(());
    }
    let need = |name: &str| -> anyhow::Result<&str> {
        parsed
            .get(name)
            .filter(|s| !s.is_empty())
            .ok_or_else(|| anyhow::anyhow!("spacdc worker: missing required --{name}"))
    };
    let addr = need("connect")?;
    let worker: usize = need("worker")?.parse().map_err(|e| anyhow::anyhow!("--worker: {e}"))?;
    let master_pk = spacdc::wire::point_from_hex(need("master-pk")?)
        .map_err(|e| anyhow::anyhow!("--master-pk: {e}"))?;
    let generation: u32 = parsed.get_str("generation").parse()
        .map_err(|e| anyhow::anyhow!("--generation: {e}"))?;
    let seed = parsed.get_u64("seed");

    let crashes: Vec<_> = parsed
        .get("crashes")
        .unwrap_or("")
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| parse_crash(t).ok_or_else(|| anyhow::anyhow!("--crashes: bad token {t:?}")))
        .collect::<Result<_, _>>()?;
    let corrupt_rate = parsed.get_f64("corrupt-rate");
    let forgers: Vec<usize> = parsed
        .get("forgers")
        .unwrap_or("")
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse().map_err(|e| anyhow::anyhow!("--forgers: bad id {t:?}: {e}")))
        .collect::<Result<_, _>>()?;
    let forge_rate = parsed.get_f64("forge-rate");
    let fault_key = FaultKey::from_token(parsed.get_str("fault-key"))
        .ok_or_else(|| anyhow::anyhow!("--fault-key: expected global | served | lane"))?;
    let faults = if crashes.is_empty() && corrupt_rate <= 0.0 && forge_rate <= 0.0 {
        None
    } else {
        Some(Arc::new(
            FaultPlan::new(crashes, corrupt_rate, parsed.get_u64("fault-seed"))
                .with_forgers(forgers, forge_rate)
                .with_key(fault_key),
        ))
    };

    let stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("spacdc worker: cannot reach master at {addr}: {e}"))?;
    stream.set_nodelay(true)?;

    let metrics = Arc::new(spacdc::metrics::MetricsRegistry::new());
    let harness = WorkerHarness {
        worker,
        generation,
        seed,
        master_pk,
        executor: Executor::native(metrics),
        // Collusion taps cannot cross process boundaries; the digest
        // never includes colluder shares, so parity with in-proc runs
        // holds regardless (DESIGN.md §9).
        collusion: None,
        faults,
        park_on_crash: true,
    };
    harness.run(WorkerLink::Tcp { stream });
    Ok(())
}

fn cmd_info(cfg: &SystemConfig) -> anyhow::Result<()> {
    println!("resolved config:\n{cfg:#?}");
    let model =
        CostModel::new(1000, 1000, cfg.partitions, cfg.workers, cfg.workers - cfg.stragglers);
    let costs = model.costs(cfg.scheme);
    println!("\nTable II row for {} (m=d=1000):", cfg.scheme.name());
    println!("  encoding        {:.3e}", costs.encoding);
    println!("  decoding        {:.3e}", costs.decoding);
    println!("  comm → workers  {:.3e}", costs.comm_to_workers);
    println!("  comm → master   {:.3e}", costs.comm_to_master);
    println!("  worker compute  {:.3e}", costs.worker_compute);
    println!("  security {}   privacy {}", costs.protects_security, costs.protects_privacy);
    if executor_for(cfg).is_some() {
        println!("\nPJRT runtime: available (artifacts loaded)");
    } else {
        println!("\nPJRT runtime: unavailable");
    }
    Ok(())
}
