//! Closed-form complexity model — paper Table II and Figs. 5–7.

pub mod complexity;

pub use complexity::{CostModel, SchemeCosts};
