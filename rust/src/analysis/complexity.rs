//! Closed-form complexity model — exactly the formulas of Table II,
//! evaluated to regenerate Figs. 5 (decoding vs K), 6 (communication vs
//! m), and 7 (per-worker computation vs K).
//!
//! Parameters follow the paper's notation: data X is m×d split into K
//! blocks, N workers, |𝓕| returned results, task f(X̃) = X̃X̃ᵀ.

use crate::config::SchemeKind;

/// Evaluated costs (in abstract "operations"/"symbols", as the paper
/// plots them).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemeCosts {
    /// Encoding complexity (master).
    pub encoding: f64,
    /// Decoding complexity (master).
    pub decoding: f64,
    /// Communication master → all workers (symbols).
    pub comm_to_workers: f64,
    /// Communication workers → master (symbols).
    pub comm_to_master: f64,
    /// Per-worker computational complexity.
    pub worker_compute: f64,
    /// Data security during transmission (MEA-ECC)?
    pub protects_security: bool,
    /// Information-theoretic privacy against colluders?
    pub protects_privacy: bool,
}

/// The Table II cost model for one parameter setting.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Rows of X.
    pub m: f64,
    /// Columns of X.
    pub d: f64,
    /// Partitions K.
    pub k: f64,
    /// Workers N.
    pub n: f64,
    /// Returned results |𝓕|.
    pub f_returned: f64,
}

impl CostModel {
    /// Convenience constructor.
    pub fn new(m: usize, d: usize, k: usize, n: usize, f_returned: usize) -> Self {
        Self {
            m: m as f64,
            d: d as f64,
            k: k as f64,
            n: n as f64,
            f_returned: f_returned as f64,
        }
    }

    fn log2(x: f64) -> f64 {
        x.max(2.0).log2()
    }

    fn loglog2(x: f64) -> f64 {
        Self::log2(Self::log2(x))
    }

    /// Evaluate the Table II row for `kind`.
    pub fn costs(&self, kind: SchemeKind) -> SchemeCosts {
        let Self { m, d, k, n, f_returned: f } = *self;
        match kind {
            // Polynomial codes [23]: decode interpolates degree-K² — the
            // table's O(m² log²K² loglog K²) row.
            SchemeKind::Polynomial => SchemeCosts {
                encoding: m * d * n,
                decoding: m * m * Self::log2(k * k).powi(2) * Self::loglog2(k * k),
                comm_to_workers: m * d * n / k,
                comm_to_master: m * m,
                worker_compute: d * m * m / (k * k),
                protects_security: false,
                protects_privacy: false,
            },
            // MatDot codes [24]: higher decode (K·m² polylog) and
            // worst-in-class download (each worker returns m×m) and
            // compute (blocks only shrink in one dimension).
            SchemeKind::MatDot => SchemeCosts {
                encoding: m * d * n,
                decoding: k * m * m * Self::log2(k).powi(2) * Self::loglog2(k),
                comm_to_workers: m * d * n / k,
                comm_to_master: k * m * m,
                worker_compute: d * m * m / k,
                protects_security: false,
                protects_privacy: false,
            },
            // SecPoly [34]: polynomial-code costs + privacy.
            SchemeKind::SecPoly => SchemeCosts {
                encoding: m * d * n,
                decoding: m * m * Self::log2(k * k).powi(2) * Self::loglog2(k * k),
                comm_to_workers: m * d * n / k,
                comm_to_master: m * m,
                worker_compute: d * m * m / (k * k),
                protects_security: false,
                protects_privacy: true,
            },
            // BACC [18]: Berrut decode is O(|𝓕|) per recovered point.
            SchemeKind::Bacc => SchemeCosts {
                encoding: m * d * n,
                decoding: f,
                comm_to_workers: m * d * n / k,
                comm_to_master: m * m * f / (k * k),
                worker_compute: d * m * m / (k * k),
                protects_security: false,
                protects_privacy: false,
            },
            // LCC [27].
            SchemeKind::Lcc => SchemeCosts {
                encoding: m * d * n,
                decoding: m * m * Self::log2(k).powi(2) * Self::loglog2(k) / k,
                comm_to_workers: m * d * n / k,
                comm_to_master: m * m / k,
                worker_compute: d * m * m / (k * k),
                protects_security: false,
                protects_privacy: true,
            },
            // SPACDC (this paper): BACC-class costs + security + privacy.
            SchemeKind::Spacdc => SchemeCosts {
                encoding: m * d * n,
                decoding: f,
                comm_to_workers: m * d * n / k,
                comm_to_master: m * m * f / (k * k),
                worker_compute: d * m * m / (k * k),
                protects_security: true,
                protects_privacy: true,
            },
            // MDS [22] (not a Table II row; modeled like the polynomial
            // family with one-sided partitioning, for the DL comparison).
            SchemeKind::Mds => SchemeCosts {
                encoding: m * d * n,
                decoding: m * m * Self::log2(k).powi(2) * Self::loglog2(k),
                comm_to_workers: m * d * n / k,
                comm_to_master: m * m * f / (k * k),
                worker_compute: d * m * m / (k * k),
                protects_security: false,
                protects_privacy: false,
            },
            // CONV: no coding; every worker computes its 1/N share, the
            // master just concatenates.
            SchemeKind::Uncoded => SchemeCosts {
                encoding: 0.0,
                decoding: n,
                comm_to_workers: m * d,
                comm_to_master: m * m / n,
                worker_compute: d * m * m / (n * n),
                protects_security: false,
                protects_privacy: false,
            },
        }
    }

    /// The six Table II rows, in the paper's order.
    pub fn table_ii_rows() -> [SchemeKind; 6] {
        [
            SchemeKind::Polynomial,
            SchemeKind::MatDot,
            SchemeKind::SecPoly,
            SchemeKind::Bacc,
            SchemeKind::Lcc,
            SchemeKind::Spacdc,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(k: usize) -> CostModel {
        CostModel::new(1000, 1000, k, 30, 10)
    }

    #[test]
    fn fig5_shape_spacdc_and_bacc_lowest_decoding() {
        // m=1000, K ∈ 1..36 — SPACDC ≈ BACC ≪ everything else; MatDot
        // highest among the polynomial-decode schemes at moderate K.
        for k in [2usize, 8, 16, 36] {
            let m = model(k);
            let spacdc = m.costs(SchemeKind::Spacdc).decoding;
            let bacc = m.costs(SchemeKind::Bacc).decoding;
            let lcc = m.costs(SchemeKind::Lcc).decoding;
            let poly = m.costs(SchemeKind::Polynomial).decoding;
            let matdot = m.costs(SchemeKind::MatDot).decoding;
            assert_eq!(spacdc, bacc);
            assert!(spacdc < lcc, "k={k}");
            assert!(lcc < poly, "k={k}");
            // MatDot overtakes the polynomial family once the polylog
            // factors settle (K ≥ 8 in the paper's plotted range).
            if k >= 8 {
                assert!(poly < matdot, "k={k}");
            }
        }
    }

    #[test]
    fn fig6_shape_matdot_worst_upload() {
        // |𝓕|=10, K=30: worker→master, MatDot ≫ others; SPACDC = BACC low.
        let m = CostModel::new(1000, 1000, 30, 30, 10);
        let matdot = m.costs(SchemeKind::MatDot).comm_to_master;
        let poly = m.costs(SchemeKind::Polynomial).comm_to_master;
        let spacdc = m.costs(SchemeKind::Spacdc).comm_to_master;
        let bacc = m.costs(SchemeKind::Bacc).comm_to_master;
        assert!(matdot > poly);
        assert!(poly > spacdc);
        assert_eq!(spacdc, bacc);
    }

    #[test]
    fn fig7_shape_matdot_worst_worker_compute() {
        // d=1000, m=5000: MatDot O(dm²/K) vs everyone else O(dm²/K²).
        let m = CostModel::new(5000, 1000, 16, 30, 10);
        let matdot = m.costs(SchemeKind::MatDot).worker_compute;
        for kind in [
            SchemeKind::Spacdc,
            SchemeKind::Bacc,
            SchemeKind::Lcc,
            SchemeKind::Polynomial,
            SchemeKind::SecPoly,
        ] {
            let c = m.costs(kind).worker_compute;
            assert!(matdot / c >= 15.0, "{kind:?}: matdot {matdot} vs {c}");
        }
    }

    #[test]
    fn only_spacdc_has_both_protections() {
        let m = model(8);
        for kind in CostModel::table_ii_rows() {
            let c = m.costs(kind);
            if kind == SchemeKind::Spacdc {
                assert!(c.protects_security && c.protects_privacy);
            } else {
                assert!(!c.protects_security, "{kind:?} should not claim security");
            }
        }
    }

    #[test]
    fn decoding_scales_linearly_in_returns_for_berrut_family() {
        let m5 = CostModel::new(1000, 1000, 8, 30, 5).costs(SchemeKind::Spacdc).decoding;
        let m20 = CostModel::new(1000, 1000, 8, 30, 20).costs(SchemeKind::Spacdc).decoding;
        assert!((m20 / m5 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn encoding_complexity_same_across_coded_schemes() {
        // Table II: all coded schemes encode at O(mdN).
        let m = model(8);
        let base = m.costs(SchemeKind::Spacdc).encoding;
        for kind in CostModel::table_ii_rows() {
            assert_eq!(m.costs(kind).encoding, base, "{kind:?}");
        }
    }
}
