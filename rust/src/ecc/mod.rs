//! Elliptic-curve cryptography layer — paper §IV.
//!
//! Implements exactly the pipeline of §IV-B:
//!
//! 1. **Key generation** — `sk` random scalar, `pk = sk·G` (`keys.rs`).
//! 2. **Key exchange** — ECDH share key `s_K = sk_M · pk_W = sk_W · pk_M`.
//! 3. **Encryption** — `C = { k·G,  M + mask(k·pk_W) }` (`mea.rs`).
//! 4. **Decryption** — recompute `mask(sk_W · (k·G))` and subtract.
//!
//! Curve arithmetic (`curve.rs`) is generic over the crate's
//! [`FieldElement`](crate::field::FieldElement): the default simulation
//! curve lives over F_{2^61−1}; a secp256k1 instantiation over the
//! 256-bit field is provided for production-parameter fidelity
//! (see DESIGN.md §3 for why the key-size substitution is behaviour-
//! preserving for every quantity the paper evaluates).

pub mod curve;
pub mod keys;
pub mod mea;

pub use curve::{Curve, Point};
pub use keys::{KeyPair, SharedSecret};
pub use mea::{MaskMode, MeaEcc, SealedBytes, SealedMatrix};

use crate::field::{Fp61, FpBig, U256};
use crate::field::FieldElement;

/// The default simulation curve over F_{2^61−1}:
/// `y² = x³ − 3x + 6`, generator G = (1, 2).
///
/// Verification that G is on the curve: 1 − 3 + 6 = 4 = 2².
/// Discriminant 4a³ + 27b² = −108 + 972 = 864 ≠ 0 (Def. 2, Eq. (4)).
pub fn sim_curve() -> Curve<Fp61> {
    let a = Fp61::zero().sub(&Fp61::new(3));
    let b = Fp61::new(6);
    let g = Point::affine(Fp61::new(1), Fp61::new(2));
    Curve::new(a, b, g)
}

/// secp256k1: `y² = x³ + 7` over the 256-bit prime field, standard
/// generator. Production-grade parameters for the fidelity tests.
pub fn secp256k1() -> Curve<FpBig> {
    let p = U256::SECP256K1_P;
    let a = FpBig::new(U256::ZERO, p);
    let b = FpBig::new(U256::from_u64(7), p);
    let gx = FpBig::new(
        U256::from_hex("79BE667EF9DCBBAC55A06295CE870B07029BFCDB2DCE28D959F2815B16F81798"),
        p,
    );
    let gy = FpBig::new(
        U256::from_hex("483ADA7726A3C4655DA4FBFC0E1108A8FD17B448A68554199C47D08FFB10D4B8"),
        p,
    );
    Curve::new(a, b, Point::affine(gx, gy))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_curve_generator_is_on_curve() {
        let c = sim_curve();
        assert!(c.contains(&c.generator()));
    }

    #[test]
    fn secp256k1_generator_is_on_curve() {
        let c = secp256k1();
        assert!(c.contains(&c.generator()));
    }
}
