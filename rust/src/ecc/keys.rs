//! Key generation and ECDH key exchange — §IV-B steps 1–2.

use super::curve::{Curve, Point};
use crate::field::{FieldElement, U256};
use crate::rng::Rng;

/// A party's key pair: private scalar `sk` and public point `pk = sk·G`.
#[derive(Clone, Debug)]
pub struct KeyPair<F: FieldElement> {
    sk: U256,
    pk: Point<F>,
}

impl<F: FieldElement> KeyPair<F> {
    /// §IV-B step 1: pick random `sk`, compute `pk = sk·G`.
    ///
    /// The scalar is drawn with 128 random bits for the simulation curve
    /// (ample for a 61-bit group) and retried if it degenerates to the
    /// identity.
    pub fn generate(curve: &Curve<F>, rng: &mut Rng) -> Self {
        loop {
            let sk = U256([rng.next_u64(), rng.next_u64(), 0, 0]);
            if sk.is_zero() {
                continue;
            }
            let pk = curve.mul_scalar(&sk, &curve.generator());
            if !pk.is_infinity() {
                return Self { sk, pk };
            }
        }
    }

    /// The public key.
    pub fn public(&self) -> Point<F> {
        self.pk
    }

    /// The private scalar (used internally by MEA decryption).
    pub(crate) fn secret(&self) -> &U256 {
        &self.sk
    }

    /// §IV-B step 2: ECDH share key `s_K = sk_self · pk_peer`.
    pub fn shared_secret(&self, curve: &Curve<F>, peer_pk: &Point<F>) -> SharedSecret<F> {
        SharedSecret { point: curve.mul_scalar(&self.sk, peer_pk) }
    }
}

/// The ECDH shared point `s_K`. Both sides derive the same point:
/// `sk_M·pk_W = sk_M·sk_W·G = sk_W·pk_M`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SharedSecret<F: FieldElement> {
    point: Point<F>,
}

impl<F: FieldElement> SharedSecret<F> {
    /// Wrap a raw point (used by MEA with the per-message point `k·pk`).
    pub fn from_point(point: Point<F>) -> Self {
        Self { point }
    }

    /// The underlying point.
    pub fn point(&self) -> Point<F> {
        self.point
    }

    /// Collapse the shared point into a 64-bit keystream seed by mixing
    /// the limbs of both coordinates through SplitMix64.
    ///
    /// (The paper's Ψ keeps only the x-coordinate; mixing in y as well
    /// costs nothing and removes the x/−x ambiguity.)
    pub fn keystream_seed(&self) -> u64 {
        use crate::rng::SplitMix64;
        let mut h = SplitMix64::new(0xC0DE_D15E_ED15_7A2B);
        let mut acc = 0u64;
        if let Some((x, y)) = self.point.xy() {
            for limb in x.to_limbs().iter().chain(y.to_limbs().iter()) {
                acc = h.next_u64() ^ acc.rotate_left(17) ^ *limb;
                h = SplitMix64::new(acc);
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecc::{secp256k1, sim_curve};
    use crate::rng::rng_from_seed;

    #[test]
    fn ecdh_agreement_sim_curve() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(42);
        let master = KeyPair::generate(&curve, &mut rng);
        let worker = KeyPair::generate(&curve, &mut rng);
        // s_K = sk_M · pk_W  ==  s'_K = sk_W · pk_M   (§IV-B step 2)
        let s1 = master.shared_secret(&curve, &worker.public());
        let s2 = worker.shared_secret(&curve, &master.public());
        assert_eq!(s1, s2);
        assert_eq!(s1.keystream_seed(), s2.keystream_seed());
    }

    #[test]
    fn ecdh_agreement_secp256k1() {
        let curve = secp256k1();
        let mut rng = rng_from_seed(43);
        let a = KeyPair::generate(&curve, &mut rng);
        let b = KeyPair::generate(&curve, &mut rng);
        assert_eq!(
            a.shared_secret(&curve, &b.public()),
            b.shared_secret(&curve, &a.public())
        );
    }

    #[test]
    fn distinct_parties_get_distinct_secrets() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(44);
        let master = KeyPair::generate(&curve, &mut rng);
        let w1 = KeyPair::generate(&curve, &mut rng);
        let w2 = KeyPair::generate(&curve, &mut rng);
        let s1 = master.shared_secret(&curve, &w1.public());
        let s2 = master.shared_secret(&curve, &w2.public());
        assert_ne!(s1, s2);
        assert_ne!(s1.keystream_seed(), s2.keystream_seed());
    }

    #[test]
    fn public_keys_are_on_curve() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(45);
        for _ in 0..10 {
            let kp = KeyPair::generate(&curve, &mut rng);
            assert!(curve.contains(&kp.public()));
        }
    }
}
