//! MEA-ECC: Matrix Encryption Algorithm based on ECC — paper §IV-B.
//!
//! Encryption of a matrix M for worker Wᵢ (steps 3–4 of §IV-B):
//!
//! ```text
//!   C = { k·G,  M ⊞ mask(k·pk_W) }          (master side, random k)
//!   M = C.payload ⊟ mask(sk_W · (k·G))      (worker side)
//! ```
//!
//! correctness resting on `k·pk_W = k·sk_W·G = sk_W·(k·G)`.
//!
//! Two mask constructions are provided ([`MaskMode`]):
//!
//! * [`MaskMode::Keystream`] (default) — the shared point seeds a
//!   SplitMix64 keystream; one 32-bit word per element is XORed onto the
//!   f32 *bit pattern*. Decryption is bit-exact, and unlike the paper's
//!   rank-one mask, two ciphertext entries never leak their plaintext
//!   difference. This is the strict strengthening documented in
//!   DESIGN.md §3.
//! * [`MaskMode::RankOne`] — the paper-literal `M + Ψ(k·pk_W)·𝟙` with
//!   Ψ folded into a bounded float so f32 addition is invertible up to
//!   rounding. Kept for complexity benches and fidelity tests.

use super::curve::{Curve, Point};
use super::keys::{KeyPair, SharedSecret};
use crate::field::{FieldElement, U256};
use crate::matrix::Matrix;
use crate::rng::Rng;

/// Which masking construction to use (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MaskMode {
    /// XOR keystream on f32 bit patterns (bit-exact, per-element).
    #[default]
    Keystream,
    /// Paper-literal rank-one additive mask `Ψ(k·pk)·𝟙`.
    RankOne,
}

/// A byte buffer sealed under MEA-ECC — the wire's *seal-the-bytes*
/// form.
///
/// Where [`SealedMatrix`] encrypts a live `Matrix` struct (the in-memory
/// form the complexity benches and fidelity tests exercise), this seals
/// an already-serialized byte buffer: the ephemeral point `k·G` travels
/// in the clear and every payload byte is XORed with a keystream derived
/// from the shared point. It is what actually crosses a transport link
/// (see `wire`/`transport`), so transmission security operates on real
/// bytes rather than on structs that were never serialized.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SealedBytes<F: FieldElement> {
    /// Ephemeral point `k·G` (§IV-B step 3, first ciphertext component).
    pub ephemeral: Point<F>,
    /// The masked payload bytes (same length as the plaintext).
    pub bytes: Vec<u8>,
}

impl<F: FieldElement> SealedBytes<F> {
    /// Ciphertext length in bytes.
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    /// True iff the payload is empty.
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

/// A matrix encrypted under MEA-ECC.
///
/// Carries the ephemeral public point `k·G` (the first ciphertext
/// component of §IV-B step 3) plus the masked payload. An eavesdropper
/// sees exactly this struct and nothing else.
#[derive(Clone, Debug)]
pub struct SealedMatrix<F: FieldElement> {
    /// Ephemeral point `k·G`.
    pub ephemeral: Point<F>,
    /// Masked payload (same shape as the plaintext).
    pub payload: Matrix,
    /// Which mask was applied.
    pub mode: MaskMode,
}

impl<F: FieldElement> SealedMatrix<F> {
    /// Ciphertext size in symbols (f32 elements) — used by the
    /// communication-complexity accounting (Fig. 6).
    pub fn symbols(&self) -> usize {
        self.payload.len()
    }
}

/// The MEA-ECC engine for one curve.
#[derive(Clone)]
pub struct MeaEcc<F: FieldElement> {
    curve: Curve<F>,
    mode: MaskMode,
}

impl<F: FieldElement> MeaEcc<F> {
    /// Create an engine with the given mask mode.
    pub fn new(curve: Curve<F>, mode: MaskMode) -> Self {
        Self { curve, mode }
    }

    /// The curve in use.
    pub fn curve(&self) -> &Curve<F> {
        &self.curve
    }

    /// §IV-B step 3 — encrypt `m` to the holder of `recipient_pk`.
    pub fn encrypt(
        &self,
        m: &Matrix,
        recipient_pk: &Point<F>,
        rng: &mut Rng,
    ) -> SealedMatrix<F> {
        let k = ephemeral_scalar(rng);
        let ephemeral = self.curve.mul_scalar(&k, &self.curve.generator());
        let shared = SharedSecret::from_point(self.curve.mul_scalar(&k, recipient_pk));
        let payload = apply_mask(m, &shared, self.mode, Direction::Seal);
        SealedMatrix { ephemeral, payload, mode: self.mode }
    }

    /// §IV-B step 4 — decrypt with the recipient's key pair.
    pub fn decrypt(&self, sealed: &SealedMatrix<F>, keys: &KeyPair<F>) -> Matrix {
        let shared =
            SharedSecret::from_point(self.curve.mul_scalar(keys.secret(), &sealed.ephemeral));
        apply_mask(&sealed.payload, &shared, sealed.mode, Direction::Open)
    }

    /// Seal a serialized byte buffer to the holder of `recipient_pk` —
    /// the wire form of §IV-B step 3.
    ///
    /// Always uses the keystream construction (a byte-level XOR pad from
    /// the shared point): the rank-one mask is an f32 addition and has no
    /// meaning on raw bytes. Self-inverse, so [`MeaEcc::open_bytes`] is
    /// the same XOR under the recomputed shared point.
    pub fn seal_bytes(
        &self,
        plain: &[u8],
        recipient_pk: &Point<F>,
        rng: &mut Rng,
    ) -> SealedBytes<F> {
        self.seal_bytes_owned(plain.to_vec(), recipient_pk, rng)
    }

    /// [`MeaEcc::seal_bytes`] consuming the plaintext buffer: the
    /// keystream is XORed *in place*, so sealing an already-serialized
    /// payload allocates nothing. This is the master/worker hot path
    /// (`SealedPayload::seal`).
    pub fn seal_bytes_owned(
        &self,
        mut plain: Vec<u8>,
        recipient_pk: &Point<F>,
        rng: &mut Rng,
    ) -> SealedBytes<F> {
        let k = ephemeral_scalar(rng);
        let ephemeral = self.curve.mul_scalar(&k, &self.curve.generator());
        let shared = SharedSecret::from_point(self.curve.mul_scalar(&k, recipient_pk));
        xor_keystream_in_place(&mut plain, &shared);
        SealedBytes { ephemeral, bytes: plain }
    }

    /// Open a sealed byte buffer with the recipient's key pair — the
    /// wire form of §IV-B step 4.
    pub fn open_bytes(&self, sealed: &SealedBytes<F>, keys: &KeyPair<F>) -> Vec<u8> {
        let mut bytes = sealed.bytes.clone();
        self.unmask_in_place(&sealed.ephemeral, &mut bytes, keys);
        bytes
    }

    /// [`MeaEcc::open_bytes`] consuming the ciphertext: the pad is
    /// removed in place and the same buffer is returned as plaintext —
    /// the collector/worker unseal path allocates nothing.
    pub fn open_bytes_owned(&self, sealed: SealedBytes<F>, keys: &KeyPair<F>) -> Vec<u8> {
        let SealedBytes { ephemeral, mut bytes } = sealed;
        self.unmask_in_place(&ephemeral, &mut bytes, keys);
        bytes
    }

    fn unmask_in_place(&self, ephemeral: &Point<F>, bytes: &mut [u8], keys: &KeyPair<F>) {
        let shared =
            SharedSecret::from_point(self.curve.mul_scalar(keys.secret(), ephemeral));
        xor_keystream_in_place(bytes, &shared);
    }
}

/// Fresh ephemeral scalar k, 1 < k < q, shared by both seal paths.
/// §Perf optimization #2: a 64-bit ephemeral is enough — the simulation
/// curve's group order is ~2^61, so wider scalars only add doubling
/// iterations without adding entropy (halves the per-message scalar-mul
/// cost).
fn ephemeral_scalar(rng: &mut Rng) -> U256 {
    loop {
        let cand = U256::from_u64(rng.next_u64());
        if !cand.is_zero() && cand != U256::ONE {
            break cand;
        }
    }
}

/// XOR `bytes` in place with the SplitMix64 keystream seeded from the
/// shared point, 8 bytes per draw. Self-inverse; no allocation.
///
/// The loop body lives in [`crate::simd::keystream`] (the scalar form
/// moved there verbatim as the oracle); the stream is byte-identical at
/// every SIMD level.
fn xor_keystream_in_place<F: FieldElement>(bytes: &mut [u8], shared: &SharedSecret<F>) {
    crate::simd::keystream::xor_in_place(bytes, shared.keystream_seed());
}

/// Per-element 32-bit XOR keystream over f32 bit patterns, in place.
/// Identical stream layout to the original out-of-place version: the
/// high half of each SplitMix64 draw masks the even element, the low
/// half the odd one, and a trailing element takes a fresh 32-bit draw.
///
/// Kernel dispatched through [`crate::simd::keystream`]; bit-identical
/// at every SIMD level.
fn mask_f32_keystream_in_place<F: FieldElement>(data: &mut [f32], shared: &SharedSecret<F>) {
    crate::simd::keystream::mask_f32_in_place(data, shared.keystream_seed());
}

#[derive(Clone, Copy, PartialEq)]
enum Direction {
    Seal,
    Open,
}

/// Apply (or remove) the mask derived from the shared point.
fn apply_mask<F: FieldElement>(
    m: &Matrix,
    shared: &SharedSecret<F>,
    mode: MaskMode,
    dir: Direction,
) -> Matrix {
    match mode {
        MaskMode::Keystream => {
            // XOR a per-element 32-bit keystream onto the f32 bit
            // pattern, in place on one buffer copy. Self-inverse, so
            // Seal and Open are the same op. §Perf optimization #3:
            // consume both 32-bit halves of each SplitMix64 output
            // (2 elements per draw); no per-element pushes.
            let mut data = m.as_slice().to_vec();
            mask_f32_keystream_in_place(&mut data, shared);
            Matrix::from_vec(m.rows(), m.cols(), data)
        }
        MaskMode::RankOne => {
            // Paper-literal: C = M + Ψ(shared)·𝟙. Ψ (the x-coordinate) is
            // folded to a float of magnitude ~2^20 so the addition stays
            // numerically invertible for f32 payloads.
            let psi = shared
                .point()
                .psi()
                .map(|x| x.to_limbs()[0])
                .unwrap_or(0);
            let scalar = ((psi % (1 << 20)) as f32) + ((psi >> 20) % 1024) as f32 / 1024.0;
            let signed = if dir == Direction::Seal { scalar } else { -scalar };
            m.map(|x| x + signed)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecc::sim_curve;
    use crate::rng::rng_from_seed;

    fn setup() -> (MeaEcc<crate::field::Fp61>, KeyPair<crate::field::Fp61>, Rng) {
        let curve = sim_curve();
        let mut rng = rng_from_seed(7);
        let worker = KeyPair::generate(&curve, &mut rng);
        (MeaEcc::new(curve, MaskMode::Keystream), worker, rng)
    }

    #[test]
    fn keystream_roundtrip_is_bit_exact() {
        let (mea, worker, mut rng) = setup();
        let m = Matrix::random_gaussian(17, 9, 0.0, 3.0, &mut rng);
        let sealed = mea.encrypt(&m, &worker.public(), &mut rng);
        let opened = mea.decrypt(&sealed, &worker);
        assert_eq!(opened, m, "keystream decrypt must be bit-exact");
    }

    #[test]
    fn rank_one_roundtrip_is_close() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(8);
        let worker = KeyPair::generate(&curve, &mut rng);
        let mea = MeaEcc::new(curve, MaskMode::RankOne);
        let m = Matrix::random_uniform(8, 8, -1.0, 1.0, &mut rng);
        let sealed = mea.encrypt(&m, &worker.public(), &mut rng);
        let opened = mea.decrypt(&sealed, &worker);
        // Rank-one mask adds then subtracts a ~2^20 float: rounding loss
        // is bounded by the f32 ulp at that magnitude (~0.0625).
        assert!(opened.max_abs_diff(&m) < 0.13, "diff={}", opened.max_abs_diff(&m));
    }

    #[test]
    fn ciphertext_differs_from_plaintext() {
        let (mea, worker, mut rng) = setup();
        let m = Matrix::ones(16, 16);
        let sealed = mea.encrypt(&m, &worker.public(), &mut rng);
        // Every element should be perturbed with overwhelming probability.
        let changed = sealed
            .payload
            .as_slice()
            .iter()
            .zip(m.as_slice())
            .filter(|(c, p)| c != p)
            .count();
        assert!(changed > 250, "only {changed}/256 elements masked");
    }

    #[test]
    fn wrong_key_fails_to_decrypt() {
        let (mea, worker, mut rng) = setup();
        let eve = KeyPair::generate(mea.curve(), &mut rng);
        let m = Matrix::random_uniform(10, 10, -1.0, 1.0, &mut rng);
        let sealed = mea.encrypt(&m, &worker.public(), &mut rng);
        let eavesdropped = mea.decrypt(&sealed, &eve);
        assert!(
            eavesdropped.max_abs_diff(&m) > 1e-3,
            "wrong key must not recover plaintext"
        );
    }

    #[test]
    fn fresh_ephemeral_per_message() {
        let (mea, worker, mut rng) = setup();
        let m = Matrix::ones(4, 4);
        let s1 = mea.encrypt(&m, &worker.public(), &mut rng);
        let s2 = mea.encrypt(&m, &worker.public(), &mut rng);
        assert_ne!(s1.ephemeral, s2.ephemeral, "ephemeral k must be fresh");
        assert_ne!(
            s1.payload.as_slice(),
            s2.payload.as_slice(),
            "same plaintext must yield different ciphertexts"
        );
    }

    #[test]
    fn seal_bytes_round_trip_is_exact() {
        let (mea, worker, mut rng) = setup();
        for len in [0usize, 1, 7, 8, 9, 64, 1023] {
            let plain: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            let sealed = mea.seal_bytes(&plain, &worker.public(), &mut rng);
            assert_eq!(sealed.len(), len);
            assert_eq!(mea.open_bytes(&sealed, &worker), plain, "len={len}");
        }
    }

    #[test]
    fn seal_bytes_masks_every_block() {
        let (mea, worker, mut rng) = setup();
        let plain = vec![0u8; 256];
        let sealed = mea.seal_bytes(&plain, &worker.public(), &mut rng);
        // The ciphertext of an all-zero buffer IS the keystream; it must
        // look nothing like the plaintext.
        let zeros = sealed.bytes.iter().filter(|&&b| b == 0).count();
        assert!(zeros < 32, "{zeros}/256 ciphertext bytes are zero");
    }

    #[test]
    fn seal_bytes_wrong_key_fails_to_open() {
        let (mea, worker, mut rng) = setup();
        let eve = KeyPair::generate(mea.curve(), &mut rng);
        let plain: Vec<u8> = (0..128).map(|i| i as u8).collect();
        let sealed = mea.seal_bytes(&plain, &worker.public(), &mut rng);
        assert_ne!(mea.open_bytes(&sealed, &eve), plain);
    }

    #[test]
    fn seal_bytes_fresh_ephemeral_per_message() {
        let (mea, worker, mut rng) = setup();
        let plain = vec![0x5Au8; 64];
        let s1 = mea.seal_bytes(&plain, &worker.public(), &mut rng);
        let s2 = mea.seal_bytes(&plain, &worker.public(), &mut rng);
        assert_ne!(s1.ephemeral, s2.ephemeral);
        assert_ne!(s1.bytes, s2.bytes);
    }

    #[test]
    fn keystream_ciphertext_decorrelated_from_plaintext() {
        // Empirical eavesdropper check: correlation between plaintext and
        // ciphertext bits should be ~0.
        let (mea, worker, mut rng) = setup();
        let m = Matrix::random_gaussian(32, 32, 0.0, 1.0, &mut rng);
        let sealed = mea.encrypt(&m, &worker.public(), &mut rng);
        // XORing bit patterns can produce NaN/Inf floats; sanitize the
        // ciphertext to finite values before computing moments.
        let sanitize = |v: f32| -> f64 {
            if v.is_finite() {
                (v.clamp(-1e6, 1e6)) as f64
            } else {
                0.0
            }
        };
        let n = m.len() as f64;
        let mx = m.as_slice().iter().map(|&x| x as f64).sum::<f64>() / n;
        let my = sealed.payload.as_slice().iter().map(|&x| sanitize(x)).sum::<f64>() / n;
        let mut cov = 0.0;
        let mut vx = 0.0;
        let mut vy = 0.0;
        for (a, b) in m.as_slice().iter().zip(sealed.payload.as_slice()) {
            let x = *a as f64 - mx;
            let y = sanitize(*b) - my;
            cov += x * y;
            vx += x * x;
            vy += y * y;
        }
        let corr = cov / (vx.sqrt() * vy.sqrt()).max(1e-30);
        assert!(corr.abs() < 0.2, "ciphertext correlates with plaintext: {corr}");
    }
}
