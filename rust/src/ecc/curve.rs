//! Short-Weierstrass curve arithmetic over a generic prime field.
//!
//! Implements the paper's Def. 2 and §IV-A operations: point addition /
//! doubling (Eqs. (9)–(11)) and scalar multiplication (Eq. (12), realized
//! as double-and-add rather than the literal repeated addition).

use crate::field::{FieldElement, U256};

/// A point on a curve: affine coordinates or the point at infinity.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Point<F: FieldElement> {
    /// The identity element 𝒪.
    Infinity,
    /// An affine point (x, y).
    Affine { x: F, y: F },
}

impl<F: FieldElement> Point<F> {
    /// Construct an affine point.
    pub fn affine(x: F, y: F) -> Self {
        Point::Affine { x, y }
    }

    /// True iff this is the identity.
    pub fn is_infinity(&self) -> bool {
        matches!(self, Point::Infinity)
    }

    /// x-coordinate, if affine. This is the paper's Ψ(x, y) = x map
    /// (§IV-B step 3).
    pub fn psi(&self) -> Option<F> {
        match self {
            Point::Infinity => None,
            Point::Affine { x, .. } => Some(*x),
        }
    }

    /// Both coordinates, if affine.
    pub fn xy(&self) -> Option<(F, F)> {
        match self {
            Point::Infinity => None,
            Point::Affine { x, y } => Some((*x, *y)),
        }
    }
}

/// A short-Weierstrass curve `y² = x³ + ax + b` with a chosen generator.
#[derive(Clone, Copy, Debug)]
pub struct Curve<F: FieldElement> {
    a: F,
    b: F,
    g: Point<F>,
}

impl<F: FieldElement> Curve<F> {
    /// Construct a curve; panics if the discriminant 4a³ + 27b² vanishes
    /// (Eq. (4)) or the generator is off-curve.
    pub fn new(a: F, b: F, g: Point<F>) -> Self {
        let four = F::from_u64(4);
        let twenty_seven = F::from_u64(27);
        let disc = four.mul(&a.mul(&a).mul(&a)).add(&twenty_seven.mul(&b.mul(&b)));
        assert!(!disc.is_zero(), "singular curve: 4a^3 + 27b^2 = 0");
        let c = Self { a, b, g };
        assert!(c.contains(&g), "generator not on curve");
        c
    }

    /// The generator point G.
    pub fn generator(&self) -> Point<F> {
        self.g
    }

    /// Curve coefficient a.
    pub fn a(&self) -> F {
        self.a
    }

    /// Curve coefficient b.
    pub fn b(&self) -> F {
        self.b
    }

    /// Membership test: y² == x³ + ax + b.
    pub fn contains(&self, p: &Point<F>) -> bool {
        match p {
            Point::Infinity => true,
            Point::Affine { x, y } => {
                let lhs = y.mul(y);
                let rhs = x.mul(x).mul(x).add(&self.a.mul(x)).add(&self.b);
                lhs == rhs
            }
        }
    }

    /// Point addition / doubling — Eqs. (9)–(11).
    pub fn add(&self, p: &Point<F>, q: &Point<F>) -> Point<F> {
        let (x1, y1) = match p {
            Point::Infinity => return *q,
            Point::Affine { x, y } => (*x, *y),
        };
        let (x2, y2) = match q {
            Point::Infinity => return *p,
            Point::Affine { x, y } => (*x, *y),
        };

        let lambda = if x1 == x2 {
            if y1 == y2.neg() {
                // P + (−P) = 𝒪 (covers y = 0 doubling too).
                return Point::Infinity;
            }
            // Doubling: λ = (3x₁² + a) / (2y₁)   (Eq. 11, P = Q branch)
            let three = F::from_u64(3);
            let two = F::from_u64(2);
            let num = three.mul(&x1.mul(&x1)).add(&self.a);
            let den = two.mul(&y1);
            num.mul(&den.inverse().expect("2y != 0 given y != -y"))
        } else {
            // Chord: λ = (y₂ − y₁) / (x₂ − x₁)   (Eq. 11, P ≠ Q branch)
            let num = y2.sub(&y1);
            let den = x2.sub(&x1);
            num.mul(&den.inverse().expect("x2 != x1"))
        };

        // x₃ = λ² − x₁ − x₂; y₃ = λ(x₁ − x₃) − y₁   (Eqs. 9–10)
        let x3 = lambda.mul(&lambda).sub(&x1).sub(&x2);
        let y3 = lambda.mul(&x1.sub(&x3)).sub(&y1);
        Point::Affine { x: x3, y: y3 }
    }

    /// Point doubling.
    pub fn double(&self, p: &Point<F>) -> Point<F> {
        self.add(p, p)
    }

    /// Scalar multiplication `k·P` by double-and-add (MSB first).
    ///
    /// Eq. (12) defines this as repeated addition; the realization here
    /// is Jacobian-projective double-and-add with mixed addition —
    /// §Perf optimization #1: the affine formulas spend one field
    /// inversion per point operation, which dominated the MEA-ECC seal
    /// cost; Jacobian coordinates defer to a single inversion at the end
    /// (measured ~5× on the seal path, see the `microbench` §Perf rows).
    pub fn mul_scalar(&self, k: &U256, p: &Point<F>) -> Point<F> {
        let (px, py) = match p {
            Point::Infinity => return Point::Infinity,
            Point::Affine { x, y } => (*x, *y),
        };
        let hb = match k.highest_bit() {
            Some(h) => h,
            None => return Point::Infinity,
        };
        // Jacobian accumulator (X, Y, Z); Z = 0 encodes infinity.
        let mut acc: Option<(F, F, F)> = None;
        for i in (0..=hb).rev() {
            if let Some(j) = acc {
                acc = Some(self.jac_double(&j));
            }
            if k.bit(i) {
                acc = Some(match acc {
                    None => (px, py, F::one()),
                    Some(j) => self.jac_add_mixed(&j, &px, &py),
                });
            }
        }
        match acc {
            None => Point::Infinity,
            Some((x, y, z)) => {
                if z.is_zero() {
                    return Point::Infinity;
                }
                // Affinize: (X/Z², Y/Z³), one inversion total.
                let zinv = z.inverse().expect("z != 0");
                let zi2 = zinv.square();
                let zi3 = zi2.mul(&zinv);
                Point::Affine { x: x.mul(&zi2), y: y.mul(&zi3) }
            }
        }
    }

    /// Jacobian doubling (general `a`):
    /// dbl-2007-bl without the a=−3 shortcut.
    fn jac_double(&self, (x, y, z): &(F, F, F)) -> (F, F, F) {
        if y.is_zero() || z.is_zero() {
            return (F::one(), F::one(), F::zero()); // infinity
        }
        let two = F::from_u64(2);
        let three = F::from_u64(3);
        let eight = F::from_u64(8);
        let xx = x.square();
        let yy = y.square();
        let yyyy = yy.square();
        // D = 2((X+YY)² − XX − YYYY)
        let d = two.mul(&(x.add(&yy)).square().sub(&xx).sub(&yyyy));
        // E = 3XX + a·Z⁴
        let z2 = z.square();
        let e = three.mul(&xx).add(&self.a.mul(&z2.square()));
        let x3 = e.square().sub(&two.mul(&d));
        let y3 = e.mul(&d.sub(&x3)).sub(&eight.mul(&yyyy));
        let z3 = two.mul(y).mul(z);
        (x3, y3, z3)
    }

    /// Mixed Jacobian + affine addition (madd-2007-bl shape).
    fn jac_add_mixed(&self, (x1, y1, z1): &(F, F, F), x2: &F, y2: &F) -> (F, F, F) {
        if z1.is_zero() {
            return (*x2, *y2, F::one());
        }
        let z1z1 = z1.square();
        let u2 = x2.mul(&z1z1);
        let s2 = y2.mul(&z1.mul(&z1z1));
        let h = u2.sub(x1);
        let r = s2.sub(y1);
        if h.is_zero() {
            if r.is_zero() {
                return self.jac_double(&(*x1, *y1, *z1));
            }
            return (F::one(), F::one(), F::zero()); // P + (−P) = 𝒪
        }
        let hh = h.square();
        let hhh = hh.mul(&h);
        let v = x1.mul(&hh);
        let two = F::from_u64(2);
        let x3 = r.square().sub(&hhh).sub(&two.mul(&v));
        let y3 = r.mul(&v.sub(&x3)).sub(&y1.mul(&hhh));
        let z3 = z1.mul(&h);
        (x3, y3, z3)
    }

    /// Scalar multiplication with a u64 scalar.
    pub fn mul_u64(&self, k: u64, p: &Point<F>) -> Point<F> {
        self.mul_scalar(&U256::from_u64(k), p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecc::sim_curve;
    use crate::field::Fp61;

    #[test]
    fn identity_laws() {
        let c = sim_curve();
        let g = c.generator();
        assert_eq!(c.add(&g, &Point::Infinity), g);
        assert_eq!(c.add(&Point::Infinity, &g), g);
        assert_eq!(
            c.add(&Point::<Fp61>::Infinity, &Point::Infinity),
            Point::Infinity
        );
    }

    #[test]
    fn addition_is_commutative_and_stays_on_curve() {
        let c = sim_curve();
        let g = c.generator();
        let g2 = c.double(&g);
        let g3 = c.add(&g, &g2);
        assert_eq!(g3, c.add(&g2, &g));
        assert!(c.contains(&g2));
        assert!(c.contains(&g3));
    }

    #[test]
    fn addition_is_associative_on_samples() {
        let c = sim_curve();
        let g = c.generator();
        let p = c.mul_u64(5, &g);
        let q = c.mul_u64(11, &g);
        let r = c.mul_u64(23, &g);
        assert_eq!(c.add(&c.add(&p, &q), &r), c.add(&p, &c.add(&q, &r)));
    }

    #[test]
    fn point_plus_negation_is_infinity() {
        let c = sim_curve();
        let g = c.generator();
        if let Point::Affine { x, y } = g {
            use crate::field::FieldElement;
            let neg = Point::affine(x, y.neg());
            assert!(c.contains(&neg));
            assert_eq!(c.add(&g, &neg), Point::Infinity);
        } else {
            panic!("generator must be affine");
        }
    }

    #[test]
    fn scalar_mul_matches_repeated_addition() {
        let c = sim_curve();
        let g = c.generator();
        let mut acc = Point::Infinity;
        for k in 1..=20u64 {
            acc = c.add(&acc, &g);
            assert_eq!(c.mul_u64(k, &g), acc, "k={k}");
        }
    }

    #[test]
    fn scalar_mul_distributes() {
        // (a+b)·G == a·G + b·G
        let c = sim_curve();
        let g = c.generator();
        for (a, b) in [(3u64, 4u64), (17, 99), (1000, 1)] {
            let lhs = c.mul_u64(a + b, &g);
            let rhs = c.add(&c.mul_u64(a, &g), &c.mul_u64(b, &g));
            assert_eq!(lhs, rhs);
        }
    }

    #[test]
    fn zero_scalar_gives_infinity() {
        let c = sim_curve();
        assert!(c.mul_u64(0, &c.generator()).is_infinity());
    }

    #[test]
    fn secp256k1_scalar_sanity() {
        // 2G, 3G on-curve; (n)·G would be 𝒪 but n-scalar test is covered
        // by the distributivity check at small scalars (full-order check
        // is expensive at 256 bits with shift-add mulmod).
        let c = crate::ecc::secp256k1();
        let g = c.generator();
        let g2 = c.double(&g);
        let g3 = c.add(&g2, &g);
        assert!(c.contains(&g2));
        assert!(c.contains(&g3));
        assert_eq!(c.mul_u64(3, &g), g3);
    }
}
