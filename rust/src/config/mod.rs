//! Typed configuration system (no `serde`/`toml` in this environment).
//!
//! [`SystemConfig`] is the single source of truth for an experiment run:
//! cluster shape (N, S, T, K), coding scheme, transport security, delay
//! model, DL hyper-parameters, and runtime/artifact paths. It can be
//! loaded from a TOML-subset file (`[section]` + `key = value` lines,
//! `#` comments), overridden by CLI options, and validated against the
//! paper's parameter constraints (e.g. K + T ≤ N for SPACDC encode).

mod parser;

pub use parser::{parse_file, parse_str, ConfigError, RawConfig};

/// Which coding scheme drives an experiment (paper Table II rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// Conventional uncoded distribution (CONV).
    Uncoded,
    /// MDS codes (Lee et al. [22]).
    Mds,
    /// MatDot codes [24].
    MatDot,
    /// Polynomial codes [23].
    Polynomial,
    /// Lagrange coded computing [27].
    Lcc,
    /// Secure polynomial codes [34].
    SecPoly,
    /// Berrut approximated coded computing [18] (no privacy).
    Bacc,
    /// This paper's scheme.
    Spacdc,
}

impl SchemeKind {
    /// Parse from the CLI/config token.
    pub fn from_str_token(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "uncoded" | "conv" => Self::Uncoded,
            "mds" => Self::Mds,
            "matdot" => Self::MatDot,
            "polynomial" | "poly" => Self::Polynomial,
            "lcc" => Self::Lcc,
            "secpoly" => Self::SecPoly,
            "bacc" => Self::Bacc,
            "spacdc" => Self::Spacdc,
            _ => return None,
        })
    }

    /// Canonical display name (paper nomenclature).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Uncoded => "CONV",
            Self::Mds => "MDS",
            Self::MatDot => "MATDOT",
            Self::Polynomial => "POLY",
            Self::Lcc => "LCC",
            Self::SecPoly => "SECPOLY",
            Self::Bacc => "BACC",
            Self::Spacdc => "SPACDC",
        }
    }

    /// All schemes, in Table II order.
    pub fn all() -> [SchemeKind; 8] {
        [
            Self::Polynomial,
            Self::MatDot,
            Self::SecPoly,
            Self::Bacc,
            Self::Lcc,
            Self::Spacdc,
            Self::Mds,
            Self::Uncoded,
        ]
    }
}

/// Transport security between master and workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TransportSecurity {
    /// Shares travel in the clear (all baselines, as in the paper).
    Plain,
    /// Shares sealed with MEA-ECC (§IV) — SPACDC's default.
    #[default]
    MeaEcc,
}

impl TransportSecurity {
    /// Parse from the CLI/config token.
    pub fn from_str_token(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "plain" => Self::Plain,
            "mea-ecc" | "mea_ecc" | "ecc" => Self::MeaEcc,
            _ => return None,
        })
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Plain => "plain",
            Self::MeaEcc => "mea-ecc",
        }
    }
}

/// Which fabric carries the framed wire bytes between master and
/// workers (`rust/src/transport/`). Every fabric moves the identical
/// serialized frames; TCP additionally crosses real localhost sockets,
/// and Proc additionally crosses real process boundaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum TransportKind {
    /// Per-worker in-process channels (default).
    #[default]
    InProc,
    /// Localhost TCP sockets, one connection per worker.
    Tcp,
    /// Real child processes (`spacdc worker`) over localhost TCP, under
    /// a process supervisor — DESIGN.md §9.
    Proc,
}

impl TransportKind {
    /// Parse from the CLI/config token.
    pub fn from_str_token(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "inproc" | "in-proc" | "channels" => Self::InProc,
            "tcp" | "sockets" => Self::Tcp,
            "proc" | "process" | "processes" => Self::Proc,
            _ => return None,
        })
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::InProc => "inproc",
            Self::Tcp => "tcp",
            Self::Proc => "proc",
        }
    }
}

/// Straggler delay injection, mirroring the paper's `sleep()` method.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DelayConfig {
    /// Multiplicative service-time factor for stragglers (e.g. 5.0 means
    /// a straggler takes 5× the nominal compute time).
    pub straggler_factor: f64,
    /// Base per-task artificial service time in seconds (the simulated
    /// "cluster-grade" compute cost floor; 0 disables).
    pub base_service_s: f64,
    /// Jitter fraction applied to every service time (uniform ±).
    pub jitter: f64,
}

impl Default for DelayConfig {
    fn default() -> Self {
        Self { straggler_factor: 5.0, base_service_s: 0.0, jitter: 0.1 }
    }
}

/// DL hyper-parameters for SPACDC-DL (§VI/§VII).
#[derive(Clone, Debug, PartialEq)]
pub struct DlConfig {
    /// Layer widths, input first, classes last.
    pub layers: Vec<usize>,
    /// Mini-batch size.
    pub batch_size: usize,
    /// Learning rate η.
    pub learning_rate: f32,
    /// Training epochs.
    pub epochs: usize,
    /// Training-set size (synthetic MNIST-like).
    pub train_examples: usize,
    /// Test-set size.
    pub test_examples: usize,
}

impl Default for DlConfig {
    fn default() -> Self {
        Self {
            layers: vec![784, 256, 128, 10],
            batch_size: 64,
            learning_rate: 0.05,
            epochs: 10,
            train_examples: 4096,
            test_examples: 1024,
        }
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    /// Number of workers N.
    pub workers: usize,
    /// Number of stragglers S.
    pub stragglers: usize,
    /// Number of colluding workers T (also the number of privacy masks).
    pub colluders: usize,
    /// Number of data partitions K.
    pub partitions: usize,
    /// Coding scheme.
    pub scheme: SchemeKind,
    /// Which fabric carries the framed bytes (in-proc channels or TCP).
    pub transport: TransportKind,
    /// Transport security (plaintext vs MEA-ECC sealed frames).
    pub security: TransportSecurity,
    /// Wall-clock budget for collecting one round's results, in seconds.
    /// A round that misses its deadline is abandoned with a typed error
    /// and its late results are counted as wasted work.
    pub round_deadline_s: f64,
    /// Width of the master-side thread pool driving the parallel hot
    /// paths (encode/seal fan-out, packed GEMM, Berrut decode). 0 = one
    /// thread per available core (the `auto` token at the config/CLI
    /// surface — an explicit `0` there is rejected as a typed error).
    /// The setting is process-wide (the last master built wins); results
    /// are bit-identical at any width (DESIGN.md §6).
    pub threads: usize,
    /// Round-stream window: how many rounds
    /// [`Master::run_stream`](crate::coordinator::Master::run_stream)
    /// keeps in flight at once (≥ 1; 1 = synchronous). Outcomes are
    /// bit-identical at any width — only throughput moves (DESIGN.md
    /// §8).
    pub inflight: usize,
    /// Speculative re-dispatch: re-send outstanding shares to other
    /// live workers — written-off shares immediately, live-but-slow
    /// ones at the deadline checkpoint; first result per share wins.
    pub speculate: bool,
    /// Named adversity scenario (or scenario-file path) for the scenario
    /// engine — empty when the run is not scenario-driven. Resolved by
    /// [`Scenario::load`](crate::sim::Scenario::load).
    pub scenario: String,
    /// Delay injection.
    pub delay: DelayConfig,
    /// DL hyper-parameters.
    pub dl: DlConfig,
    /// RNG seed for the whole experiment.
    pub seed: u64,
    /// Directory of AOT artifacts.
    pub artifacts_dir: String,
    /// Prefer the PJRT path when an artifact matches.
    pub use_pjrt: bool,
}

impl Default for SystemConfig {
    fn default() -> Self {
        // Paper §VII-B: N = 30 workers, T = 3 colluders, K chosen by the
        // experiment; scenarios vary S ∈ {0, 3, 5, 7}.
        Self {
            workers: 30,
            stragglers: 3,
            colluders: 3,
            partitions: 4,
            scheme: SchemeKind::Spacdc,
            transport: TransportKind::InProc,
            security: TransportSecurity::MeaEcc,
            round_deadline_s: 60.0,
            threads: 0,
            inflight: 1,
            speculate: false,
            scenario: String::new(),
            delay: DelayConfig::default(),
            dl: DlConfig::default(),
            seed: 0xC0DE,
            artifacts_dir: "artifacts".to_string(),
            use_pjrt: true,
        }
    }
}

/// Validation failure.
#[derive(Debug)]
pub enum ConfigValidationError {
    /// A structural constraint was violated.
    Invalid(String),
}

impl std::fmt::Display for ConfigValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigValidationError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigValidationError {}

/// Parse a thread-pool-width token from the config/CLI surface:
/// `"auto"` → 0 (one thread per core), `"N"` (N ≥ 1) → N. An explicit
/// `"0"` is rejected (`None`) — the caller turns that into a typed
/// error instead of letting the pool silently go auto-width.
pub fn parse_threads_token(s: &str) -> Option<usize> {
    if s.eq_ignore_ascii_case("auto") {
        return Some(0);
    }
    match s.parse::<usize>() {
        Ok(0) | Err(_) => None,
        Ok(n) => Some(n),
    }
}

impl SystemConfig {
    /// Validate the paper's structural constraints.
    pub fn validate(&self) -> Result<(), ConfigValidationError> {
        let err = |m: String| Err(ConfigValidationError::Invalid(m));
        if self.workers == 0 {
            return err("workers must be ≥ 1".into());
        }
        if self.partitions == 0 {
            return err("partitions K must be ≥ 1".into());
        }
        if self.stragglers >= self.workers {
            return err(format!(
                "stragglers S={} must be < workers N={}",
                self.stragglers, self.workers
            ));
        }
        // SPACDC/BACC encode at K+T interpolation nodes; sensible setups
        // keep K+T ≤ N so the non-straggling returns carry information.
        if matches!(self.scheme, SchemeKind::Spacdc)
            && self.partitions + self.colluders > self.workers
        {
            return err(format!(
                "SPACDC needs K+T ≤ N (K={}, T={}, N={})",
                self.partitions, self.colluders, self.workers
            ));
        }
        if matches!(self.scheme, SchemeKind::Mds | SchemeKind::Polynomial)
            && self.partitions > self.workers
        {
            return err(format!(
                "{} needs K ≤ N (K={}, N={})",
                self.scheme.name(),
                self.partitions,
                self.workers
            ));
        }
        if !(self.round_deadline_s > 0.0) {
            return err("round_deadline_s must be positive".into());
        }
        if self.inflight == 0 {
            return err("inflight must be ≥ 1 (1 = synchronous rounds)".into());
        }
        if self.dl.layers.len() < 2 {
            return err("DL network needs ≥ 2 layers".into());
        }
        if !(self.dl.learning_rate > 0.0) {
            return err("learning rate must be positive".into());
        }
        Ok(())
    }

    /// Apply `key = value` overrides from a parsed raw config.
    pub fn apply_raw(&mut self, raw: &RawConfig) -> Result<(), ConfigError> {
        for (section, key, value) in raw.entries() {
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            self.apply_kv(&full, value)?;
        }
        Ok(())
    }

    /// Apply one dotted-path override (also used for CLI `--set k=v`).
    pub fn apply_kv(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let bad = |k: &str, v: &str| ConfigError::BadValue(k.to_string(), v.to_string());
        match key {
            "cluster.workers" | "workers" => {
                self.workers = value.parse().map_err(|_| bad(key, value))?
            }
            "cluster.stragglers" | "stragglers" => {
                self.stragglers = value.parse().map_err(|_| bad(key, value))?
            }
            "cluster.colluders" | "colluders" => {
                self.colluders = value.parse().map_err(|_| bad(key, value))?
            }
            "cluster.partitions" | "partitions" => {
                self.partitions = value.parse().map_err(|_| bad(key, value))?
            }
            "cluster.scheme" | "scheme" => {
                self.scheme =
                    SchemeKind::from_str_token(value).ok_or_else(|| bad(key, value))?
            }
            "cluster.transport" | "transport" => {
                // This key historically carried the security knob; keep
                // accepting that vocabulary so old config files load.
                if let Some(sec) = TransportSecurity::from_str_token(value) {
                    self.security = sec;
                } else {
                    self.transport =
                        TransportKind::from_str_token(value).ok_or_else(|| bad(key, value))?
                }
            }
            "cluster.security" | "security" => {
                self.security =
                    TransportSecurity::from_str_token(value).ok_or_else(|| bad(key, value))?
            }
            "cluster.round_deadline_s" | "round_deadline_s" => {
                self.round_deadline_s = value.parse().map_err(|_| bad(key, value))?
            }
            "cluster.threads" | "threads" => {
                // An explicit 0 is a config mistake (the pool would
                // silently go auto-width); the auto behavior is spelled
                // "auto".
                self.threads = parse_threads_token(value).ok_or_else(|| {
                    ConfigError::BadValue(
                        key.to_string(),
                        format!("{value} (pool width must be ≥ 1, or 'auto')"),
                    )
                })?
            }
            "cluster.inflight" | "stream.inflight" | "inflight" => {
                let n: usize = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(ConfigError::BadValue(
                        key.to_string(),
                        format!("{value} (stream window must be ≥ 1)"),
                    ));
                }
                self.inflight = n;
            }
            "cluster.speculate" | "stream.speculate" | "speculate" => {
                self.speculate = match value {
                    "true" | "1" | "yes" | "on" => true,
                    "false" | "0" | "no" | "off" => false,
                    _ => return Err(bad(key, value)),
                }
            }
            "cluster.scenario" | "scenario" => self.scenario = value.to_string(),
            "delay.straggler_factor" => {
                self.delay.straggler_factor = value.parse().map_err(|_| bad(key, value))?
            }
            "delay.base_service_s" => {
                self.delay.base_service_s = value.parse().map_err(|_| bad(key, value))?
            }
            "delay.jitter" => self.delay.jitter = value.parse().map_err(|_| bad(key, value))?,
            "dl.batch_size" => {
                self.dl.batch_size = value.parse().map_err(|_| bad(key, value))?
            }
            "dl.learning_rate" => {
                self.dl.learning_rate = value.parse().map_err(|_| bad(key, value))?
            }
            "dl.epochs" => self.dl.epochs = value.parse().map_err(|_| bad(key, value))?,
            "dl.train_examples" => {
                self.dl.train_examples = value.parse().map_err(|_| bad(key, value))?
            }
            "dl.test_examples" => {
                self.dl.test_examples = value.parse().map_err(|_| bad(key, value))?
            }
            "dl.layers" => {
                let layers: Result<Vec<usize>, _> =
                    value.split(',').map(|t| t.trim().parse()).collect();
                self.dl.layers = layers.map_err(|_| bad(key, value))?;
            }
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            "runtime.artifacts_dir" | "artifacts_dir" => {
                self.artifacts_dir = value.to_string()
            }
            "runtime.use_pjrt" | "use_pjrt" => {
                self.use_pjrt = match value {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    _ => return Err(bad(key, value)),
                }
            }
            _ => return Err(ConfigError::UnknownKey(key.to_string())),
        }
        Ok(())
    }

    /// Load from a config file, then validate.
    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let raw = parse_file(path)?;
        let mut cfg = Self::default();
        cfg.apply_raw(&raw)?;
        cfg.validate().map_err(|e| ConfigError::Validation(e.to_string()))?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_scenario_2() {
        let c = SystemConfig::default();
        assert_eq!(c.workers, 30);
        assert_eq!(c.colluders, 3);
        assert_eq!(c.stragglers, 3);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn apply_kv_overrides() {
        let mut c = SystemConfig::default();
        c.apply_kv("workers", "8").unwrap();
        c.apply_kv("scheme", "bacc").unwrap();
        c.apply_kv("dl.layers", "784, 100, 10").unwrap();
        assert_eq!(c.workers, 8);
        assert_eq!(c.scheme, SchemeKind::Bacc);
        assert_eq!(c.dl.layers, vec![784, 100, 10]);
    }

    #[test]
    fn transport_key_selects_the_fabric() {
        let mut c = SystemConfig::default();
        assert_eq!(c.transport, TransportKind::InProc);
        c.apply_kv("transport", "tcp").unwrap();
        assert_eq!(c.transport, TransportKind::Tcp);
        c.apply_kv("cluster.transport", "inproc").unwrap();
        assert_eq!(c.transport, TransportKind::InProc);
        assert!(c.apply_kv("transport", "carrier-pigeon").is_err());
    }

    #[test]
    fn legacy_transport_values_still_set_security() {
        // The `transport` key carried the security knob before the
        // fabric existed; old config files must keep loading.
        let mut c = SystemConfig::default();
        c.apply_kv("transport", "plain").unwrap();
        assert_eq!(c.security, TransportSecurity::Plain);
        assert_eq!(c.transport, TransportKind::InProc, "fabric untouched");
        c.apply_kv("security", "mea-ecc").unwrap();
        assert_eq!(c.security, TransportSecurity::MeaEcc);
    }

    #[test]
    fn threads_key_is_configurable() {
        let mut c = SystemConfig::default();
        assert_eq!(c.threads, 0, "default is auto");
        c.apply_kv("threads", "8").unwrap();
        assert_eq!(c.threads, 8);
        c.apply_kv("cluster.threads", "1").unwrap();
        assert_eq!(c.threads, 1);
        c.apply_kv("threads", "auto").unwrap();
        assert_eq!(c.threads, 0, "'auto' spells the one-per-core width");
        assert!(c.apply_kv("threads", "many").is_err());
        assert!(
            matches!(c.apply_kv("threads", "0"), Err(ConfigError::BadValue(_, _))),
            "an explicit 0 must be a typed config error, not silent auto"
        );
        assert!(c.validate().is_ok());
    }

    #[test]
    fn threads_token_parser_spells_auto() {
        assert_eq!(parse_threads_token("auto"), Some(0));
        assert_eq!(parse_threads_token("AUTO"), Some(0));
        assert_eq!(parse_threads_token("4"), Some(4));
        assert_eq!(parse_threads_token("0"), None);
        assert_eq!(parse_threads_token("-1"), None);
        assert_eq!(parse_threads_token("lots"), None);
    }

    #[test]
    fn stream_keys_are_plumbed_and_validated() {
        let mut c = SystemConfig::default();
        assert_eq!(c.inflight, 1, "default stream is synchronous");
        assert!(!c.speculate, "speculation is opt-in");
        c.apply_kv("inflight", "16").unwrap();
        assert_eq!(c.inflight, 16);
        c.apply_kv("stream.inflight", "4").unwrap();
        assert_eq!(c.inflight, 4);
        assert!(
            matches!(c.apply_kv("inflight", "0"), Err(ConfigError::BadValue(_, _))),
            "a zero window must be a typed config error"
        );
        assert!(c.apply_kv("inflight", "wide").is_err());
        c.apply_kv("speculate", "true").unwrap();
        assert!(c.speculate);
        c.apply_kv("stream.speculate", "off").unwrap();
        assert!(!c.speculate);
        assert!(c.apply_kv("speculate", "maybe").is_err());
        assert!(c.validate().is_ok());
        c.inflight = 0;
        assert!(c.validate().is_err(), "inflight = 0 must not validate");
    }

    #[test]
    fn scenario_key_is_plumbed() {
        let mut c = SystemConfig::default();
        assert!(c.scenario.is_empty());
        c.apply_kv("scenario", "crash-respawn").unwrap();
        assert_eq!(c.scenario, "crash-respawn");
        c.apply_kv("cluster.scenario", "scenarios/baseline.toml").unwrap();
        assert_eq!(c.scenario, "scenarios/baseline.toml");
        assert!(c.validate().is_ok());
    }

    #[test]
    fn round_deadline_is_configurable_and_validated() {
        let mut c = SystemConfig::default();
        c.apply_kv("round_deadline_s", "2.5").unwrap();
        assert_eq!(c.round_deadline_s, 2.5);
        c.round_deadline_s = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = SystemConfig::default();
        assert!(matches!(
            c.apply_kv("nope.nothing", "1"),
            Err(ConfigError::UnknownKey(_))
        ));
    }

    #[test]
    fn bad_value_rejected() {
        let mut c = SystemConfig::default();
        assert!(matches!(
            c.apply_kv("workers", "lots"),
            Err(ConfigError::BadValue(_, _))
        ));
    }

    #[test]
    fn validation_catches_too_many_stragglers() {
        let mut c = SystemConfig::default();
        c.stragglers = 30;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_catches_kt_exceeding_n() {
        let mut c = SystemConfig::default();
        c.partitions = 28;
        c.colluders = 5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn scheme_token_roundtrip() {
        for s in SchemeKind::all() {
            let token = s.name().to_ascii_lowercase();
            let token = match token.as_str() {
                "conv" => "uncoded".to_string(),
                "poly" => "polynomial".to_string(),
                t => t.to_string(),
            };
            assert_eq!(SchemeKind::from_str_token(&token), Some(s));
        }
    }
}
