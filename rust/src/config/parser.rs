//! Minimal TOML-subset parser: `[section]` headers, `key = value` pairs,
//! `#` comments, blank lines. Values are kept as raw strings; typing
//! happens in `SystemConfig::apply_kv`.

/// Parse/IO error for config loading.
#[derive(Debug)]
pub enum ConfigError {
    /// File could not be read.
    Io(String, String),
    /// A line failed to parse.
    Syntax(usize, String),
    /// Key exists but value failed to type-check.
    BadValue(String, String),
    /// Key is not a recognized configuration path.
    UnknownKey(String),
    /// Structural validation failed after load.
    Validation(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(path, err) => write!(f, "cannot read config {path}: {err}"),
            ConfigError::Syntax(line, msg) => {
                write!(f, "config syntax error at line {line}: {msg}")
            }
            ConfigError::BadValue(key, value) => write!(f, "bad value for {key}: {value:?}"),
            ConfigError::UnknownKey(key) => write!(f, "unknown config key: {key}"),
            ConfigError::Validation(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// A parsed-but-untyped config: ordered (section, key, value) triples.
#[derive(Debug, Default, Clone)]
pub struct RawConfig {
    entries: Vec<(String, String, String)>,
}

impl RawConfig {
    /// Iterate (section, key, value). Section is "" before any header.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &str)> {
        self.entries.iter().map(|(s, k, v)| (s.as_str(), k.as_str(), v.as_str()))
    }

    /// Lookup the last value for (section, key).
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .rev()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v.as_str())
    }
}

/// Parse config text.
pub fn parse_str(text: &str) -> Result<RawConfig, ConfigError> {
    let mut cfg = RawConfig::default();
    let mut section = String::new();
    for (lineno, raw_line) in text.lines().enumerate() {
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| ConfigError::Syntax(lineno + 1, raw_line.to_string()))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| ConfigError::Syntax(lineno + 1, raw_line.to_string()))?;
        let value = value.trim().trim_matches('"');
        cfg.entries.push((section.clone(), key.trim().to_string(), value.to_string()));
    }
    Ok(cfg)
}

/// Parse a config file from disk.
pub fn parse_file(path: &str) -> Result<RawConfig, ConfigError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ConfigError::Io(path.to_string(), e.to_string()))?;
    parse_str(&text)
}

fn strip_comment(line: &str) -> &str {
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_keys_comments() {
        let text = r#"
# experiment
seed = 7
[cluster]
workers = 30     # paper N
scheme = "spacdc"
[dl]
layers = 784,256,10
"#;
        let cfg = parse_str(text).unwrap();
        assert_eq!(cfg.get("", "seed"), Some("7"));
        assert_eq!(cfg.get("cluster", "workers"), Some("30"));
        assert_eq!(cfg.get("cluster", "scheme"), Some("spacdc"));
        assert_eq!(cfg.get("dl", "layers"), Some("784,256,10"));
    }

    #[test]
    fn later_values_win() {
        let cfg = parse_str("a = 1\na = 2\n").unwrap();
        assert_eq!(cfg.get("", "a"), Some("2"));
    }

    #[test]
    fn syntax_errors_carry_line_numbers() {
        let err = parse_str("ok = 1\nbroken line\n").unwrap_err();
        match err {
            ConfigError::Syntax(line, _) => assert_eq!(line, 2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn unterminated_section_is_error() {
        assert!(parse_str("[cluster\n").is_err());
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(matches!(
            parse_file("/nonexistent/path.toml"),
            Err(ConfigError::Io(_, _))
        ));
    }
}
