//! xoshiro256++ — Blackman & Vigna (2019). The crate's general-purpose
//! generator: 256 bits of state, 1-cycle output mix, passes BigCrush.

use super::SplitMix64;

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 per the authors' recommendation (avoids the
    /// all-zero state and decorrelates similar seeds).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next u32 from the upper bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0,1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0,1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below: bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; the twin is
    /// discarded — simplicity beats caching here).
    pub fn gaussian(&mut self) -> f64 {
        // Avoid ln(0) by nudging u1 away from zero.
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with the given mean / standard deviation.
    pub fn gaussian_with(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.gaussian()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Xoshiro256pp::seed_from_u64(5);
        let mut b = Xoshiro256pp::seed_from_u64(5);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn next_below_respects_bound_and_hits_all_values() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gaussian_moments_are_sane() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>(), "astronomically unlikely");
    }

    #[test]
    fn choose_indices_distinct_and_in_range() {
        let mut r = Xoshiro256pp::seed_from_u64(13);
        for _ in 0..50 {
            let idx = r.choose_indices(30, 7);
            assert_eq!(idx.len(), 7);
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 7, "indices must be distinct");
            assert!(idx.iter().all(|&i| i < 30));
        }
    }
}
