//! SplitMix64 — Steele, Lea & Flood (2014). 64 bits of state, passes
//! BigCrush, and is the canonical seeder for xoshiro-family generators.

/// SplitMix64 generator. One `u64` of state; each step adds the golden
/// gamma and mixes with two xor-shift-multiply rounds.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Golden-ratio increment.
    pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

    /// Create a generator from a raw 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(Self::GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next u32 (upper bits — better distributed than lower).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0,1) using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0,1) using the top 24 bits.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Fill a slice with raw u64 output (MEA-ECC keystream expansion).
    pub fn fill_u64(&mut self, out: &mut [u64]) {
        for v in out.iter_mut() {
            *v = self.next_u64();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vector() {
        // Reference values for seed 0 from the public-domain C code
        // (http://prng.di.unimi.it/splitmix64.c).
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(g.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(g.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = SplitMix64::new(123);
        let mut b = SplitMix64::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_stays_in_unit_interval_and_is_not_constant() {
        let mut g = SplitMix64::new(7);
        let xs: Vec<f64> = (0..256).map(|_| g.next_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn fill_matches_sequential_draws() {
        let mut a = SplitMix64::new(9);
        let mut b = SplitMix64::new(9);
        let mut buf = [0u64; 16];
        a.fill_u64(&mut buf);
        for v in buf {
            assert_eq!(v, b.next_u64());
        }
    }
}
