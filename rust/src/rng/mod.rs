//! Deterministic pseudo-random number generation substrate.
//!
//! The crate registry available in this environment ships no `rand`, so
//! this module provides the generators the rest of the system needs:
//!
//! * [`SplitMix64`] — tiny, state-jumpable; used for seeding and for the
//!   MEA-ECC keystream expansion (`ecc::mea`).
//! * [`Xoshiro256pp`] — the general-purpose generator (uniform u64/f32/f64,
//!   ranges, shuffles, Gaussians via Box–Muller).
//!
//! All generators are deterministic from their seed; every experiment in
//! the benches threads an explicit seed so runs are reproducible.

mod splitmix;
mod xoshiro;

pub use splitmix::SplitMix64;
pub use xoshiro::Xoshiro256pp;

/// Convenience alias: the default RNG used across the crate.
pub type Rng = Xoshiro256pp;

/// Build the default RNG from a u64 seed.
pub fn rng_from_seed(seed: u64) -> Rng {
    Xoshiro256pp::seed_from_u64(seed)
}

/// Derive a child seed from a parent seed and a stream index.
///
/// Used to give each worker / layer / epoch an independent stream without
/// correlated low bits (plain `seed + i` would correlate xoshiro states).
pub fn derive_seed(parent: u64, stream: u64) -> u64 {
    let mut sm = SplitMix64::new(parent ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    sm.next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_deterministic() {
        assert_eq!(derive_seed(42, 7), derive_seed(42, 7));
    }

    #[test]
    fn derive_seed_differs_by_stream() {
        assert_ne!(derive_seed(42, 0), derive_seed(42, 1));
        assert_ne!(derive_seed(42, 1), derive_seed(43, 1));
    }

    #[test]
    fn default_rng_uniform_f64_in_unit_interval() {
        let mut r = rng_from_seed(1);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
