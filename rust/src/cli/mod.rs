//! Command-line parsing substrate (no `clap` in this environment).
//!
//! A small declarative parser: `ArgSpec` declares flags with defaults and
//! help text; `parse` validates, fills defaults, and renders usage. Used
//! by `main.rs` and the examples.

use std::collections::BTreeMap;

/// Declared option kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgKind {
    /// `--flag` (boolean, no value).
    Flag,
    /// `--key value` (string-valued).
    Value,
}

/// One declared argument.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    /// Long name without the `--`.
    pub name: &'static str,
    /// Kind of the argument.
    pub kind: ArgKind,
    /// Default (for Value args).
    pub default: Option<&'static str>,
    /// Help line.
    pub help: &'static str,
}

impl ArgSpec {
    /// Declare a boolean flag.
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        Self { name, kind: ArgKind::Flag, default: None, help }
    }

    /// Declare a valued option with a default.
    pub fn opt(name: &'static str, default: &'static str, help: &'static str) -> Self {
        Self { name, kind: ArgKind::Value, default: Some(default), help }
    }

    /// Declare a required valued option.
    pub fn required(name: &'static str, help: &'static str) -> Self {
        Self { name, kind: ArgKind::Value, default: None, help }
    }
}

/// Parsed arguments: typed getters over a string map.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Non-flag positional arguments in order.
    pub positional: Vec<String>,
}

impl Parsed {
    /// String value (always present when declared with a default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Required string value; panics with a clear message if missing.
    pub fn get_str(&self, name: &str) -> &str {
        self.get(name).unwrap_or_else(|| panic!("missing required --{name}"))
    }

    /// Parse a value as usize.
    pub fn get_usize(&self, name: &str) -> usize {
        self.parse_as(name)
    }

    /// Parse a value as u64.
    pub fn get_u64(&self, name: &str) -> u64 {
        self.parse_as(name)
    }

    /// Parse a value as f64.
    pub fn get_f64(&self, name: &str) -> f64 {
        self.parse_as(name)
    }

    fn parse_as<T: std::str::FromStr>(&self, name: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get_str(name);
        raw.parse().unwrap_or_else(|e| panic!("--{name}={raw}: {e}"))
    }

    /// Was the boolean flag given?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse error.
#[derive(Debug)]
pub enum CliError {
    /// Unknown `--option`.
    Unknown(String, String),
    /// Declared Value option had no value token.
    MissingValue(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name, usage) => write!(f, "unknown option --{name}\n{usage}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
        }
    }
}

impl std::error::Error for CliError {}

/// Render a usage/help block for a spec set.
pub fn usage(program: &str, specs: &[ArgSpec]) -> String {
    let mut out = format!("usage: {program} [options]\n\noptions:\n");
    for s in specs {
        let left = match (s.kind, s.default) {
            (ArgKind::Flag, _) => format!("--{}", s.name),
            (ArgKind::Value, Some(d)) => format!("--{} <v={}>", s.name, d),
            (ArgKind::Value, None) => format!("--{} <v> (required)", s.name),
        };
        out.push_str(&format!("  {left:<28} {}\n", s.help));
    }
    out
}

/// Parse `args` (without argv[0]) against `specs`.
pub fn parse(args: &[String], specs: &[ArgSpec]) -> Result<Parsed, CliError> {
    let mut parsed = Parsed::default();
    // Seed defaults.
    for s in specs {
        if let (ArgKind::Value, Some(d)) = (s.kind, s.default) {
            parsed.values.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < args.len() {
        let tok = &args[i];
        if let Some(name) = tok.strip_prefix("--") {
            // Support --key=value in one token.
            let (name, inline_val) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let spec = specs.iter().find(|s| s.name == name).ok_or_else(|| {
                CliError::Unknown(name.to_string(), usage("", specs))
            })?;
            match spec.kind {
                ArgKind::Flag => parsed.flags.push(name.to_string()),
                ArgKind::Value => {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.to_string()))?
                        }
                    };
                    parsed.values.insert(name.to_string(), val);
                }
            }
        } else {
            parsed.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(parsed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ArgSpec> {
        vec![
            ArgSpec::opt("workers", "30", "number of workers N"),
            ArgSpec::opt("stragglers", "3", "number of stragglers S"),
            ArgSpec::flag("verbose", "chatty output"),
            ArgSpec::required("scheme", "coding scheme"),
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_are_seeded() {
        let p = parse(&sv(&["--scheme", "spacdc"]), &specs()).unwrap();
        assert_eq!(p.get_usize("workers"), 30);
        assert_eq!(p.get_str("scheme"), "spacdc");
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let p =
            parse(&sv(&["--workers", "8", "--verbose", "--scheme=mds"]), &specs()).unwrap();
        assert_eq!(p.get_usize("workers"), 8);
        assert!(p.has_flag("verbose"));
        assert_eq!(p.get_str("scheme"), "mds");
    }

    #[test]
    fn positional_args_collected() {
        let p = parse(&sv(&["train", "--scheme", "bacc", "extra"]), &specs()).unwrap();
        assert_eq!(p.positional, vec!["train", "extra"]);
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(matches!(
            parse(&sv(&["--nope"]), &specs()),
            Err(CliError::Unknown(_, _))
        ));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(matches!(
            parse(&sv(&["--workers"]), &specs()),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn usage_mentions_every_option() {
        let u = usage("spacdc", &specs());
        for s in specs() {
            assert!(u.contains(s.name));
        }
    }

    #[test]
    #[should_panic(expected = "missing required --scheme")]
    fn required_getter_panics_when_absent() {
        let p = parse(&sv(&[]), &specs()).unwrap();
        let _ = p.get_str("scheme");
    }
}
