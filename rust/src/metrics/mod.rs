//! Telemetry substrate: counters, timers, and histograms used by the
//! coordinator and the bench harnesses.
//!
//! The communication-complexity experiments (Fig. 6, Table II) need exact
//! symbol counts on every master↔worker edge; the training-time
//! experiments (Figs. 3–4) need wall-clock phase timers. Everything here
//! is plain data guarded by atomics/mutexes so worker threads can record
//! without contention on the hot path.

mod histogram;
mod registry;

pub use histogram::Histogram;
pub use registry::{names, MetricsRegistry, PhaseTimer};

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// New counter at zero.
    pub const fn new() -> Self {
        Self { value: AtomicU64::new(0) }
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Reset to zero, returning the previous value.
    pub fn reset(&self) -> u64 {
        self.value.swap(0, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        assert_eq!(c.reset(), 5);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn counter_is_thread_safe() {
        let c = Arc::new(Counter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }
}
