//! A simple sample-recording histogram with percentile queries.

/// Records raw f64 samples; queries sort on demand. Fine for the volumes
/// the benches produce (≤ 10⁶ samples).
#[derive(Debug, Default, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (0 if < 2 samples).
    pub fn std_dev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Minimum (0 if empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min).min(f64::INFINITY)
    }

    /// Maximum (0 if empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by nearest-rank (p in [0,100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 99th percentile.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// All samples (for export).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_of_known_sequence() {
        let mut h = Histogram::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean() - 3.0).abs() < 1e-12);
        assert!((h.std_dev() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 5.0);
        assert_eq!(h.p50(), 3.0);
    }

    #[test]
    fn percentile_edges() {
        let mut h = Histogram::new();
        for i in 0..100 {
            h.record(i as f64);
        }
        assert_eq!(h.percentile(0.0), 0.0);
        assert_eq!(h.percentile(100.0), 99.0);
        assert!((h.p99() - 98.0).abs() <= 1.0);
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.std_dev(), 0.0);
    }
}
