//! Named metric registry + phase timers for the coordinator.

use super::Histogram;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Central metrics sink shared by master and workers.
///
/// Counters cover the communication accounting the paper's Fig. 6 needs
/// (symbols master→workers, workers→master) plus scheduling events;
/// histograms cover per-phase latencies.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

/// Well-known counter names.
pub mod names {
    /// Symbols (f32 elements) sent master → workers.
    pub const SYMBOLS_TO_WORKERS: &str = "comm.symbols_to_workers";
    /// Symbols (f32 elements) sent workers → master.
    pub const SYMBOLS_TO_MASTER: &str = "comm.symbols_to_master";
    /// Serialized frame bytes the transport sent master → workers.
    pub const BYTES_TX: &str = "comm.bytes_tx";
    /// Serialized frame bytes of the results each decode consumed
    /// (credited at decode time, so the counter is deterministic;
    /// rejected/late frames are never charged).
    pub const BYTES_RX: &str = "comm.bytes_rx";
    /// Frames dropped for failing wire validation (truncation/corruption).
    pub const WIRE_ERRORS: &str = "comm.wire_errors";
    /// Tasks dispatched.
    pub const TASKS_DISPATCHED: &str = "sched.tasks_dispatched";
    /// Results accepted by the decoder.
    pub const RESULTS_USED: &str = "sched.results_used";
    /// Results that arrived after the decode fired (wasted work).
    pub const RESULTS_LATE: &str = "sched.results_late";
    /// Rounds whose wait policy was lowered to "decode from what can
    /// still arrive" after mid-round worker loss.
    pub const ROUNDS_DEGRADED: &str = "sched.rounds_degraded";
    /// Work orders re-sent speculatively (a written-off share re-keyed
    /// to another worker, or a pending share duplicated near the
    /// deadline).
    pub const SPEC_REDISPATCHED: &str = "spec.redispatched";
    /// Written-off shares whose result arrived after a speculative
    /// re-dispatch — work the round would otherwise have lost.
    pub const SPEC_RECOVERED: &str = "spec.recovered";
    /// Duplicate share copies discarded by first-result-wins (the losing
    /// side of a speculative race).
    pub const SPEC_WASTED: &str = "spec.wasted";
    /// Buffered results computed by a *different* worker than the share's
    /// original owner — speculative races won by the re-dispatch copy.
    /// Attributable since wire v2 put the executor id on `ResultMsg`.
    pub const SPEC_WON_BY_PROXY: &str = "spec.won_by_proxy";
    /// Worker crashes the master observed (injected, scheduled, or link
    /// death).
    pub const WORKER_CRASHES: &str = "lifecycle.crashes";
    /// Worker incarnations respawned and re-registered.
    pub const WORKER_RESPAWNS: &str = "lifecycle.respawns";
    /// Executions that went through the PJRT artifact path.
    pub const PJRT_EXECUTIONS: &str = "runtime.pjrt_executions";
    /// Executions that fell back to the native kernel.
    pub const NATIVE_EXECUTIONS: &str = "runtime.native_executions";
    /// Results whose share commitment the collector checked against the
    /// round's encode-time ledger (every verifiable arrival).
    pub const VERIFY_CHECKED: &str = "verify.checked";
    /// Results dropped for a commitment mismatch (collector layer) or a
    /// failed redundancy residual at decode — forged results detected.
    pub const VERIFY_FORGED_DETECTED: &str = "verify.forged_detected";
    /// Executors newly quarantined (marked suspect) after a verified
    /// forgery; a suspect is excluded from speculative picks.
    pub const VERIFY_QUARANTINED: &str = "verify.quarantined";
    /// Quarantined executors readmitted after a verified-good result.
    pub const VERIFY_REHABILITATED: &str = "verify.rehabilitated";
    /// Rounds completed through the session front end (all tenants).
    pub const TENANT_ROUNDS: &str = "tenant.rounds";
    /// Completed tenant rounds that decoded degraded (fewer results).
    pub const TENANT_DEGRADED: &str = "tenant.degraded";
    /// Admission-control refusals: a lane had window space but the
    /// global in-flight cap turned its next submission away.
    pub const TENANT_REFUSED: &str = "tenant.refused";
}

impl MetricsRegistry {
    /// Fresh empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` to the named counter (creating it at zero).
    pub fn add(&self, name: &str, n: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += n;
    }

    /// Increment the named counter.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Read a counter (0 if absent).
    pub fn get(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Record a duration/latency sample (seconds) under `name`.
    pub fn record(&self, name: &str, seconds: f64) {
        let mut h = self.histograms.lock().unwrap();
        h.entry(name.to_string()).or_default().record(seconds);
    }

    /// Snapshot a histogram by name.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.histograms.lock().unwrap().get(name).cloned()
    }

    /// Start a phase timer that records into `name` on drop.
    pub fn time_phase<'a>(&'a self, name: &'a str) -> PhaseTimer<'a> {
        PhaseTimer { registry: self, name, start: Instant::now() }
    }

    /// Render all counters + histogram summaries as aligned text.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {k:<32} {v}\n"));
            }
        }
        let hists = self.histograms.lock().unwrap();
        if !hists.is_empty() {
            out.push_str("timers (s): name, n, mean, p50, p99, max\n");
            for (k, h) in hists.iter() {
                out.push_str(&format!(
                    "  {:<32} {:>6} {:>10.6} {:>10.6} {:>10.6} {:>10.6}\n",
                    k,
                    h.count(),
                    h.mean(),
                    h.p50(),
                    h.p99(),
                    h.max()
                ));
            }
        }
        out
    }

    /// Reset everything (between bench scenarios).
    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.histograms.lock().unwrap().clear();
    }
}

/// RAII phase timer: records elapsed seconds into its histogram on drop.
pub struct PhaseTimer<'a> {
    registry: &'a MetricsRegistry,
    name: &'a str,
    start: Instant,
}

impl Drop for PhaseTimer<'_> {
    fn drop(&mut self) {
        self.registry.record(self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_by_name() {
        let m = MetricsRegistry::new();
        m.add(names::SYMBOLS_TO_WORKERS, 100);
        m.add(names::SYMBOLS_TO_WORKERS, 50);
        m.inc(names::TASKS_DISPATCHED);
        assert_eq!(m.get(names::SYMBOLS_TO_WORKERS), 150);
        assert_eq!(m.get(names::TASKS_DISPATCHED), 1);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn phase_timer_records_on_drop() {
        let m = MetricsRegistry::new();
        {
            let _t = m.time_phase("phase.test");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let h = m.histogram("phase.test").unwrap();
        assert_eq!(h.count(), 1);
        assert!(h.mean() >= 0.004, "recorded {}", h.mean());
    }

    #[test]
    fn report_contains_names() {
        let m = MetricsRegistry::new();
        m.inc("a.b");
        m.record("t.x", 0.5);
        let rep = m.report();
        assert!(rep.contains("a.b"));
        assert!(rep.contains("t.x"));
    }

    #[test]
    fn reset_clears_state() {
        let m = MetricsRegistry::new();
        m.inc("x");
        m.record("y", 1.0);
        m.reset();
        assert_eq!(m.get("x"), 0);
        assert!(m.histogram("y").is_none());
    }
}
