//! Body codecs: typed messages ⇄ little-endian bytes.
//!
//! Field-by-field layouts (all integers LE):
//!
//! ```text
//! Matrix       := rows:u32 cols:u32 data:[f32; rows·cols]
//! Point        := tag:u8 (0 = infinity | 1 = affine x:u64 y:u64)
//! WorkerOp     := tag:u8 (0 = Gram | 1 = RightMul Matrix |
//!                         2 = PairProduct | 3 = Identity)
//! WirePayload  := tag:u8 (0 = Plain Matrix |
//!                         1 = Sealed Point rows:u32 cols:u32
//!                             len:u32 bytes:[u8; len])
//! WorkOrder    := round:u64 worker:u32 lane:u32 lane_round:u64
//!                 served:u64 delay_ns:u64 WorkerOp
//!                 n_payloads:u16 WirePayload* commitment:u64
//! ResultMsg    := round:u64 worker:u32 executor:u32 WirePayload
//!                 commitment:u64
//! ControlMsg   := tag:u8 (1 = Crash worker:u32 |
//!                         2 = Register worker:u32 generation:u32 Point)
//! ```
//!
//! A sealed payload travels as MEA-ECC seal-the-bytes: the ephemeral
//! point in the clear, the matrix *shape* in the clear (framing needs
//! it), and the row-major f32 data bytes XOR-masked by the keystream —
//! see [`SealedPayload`](crate::coordinator::SealedPayload).

use super::frame::{unframe, MsgKind, WireError, MAX_BODY_LEN};
use crate::coordinator::{ControlMsg, ResultMsg, SealedPayload, WirePayload, WorkOrder};
use crate::ecc::{Point, SealedBytes};
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::runtime::WorkerOp;
use std::sync::Arc;
use std::time::Duration;

/// Matrix dimensions above this are treated as corruption.
const MAX_DIM: usize = 1 << 24;

/// A decoded frame, either direction.
#[derive(Debug)]
pub enum WireMessage {
    /// Master → worker.
    Order(WorkOrder),
    /// Worker → master.
    Result(ResultMsg),
    /// Lifecycle control, either direction.
    Control(ControlMsg),
}

impl WireMessage {
    /// Compact tag for diagnostics: misrouted frames are reported by
    /// kind only — Debug-formatting a whole message would dump payload
    /// buffers (megabytes for a large sealed matrix) to the log.
    pub fn kind_name(&self) -> &'static str {
        match self {
            WireMessage::Order(_) => "order",
            WireMessage::Result(_) => "result",
            WireMessage::Control(_) => "control",
        }
    }
}

/// Encode a work order into a complete frame.
pub fn encode_order(order: &WorkOrder) -> Vec<u8> {
    let mut out = Vec::new();
    encode_order_into(order, &mut out);
    out
}

/// Encode a work order into a caller-owned buffer: cleared, sized to
/// the exact frame length up front (one `reserve`, no growth
/// reallocations), body written straight into it. The dispatch path
/// must hand frame ownership to the transport, so it uses the one-shot
/// [`encode_order`] wrapper and gets the exact-capacity single
/// allocation; true scratch reuse is for callers that send from a
/// borrowed slice (the worker loop's [`encode_result_into`]).
pub fn encode_order_into(order: &WorkOrder, out: &mut Vec<u8>) {
    // Clear before reserving: `reserve` is relative to the current len,
    // so reserving over a still-full scratch would over-allocate.
    // (frame_begin clears again, harmlessly — it must also serve
    // callers that never reserve.)
    out.clear();
    let body_len = 8
        + 4
        + 4
        + 8
        + 8
        + 8
        + op_encoded_len(&order.op)
        + 2
        + order.payloads.iter().map(payload_encoded_len).sum::<usize>()
        + 8;
    let total = super::frame::HEADER_LEN + body_len + super::frame::TRAILER_LEN;
    out.reserve(total);
    let start = super::frame::frame_begin(out, MsgKind::Order);
    put_u64(out, order.round);
    put_u32(out, order.worker as u32);
    // Wire v4: the fault coordinates ride between the routing fields
    // and the delay (DESIGN.md §13).
    put_u32(out, order.lane);
    put_u64(out, order.lane_round);
    put_u64(out, order.served);
    put_u64(out, order.delay.as_nanos() as u64);
    put_op(out, &order.op);
    put_u16(out, order.payloads.len() as u16);
    for p in &order.payloads {
        put_payload(out, p);
    }
    // Wire v3: the share commitment rides at the end of the body so the
    // fixed-offset router peeks over the leading fields stay valid.
    put_u64(out, order.commitment);
    super::frame::frame_end(out, start);
    debug_assert_eq!(out.len(), total, "order size estimate out of sync with the writers");
}

/// Encode a worker result into a complete frame.
pub fn encode_result(msg: &ResultMsg) -> Vec<u8> {
    let mut out = Vec::new();
    encode_result_into(msg, &mut out);
    out
}

/// Encode a worker result into a caller-owned scratch buffer (see
/// [`encode_order_into`]); the worker loop reuses one buffer for every
/// result it sends.
pub fn encode_result_into(msg: &ResultMsg, out: &mut Vec<u8>) {
    // Clear before reserving — see encode_order_into.
    out.clear();
    let body_len = 8 + 4 + 4 + payload_encoded_len(&msg.payload) + 8;
    let total = super::frame::HEADER_LEN + body_len + super::frame::TRAILER_LEN;
    out.reserve(total);
    let start = super::frame::frame_begin(out, MsgKind::Result);
    put_u64(out, msg.round);
    put_u32(out, msg.worker as u32);
    put_u32(out, msg.executor as u32);
    put_payload(out, &msg.payload);
    // Wire v3: the commitment echo trails the payload (see order codec).
    put_u64(out, msg.commitment);
    super::frame::frame_end(out, start);
    debug_assert_eq!(out.len(), total, "result size estimate out of sync with the writers");
}

/// Encode a control message into a complete frame.
pub fn encode_control(msg: &ControlMsg) -> Vec<u8> {
    let mut out = Vec::new();
    encode_control_into(msg, &mut out);
    out
}

/// Encode a control message into a caller-owned scratch buffer (see
/// [`encode_order_into`]).
pub fn encode_control_into(msg: &ControlMsg, out: &mut Vec<u8>) {
    // Clear before reserving — see encode_order_into.
    out.clear();
    let body_len = match msg {
        ControlMsg::Crash { .. } => 1 + 4,
        ControlMsg::Register { pk, .. } => 1 + 4 + 4 + point_encoded_len(pk),
    };
    let total = super::frame::HEADER_LEN + body_len + super::frame::TRAILER_LEN;
    out.reserve(total);
    let start = super::frame::frame_begin(out, MsgKind::Control);
    match msg {
        ControlMsg::Crash { worker } => {
            out.push(1);
            put_u32(out, *worker as u32);
        }
        ControlMsg::Register { worker, generation, pk } => {
            out.push(2);
            put_u32(out, *worker as u32);
            put_u32(out, *generation);
            put_point(out, pk);
        }
    }
    super::frame::frame_end(out, start);
    debug_assert_eq!(out.len(), total, "control size estimate out of sync with the writers");
}

/// Exact encoded size of a [`Point`] body field.
fn point_encoded_len(p: &Point<Fp61>) -> usize {
    if p.xy().is_some() {
        17
    } else {
        1
    }
}

/// Exact encoded size of a [`WorkerOp`] body field.
fn op_encoded_len(op: &WorkerOp) -> usize {
    match op {
        WorkerOp::Gram | WorkerOp::PairProduct | WorkerOp::Identity => 1,
        WorkerOp::RightMul(v) => 1 + 8 + v.len() * 4,
    }
}

/// Exact encoded size of a [`WirePayload`] body field.
fn payload_encoded_len(p: &WirePayload) -> usize {
    match p {
        WirePayload::Plain(m) => 1 + 8 + m.len() * 4,
        WirePayload::Sealed(s) => {
            1 + point_encoded_len(&s.sealed.ephemeral) + 4 + 4 + 4 + s.sealed.bytes.len()
        }
    }
}

/// Cheap router peek: the message kind of a frame, from the fixed
/// header alone. `None` when the buffer is too short or carries the
/// wrong magic — the caller hands such frames to a full decoder, which
/// produces the typed error and the `comm.wire_errors` tick.
pub fn peek_kind(buf: &[u8]) -> Option<MsgKind> {
    use super::frame::{HEADER_LEN, MAGIC};
    if buf.len() < HEADER_LEN {
        return None;
    }
    if u32::from_le_bytes(buf[0..4].try_into().unwrap()) != MAGIC {
        return None;
    }
    match buf[6] {
        1 => Some(MsgKind::Order),
        2 => Some(MsgKind::Result),
        3 => Some(MsgKind::Control),
        _ => None,
    }
}

/// Cheap router peek: the round id of a *result* frame (the first body
/// field), without validating or decoding the rest. The collector's
/// router uses this to shard inbound frames by round; full validation —
/// CRC included — still happens on the shard thread, so a corrupted
/// round id merely routes the frame to the wrong shard, where it fails
/// validation exactly as it would have on the right one.
pub fn peek_result_round(buf: &[u8]) -> Option<u64> {
    use super::frame::HEADER_LEN;
    if peek_kind(buf) != Some(MsgKind::Result) || buf.len() < HEADER_LEN + 8 {
        return None;
    }
    Some(u64::from_le_bytes(buf[HEADER_LEN..HEADER_LEN + 8].try_into().unwrap()))
}

/// Decode either message kind from a complete frame.
pub fn decode_message(buf: &[u8]) -> Result<WireMessage, WireError> {
    let (kind, body) = unframe(buf)?;
    let mut cur = Cur::new(body);
    let msg = match kind {
        MsgKind::Order => WireMessage::Order(read_order(&mut cur)?),
        MsgKind::Result => WireMessage::Result(read_result(&mut cur)?),
        MsgKind::Control => WireMessage::Control(read_control(&mut cur)?),
    };
    cur.finish()?;
    Ok(msg)
}

/// Decode a frame that must be a work order.
pub fn decode_order(buf: &[u8]) -> Result<WorkOrder, WireError> {
    match decode_message(buf)? {
        WireMessage::Order(o) => Ok(o),
        _ => Err(WireError::Malformed("expected an order frame".into())),
    }
}

/// Decode a frame that must be a worker result.
pub fn decode_result(buf: &[u8]) -> Result<ResultMsg, WireError> {
    match decode_message(buf)? {
        WireMessage::Result(r) => Ok(r),
        _ => Err(WireError::Malformed("expected a result frame".into())),
    }
}

/// Row-major little-endian f32 bytes of a matrix — the buffer MEA-ECC
/// seals for the wire.
pub fn matrix_to_le_bytes(m: &Matrix) -> Vec<u8> {
    let mut out = Vec::with_capacity(m.len() * 4);
    for v in m.as_slice() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Rebuild a matrix from row-major little-endian f32 bytes.
pub fn matrix_from_le_bytes(rows: usize, cols: usize, bytes: &[u8]) -> Result<Matrix, WireError> {
    let elems = check_dims(rows, cols)?;
    if bytes.len() != elems * 4 {
        return Err(WireError::Malformed(format!(
            "matrix data is {} bytes, {rows}x{cols} needs {}",
            bytes.len(),
            elems * 4
        )));
    }
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Hex-encode a point in its wire layout — the `--master-pk`
/// command-line form for out-of-process workers: tiny, shell-safe, and
/// byte-identical to what a `Register` frame would carry.
pub fn point_to_hex(p: &Point<Fp61>) -> String {
    let mut bytes = Vec::with_capacity(17);
    put_point(&mut bytes, p);
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        use std::fmt::Write;
        let _ = write!(s, "{b:02x}");
    }
    s
}

/// Decode [`point_to_hex`].
pub fn point_from_hex(s: &str) -> Result<Point<Fp61>, WireError> {
    let s = s.trim();
    if !s.is_ascii() || s.len() % 2 != 0 {
        return Err(WireError::Malformed(format!("bad point hex {s:?}")));
    }
    let bytes: Vec<u8> = (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16))
        .collect::<Result<_, _>>()
        .map_err(|_| WireError::Malformed(format!("bad point hex {s:?}")))?;
    let mut cur = Cur::new(&bytes);
    let p = read_point(&mut cur)?;
    cur.finish()?;
    Ok(p)
}

fn check_dims(rows: usize, cols: usize) -> Result<usize, WireError> {
    if rows > MAX_DIM || cols > MAX_DIM {
        return Err(WireError::Malformed(format!("matrix dims {rows}x{cols} over cap")));
    }
    let elems = rows
        .checked_mul(cols)
        .ok_or_else(|| WireError::Malformed(format!("matrix dims {rows}x{cols} overflow")))?;
    if elems * 4 > MAX_BODY_LEN {
        return Err(WireError::Malformed(format!("matrix {rows}x{cols} over body cap")));
    }
    Ok(elems)
}

// ---------------------------------------------------------------- writers

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_matrix(out: &mut Vec<u8>, m: &Matrix) {
    put_u32(out, m.rows() as u32);
    put_u32(out, m.cols() as u32);
    out.extend_from_slice(&matrix_to_le_bytes(m));
}

fn put_point(out: &mut Vec<u8>, p: &Point<Fp61>) {
    match p.xy() {
        None => out.push(0),
        Some((x, y)) => {
            out.push(1);
            put_u64(out, x.value());
            put_u64(out, y.value());
        }
    }
}

fn put_op(out: &mut Vec<u8>, op: &WorkerOp) {
    match op {
        WorkerOp::Gram => out.push(0),
        WorkerOp::RightMul(v) => {
            out.push(1);
            put_matrix(out, v);
        }
        WorkerOp::PairProduct => out.push(2),
        WorkerOp::Identity => out.push(3),
    }
}

fn put_payload(out: &mut Vec<u8>, p: &WirePayload) {
    match p {
        WirePayload::Plain(m) => {
            out.push(0);
            put_matrix(out, m);
        }
        WirePayload::Sealed(s) => {
            out.push(1);
            put_point(out, &s.sealed.ephemeral);
            put_u32(out, s.rows as u32);
            put_u32(out, s.cols as u32);
            put_u32(out, s.sealed.bytes.len() as u32);
            out.extend_from_slice(&s.sealed.bytes);
        }
    }
}

// ---------------------------------------------------------------- readers

/// Bounds-checked body reader.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let rest = self.buf.len() - self.pos;
        if rest < n {
            return Err(WireError::Truncated { need: n, got: rest });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// The whole body must be consumed — leftovers mean a framing bug.
    fn finish(&self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed(format!(
                "{} unconsumed body bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn read_matrix(cur: &mut Cur) -> Result<Matrix, WireError> {
    let rows = cur.u32()? as usize;
    let cols = cur.u32()? as usize;
    let elems = check_dims(rows, cols)?;
    let bytes = cur.take(elems * 4)?;
    matrix_from_le_bytes(rows, cols, bytes)
}

fn read_point(cur: &mut Cur) -> Result<Point<Fp61>, WireError> {
    match cur.u8()? {
        0 => Ok(Point::Infinity),
        1 => {
            let x = Fp61::new(cur.u64()?);
            let y = Fp61::new(cur.u64()?);
            Ok(Point::affine(x, y))
        }
        tag => Err(WireError::BadTag { what: "point", tag }),
    }
}

fn read_op(cur: &mut Cur) -> Result<WorkerOp, WireError> {
    match cur.u8()? {
        0 => Ok(WorkerOp::Gram),
        1 => Ok(WorkerOp::RightMul(Arc::new(read_matrix(cur)?))),
        2 => Ok(WorkerOp::PairProduct),
        3 => Ok(WorkerOp::Identity),
        tag => Err(WireError::BadTag { what: "worker op", tag }),
    }
}

fn read_payload(cur: &mut Cur) -> Result<WirePayload, WireError> {
    match cur.u8()? {
        0 => Ok(WirePayload::Plain(read_matrix(cur)?)),
        1 => {
            let ephemeral = read_point(cur)?;
            let rows = cur.u32()? as usize;
            let cols = cur.u32()? as usize;
            let elems = check_dims(rows, cols)?;
            let len = cur.u32()? as usize;
            if len != elems * 4 {
                return Err(WireError::Malformed(format!(
                    "sealed payload is {len} bytes, {rows}x{cols} needs {}",
                    elems * 4
                )));
            }
            let bytes = cur.take(len)?.to_vec();
            Ok(WirePayload::Sealed(SealedPayload {
                sealed: SealedBytes { ephemeral, bytes },
                rows,
                cols,
            }))
        }
        tag => Err(WireError::BadTag { what: "payload", tag }),
    }
}

fn read_order(cur: &mut Cur) -> Result<WorkOrder, WireError> {
    let round = cur.u64()?;
    let worker = cur.u32()? as usize;
    let lane = cur.u32()?;
    let lane_round = cur.u64()?;
    let served = cur.u64()?;
    let delay = Duration::from_nanos(cur.u64()?);
    let op = read_op(cur)?;
    let n = cur.u16()? as usize;
    let mut payloads = Vec::with_capacity(n);
    for _ in 0..n {
        payloads.push(read_payload(cur)?);
    }
    let commitment = cur.u64()?;
    Ok(WorkOrder { round, worker, lane, lane_round, served, op, payloads, delay, commitment })
}

fn read_result(cur: &mut Cur) -> Result<ResultMsg, WireError> {
    let round = cur.u64()?;
    let worker = cur.u32()? as usize;
    let executor = cur.u32()? as usize;
    let payload = read_payload(cur)?;
    let commitment = cur.u64()?;
    Ok(ResultMsg { round, worker, executor, payload, commitment })
}

fn read_control(cur: &mut Cur) -> Result<ControlMsg, WireError> {
    match cur.u8()? {
        1 => Ok(ControlMsg::Crash { worker: cur.u32()? as usize }),
        2 => {
            let worker = cur.u32()? as usize;
            let generation = cur.u32()?;
            let pk = read_point(cur)?;
            Ok(ControlMsg::Register { worker, generation, pk })
        }
        tag => Err(WireError::BadTag { what: "control", tag }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;
    use crate::wire::frame;

    fn payloads_eq(a: &WirePayload, b: &WirePayload) -> bool {
        match (a, b) {
            (WirePayload::Plain(x), WirePayload::Plain(y)) => x == y,
            (WirePayload::Sealed(x), WirePayload::Sealed(y)) => {
                x.sealed.ephemeral == y.sealed.ephemeral
                    && x.sealed.bytes == y.sealed.bytes
                    && x.rows == y.rows
                    && x.cols == y.cols
            }
            _ => false,
        }
    }

    #[test]
    fn plain_order_round_trips() {
        let mut rng = rng_from_seed(1);
        let m = Matrix::random_gaussian(5, 7, 0.0, 1.0, &mut rng);
        let v = Matrix::random_gaussian(7, 3, 0.0, 1.0, &mut rng);
        let order = WorkOrder {
            round: 42,
            worker: 3,
            lane: 2,
            lane_round: 11,
            served: 40,
            op: WorkerOp::RightMul(Arc::new(v.clone())),
            payloads: vec![WirePayload::Plain(m.clone())],
            delay: Duration::from_millis(17),
            commitment: 0xDEAD_BEEF_0123_4567,
        };
        let back = decode_order(&encode_order(&order)).unwrap();
        assert_eq!(back.round, 42);
        assert_eq!(back.worker, 3);
        assert_eq!(back.lane, 2);
        assert_eq!(back.lane_round, 11);
        assert_eq!(back.served, 40);
        assert_eq!(back.delay, Duration::from_millis(17));
        assert_eq!(back.commitment, 0xDEAD_BEEF_0123_4567);
        assert!(matches!(&back.op, WorkerOp::RightMul(w) if **w == v));
        assert_eq!(back.payloads.len(), 1);
        assert!(payloads_eq(&back.payloads[0], &order.payloads[0]));
    }

    #[test]
    fn sealed_result_round_trips() {
        let msg = ResultMsg {
            round: 9,
            worker: 11,
            executor: 4,
            payload: WirePayload::Sealed(SealedPayload {
                sealed: SealedBytes {
                    ephemeral: Point::affine(Fp61::new(123), Fp61::new(456)),
                    bytes: vec![0xAB; 2 * 3 * 4],
                },
                rows: 2,
                cols: 3,
            }),
            commitment: 0x0123_4567_89AB_CDEF,
        };
        let back = decode_result(&encode_result(&msg)).unwrap();
        assert_eq!(back.round, 9);
        assert_eq!(back.worker, 11);
        assert_eq!(back.executor, 4);
        assert_eq!(back.commitment, 0x0123_4567_89AB_CDEF);
        assert!(payloads_eq(&back.payload, &msg.payload));
    }

    #[test]
    fn into_encoders_match_and_reuse_scratch_exactly() {
        let mut rng = rng_from_seed(77);
        let m = Matrix::random_gaussian(6, 9, 0.0, 1.0, &mut rng);
        let order = WorkOrder {
            round: 3,
            worker: 1,
            lane: 0,
            lane_round: 3,
            served: 3,
            op: WorkerOp::RightMul(Arc::new(Matrix::ones(9, 2))),
            payloads: vec![
                WirePayload::Plain(m),
                WirePayload::Sealed(SealedPayload {
                    sealed: SealedBytes {
                        ephemeral: Point::affine(Fp61::new(5), Fp61::new(9)),
                        bytes: vec![0x11; 6 * 9 * 4],
                    },
                    rows: 6,
                    cols: 9,
                }),
            ],
            delay: Duration::ZERO,
            commitment: 77,
        };
        let one_shot = encode_order(&order);
        let mut scratch = Vec::new();
        encode_order_into(&order, &mut scratch);
        assert_eq!(scratch, one_shot);
        // The size estimate is exact (the debug_assert inside the
        // encoder pins estimate == actual), so a second encode into the
        // grown buffer must not reallocate.
        let before = scratch.capacity();
        encode_order_into(&order, &mut scratch);
        assert_eq!(scratch.capacity(), before, "re-encoding must not reallocate");
        assert_eq!(scratch, one_shot);

        let msg = ResultMsg {
            round: 3,
            worker: 1,
            executor: 1,
            payload: WirePayload::Plain(Matrix::ones(2, 2)),
            commitment: 78,
        };
        let mut scratch = Vec::new();
        encode_result_into(&msg, &mut scratch);
        assert_eq!(scratch, encode_result(&msg));
    }

    #[test]
    fn control_messages_round_trip() {
        for msg in [
            ControlMsg::Crash { worker: 7 },
            ControlMsg::Register {
                worker: 3,
                generation: 2,
                pk: Point::affine(Fp61::new(11), Fp61::new(22)),
            },
            ControlMsg::Register { worker: 0, generation: 0, pk: Point::Infinity },
        ] {
            let f = encode_control(&msg);
            match decode_message(&f).unwrap() {
                WireMessage::Control(back) => assert_eq!(back, msg),
                other => panic!("expected a control frame, got {other:?}"),
            }
            // Control frames must not decode as orders or results.
            assert!(decode_order(&f).is_err());
            assert!(decode_result(&f).is_err());
        }
    }

    #[test]
    fn kind_confusion_is_rejected() {
        let msg = ResultMsg {
            round: 1,
            worker: 0,
            executor: 0,
            payload: WirePayload::Plain(Matrix::ones(1, 1)),
            commitment: 0,
        };
        let f = encode_result(&msg);
        assert!(decode_order(&f).is_err());
    }

    #[test]
    fn empty_matrix_round_trips() {
        let order = WorkOrder {
            round: 1,
            worker: 0,
            lane: 0,
            lane_round: 1,
            served: 1,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Plain(Matrix::zeros(0, 4))],
            delay: Duration::ZERO,
            commitment: 0,
        };
        let back = decode_order(&encode_order(&order)).unwrap();
        assert!(matches!(&back.payloads[0],
            WirePayload::Plain(m) if m.shape() == (0, 4)));
    }

    #[test]
    fn point_hex_round_trips() {
        for p in [Point::Infinity, Point::affine(Fp61::new(7), Fp61::new(123_4567))] {
            let hex = point_to_hex(&p);
            assert_eq!(point_from_hex(&hex).unwrap(), p);
        }
        assert!(point_from_hex("zz").is_err(), "non-hex digits");
        assert!(point_from_hex("0").is_err(), "odd length");
        assert!(point_from_hex("02").is_err(), "unknown point tag");
        assert!(point_from_hex("01ff").is_err(), "truncated affine point");
    }

    #[test]
    fn sealed_length_mismatch_is_rejected() {
        // Hand-assemble a sealed payload whose byte length disagrees
        // with its shape.
        let mut body = Vec::new();
        put_u64(&mut body, 1); // round
        put_u32(&mut body, 0); // worker
        put_u32(&mut body, 0); // executor
        body.push(1); // sealed payload tag
        put_point(&mut body, &Point::affine(Fp61::new(1), Fp61::new(2)));
        put_u32(&mut body, 2); // rows
        put_u32(&mut body, 2); // cols
        put_u32(&mut body, 7); // wrong: needs 16
        body.extend_from_slice(&[0u8; 7]);
        put_u64(&mut body, 0); // commitment echo
        let f = frame(MsgKind::Result, &body);
        assert!(matches!(decode_result(&f), Err(WireError::Malformed(_))));
    }
}
