//! The byte-level wire format — DESIGN.md §5.
//!
//! Until this module existed, master↔worker "transmission" moved live
//! `Matrix` structs through channels: nothing was ever serialized, so the
//! MEA-ECC transmission-security story was simulated rather than
//! exercised, and the Fig. 6 communication accounting could only count
//! symbols. Everything crossing a link is now one *frame* — a versioned,
//! checksummed, little-endian byte envelope ([`frame`]/[`unframe`]) around
//! a message body ([`encode_order`]/[`decode_order`],
//! [`encode_result`]/[`decode_result`]) — whatever the transport
//! ([`crate::transport`]) underneath: in-process channels carry the same
//! bytes TCP sockets do, and the byte counters (`comm.bytes_tx` /
//! `comm.bytes_rx`) measure real serialized traffic.
//!
//! Corruption and truncation surface as typed [`WireError`]s: a flipped
//! bit anywhere in a frame fails the CRC (or a structural check) rather
//! than decoding into a plausible message.

mod codec;
mod frame;

pub use codec::{
    decode_message, decode_order, decode_result, encode_control, encode_control_into,
    encode_order, encode_order_into, encode_result, encode_result_into, matrix_from_le_bytes,
    matrix_to_le_bytes, peek_kind, peek_result_round, point_from_hex, point_to_hex, WireMessage,
};
pub use frame::{
    crc32, frame, frame_begin, frame_end, read_frame, unframe, MsgKind, WireError, HEADER_LEN,
    MAGIC, MAX_BODY_LEN, TRAILER_LEN, VERSION,
};
