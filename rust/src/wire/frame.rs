//! Frame layer: magic, version, kind, length, CRC — DESIGN.md §5.
//!
//! Every message crossing a master↔worker link is one frame:
//!
//! ```text
//! offset  size  field
//!      0     4  magic      b"SPDC" (little-endian u32 0x43445053)
//!      4     2  version    u16 LE — see [`VERSION`]
//!      6     1  kind       1 = WorkOrder, 2 = ResultMsg, 3 = ControlMsg
//!      7     1  reserved   0
//!      8     4  body_len   u32 LE
//!     12     n  body       message-specific (see `codec`)
//!  12+n      4  checksum   CRC-32 (IEEE) over body, u32 LE
//! ```
//!
//! The header is fixed-size, so a stream reader ([`read_frame`]) can pull
//! the header, learn `body_len`, and read the exact remainder — the
//! length-prefixed framing the TCP transport relies on. Truncation and
//! corruption surface as typed [`WireError`]s, never as garbage messages.

use std::io::Read;

/// Frame magic: the bytes `b"SPDC"` read as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"SPDC");

/// Current wire-format version. Version 2 added the executor id to
/// `ResultMsg` (the share id says *what* was computed, the executor id
/// says *who* computed it — per-result load settling and speculation
/// attribution need the latter). Version 3 added the share commitment:
/// a FNV-64 digest of the share's plaintext operands, shipped on the
/// `WorkOrder` and echoed on the `ResultMsg` so the master's collector
/// can verify a result against the order it answers before it may
/// count toward the round (Byzantine forger detection, DESIGN.md §11).
/// Version 4 added the fault coordinates to the `WorkOrder` — session
/// lane, lane-local round, and the executor's wall-rounds-served count
/// — so a worker's fault plan can key on stable identities instead of
/// the global round id (DESIGN.md §13).
pub const VERSION: u16 = 4;

/// Fixed header size (magic + version + kind + reserved + body_len).
pub const HEADER_LEN: usize = 12;

/// Trailer size (CRC-32 over the body).
pub const TRAILER_LEN: usize = 4;

/// Hard cap on a frame body (guards corrupted lengths from OOM-ing the
/// reader): 1 GiB covers any matrix this system ships.
pub const MAX_BODY_LEN: usize = 1 << 30;

/// Message kinds carried by a frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// Master → worker: a [`WorkOrder`](crate::coordinator::WorkOrder).
    Order,
    /// Worker → master: a [`ResultMsg`](crate::coordinator::ResultMsg).
    Result,
    /// Lifecycle control, either direction: a
    /// [`ControlMsg`](crate::coordinator::ControlMsg) (worker
    /// registration, injected crash).
    Control,
}

impl MsgKind {
    fn code(self) -> u8 {
        match self {
            MsgKind::Order => 1,
            MsgKind::Result => 2,
            MsgKind::Control => 3,
        }
    }

    fn from_code(c: u8) -> Result<Self, WireError> {
        match c {
            1 => Ok(MsgKind::Order),
            2 => Ok(MsgKind::Result),
            3 => Ok(MsgKind::Control),
            other => Err(WireError::BadKind(other)),
        }
    }
}

/// Everything that can go wrong between bytes and messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Fewer bytes than the format requires at this position.
    Truncated {
        /// Bytes the decoder needed.
        need: usize,
        /// Bytes actually available.
        got: usize,
    },
    /// The frame does not start with [`MAGIC`].
    BadMagic(u32),
    /// A frame from a future (or corrupted) format version.
    UnsupportedVersion(u16),
    /// Unknown message-kind byte.
    BadKind(u8),
    /// The CRC over the body does not match the trailer.
    ChecksumMismatch {
        /// CRC computed over the received body.
        computed: u32,
        /// CRC carried in the frame trailer.
        stored: u32,
    },
    /// An enum tag byte with no defined meaning.
    BadTag {
        /// Which field carried the tag.
        what: &'static str,
        /// The offending byte.
        tag: u8,
    },
    /// Structurally invalid contents (bad lengths, oversized dims, …).
    Malformed(String),
    /// The peer closed the link at a clean frame boundary.
    Closed,
    /// An I/O failure underneath the framing (stream transports).
    Io(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            WireError::UnsupportedVersion(v) => write!(f, "unsupported wire version {v}"),
            WireError::BadKind(k) => write!(f, "unknown message kind {k}"),
            WireError::ChecksumMismatch { computed, stored } => write!(
                f,
                "frame checksum mismatch: computed {computed:#010x}, stored {stored:#010x}"
            ),
            WireError::BadTag { what, tag } => write!(f, "bad {what} tag {tag}"),
            WireError::Malformed(msg) => write!(f, "malformed frame: {msg}"),
            WireError::Closed => write!(f, "link closed"),
            WireError::Io(msg) => write!(f, "wire i/o error: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e.to_string())
    }
}

/// CRC-32 (IEEE 802.3 polynomial, reflected), table computed at compile
/// time — no runtime init, no dependency.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Wrap a message body into a complete frame.
pub fn frame(kind: MsgKind, body: &[u8]) -> Vec<u8> {
    assert!(body.len() <= MAX_BODY_LEN, "frame body over MAX_BODY_LEN");
    let mut out = Vec::with_capacity(HEADER_LEN + body.len() + TRAILER_LEN);
    let start = frame_begin(&mut out, kind);
    out.extend_from_slice(body);
    frame_end(&mut out, start);
    out
}

/// Start writing a frame directly into `out` (cleared first, so a
/// caller-owned scratch buffer is reused across messages): the header
/// goes in with a placeholder body length. Returns the body start
/// offset to hand to [`frame_end`]. Body codecs append their bytes
/// straight to `out` — no intermediate body buffer, no copy.
pub fn frame_begin(out: &mut Vec<u8>, kind: MsgKind) -> usize {
    out.clear();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind.code());
    out.push(0); // reserved
    out.extend_from_slice(&0u32.to_le_bytes()); // body_len placeholder
    out.len()
}

/// Finish a frame started with [`frame_begin`]: patch the body length
/// and append the CRC-32 trailer over the body bytes.
pub fn frame_end(out: &mut Vec<u8>, body_start: usize) {
    let body_len = out.len() - body_start;
    assert!(body_len <= MAX_BODY_LEN, "frame body over MAX_BODY_LEN");
    out[body_start - 4..body_start].copy_from_slice(&(body_len as u32).to_le_bytes());
    let crc = crc32(&out[body_start..]);
    out.extend_from_slice(&crc.to_le_bytes());
}

/// Validate a complete frame and return its kind and body slice.
///
/// Rejects short buffers, wrong magic/version, unknown kinds, length
/// mismatches (the buffer must be *exactly* one frame), and CRC failures.
pub fn unframe(buf: &[u8]) -> Result<(MsgKind, &[u8]), WireError> {
    if buf.len() < HEADER_LEN + TRAILER_LEN {
        return Err(WireError::Truncated { need: HEADER_LEN + TRAILER_LEN, got: buf.len() });
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(WireError::UnsupportedVersion(version));
    }
    let kind = MsgKind::from_code(buf[6])?;
    if buf[7] != 0 {
        return Err(WireError::Malformed(format!("reserved byte is {}", buf[7])));
    }
    let body_len = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    if body_len > MAX_BODY_LEN {
        return Err(WireError::Malformed(format!("body_len {body_len} over cap")));
    }
    let total = HEADER_LEN + body_len + TRAILER_LEN;
    if buf.len() < total {
        return Err(WireError::Truncated { need: total, got: buf.len() });
    }
    if buf.len() > total {
        return Err(WireError::Malformed(format!(
            "frame is {} bytes, header says {total}",
            buf.len()
        )));
    }
    let body = &buf[HEADER_LEN..HEADER_LEN + body_len];
    let stored = u32::from_le_bytes(buf[total - TRAILER_LEN..total].try_into().unwrap());
    let computed = crc32(body);
    if computed != stored {
        return Err(WireError::ChecksumMismatch { computed, stored });
    }
    Ok((kind, body))
}

/// Read exactly one frame from a byte stream (the TCP read path).
///
/// Returns the complete frame bytes (header + body + trailer), to be
/// handed to [`unframe`]/decoders. A clean EOF *before* any header byte
/// maps to [`WireError::Closed`] (the peer hung up between frames); EOF
/// mid-frame maps to [`WireError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>, WireError> {
    let mut header = [0u8; HEADER_LEN];
    let mut filled = 0usize;
    while filled < HEADER_LEN {
        match r.read(&mut header[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Err(WireError::Closed)
                } else {
                    Err(WireError::Truncated { need: HEADER_LEN, got: filled })
                };
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    // Validate the length field before allocating.
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let body_len = u32::from_le_bytes(header[8..12].try_into().unwrap()) as usize;
    if body_len > MAX_BODY_LEN {
        return Err(WireError::Malformed(format!("body_len {body_len} over cap")));
    }
    let total = HEADER_LEN + body_len + TRAILER_LEN;
    let mut buf = vec![0u8; total];
    buf[..HEADER_LEN].copy_from_slice(&header);
    let mut filled = HEADER_LEN;
    while filled < total {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(WireError::Truncated { need: total, got: filled }),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_unframe_round_trip() {
        let body = b"hello wire".to_vec();
        let f = frame(MsgKind::Order, &body);
        let (kind, got) = unframe(&f).unwrap();
        assert_eq!(kind, MsgKind::Order);
        assert_eq!(got, &body[..]);
    }

    #[test]
    fn every_truncation_is_rejected() {
        let f = frame(MsgKind::Result, b"payload bytes");
        for cut in 0..f.len() {
            assert!(unframe(&f[..cut]).is_err(), "prefix of {cut} bytes must not parse");
        }
    }

    #[test]
    fn every_single_byte_corruption_is_rejected() {
        let f = frame(MsgKind::Order, b"some body content here");
        for i in 0..f.len() {
            let mut bad = f.clone();
            bad[i] ^= 0x41;
            assert!(unframe(&bad).is_err(), "corruption at byte {i} must not parse");
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut f = frame(MsgKind::Order, b"body");
        f.push(0);
        assert!(matches!(unframe(&f), Err(WireError::Malformed(_))));
    }

    #[test]
    fn stream_reader_round_trips_and_reports_clean_close() {
        let f1 = frame(MsgKind::Order, b"first");
        let f2 = frame(MsgKind::Result, b"second");
        let mut stream: Vec<u8> = Vec::new();
        stream.extend_from_slice(&f1);
        stream.extend_from_slice(&f2);
        let mut cursor = std::io::Cursor::new(stream);
        assert_eq!(read_frame(&mut cursor).unwrap(), f1);
        assert_eq!(read_frame(&mut cursor).unwrap(), f2);
        assert_eq!(read_frame(&mut cursor), Err(WireError::Closed));
    }

    #[test]
    fn stream_reader_rejects_mid_frame_eof() {
        let f = frame(MsgKind::Order, b"cut short");
        let mut cursor = std::io::Cursor::new(f[..f.len() - 2].to_vec());
        assert!(matches!(
            read_frame(&mut cursor),
            Err(WireError::Truncated { .. })
        ));
    }
}
