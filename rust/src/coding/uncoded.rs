//! CONV — conventional uncoded distribution, the paper's first baseline
//! (CONV-DL in §VII-B). The data is split into K = N parts, worker i
//! computes f on part i, and the master must wait for **all** workers:
//! a single straggler stalls the step, which is exactly the effect
//! Figs. 3–4 measure.

use super::task::TaskShape;
use super::traits::{
    validate_results, BlockCode, CodeParams, CodingError, DecodeCtx, Encoded, Threshold,
};
use crate::config::SchemeKind;
use crate::matrix::{split_rows, Matrix, PartitionSpec};
use crate::rng::Rng;

/// Uncoded (CONV) distribution.
#[derive(Clone, Debug)]
pub struct Uncoded {
    params: CodeParams,
}

impl Uncoded {
    /// Construct. K is forced to N (one raw part per worker); T to 0.
    pub fn new(params: CodeParams) -> Self {
        Self { params: CodeParams { k: params.n, t: 0, n: params.n } }
    }
}

impl BlockCode for Uncoded {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Uncoded
    }

    fn params(&self) -> CodeParams {
        self.params
    }

    fn block_threshold(&self, _deg: u32) -> Threshold {
        Threshold::Exact(self.params.n)
    }

    fn supports_degree(&self, _deg: u32) -> bool {
        true // raw parts: any f works
    }

    fn encode_blocks(&self, x: &Matrix, deg: u32, _rng: &mut Rng) -> Result<Encoded, CodingError> {
        let (blocks, spec) = split_rows(x, self.params.n);
        Ok(Encoded {
            shares: blocks,
            ctx: DecodeCtx {
                kind: SchemeKind::Uncoded,
                params: self.params,
                alphas: vec![],
                betas: vec![],
                spec,
                degree: deg,
                shape: TaskShape::BlockMap,
            },
        })
    }

    fn decode_blocks(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError> {
        let n = ctx.params.n;
        if results.len() < n {
            return Err(CodingError::NotEnoughResults { need: n, got: results.len() });
        }
        let sorted = validate_results(n, results)?;
        Ok(sorted.into_iter().map(|(_, m)| m).collect())
    }
}

/// Spec helper used by tests/integration: uncoded "decode" output is one
/// block per worker.
pub fn uncoded_spec(x_rows: usize, n: usize) -> PartitionSpec {
    PartitionSpec::new(x_rows, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{gram, stack_rows};
    use crate::rng::rng_from_seed;

    #[test]
    fn decode_requires_all_workers() {
        let scheme = Uncoded::new(CodeParams::new(6, 0, 0));
        let mut rng = rng_from_seed(80);
        let x = Matrix::random_uniform(12, 3, -1.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let partial: Vec<(usize, Matrix)> =
            (0..5).map(|i| (i, enc.shares[i].clone())).collect();
        assert!(matches!(
            scheme.decode_blocks(&enc.ctx, &partial),
            Err(CodingError::NotEnoughResults { need: 6, got: 5 })
        ));
    }

    #[test]
    fn identity_task_roundtrips_exactly() {
        let scheme = Uncoded::new(CodeParams::new(5, 0, 0));
        let mut rng = rng_from_seed(81);
        let x = Matrix::random_gaussian(13, 4, 0.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let results: Vec<(usize, Matrix)> =
            enc.shares.iter().enumerate().map(|(i, s)| (i, s.clone())).collect();
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
        assert_eq!(stack_rows(&decoded, &enc.ctx.spec), x);
    }

    #[test]
    fn gram_task_is_exact_per_part() {
        let scheme = Uncoded::new(CodeParams::new(4, 0, 0));
        let mut rng = rng_from_seed(82);
        let x = Matrix::random_gaussian(16, 6, 0.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 2, &mut rng).unwrap();
        let results: Vec<(usize, Matrix)> =
            enc.shares.iter().enumerate().map(|(i, s)| (i, gram(s))).collect();
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
        for (d, s) in decoded.iter().zip(&enc.shares) {
            assert_eq!(d.as_slice(), gram(s).as_slice());
        }
    }

    #[test]
    fn threshold_is_n() {
        let scheme = Uncoded::new(CodeParams::new(30, 0, 0));
        assert_eq!(scheme.block_threshold(1), Threshold::Exact(30));
        assert!(!scheme.is_private());
    }
}
