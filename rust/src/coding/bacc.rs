//! BACC — Berrut Approximated Coded Computing (Jahani-Nezhad &
//! Maddah-Ali [18]), the paper's closest baseline: identical Berrut
//! encode/decode machinery but **no privacy masks** (T = 0). Table II row
//! 4; the scheme SPACDC matches on complexity while adding privacy.

use super::interp::{berrut_eval, chebyshev_nodes_in, disjoint_eval_nodes};
use super::spacdc::decode_berrut;
use super::task::TaskShape;
use super::traits::{BlockCode, CodeParams, CodingError, DecodeCtx, Encoded, Threshold};
use crate::config::SchemeKind;
use crate::matrix::{split_rows, Matrix};
use crate::rng::Rng;

/// BACC code.
#[derive(Clone, Debug)]
pub struct Bacc {
    params: CodeParams,
}

impl Bacc {
    /// Construct; any `t` in `params` is ignored (BACC has no masks).
    pub fn new(params: CodeParams) -> Self {
        Self { params: CodeParams { t: 0, ..params } }
    }
}

impl BlockCode for Bacc {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Bacc
    }

    fn params(&self) -> CodeParams {
        self.params
    }

    fn block_threshold(&self, _deg: u32) -> Threshold {
        Threshold::Flexible { min: 1 }
    }

    fn supports_degree(&self, _deg: u32) -> bool {
        true
    }

    fn encode_blocks(&self, x: &Matrix, deg: u32, _rng: &mut Rng) -> Result<Encoded, CodingError> {
        let CodeParams { n, k, .. } = self.params;
        let (blocks, spec) = split_rows(x, k);
        let betas = chebyshev_nodes_in(k, -0.95, 0.95);
        let alphas = disjoint_eval_nodes(n, &betas);
        let signs: Vec<u32> = (0..k as u32).collect();
        // Per-worker encode fan-out on the pool (shares are independent;
        // index order keeps the output deterministic).
        let pool = crate::parallel::global();
        let shares: Vec<Matrix> = pool
            .map_indexed(alphas.len(), |j| berrut_eval(&betas, &signs, &blocks, alphas[j]));
        Ok(Encoded {
            shares,
            ctx: DecodeCtx {
                kind: SchemeKind::Bacc,
                params: self.params,
                alphas,
                betas,
                spec,
                degree: deg,
                shape: TaskShape::BlockMap,
            },
        })
    }

    fn decode_blocks(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError> {
        decode_berrut(ctx, results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{gram, matmul};
    use crate::rng::rng_from_seed;

    #[test]
    fn bacc_and_spacdc_both_decode_under_stragglers() {
        // Same Berrut machinery, different node grids (K vs K+T): both
        // must decode with bounded error from a 16/20 return set.
        use super::super::spacdc::Spacdc;
        let mut rng = rng_from_seed(60);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        let v = Matrix::random_gaussian(8, 4, 0.0, 1.0, &mut rng);
        let (blocks, _) = split_rows(&x, 3);
        let expect: Vec<Matrix> = blocks.iter().map(|b| matmul(b, &v)).collect();

        let bacc = Bacc::new(CodeParams::new(20, 3, 0));
        let spacdc = Spacdc::new(CodeParams::new(20, 3, 3));

        let mut err = [0.0f64; 2];
        for (s, scheme) in [&bacc as &dyn BlockCode, &spacdc as &dyn BlockCode].iter().enumerate() {
            let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
            let results: Vec<(usize, Matrix)> = enc
                .shares
                .iter()
                .enumerate()
                .take(16)
                .map(|(i, sh)| (i, matmul(sh, &v)))
                .collect();
            let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
            err[s] = decoded
                .iter()
                .zip(&expect)
                .map(|(d, e)| d.rel_error(e))
                .fold(0.0f64, f64::max);
        }
        assert!(err[0] < 0.20, "BACC error too high: {}", err[0]);
        assert!(err[1] < 0.40, "SPACDC error too high: {}", err[1]);
    }

    #[test]
    fn gram_decode_close_without_masks() {
        let mut rng = rng_from_seed(61);
        let scheme = Bacc::new(CodeParams::new(24, 2, 0));
        let x = Matrix::random_gaussian(16, 10, 0.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 2, &mut rng).unwrap();
        let results: Vec<(usize, Matrix)> =
            enc.shares.iter().enumerate().map(|(i, s)| (i, gram(s))).collect();
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
        let (blocks, _) = split_rows(&x, 2);
        for (d, b) in decoded.iter().zip(&blocks) {
            let err = d.rel_error(&gram(b));
            assert!(err < 0.15, "err={err}");
        }
    }

    #[test]
    fn encode_is_deterministic_without_masks() {
        let scheme = Bacc::new(CodeParams::new(8, 2, 0));
        let x = Matrix::ones(8, 4);
        let e1 = scheme.encode_blocks(&x, 1, &mut rng_from_seed(1)).unwrap();
        let e2 = scheme.encode_blocks(&x, 1, &mut rng_from_seed(2)).unwrap();
        for (a, b) in e1.shares.iter().zip(&e2.shares) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
    }

    #[test]
    fn not_private() {
        let scheme = Bacc::new(CodeParams::new(8, 2, 0));
        assert!(!scheme.is_private());
        assert_eq!(scheme.block_threshold(1), Threshold::Flexible { min: 1 });
    }
}
