//! The common interface every coding scheme implements.
//!
//! All row-partition schemes (everything except MatDot, which is a
//! matrix-product code with its own pair API in `matdot.rs`) share the
//! same shape: encode K row-blocks (plus T random mask blocks for the
//! private schemes) into N worker shares; workers apply `f` to their
//! share; the master decodes per-block results `Yᵢ ≈ f(Xᵢ)` from
//! whichever workers returned.

use crate::config::SchemeKind;
use crate::matrix::{Matrix, PartitionSpec};
use crate::rng::Rng;

/// Code parameters: N workers, K data blocks, T privacy masks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CodeParams {
    /// Number of workers N.
    pub n: usize,
    /// Number of data partitions K.
    pub k: usize,
    /// Number of colluding workers tolerated T (= number of masks).
    pub t: usize,
}

impl CodeParams {
    /// Convenience constructor.
    pub fn new(n: usize, k: usize, t: usize) -> Self {
        Self { n, k, t }
    }
}

/// The recovery threshold semantics — the paper's central axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Threshold {
    /// The master must wait for exactly this many results (classical
    /// coded computing: MDS/Polynomial/LCC/SecPoly/MatDot/CONV).
    Exact(usize),
    /// The master may decode from *any* `min`-or-more results, trading
    /// accuracy for latency (SPACDC/BACC — "does not impose strict
    /// constraints on the minimum number of results").
    Flexible {
        /// Smallest return set the decoder will accept (≥ 1).
        min: usize,
    },
}

impl Threshold {
    /// The count the coordinator waits for given the paper's semantics:
    /// exact schemes wait for the threshold; flexible schemes take every
    /// non-straggler result available — here expressed as "wait for at
    /// least `min`, then decode with whatever has arrived".
    pub fn wait_count(&self, available: usize) -> usize {
        match *self {
            Threshold::Exact(k) => k,
            Threshold::Flexible { min } => min.min(available),
        }
    }
}

/// Decode failure modes.
#[derive(Debug, thiserror::Error)]
pub enum CodingError {
    /// Fewer results than the scheme's recovery threshold.
    #[error("not enough results: need {need}, got {got}")]
    NotEnoughResults {
        /// Required result count.
        need: usize,
        /// Supplied result count.
        got: usize,
    },
    /// Scheme cannot handle a task of this polynomial degree.
    #[error("{scheme} does not support task degree {degree}")]
    UnsupportedDegree {
        /// Scheme name.
        scheme: &'static str,
        /// Requested degree.
        degree: u32,
    },
    /// A result matrix had an unexpected shape.
    #[error("result shape mismatch: {0}")]
    ShapeMismatch(String),
    /// Linear-algebra failure during decode.
    #[error("decode failed: {0}")]
    Numerical(String),
    /// Worker index out of range or duplicated.
    #[error("bad worker index: {0}")]
    BadWorkerIndex(usize),
}

/// Everything the decoder needs, produced at encode time.
#[derive(Clone, Debug)]
pub struct DecodeCtx {
    /// Which scheme encoded this.
    pub kind: SchemeKind,
    /// Code parameters at encode time.
    pub params: CodeParams,
    /// Worker evaluation nodes αⱼ (one per worker; empty for uncoded).
    pub alphas: Vec<f64>,
    /// Recovery nodes βᵢ (the first K index the data blocks).
    pub betas: Vec<f64>,
    /// Row-partition bookkeeping (to undo padding).
    pub spec: PartitionSpec,
    /// Polynomial degree of the worker task f (1 = linear).
    pub degree: u32,
}

/// An encoded computation: one share per worker + the decode context.
#[derive(Clone, Debug)]
pub struct Encoded {
    /// Share for worker j at index j.
    pub shares: Vec<Matrix>,
    /// Decode context.
    pub ctx: DecodeCtx,
}

/// A coding scheme over row-partitioned data.
pub trait Scheme: Send + Sync {
    /// Which scheme this is.
    fn kind(&self) -> SchemeKind;

    /// Code parameters.
    fn params(&self) -> CodeParams;

    /// Recovery threshold for a worker task of polynomial degree `deg`.
    fn threshold(&self, deg: u32) -> Threshold;

    /// Can this scheme decode a task of degree `deg`? Exact linear codes
    /// (MDS/Polynomial/SecPoly) only commute with linear `f`.
    fn supports_degree(&self, deg: u32) -> bool;

    /// Does the encoding information-theoretically hide the data from up
    /// to T colluding workers?
    fn is_private(&self) -> bool {
        false
    }

    /// Encode `x` for a worker task of degree `deg`.
    fn encode(&self, x: &Matrix, deg: u32, rng: &mut Rng) -> Result<Encoded, CodingError>;

    /// Decode per-block results from `(worker index, f(share))` pairs.
    /// Returns K matrices `Yᵢ ≈ f(Xᵢ)`.
    fn decode(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError>;
}

/// Validate a result set: indices in range, no duplicates. Returns the
/// results sorted by worker index.
pub fn validate_results(
    n: usize,
    results: &[(usize, Matrix)],
) -> Result<Vec<(usize, Matrix)>, CodingError> {
    let mut sorted: Vec<(usize, Matrix)> = results.to_vec();
    sorted.sort_by_key(|(i, _)| *i);
    for w in sorted.windows(2) {
        if w[0].0 == w[1].0 {
            return Err(CodingError::BadWorkerIndex(w[0].0));
        }
    }
    if let Some((i, _)) = sorted.last() {
        if *i >= n {
            return Err(CodingError::BadWorkerIndex(*i));
        }
    }
    Ok(sorted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_wait_count_semantics() {
        assert_eq!(Threshold::Exact(10).wait_count(30), 10);
        assert_eq!(Threshold::Flexible { min: 1 }.wait_count(30), 1);
        assert_eq!(Threshold::Flexible { min: 5 }.wait_count(3), 3);
    }

    #[test]
    fn validate_rejects_duplicates() {
        let m = Matrix::zeros(1, 1);
        let r = vec![(0, m.clone()), (0, m.clone())];
        assert!(matches!(
            validate_results(4, &r),
            Err(CodingError::BadWorkerIndex(0))
        ));
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let m = Matrix::zeros(1, 1);
        let r = vec![(5, m)];
        assert!(matches!(
            validate_results(4, &r),
            Err(CodingError::BadWorkerIndex(5))
        ));
    }

    #[test]
    fn validate_sorts_by_index() {
        let m = Matrix::zeros(1, 1);
        let r = vec![(3, m.clone()), (1, m.clone()), (2, m)];
        let sorted = validate_results(4, &r).unwrap();
        let idx: Vec<usize> = sorted.iter().map(|(i, _)| *i).collect();
        assert_eq!(idx, vec![1, 2, 3]);
    }
}
