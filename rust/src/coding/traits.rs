//! The common interface every coding scheme implements.
//!
//! Two levels:
//!
//! * [`Scheme`] — the task-level API the coordinator drives: `encode` /
//!   `threshold` / `decode` all take a typed [`CodedTask`], and the
//!   encoded output is an [`EncodedJob`] whose per-worker payloads are
//!   `Vec<Matrix>`, so MatDot's two-operand shares travel the same wire
//!   path as single-share schemes. Every one of the 8
//!   [`SchemeKind`](crate::config::SchemeKind)s implements this.
//! * [`BlockCode`] — the row-partition machinery (everything except
//!   MatDot): encode K row-blocks (plus T random mask blocks for the
//!   private schemes) into N worker shares; workers apply `f` to their
//!   share; the master decodes per-block results `Yᵢ ≈ f(Xᵢ)` from
//!   whichever workers returned. A blanket impl lifts any `BlockCode`
//!   into a `Scheme`, including serving [`CodedTask::PairProduct`] by
//!   encoding A, broadcasting B as a right-multiply, and restacking the
//!   decoded blocks.

use super::task::{CodedTask, TaskShape};
use crate::config::SchemeKind;
use crate::matrix::{stack_rows, Matrix, PartitionSpec};
use crate::rng::Rng;
use crate::runtime::WorkerOp;
use std::sync::Arc;

/// Code parameters: N workers, K data blocks, T privacy masks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CodeParams {
    /// Number of workers N.
    pub n: usize,
    /// Number of data partitions K.
    pub k: usize,
    /// Number of colluding workers tolerated T (= number of masks).
    pub t: usize,
}

impl CodeParams {
    /// Convenience constructor (unvalidated; schemes report
    /// [`CodingError::InvalidParams`] at encode time for shapes they
    /// cannot serve).
    pub fn new(n: usize, k: usize, t: usize) -> Self {
        Self { n, k, t }
    }

    /// Validated constructor: rejects structurally unusable parameters
    /// instead of panicking downstream.
    pub fn checked(n: usize, k: usize, t: usize) -> Result<Self, CodingError> {
        if n == 0 {
            return Err(CodingError::InvalidParams("N must be ≥ 1".into()));
        }
        if k == 0 {
            return Err(CodingError::InvalidParams("K must be ≥ 1".into()));
        }
        if k + t > n {
            return Err(CodingError::InvalidParams(format!(
                "K+T must be ≤ N (K={k}, T={t}, N={n})"
            )));
        }
        Ok(Self { n, k, t })
    }
}

/// The recovery threshold semantics — the paper's central axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Threshold {
    /// The master must wait for exactly this many results (classical
    /// coded computing: MDS/Polynomial/LCC/SecPoly/MatDot/CONV).
    Exact(usize),
    /// The master may decode from *any* `min`-or-more results, trading
    /// accuracy for latency (SPACDC/BACC — "does not impose strict
    /// constraints on the minimum number of results").
    Flexible {
        /// Smallest return set the decoder will accept (≥ 1).
        min: usize,
    },
}

impl Threshold {
    /// The count the coordinator waits for given the paper's semantics:
    /// exact schemes wait for the threshold; flexible schemes take every
    /// non-straggler result available — here expressed as "wait for at
    /// least `min`, then decode with whatever has arrived".
    pub fn wait_count(&self, available: usize) -> usize {
        match *self {
            Threshold::Exact(k) => k,
            Threshold::Flexible { min } => min.min(available),
        }
    }
}

/// Decode failure modes.
#[derive(Debug)]
pub enum CodingError {
    /// Fewer results than the scheme's recovery threshold.
    NotEnoughResults {
        /// Required result count.
        need: usize,
        /// Supplied result count.
        got: usize,
    },
    /// Scheme cannot handle a task of this polynomial degree.
    UnsupportedDegree {
        /// Scheme name.
        scheme: &'static str,
        /// Requested degree.
        degree: u32,
    },
    /// Scheme cannot serve this task shape at all.
    UnsupportedTask {
        /// Scheme name.
        scheme: &'static str,
        /// Task name.
        task: &'static str,
    },
    /// Code parameters are structurally unusable.
    InvalidParams(String),
    /// A result matrix had an unexpected shape.
    ShapeMismatch(String),
    /// Linear-algebra failure during decode.
    Numerical(String),
    /// Worker index out of range or duplicated.
    BadWorkerIndex(usize),
}

impl std::fmt::Display for CodingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodingError::NotEnoughResults { need, got } => {
                write!(f, "not enough results: need {need}, got {got}")
            }
            CodingError::UnsupportedDegree { scheme, degree } => {
                write!(f, "{scheme} does not support task degree {degree}")
            }
            CodingError::UnsupportedTask { scheme, task } => {
                write!(f, "{scheme} does not support {task} tasks")
            }
            CodingError::InvalidParams(msg) => write!(f, "invalid code parameters: {msg}"),
            CodingError::ShapeMismatch(msg) => write!(f, "result shape mismatch: {msg}"),
            CodingError::Numerical(msg) => write!(f, "decode failed: {msg}"),
            CodingError::BadWorkerIndex(i) => write!(f, "bad worker index: {i}"),
        }
    }
}

impl std::error::Error for CodingError {}

/// Everything the decoder needs, produced at encode time.
#[derive(Clone, Debug)]
pub struct DecodeCtx {
    /// Which scheme encoded this.
    pub kind: SchemeKind,
    /// Code parameters at encode time.
    pub params: CodeParams,
    /// Worker evaluation nodes αⱼ (one per worker; empty for uncoded).
    pub alphas: Vec<f64>,
    /// Recovery nodes βᵢ (the first K index the data blocks).
    pub betas: Vec<f64>,
    /// Row-partition bookkeeping (to undo padding).
    pub spec: PartitionSpec,
    /// Polynomial degree of the worker task f (1 = linear).
    pub degree: u32,
    /// The task shape this round decodes back into.
    pub shape: TaskShape,
}

/// A block-level encoding: one share per worker + the decode context.
/// Produced by [`BlockCode::encode_blocks`]; the blanket [`Scheme`] impl
/// wraps it into an [`EncodedJob`].
#[derive(Clone, Debug)]
pub struct Encoded {
    /// Share for worker j at index j.
    pub shares: Vec<Matrix>,
    /// Decode context.
    pub ctx: DecodeCtx,
}

/// A fully-encoded coded round, ready to dispatch: per-worker operand
/// payloads (1 matrix for single-share schemes, 2 for MatDot), the
/// worker op to run on them, and the decode context.
#[derive(Clone, Debug)]
pub struct EncodedJob {
    /// `payloads[j]` — the operand matrices worker j receives.
    pub payloads: Vec<Vec<Matrix>>,
    /// The operation every worker applies to its payloads.
    pub op: WorkerOp,
    /// Decode context.
    pub ctx: DecodeCtx,
}

/// A coding scheme over a typed [`CodedTask`] — the interface the
/// coordinator drives. All eight schemes implement it (the seven
/// row-partition codes through the blanket [`BlockCode`] impl, MatDot
/// directly).
pub trait Scheme: Send + Sync {
    /// Which scheme this is.
    fn kind(&self) -> SchemeKind;

    /// Code parameters.
    fn params(&self) -> CodeParams;

    /// Recovery threshold for `task`.
    fn threshold(&self, task: &CodedTask) -> Threshold;

    /// Can this scheme serve `task`?
    fn supports(&self, task: &CodedTask) -> bool;

    /// Does the encoding information-theoretically hide the data from up
    /// to T colluding workers?
    fn is_private(&self) -> bool {
        false
    }

    /// Encode `task` into per-worker payloads.
    fn encode(&self, task: &CodedTask, rng: &mut Rng) -> Result<EncodedJob, CodingError>;

    /// Decode from `(worker index, f(payloads))` pairs. Returns K block
    /// matrices for a block-map round, or a single full-product matrix
    /// for a pair-product round.
    fn decode(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError>;
}

/// A coding scheme over row-partitioned data — the block-level machinery
/// shared by everything except MatDot. Implementing this automatically
/// provides [`Scheme`] via the blanket impl below.
pub trait BlockCode: Send + Sync {
    /// Which scheme this is.
    fn kind(&self) -> SchemeKind;

    /// Code parameters.
    fn params(&self) -> CodeParams;

    /// Recovery threshold for a worker task of polynomial degree `deg`.
    fn block_threshold(&self, deg: u32) -> Threshold;

    /// Can this scheme decode a task of degree `deg`? Exact linear codes
    /// (MDS/Polynomial/SecPoly) only commute with linear `f`.
    fn supports_degree(&self, deg: u32) -> bool;

    /// Does the encoding information-theoretically hide the data from up
    /// to T colluding workers?
    fn is_private(&self) -> bool {
        false
    }

    /// Encode `x` for a worker task of degree `deg`.
    fn encode_blocks(&self, x: &Matrix, deg: u32, rng: &mut Rng) -> Result<Encoded, CodingError>;

    /// Decode per-block results from `(worker index, f(share))` pairs.
    /// Returns K matrices `Yᵢ ≈ f(Xᵢ)`.
    fn decode_blocks(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError>;
}

impl<C: BlockCode> Scheme for C {
    fn kind(&self) -> SchemeKind {
        BlockCode::kind(self)
    }

    fn params(&self) -> CodeParams {
        BlockCode::params(self)
    }

    fn threshold(&self, task: &CodedTask) -> Threshold {
        self.block_threshold(task.degree())
    }

    fn supports(&self, task: &CodedTask) -> bool {
        match task {
            CodedTask::BlockMap { op, .. } => {
                op.operand_count() == 1 && self.supports_degree(op.degree())
            }
            // Served as encode(A) + broadcast right-multiply by B.
            CodedTask::PairProduct { .. } => self.supports_degree(1),
        }
    }

    fn is_private(&self) -> bool {
        BlockCode::is_private(self)
    }

    fn encode(&self, task: &CodedTask, rng: &mut Rng) -> Result<EncodedJob, CodingError> {
        match task {
            CodedTask::BlockMap { op, x } => {
                if op.operand_count() != 1 {
                    return Err(CodingError::UnsupportedTask {
                        scheme: BlockCode::kind(self).name(),
                        task: "block-map with a pair op",
                    });
                }
                let enc = self.encode_blocks(x, op.degree(), rng)?;
                Ok(EncodedJob {
                    payloads: enc.shares.into_iter().map(|s| vec![s]).collect(),
                    op: op.clone(),
                    ctx: enc.ctx,
                })
            }
            CodedTask::PairProduct { a, b } => {
                if a.cols() != b.rows() {
                    return Err(CodingError::ShapeMismatch(format!(
                        "A cols {} != B rows {}",
                        a.cols(),
                        b.rows()
                    )));
                }
                let mut enc = self.encode_blocks(a, 1, rng)?;
                enc.ctx.shape = TaskShape::PairProduct;
                Ok(EncodedJob {
                    payloads: enc.shares.into_iter().map(|s| vec![s]).collect(),
                    op: WorkerOp::RightMul(Arc::clone(b)),
                    ctx: enc.ctx,
                })
            }
        }
    }

    fn decode(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError> {
        let blocks = self.decode_blocks(ctx, results)?;
        Ok(match ctx.shape {
            TaskShape::BlockMap => blocks,
            // Pair products restack the per-block rows of A·B into the
            // single full product, dropping padding.
            TaskShape::PairProduct => vec![stack_rows(&blocks, &ctx.spec)],
        })
    }
}

/// Validate a result set: indices in range, no duplicates. Returns the
/// results sorted by worker index.
pub fn validate_results(
    n: usize,
    results: &[(usize, Matrix)],
) -> Result<Vec<(usize, Matrix)>, CodingError> {
    let mut sorted: Vec<(usize, Matrix)> = results.to_vec();
    sorted.sort_by_key(|(i, _)| *i);
    for w in sorted.windows(2) {
        if w[0].0 == w[1].0 {
            return Err(CodingError::BadWorkerIndex(w[0].0));
        }
    }
    if let Some((i, _)) = sorted.last() {
        if *i >= n {
            return Err(CodingError::BadWorkerIndex(*i));
        }
    }
    Ok(sorted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_wait_count_semantics() {
        assert_eq!(Threshold::Exact(10).wait_count(30), 10);
        assert_eq!(Threshold::Flexible { min: 1 }.wait_count(30), 1);
        assert_eq!(Threshold::Flexible { min: 5 }.wait_count(3), 3);
    }

    #[test]
    fn validate_rejects_duplicates() {
        let m = Matrix::zeros(1, 1);
        let r = vec![(0, m.clone()), (0, m.clone())];
        assert!(matches!(
            validate_results(4, &r),
            Err(CodingError::BadWorkerIndex(0))
        ));
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let m = Matrix::zeros(1, 1);
        let r = vec![(5, m)];
        assert!(matches!(
            validate_results(4, &r),
            Err(CodingError::BadWorkerIndex(5))
        ));
    }

    #[test]
    fn validate_sorts_by_index() {
        let m = Matrix::zeros(1, 1);
        let r = vec![(3, m.clone()), (1, m.clone()), (2, m)];
        let sorted = validate_results(4, &r).unwrap();
        let idx: Vec<usize> = sorted.iter().map(|(i, _)| *i).collect();
        assert_eq!(idx, vec![1, 2, 3]);
    }

    #[test]
    fn checked_params_reject_structural_nonsense() {
        assert!(matches!(
            CodeParams::checked(0, 1, 0),
            Err(CodingError::InvalidParams(_))
        ));
        assert!(matches!(
            CodeParams::checked(8, 0, 0),
            Err(CodingError::InvalidParams(_))
        ));
        assert!(matches!(
            CodeParams::checked(8, 6, 4),
            Err(CodingError::InvalidParams(_))
        ));
        assert_eq!(CodeParams::checked(8, 4, 2).unwrap(), CodeParams::new(8, 4, 2));
    }
}
