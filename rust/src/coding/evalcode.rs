//! Shared machinery for the exact polynomial-evaluation baselines:
//! MDS [22], Polynomial codes [23], LCC [27], and SecPoly [34].
//!
//! All four encode the K blocks (plus T masks for the private variants)
//! as evaluations of a polynomial u(z) with u(βᵢ) = Xᵢ, and decode by
//! *exact* polynomial interpolation of f∘u from `deg·(K+T−1)+1` returned
//! evaluations — the classical recovery threshold that SPACDC's rational
//! decode removes.
//!
//! Faithfulness note (DESIGN.md §3): the original codes work over a large
//! finite field with monomial (Vandermonde) bases. Over ℝ a monomial
//! basis at K ≈ 30 is numerically singular, so encode uses the Lagrange
//! basis on Chebyshev recovery nodes — the *same codeword space* and the
//! same thresholds, in the numerically meaningful basis (this is also
//! exactly how LCC is specified).

use super::interp::{chebyshev_nodes_in, disjoint_eval_nodes, lagrange_eval, lagrange_weights};
use super::task::TaskShape;
use super::traits::{
    validate_results, BlockCode, CodeParams, CodingError, DecodeCtx, Encoded, Threshold,
};
use crate::config::SchemeKind;
use crate::matrix::{split_rows, Matrix};
use crate::rng::Rng;

/// Configuration of one member of the evaluation-code family.
#[derive(Clone, Debug)]
pub struct EvalCode {
    kind: SchemeKind,
    params: CodeParams,
    /// Highest task degree this member admits (1 for the linear-only
    /// MDS/Polynomial/SecPoly; u32::MAX for LCC).
    max_degree: u32,
    /// Whether T masks are appended (LCC/SecPoly).
    private: bool,
    /// Mask amplitude for private members.
    mask_scale: f32,
}

impl EvalCode {
    /// MDS codes (Lee et al. [22]): linear tasks, no privacy, threshold K.
    pub fn mds(params: CodeParams) -> Self {
        Self {
            kind: SchemeKind::Mds,
            params: CodeParams { t: 0, ..params },
            max_degree: 1,
            private: false,
            mask_scale: 1.0,
        }
    }

    /// Polynomial codes [23]: linear tasks in this row-partition framing
    /// (the two-sided matmul variant lives in the complexity model),
    /// threshold K.
    pub fn polynomial(params: CodeParams) -> Self {
        Self {
            kind: SchemeKind::Polynomial,
            params: CodeParams { t: 0, ..params },
            max_degree: 1,
            private: false,
            mask_scale: 1.0,
        }
    }

    /// LCC [27]: arbitrary polynomial degree, T-private,
    /// threshold deg·(K+T−1)+1.
    pub fn lcc(params: CodeParams) -> Self {
        Self {
            kind: SchemeKind::Lcc,
            params,
            max_degree: u32::MAX,
            private: params.t > 0,
            mask_scale: 1.0,
        }
    }

    /// SecPoly [34]: linear tasks, T-private, threshold K+T.
    pub fn secpoly(params: CodeParams) -> Self {
        Self {
            kind: SchemeKind::SecPoly,
            params,
            max_degree: 1,
            private: params.t > 0,
            mask_scale: 1.0,
        }
    }

    fn mask_count(&self) -> usize {
        if self.private {
            self.params.t
        } else {
            0
        }
    }
}

impl BlockCode for EvalCode {
    fn kind(&self) -> SchemeKind {
        self.kind
    }

    fn params(&self) -> CodeParams {
        self.params
    }

    fn block_threshold(&self, deg: u32) -> Threshold {
        // deg·(K+T−1)+1: K for linear non-private, K+T for linear
        // private, 2(K+T−1)+1 for quadratic LCC, …
        let kt = self.params.k + self.mask_count();
        Threshold::Exact((deg as usize) * (kt - 1) + 1)
    }

    fn supports_degree(&self, deg: u32) -> bool {
        deg >= 1 && deg <= self.max_degree
    }

    fn is_private(&self) -> bool {
        self.private
    }

    fn encode_blocks(&self, x: &Matrix, deg: u32, rng: &mut Rng) -> Result<Encoded, CodingError> {
        if !self.supports_degree(deg) {
            return Err(CodingError::UnsupportedDegree {
                scheme: self.kind.name(),
                degree: deg,
            });
        }
        let CodeParams { n, k, .. } = self.params;
        let t = self.mask_count();
        if let Threshold::Exact(need) = self.block_threshold(deg) {
            if need > n {
                return Err(CodingError::NotEnoughResults { need, got: n });
            }
        }
        let (mut blocks, spec) = split_rows(x, k);
        let (br, bc) = blocks[0].shape();
        for _ in 0..t {
            blocks.push(Matrix::random_uniform(
                br,
                bc,
                -self.mask_scale,
                self.mask_scale,
                rng,
            ));
        }
        let betas = chebyshev_nodes_in(k + t, -0.95, 0.95);
        let alphas = disjoint_eval_nodes(n, &betas);
        // u(αⱼ) = Σᵢ Bᵢ·Lᵢ(αⱼ): exact degree-(K+T−1) polynomial through
        // the blocks at the β nodes. Per-worker fan-out on the pool;
        // index order keeps the share vector deterministic.
        let pool = crate::parallel::global();
        let shares: Vec<Matrix> =
            pool.map_indexed(alphas.len(), |j| lagrange_eval(&betas, &blocks, alphas[j]));
        Ok(Encoded {
            shares,
            ctx: DecodeCtx {
                kind: self.kind,
                params: self.params,
                alphas,
                betas,
                spec,
                degree: deg,
                shape: TaskShape::BlockMap,
            },
        })
    }

    fn decode_blocks(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError> {
        let need = match self.block_threshold(ctx.degree) {
            Threshold::Exact(k) => k,
            Threshold::Flexible { min } => min,
        };
        if results.len() < need {
            return Err(CodingError::NotEnoughResults { need, got: results.len() });
        }
        let sorted = validate_results(ctx.params.n, results)?;
        // Exact interpolation of f∘u (degree deg·(K+T−1)) from the first
        // `need` returns.
        let take = &sorted[..need];
        let nodes: Vec<f64> = take.iter().map(|(i, _)| ctx.alphas[*i]).collect();
        let values: Vec<Matrix> = take.iter().map(|(_, m)| m.clone()).collect();
        let mut out = Vec::with_capacity(ctx.params.k);
        for i in 0..ctx.params.k {
            let w = lagrange_weights(&nodes, ctx.betas[i]);
            out.push(super::interp::weighted_sum(&values, &w));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{gram, matmul};
    use crate::prop::{forall, prop_assert};
    use crate::rng::rng_from_seed;

    fn check_linear_exact(code: &EvalCode, n: usize, k: usize, seed: u64) {
        let mut rng = rng_from_seed(seed);
        let x = Matrix::random_gaussian(8 * k, 6, 0.0, 1.0, &mut rng);
        let v = Matrix::random_gaussian(6, 5, 0.0, 1.0, &mut rng);
        let enc = code.encode_blocks(&x, 1, &mut rng).unwrap();
        assert_eq!(enc.shares.len(), n);
        // Return exactly the threshold, from an arbitrary offset.
        let need = match code.block_threshold(1) {
            Threshold::Exact(t) => t,
            _ => unreachable!(),
        };
        let results: Vec<(usize, Matrix)> = (0..need)
            .map(|j| {
                let idx = (j * 7 + 3) % n; // scattered subset
                (idx, matmul(&enc.shares[idx], &v))
            })
            .collect();
        // Dedup protection: indices must be distinct for this test setup.
        let mut seen: Vec<usize> = results.iter().map(|(i, _)| *i).collect();
        seen.sort_unstable();
        seen.dedup();
        if seen.len() < need {
            // fall back to first `need` workers
            let results: Vec<(usize, Matrix)> =
                (0..need).map(|i| (i, matmul(&enc.shares[i], &v))).collect();
            let decoded = code.decode_blocks(&enc.ctx, &results).unwrap();
            assert_exact(&x, &v, k, &decoded);
            return;
        }
        let decoded = code.decode_blocks(&enc.ctx, &results).unwrap();
        assert_exact(&x, &v, k, &decoded);
    }

    fn assert_exact(x: &Matrix, v: &Matrix, k: usize, decoded: &[Matrix]) {
        let (blocks, _) = split_rows(x, k);
        for (i, d) in decoded.iter().enumerate() {
            let expect = matmul(&blocks[i], v);
            let err = d.rel_error(&expect);
            assert!(err < 1e-2, "block {i}: err {err}");
        }
    }

    #[test]
    fn mds_decodes_exactly_from_threshold() {
        check_linear_exact(&EvalCode::mds(CodeParams::new(12, 4, 0)), 12, 4, 70);
    }

    #[test]
    fn polynomial_decodes_exactly_from_threshold() {
        check_linear_exact(&EvalCode::polynomial(CodeParams::new(10, 3, 0)), 10, 3, 71);
    }

    #[test]
    fn secpoly_decodes_exactly_and_is_private() {
        let code = EvalCode::secpoly(CodeParams::new(14, 4, 2));
        assert!(code.is_private());
        assert_eq!(code.block_threshold(1), Threshold::Exact(6)); // K+T
        check_linear_exact(&code, 14, 4, 72);
    }

    #[test]
    fn lcc_handles_quadratic_tasks() {
        // Gram (degree 2): threshold 2(K+T−1)+1.
        let k = 2;
        let t = 1;
        let n = 12;
        let code = EvalCode::lcc(CodeParams::new(n, k, t));
        assert_eq!(code.block_threshold(2), Threshold::Exact(5));
        let mut rng = rng_from_seed(73);
        let x = Matrix::random_gaussian(10, 6, 0.0, 1.0, &mut rng);
        let enc = code.encode_blocks(&x, 2, &mut rng).unwrap();
        let results: Vec<(usize, Matrix)> =
            (0..5).map(|i| (i, gram(&enc.shares[i]))).collect();
        let decoded = code.decode_blocks(&enc.ctx, &results).unwrap();
        let (blocks, _) = split_rows(&x, k);
        for (d, b) in decoded.iter().zip(&blocks) {
            let err = d.rel_error(&gram(b));
            assert!(err < 5e-2, "err={err}");
        }
    }

    #[test]
    fn below_threshold_fails() {
        let code = EvalCode::mds(CodeParams::new(8, 4, 0));
        let mut rng = rng_from_seed(74);
        let x = Matrix::random_uniform(8, 4, -1.0, 1.0, &mut rng);
        let enc = code.encode_blocks(&x, 1, &mut rng).unwrap();
        let results: Vec<(usize, Matrix)> =
            (0..3).map(|i| (i, enc.shares[i].clone())).collect();
        assert!(matches!(
            code.decode_blocks(&enc.ctx, &results),
            Err(CodingError::NotEnoughResults { need: 4, got: 3 })
        ));
    }

    #[test]
    fn mds_rejects_nonlinear_tasks() {
        let code = EvalCode::mds(CodeParams::new(8, 4, 0));
        let mut rng = rng_from_seed(75);
        let x = Matrix::ones(8, 4);
        assert!(matches!(
            code.encode_blocks(&x, 2, &mut rng),
            Err(CodingError::UnsupportedDegree { .. })
        ));
    }

    #[test]
    fn threshold_exceeding_n_rejected_at_encode() {
        // LCC degree 2 with K+T too large for N.
        let code = EvalCode::lcc(CodeParams::new(8, 4, 2));
        // threshold = 2(6−1)+1 = 11 > 8
        let mut rng = rng_from_seed(76);
        let x = Matrix::ones(8, 2);
        assert!(matches!(
            code.encode_blocks(&x, 2, &mut rng),
            Err(CodingError::NotEnoughResults { need: 11, got: 8 })
        ));
    }

    #[test]
    fn property_any_threshold_subset_decodes_linear_tasks() {
        forall(10, 77, |g| {
            let k = g.usize_in(2..5);
            let n = k + 4 + g.usize_in(0..6);
            let code = EvalCode::mds(CodeParams::new(n, k, 0));
            let mut rng = rng_from_seed(g.u64());
            let x = Matrix::random_gaussian(4 * k, 5, 0.0, 1.0, &mut rng);
            let v = Matrix::random_gaussian(5, 3, 0.0, 1.0, &mut rng);
            let enc = code.encode_blocks(&x, 1, &mut rng).unwrap();
            let idx = g.subset(n, k);
            let results: Vec<(usize, Matrix)> =
                idx.iter().map(|&i| (i, matmul(&enc.shares[i], &v))).collect();
            let decoded = code.decode_blocks(&enc.ctx, &results).unwrap();
            let (blocks, _) = split_rows(&x, k);
            for (d, b) in decoded.iter().zip(&blocks) {
                let err = d.rel_error(&matmul(b, &v));
                if err > 0.05 {
                    return Err(format!("subset decode err {err} (n={n}, k={k})"));
                }
            }
            prop_assert(true, "")
        });
    }
}
