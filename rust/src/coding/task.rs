//! Typed coded-computation tasks.
//!
//! A [`CodedTask`] is *what* the master wants computed, independent of
//! *how* any particular scheme encodes it — the framing of Lagrange
//! coded computing (Yu et al.) where one encode → compute → decode
//! pipeline is parameterized by the task. Two shapes cover every
//! workload in the paper:
//!
//! * [`CodedTask::BlockMap`] — distribute a single-operand worker op `f`
//!   over the K row-blocks of `x`; the decode result is the per-block
//!   vector `{Yᵢ ≈ f(Xᵢ)}`. This is the row-partition schemes' native
//!   shape (SPACDC, BACC, MDS, Polynomial, LCC, SecPoly, CONV).
//! * [`CodedTask::PairProduct`] — the full product `A·B`; the decode
//!   result is a single matrix. This is MatDot's native shape, and the
//!   row-partition schemes serve it too (encode A's row-blocks, workers
//!   right-multiply by the broadcast B, decode + restack).
//!
//! Every scheme receives the task through the widened
//! [`Scheme`](super::Scheme) trait, so the coordinator needs exactly one
//! round pipeline for all eight [`SchemeKind`](crate::config::SchemeKind)s.

use crate::matrix::Matrix;
use crate::runtime::WorkerOp;
use std::sync::Arc;

/// One coded computation request.
#[derive(Clone, Debug)]
pub enum CodedTask {
    /// Distribute `op` over the row-blocks of `x`: decode yields
    /// `{Yᵢ ≈ op(Xᵢ)}`, one matrix per partition.
    BlockMap {
        /// The single-operand worker task `f` (its polynomial degree
        /// drives each scheme's recovery threshold).
        op: WorkerOp,
        /// The data matrix to partition and encode.
        x: Matrix,
    },
    /// Compute the full product `A·B`: decode yields one matrix.
    PairProduct {
        /// Left operand (the encoded side for row-partition schemes).
        a: Matrix,
        /// Right operand. Shared so the row-partition schemes can
        /// broadcast it into a [`WorkerOp::RightMul`] without another
        /// full-matrix copy.
        b: Arc<Matrix>,
    },
}

impl CodedTask {
    /// Convenience constructor for a block-map task.
    pub fn block_map(op: WorkerOp, x: Matrix) -> Self {
        CodedTask::BlockMap { op, x }
    }

    /// Convenience constructor for a pair-product task.
    pub fn pair_product(a: Matrix, b: Matrix) -> Self {
        CodedTask::PairProduct { a, b: Arc::new(b) }
    }

    /// Pair-product constructor for an already-shared right operand
    /// (e.g. the same weight matrix reused across rounds).
    pub fn pair_product_shared(a: Matrix, b: Arc<Matrix>) -> Self {
        CodedTask::PairProduct { a, b }
    }

    /// Short task name for error messages and metrics.
    pub fn name(&self) -> &'static str {
        match self {
            CodedTask::BlockMap { .. } => "block-map",
            CodedTask::PairProduct { .. } => "pair-product",
        }
    }

    /// Polynomial degree of the worker task *in the encoded operand*, the
    /// quantity every row-partition threshold formula consumes. A pair
    /// product is degree 1 from a row-partition scheme's point of view
    /// (only A is encoded; B is broadcast), even though MatDot — which
    /// encodes both operands — ignores this and uses its own 2K−1.
    pub fn degree(&self) -> u32 {
        match self {
            CodedTask::BlockMap { op, .. } => op.degree(),
            CodedTask::PairProduct { .. } => 1,
        }
    }

    /// The shape tag recorded into the decode context.
    pub fn shape(&self) -> TaskShape {
        match self {
            CodedTask::BlockMap { .. } => TaskShape::BlockMap,
            CodedTask::PairProduct { .. } => TaskShape::PairProduct,
        }
    }
}

/// Which task shape a round was encoded for — recorded in the
/// [`DecodeCtx`](super::DecodeCtx) so decode knows whether to return
/// per-block results or a single stacked/interpolated product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskShape {
    /// Per-block results `{Yᵢ}`.
    BlockMap,
    /// One full-product result.
    PairProduct,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_degrees_follow_the_encoded_operand() {
        let x = Matrix::ones(4, 4);
        assert_eq!(CodedTask::block_map(WorkerOp::Gram, x.clone()).degree(), 2);
        assert_eq!(CodedTask::block_map(WorkerOp::Identity, x.clone()).degree(), 1);
        assert_eq!(CodedTask::pair_product(x.clone(), x).degree(), 1);
    }

    #[test]
    fn shapes_and_names() {
        let x = Matrix::ones(2, 2);
        let bm = CodedTask::block_map(WorkerOp::Identity, x.clone());
        let pp = CodedTask::pair_product(x.clone(), x);
        assert_eq!(bm.shape(), TaskShape::BlockMap);
        assert_eq!(pp.shape(), TaskShape::PairProduct);
        assert_eq!(bm.name(), "block-map");
        assert_eq!(pp.name(), "pair-product");
    }
}
