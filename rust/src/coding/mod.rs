//! Coding schemes: the paper's SPACDC plus every baseline in Table II.
//!
//! | Module       | Scheme | Threshold (deg-1 task) | Private | Exact? |
//! |--------------|--------|------------------------|---------|--------|
//! | `spacdc`     | SPACDC (this paper) | flexible (any ≥ 1) | yes (T masks) | approximate |
//! | `bacc`       | BACC [18]           | flexible (any ≥ 1) | no  | approximate |
//! | `evalcode`   | MDS [22]            | K                  | no  | exact |
//! | `evalcode`   | Polynomial [23]     | K                  | no  | exact |
//! | `evalcode`   | LCC [27]            | deg·(K+T−1)+1      | yes | exact |
//! | `evalcode`   | SecPoly [34]        | K+T                | yes | exact |
//! | `matdot`     | MatDot [24]         | 2K−1 (pair code)   | no  | exact |
//! | `uncoded`    | CONV                | N                  | no  | exact |

pub mod bacc;
pub mod evalcode;
pub mod interp;
pub mod matdot;
pub mod spacdc;
pub mod traits;
pub mod uncoded;

pub use bacc::Bacc;
pub use evalcode::EvalCode;
pub use matdot::{MatDot, MatDotEncoded};
pub use spacdc::Spacdc;
pub use traits::{CodeParams, CodingError, DecodeCtx, Encoded, Scheme, Threshold};
pub use uncoded::Uncoded;

use crate::config::SchemeKind;

/// Build the row-partition scheme for `kind`.
///
/// MatDot is a pair code with a different API; asking for it here returns
/// `None` and callers must use [`MatDot`] directly (the DL trainer does).
pub fn make_scheme(kind: SchemeKind, params: CodeParams) -> Option<Box<dyn Scheme>> {
    Some(match kind {
        SchemeKind::Spacdc => Box::new(Spacdc::new(params)),
        SchemeKind::Bacc => Box::new(Bacc::new(params)),
        SchemeKind::Mds => Box::new(EvalCode::mds(params)),
        SchemeKind::Polynomial => Box::new(EvalCode::polynomial(params)),
        SchemeKind::Lcc => Box::new(EvalCode::lcc(params)),
        SchemeKind::SecPoly => Box::new(EvalCode::secpoly(params)),
        SchemeKind::Uncoded => Box::new(Uncoded::new(params)),
        SchemeKind::MatDot => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_every_row_partition_scheme() {
        let params = CodeParams::new(12, 3, 2);
        for kind in [
            SchemeKind::Spacdc,
            SchemeKind::Bacc,
            SchemeKind::Mds,
            SchemeKind::Polynomial,
            SchemeKind::Lcc,
            SchemeKind::SecPoly,
            SchemeKind::Uncoded,
        ] {
            let s = make_scheme(kind, params).unwrap_or_else(|| panic!("{kind:?}"));
            assert_eq!(s.kind(), kind);
        }
    }

    #[test]
    fn factory_declines_matdot() {
        assert!(make_scheme(SchemeKind::MatDot, CodeParams::new(12, 3, 0)).is_none());
    }

    #[test]
    fn privacy_flags_match_table_ii() {
        let params = CodeParams::new(12, 3, 2);
        let expect = [
            (SchemeKind::Spacdc, true),
            (SchemeKind::Bacc, false),
            (SchemeKind::Mds, false),
            (SchemeKind::Polynomial, false),
            (SchemeKind::Lcc, true),
            (SchemeKind::SecPoly, true),
            (SchemeKind::Uncoded, false),
        ];
        for (kind, private) in expect {
            let s = make_scheme(kind, params).unwrap();
            assert_eq!(s.is_private(), private, "{kind:?}");
        }
    }

    #[test]
    fn thresholds_match_table_ii_ordering() {
        // For a linear task at K=4, T=2, N=30:
        //   SPACDC/BACC flexible < MDS/Poly (4) < SecPoly/LCC (6) < CONV (30).
        let params = CodeParams::new(30, 4, 2);
        let exact = |k: SchemeKind| match make_scheme(k, params).unwrap().threshold(1) {
            Threshold::Exact(v) => v,
            Threshold::Flexible { .. } => 0,
        };
        assert_eq!(exact(SchemeKind::Mds), 4);
        assert_eq!(exact(SchemeKind::Polynomial), 4);
        assert_eq!(exact(SchemeKind::SecPoly), 6);
        assert_eq!(exact(SchemeKind::Lcc), 6);
        assert_eq!(exact(SchemeKind::Uncoded), 30);
        assert!(matches!(
            make_scheme(SchemeKind::Spacdc, params).unwrap().threshold(1),
            Threshold::Flexible { min: 1 }
        ));
        // MatDot: 2K−1 = 7.
        assert_eq!(MatDot::new(30, 4).threshold(), 7);
    }
}
