//! Coding schemes: the paper's SPACDC plus every baseline in Table II.
//!
//! | Module       | Scheme | Threshold (deg-1 task) | Private | Exact? |
//! |--------------|--------|------------------------|---------|--------|
//! | `spacdc`     | SPACDC (this paper) | flexible (any ≥ 1) | yes (T masks) | approximate |
//! | `bacc`       | BACC [18]           | flexible (any ≥ 1) | no  | approximate |
//! | `evalcode`   | MDS [22]            | K                  | no  | exact |
//! | `evalcode`   | Polynomial [23]     | K                  | no  | exact |
//! | `evalcode`   | LCC [27]            | deg·(K+T−1)+1      | yes | exact |
//! | `evalcode`   | SecPoly [34]        | K+T                | yes | exact |
//! | `matdot`     | MatDot [24]         | 2K−1 (pair code)   | no  | exact |
//! | `uncoded`    | CONV                | N                  | no  | exact |
//!
//! Every scheme — MatDot included — implements the task-level [`Scheme`]
//! trait over typed [`CodedTask`]s, so [`make_scheme`] is total over
//! [`SchemeKind`] and the coordinator runs one round pipeline for all
//! eight. The seven row-partition schemes implement [`BlockCode`] (the
//! per-block encode/decode machinery) and pick up `Scheme` through a
//! blanket impl; MatDot, a pair code, implements `Scheme` directly.

pub mod bacc;
pub mod evalcode;
pub mod interp;
pub mod matdot;
pub mod spacdc;
pub mod task;
pub mod traits;
pub mod uncoded;

pub use bacc::Bacc;
pub use evalcode::EvalCode;
pub use matdot::{MatDot, MatDotEncoded};
pub use spacdc::Spacdc;
pub use task::{CodedTask, TaskShape};
pub use traits::{
    BlockCode, CodeParams, CodingError, DecodeCtx, Encoded, EncodedJob, Scheme, Threshold,
};
pub use uncoded::Uncoded;

use crate::config::SchemeKind;

/// Build the scheme for `kind` — total over all 8 [`SchemeKind`]s.
///
/// Construction never fails; parameter sets a scheme cannot serve (e.g.
/// MatDot with 2K−1 > N, or SPACDC with T = 0) surface as
/// [`CodingError::InvalidParams`] when the first task is encoded.
pub fn make_scheme(kind: SchemeKind, params: CodeParams) -> Box<dyn Scheme> {
    match kind {
        SchemeKind::Spacdc => Box::new(Spacdc::new(params)),
        SchemeKind::Bacc => Box::new(Bacc::new(params)),
        SchemeKind::Mds => Box::new(EvalCode::mds(params)),
        SchemeKind::Polynomial => Box::new(EvalCode::polynomial(params)),
        SchemeKind::Lcc => Box::new(EvalCode::lcc(params)),
        SchemeKind::SecPoly => Box::new(EvalCode::secpoly(params)),
        SchemeKind::Uncoded => Box::new(Uncoded::new(params)),
        SchemeKind::MatDot => Box::new(MatDot::from_params(params)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Matrix;
    use crate::runtime::WorkerOp;

    fn probe_task() -> CodedTask {
        CodedTask::block_map(WorkerOp::Identity, Matrix::ones(4, 4))
    }

    #[test]
    fn factory_builds_every_scheme() {
        let params = CodeParams::new(12, 3, 2);
        for kind in SchemeKind::all() {
            let s = make_scheme(kind, params);
            assert_eq!(s.kind(), kind);
        }
    }

    #[test]
    fn privacy_flags_match_table_ii() {
        let params = CodeParams::new(12, 3, 2);
        let expect = [
            (SchemeKind::Spacdc, true),
            (SchemeKind::Bacc, false),
            (SchemeKind::Mds, false),
            (SchemeKind::Polynomial, false),
            (SchemeKind::Lcc, true),
            (SchemeKind::SecPoly, true),
            (SchemeKind::Uncoded, false),
            (SchemeKind::MatDot, false),
        ];
        for (kind, private) in expect {
            let s = make_scheme(kind, params);
            assert_eq!(s.is_private(), private, "{kind:?}");
        }
    }

    #[test]
    fn thresholds_match_table_ii_ordering() {
        // For a linear task at K=4, T=2, N=30:
        //   SPACDC/BACC flexible < MDS/Poly (4) < SecPoly/LCC (6) <
        //   MatDot (7) < CONV (30).
        let params = CodeParams::new(30, 4, 2);
        let task = probe_task();
        let exact = |k: SchemeKind| match make_scheme(k, params).threshold(&task) {
            Threshold::Exact(v) => v,
            Threshold::Flexible { .. } => 0,
        };
        assert_eq!(exact(SchemeKind::Mds), 4);
        assert_eq!(exact(SchemeKind::Polynomial), 4);
        assert_eq!(exact(SchemeKind::SecPoly), 6);
        assert_eq!(exact(SchemeKind::Lcc), 6);
        assert_eq!(exact(SchemeKind::MatDot), 7);
        assert_eq!(exact(SchemeKind::Uncoded), 30);
        assert!(matches!(
            make_scheme(SchemeKind::Spacdc, params).threshold(&task),
            Threshold::Flexible { min: 1 }
        ));
        // MatDot's own constructor agrees: 2K−1 = 7.
        assert_eq!(MatDot::new(30, 4).unwrap().recovery_threshold(), 7);
    }

    #[test]
    fn task_support_matrix() {
        // Row-partition schemes serve both task shapes; MatDot serves
        // pair products only; linear-only schemes reject degree-2 maps.
        let params = CodeParams::new(12, 3, 2);
        let gram = CodedTask::block_map(WorkerOp::Gram, Matrix::ones(6, 4));
        let pair = CodedTask::pair_product(Matrix::ones(6, 4), Matrix::ones(4, 6));
        for kind in SchemeKind::all() {
            let s = make_scheme(kind, params);
            assert!(s.supports(&pair), "{kind:?} must serve pair products");
            let expect_blockmap = kind != SchemeKind::MatDot;
            assert_eq!(s.supports(&probe_task()), expect_blockmap, "{kind:?} block-map");
            let expect_gram = matches!(
                kind,
                SchemeKind::Spacdc
                    | SchemeKind::Bacc
                    | SchemeKind::Lcc
                    | SchemeKind::Uncoded
            );
            assert_eq!(s.supports(&gram), expect_gram, "{kind:?} gram");
        }
    }
}
