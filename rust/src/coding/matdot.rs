//! MatDot codes (Dutta et al. [24]) — the paper's matrix-product
//! baseline (MATDOT-DL), Table II row 2.
//!
//! MatDot is a *pair* code, not a row-partition code: for `Y = A·B` the
//! master splits A by **columns** and B by **rows** into K blocks each,
//! so `A·B = Σᵢ AᵢBᵢ`. With
//!
//! ```text
//!   p_A(z) = Σᵢ Aᵢ zⁱ,     p_B(z) = Σⱼ Bⱼ z^{K−1−j},
//! ```
//!
//! worker j computes `p_A(αⱼ)·p_B(αⱼ)` — one product of small matrices —
//! and the coefficient of z^{K−1} in `p_A·p_B` (degree 2K−2) is exactly
//! `A·B`. The recovery threshold is therefore **2K−1**, the highest of
//! all baselines, and each worker's result is a full `r×c` matrix — the
//! two facts behind MatDot's worst-in-class communication (Fig. 6) and
//! computation (Fig. 7) curves.
//!
//! MatDot implements the task-level [`Scheme`] trait directly (it is the
//! one non-[`BlockCode`](super::BlockCode) scheme): it serves
//! [`CodedTask::PairProduct`] with two operand payloads per worker and
//! rejects [`CodedTask::BlockMap`], so the coordinator drives it through
//! the same `encode → dispatch → decode` pipeline as every other scheme.

use super::interp::{chebyshev_nodes_in, polynomial_coefficients};
use super::task::{CodedTask, TaskShape};
use super::traits::{
    validate_results, CodeParams, CodingError, DecodeCtx, EncodedJob, Scheme, Threshold,
};
use crate::config::SchemeKind;
use crate::matrix::{matmul, Matrix, PartitionSpec};
use crate::rng::Rng;
use crate::runtime::WorkerOp;

/// MatDot code for the product `A·B`.
#[derive(Clone, Debug)]
pub struct MatDot {
    /// Workers N.
    pub n: usize,
    /// Partitions K (per operand).
    pub k: usize,
}

/// Encoded MatDot computation: per-worker operand pairs + decode context.
#[derive(Clone, Debug)]
pub struct MatDotEncoded {
    /// (Ãⱼ, B̃ⱼ) per worker.
    pub shares: Vec<(Matrix, Matrix)>,
    /// Worker evaluation nodes.
    pub alphas: Vec<f64>,
    /// Partitions.
    pub k: usize,
}

impl MatDot {
    /// Construct; rejects parameter sets that could never decode
    /// (needs K ≥ 1 and 2K−1 ≤ N).
    pub fn new(n: usize, k: usize) -> Result<Self, CodingError> {
        if k < 1 {
            return Err(CodingError::InvalidParams("MatDot needs K ≥ 1".into()));
        }
        if 2 * k - 1 > n {
            return Err(CodingError::InvalidParams(format!(
                "MatDot needs 2K-1 ≤ N (K={k}, N={n})"
            )));
        }
        Ok(Self { n, k })
    }

    /// Unvalidated construction from shared code parameters — used by the
    /// infallible scheme factory; an undecodable shape is reported as
    /// [`CodingError::InvalidParams`] at encode time.
    pub fn from_params(params: CodeParams) -> Self {
        Self { n: params.n, k: params.k }
    }

    /// Recovery threshold 2K−1 (0 for the degenerate K = 0 shape, which
    /// [`MatDot::new`] rejects and `encode` reports as `InvalidParams` —
    /// saturating here keeps factory-built probes panic-free).
    pub fn recovery_threshold(&self) -> usize {
        (2 * self.k).saturating_sub(1)
    }

    /// Split A by columns and B by rows into K blocks each (zero-padding
    /// the shared inner dimension), and encode the polynomial pair at N
    /// Chebyshev nodes.
    pub fn encode_pair(&self, a: &Matrix, b: &Matrix) -> Result<MatDotEncoded, CodingError> {
        if self.k < 1 || 2 * self.k - 1 > self.n {
            return Err(CodingError::InvalidParams(format!(
                "MatDot needs 2K-1 ≤ N (K={}, N={})",
                self.k, self.n
            )));
        }
        if a.cols() != b.rows() {
            return Err(CodingError::ShapeMismatch(format!(
                "A cols {} != B rows {}",
                a.cols(),
                b.rows()
            )));
        }
        let k = self.k;
        let inner = a.cols();
        let block = inner.div_ceil(k);

        // Column blocks of A (padded with zero columns).
        let a_blocks: Vec<Matrix> = (0..k)
            .map(|i| {
                Matrix::from_fn(a.rows(), block, |r, c| {
                    let col = i * block + c;
                    if col < inner {
                        a.get(r, col)
                    } else {
                        0.0
                    }
                })
            })
            .collect();
        // Row blocks of B (padded with zero rows).
        let b_blocks: Vec<Matrix> = (0..k)
            .map(|i| {
                Matrix::from_fn(block, b.cols(), |r, c| {
                    let row = i * block + r;
                    if row < inner {
                        b.get(row, c)
                    } else {
                        0.0
                    }
                })
            })
            .collect();

        let alphas = chebyshev_nodes_in(self.n, -1.0, 1.0);
        let shares = alphas
            .iter()
            .map(|&z| {
                // p_A(z) = Σ Aᵢ zⁱ;  p_B(z) = Σ Bⱼ z^{K−1−j}
                let mut pa = Matrix::zeros(a.rows(), block);
                let mut pb = Matrix::zeros(block, b.cols());
                for i in 0..k {
                    pa.axpy(z.powi(i as i32) as f32, &a_blocks[i]);
                    pb.axpy(z.powi((k - 1 - i) as i32) as f32, &b_blocks[i]);
                }
                (pa, pb)
            })
            .collect();
        Ok(MatDotEncoded { shares, alphas, k })
    }

    /// The worker task: multiply the two received operands.
    pub fn worker_compute(share: &(Matrix, Matrix)) -> Matrix {
        matmul(&share.0, &share.1)
    }

    /// Decode `A·B` from ≥ 2K−1 worker products (block-level API over a
    /// [`MatDotEncoded`]; the coordinator path goes through
    /// [`Scheme::decode`] instead).
    pub fn decode_pair(
        &self,
        enc: &MatDotEncoded,
        results: &[(usize, Matrix)],
    ) -> Result<Matrix, CodingError> {
        interpolate_product(self.n, enc.k, &enc.alphas, results)
    }
}

/// Interpolate the degree-2K−2 matrix polynomial from ≥ 2K−1 worker
/// products at nodes `alphas`; `A·B` is the coefficient of z^{K−1}.
fn interpolate_product(
    n: usize,
    k: usize,
    alphas: &[f64],
    results: &[(usize, Matrix)],
) -> Result<Matrix, CodingError> {
    if k < 1 {
        return Err(CodingError::InvalidParams("MatDot needs K ≥ 1".into()));
    }
    let need = 2 * k - 1;
    if results.len() < need {
        return Err(CodingError::NotEnoughResults { need, got: results.len() });
    }
    let sorted = validate_results(n, results)?;
    let take = &sorted[..need];
    let nodes: Vec<f64> = take.iter().map(|(i, _)| alphas[*i]).collect();
    let values: Vec<Matrix> = take.iter().map(|(_, m)| m.clone()).collect();
    let coeffs =
        polynomial_coefficients(&nodes, &values, 2 * k - 2).map_err(CodingError::Numerical)?;
    Ok(coeffs.into_iter().nth(k - 1).unwrap())
}

impl Scheme for MatDot {
    fn kind(&self) -> SchemeKind {
        SchemeKind::MatDot
    }

    fn params(&self) -> CodeParams {
        CodeParams::new(self.n, self.k, 0)
    }

    fn threshold(&self, _task: &CodedTask) -> Threshold {
        Threshold::Exact(self.recovery_threshold())
    }

    fn supports(&self, task: &CodedTask) -> bool {
        matches!(task, CodedTask::PairProduct { .. })
    }

    fn encode(&self, task: &CodedTask, _rng: &mut Rng) -> Result<EncodedJob, CodingError> {
        let (a, b) = match task {
            CodedTask::PairProduct { a, b } => (a, b),
            CodedTask::BlockMap { .. } => {
                return Err(CodingError::UnsupportedTask {
                    scheme: SchemeKind::MatDot.name(),
                    task: task.name(),
                })
            }
        };
        let enc = self.encode_pair(a, b)?;
        Ok(EncodedJob {
            payloads: enc.shares.into_iter().map(|(pa, pb)| vec![pa, pb]).collect(),
            op: WorkerOp::PairProduct,
            ctx: DecodeCtx {
                kind: SchemeKind::MatDot,
                params: Scheme::params(self),
                alphas: enc.alphas,
                betas: vec![],
                spec: PartitionSpec::new(a.rows(), 1),
                degree: 2,
                shape: TaskShape::PairProduct,
            },
        })
    }

    fn decode(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError> {
        let product = interpolate_product(ctx.params.n, ctx.params.k, &ctx.alphas, results)?;
        Ok(vec![product])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn exact_product_from_threshold_returns() {
        let mut rng = rng_from_seed(90);
        for k in [1usize, 2, 3, 4] {
            let n = 2 * k + 3;
            let code = MatDot::new(n, k).unwrap();
            let a = Matrix::random_gaussian(10, 8, 0.0, 1.0, &mut rng);
            let b = Matrix::random_gaussian(8, 6, 0.0, 1.0, &mut rng);
            let enc = code.encode_pair(&a, &b).unwrap();
            let results: Vec<(usize, Matrix)> = (0..code.recovery_threshold())
                .map(|i| (i, MatDot::worker_compute(&enc.shares[i])))
                .collect();
            let got = code.decode_pair(&enc, &results).unwrap();
            let expect = matmul(&a, &b);
            assert!(got.rel_error(&expect) < 1e-2, "k={k}: err {}", got.rel_error(&expect));
        }
    }

    #[test]
    fn works_with_scattered_subset() {
        let mut rng = rng_from_seed(91);
        let code = MatDot::new(12, 3).unwrap();
        let a = Matrix::random_gaussian(6, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(9, 4, 0.0, 1.0, &mut rng);
        let enc = code.encode_pair(&a, &b).unwrap();
        let idx = [1usize, 4, 6, 8, 11];
        let results: Vec<(usize, Matrix)> = idx
            .iter()
            .map(|&i| (i, MatDot::worker_compute(&enc.shares[i])))
            .collect();
        let got = code.decode_pair(&enc, &results).unwrap();
        assert!(got.rel_error(&matmul(&a, &b)) < 1e-2);
    }

    #[test]
    fn below_threshold_rejected() {
        let mut rng = rng_from_seed(92);
        let code = MatDot::new(8, 3).unwrap();
        let a = Matrix::random_uniform(4, 6, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(6, 4, -1.0, 1.0, &mut rng);
        let enc = code.encode_pair(&a, &b).unwrap();
        let results: Vec<(usize, Matrix)> = (0..4)
            .map(|i| (i, MatDot::worker_compute(&enc.shares[i])))
            .collect();
        assert!(matches!(
            code.decode_pair(&enc, &results),
            Err(CodingError::NotEnoughResults { need: 5, got: 4 })
        ));
    }

    #[test]
    fn inner_dim_padding_handled() {
        // inner = 7, K = 3 → block = 3, padded to 9.
        let mut rng = rng_from_seed(93);
        let code = MatDot::new(9, 3).unwrap();
        let a = Matrix::random_gaussian(5, 7, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(7, 5, 0.0, 1.0, &mut rng);
        let enc = code.encode_pair(&a, &b).unwrap();
        let results: Vec<(usize, Matrix)> = (0..5)
            .map(|i| (i, MatDot::worker_compute(&enc.shares[i])))
            .collect();
        let got = code.decode_pair(&enc, &results).unwrap();
        assert!(got.rel_error(&matmul(&a, &b)) < 1e-2);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let code = MatDot::new(5, 2).unwrap();
        let a = Matrix::ones(3, 4);
        let b = Matrix::ones(5, 3);
        assert!(matches!(
            code.encode_pair(&a, &b),
            Err(CodingError::ShapeMismatch(_))
        ));
    }

    #[test]
    fn gram_via_matdot() {
        // X·Xᵀ through the pair API (how MatDot serves the paper's
        // running example).
        let mut rng = rng_from_seed(94);
        let code = MatDot::new(10, 2).unwrap();
        let x = Matrix::random_gaussian(6, 8, 0.0, 1.0, &mut rng);
        let xt = x.transpose();
        let enc = code.encode_pair(&x, &xt).unwrap();
        let results: Vec<(usize, Matrix)> = (3..6)
            .map(|i| (i, MatDot::worker_compute(&enc.shares[i])))
            .collect();
        let got = code.decode_pair(&enc, &results).unwrap();
        assert!(got.rel_error(&crate::matrix::gram(&x)) < 1e-2);
    }

    #[test]
    fn constructor_enforces_decodability() {
        // 2K−1 = 5 > N = 4 → rejected with InvalidParams (not a panic).
        assert!(matches!(
            MatDot::new(4, 3),
            Err(CodingError::InvalidParams(_))
        ));
        assert!(matches!(
            MatDot::new(5, 0),
            Err(CodingError::InvalidParams(_))
        ));
    }

    #[test]
    fn scheme_encode_decode_round_trip() {
        // The task-level Scheme path: two payloads per worker, decode to
        // the single full product.
        let mut rng = rng_from_seed(95);
        let code = MatDot::new(10, 3).unwrap();
        let a = Matrix::random_gaussian(7, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(9, 5, 0.0, 1.0, &mut rng);
        let task = CodedTask::pair_product(a.clone(), b.clone());
        assert!(code.supports(&task));
        assert_eq!(code.threshold(&task), Threshold::Exact(5));
        let job = code.encode(&task, &mut rng).unwrap();
        assert_eq!(job.payloads.len(), 10);
        assert_eq!(job.payloads[0].len(), 2);
        let results: Vec<(usize, Matrix)> = (2..7)
            .map(|i| (i, matmul(&job.payloads[i][0], &job.payloads[i][1])))
            .collect();
        let decoded = code.decode(&job.ctx, &results).unwrap();
        assert_eq!(decoded.len(), 1);
        assert!(decoded[0].rel_error(&matmul(&a, &b)) < 1e-2);
    }

    #[test]
    fn scheme_rejects_blockmap_tasks() {
        let code = MatDot::new(10, 3).unwrap();
        let task = CodedTask::block_map(WorkerOp::Identity, Matrix::ones(6, 4));
        assert!(!code.supports(&task));
        assert!(matches!(
            code.encode(&task, &mut rng_from_seed(0)),
            Err(CodingError::UnsupportedTask { .. })
        ));
    }

    #[test]
    fn factory_shape_errors_surface_at_encode() {
        // from_params never fails; the undecodable shape errors on use.
        let code = MatDot::from_params(CodeParams::new(4, 3, 0));
        let a = Matrix::ones(4, 6);
        let b = Matrix::ones(6, 4);
        assert!(matches!(
            code.encode_pair(&a, &b),
            Err(CodingError::InvalidParams(_))
        ));
    }
}
