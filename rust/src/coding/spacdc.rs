//! The SPACDC scheme — paper §V, Algorithm 1.
//!
//! **Encode** (Eq. (17)): the K data blocks and T i.i.d. random mask
//! blocks are combined through the Berrut rational basis at nodes
//! β₀..β_{K+T−1}; worker j receives `X̃ⱼ = u(αⱼ)`. The interpolation
//! property `u(βᵢ) = Xᵢ` holds by construction, and any T shares are
//! jointly independent of the data because the T masks enter every share
//! with an invertible mixing (Theorem 2).
//!
//! **Decode** (Eq. (18)): from any subset 𝓕 of returned `Ỹⱼ = f(X̃ⱼ)`,
//! the master builds the Berrut interpolant h(z) of f∘u on the nodes
//! {αⱼ}ⱼ∈𝓕 and reads off `Yᵢ ≈ h(βᵢ)`. No strict recovery threshold:
//! |𝓕| ≥ 1 decodes, and accuracy improves with |𝓕|.
//!
//! *Sign convention*: Eq. (18) writes the global worker sign (−1)ʲ, but
//! Berrut's interpolant is pole-free only when signs alternate along the
//! *sorted* node sequence — with an arbitrary straggler pattern the
//! global signs break alternation and the denominator can vanish near a
//! recovery point. We therefore renumber signs consecutively over the
//! sorted returned nodes, which is exactly the BACC decoder's behaviour
//! and restores the stability guarantee (see `decode_berrut`).

use super::interp::{berrut_eval, berrut_weights, chebyshev_nodes_in, disjoint_eval_nodes};
use super::task::TaskShape;
use super::traits::{
    validate_results, BlockCode, CodeParams, CodingError, DecodeCtx, Encoded, Threshold,
};
use crate::config::SchemeKind;
use crate::matrix::{split_rows, Matrix};
use crate::rng::Rng;

/// SPACDC code (this paper's contribution).
#[derive(Clone, Debug)]
pub struct Spacdc {
    params: CodeParams,
    /// Amplitude of the uniform mask blocks Z (paper: uniform over 𝔽;
    /// over ℝ this sets the privacy/accuracy trade-off — see the
    /// `mask_scale` ablation bench).
    mask_scale: f32,
}

impl Spacdc {
    /// Standard construction: masks at the data's unit scale.
    ///
    /// SPACDC requires T ≥ 1 mask; a T = 0 construction is accepted here
    /// (so the scheme factory is infallible) and rejected with
    /// [`CodingError::InvalidParams`] at encode time.
    pub fn new(params: CodeParams) -> Self {
        Self { params, mask_scale: 1.0 }
    }

    /// Construction with explicit mask amplitude.
    pub fn with_mask_scale(params: CodeParams, mask_scale: f32) -> Self {
        assert!(mask_scale > 0.0, "mask scale must be positive");
        let mut s = Self::new(params);
        s.mask_scale = mask_scale;
        s
    }

    /// The interpolation nodes β₀..β_{K+T−1} for these parameters.
    pub fn betas(&self) -> Vec<f64> {
        chebyshev_nodes_in(self.params.k + self.params.t, -0.95, 0.95)
    }

    /// Node layout: which of the K+T β-nodes carry data blocks and which
    /// carry masks. Masks are *interleaved* (evenly spread) rather than
    /// appended: a mask parked at the end of the grid contributes almost
    /// nothing to shares at the other end, leaving those shares
    /// data-dominated. Interleaving maximizes the minimum mask weight
    /// across shares. Returns (data_positions, mask_positions), both in
    /// block order.
    pub fn node_layout(k: usize, t: usize) -> (Vec<usize>, Vec<usize>) {
        let total = k + t;
        let mut mask_pos: Vec<usize> = (0..t)
            .map(|j| ((j as f64 + 0.5) * total as f64 / t as f64).floor() as usize)
            .map(|p| p.min(total - 1))
            .collect();
        mask_pos.dedup();
        // Guarantee t distinct positions even after floor collisions.
        let mut used: Vec<bool> = vec![false; total];
        let mut final_mask = Vec::with_capacity(t);
        for p in mask_pos {
            let mut q = p;
            while used[q] {
                q = (q + 1) % total;
            }
            used[q] = true;
            final_mask.push(q);
        }
        while final_mask.len() < t {
            let q = used.iter().position(|&u| !u).unwrap();
            used[q] = true;
            final_mask.push(q);
        }
        let data_pos: Vec<usize> = (0..total).filter(|p| !used[*p]).collect();
        (data_pos, final_mask)
    }
}

impl BlockCode for Spacdc {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Spacdc
    }

    fn params(&self) -> CodeParams {
        self.params
    }

    fn block_threshold(&self, _deg: u32) -> Threshold {
        // The headline property: decode from any non-empty return set.
        Threshold::Flexible { min: 1 }
    }

    fn supports_degree(&self, _deg: u32) -> bool {
        // Approximates arbitrary (smooth) f — Berrut interpolation does
        // not require f∘u to be polynomial.
        true
    }

    fn is_private(&self) -> bool {
        true
    }

    fn encode_blocks(&self, x: &Matrix, deg: u32, rng: &mut Rng) -> Result<Encoded, CodingError> {
        let CodeParams { n, k, t } = self.params;
        if t == 0 {
            return Err(CodingError::InvalidParams(
                "SPACDC requires T ≥ 1 mask (use BACC for T = 0)".into(),
            ));
        }
        let (blocks, spec) = split_rows(x, k);
        let (br, bc) = blocks[0].shape();

        // Arrange blocks on the β grid with masks interleaved: slot[p] is
        // a data block for p ∈ data_pos (in block order) and an i.i.d.
        // uniform mask Z (Eq. (17)) for p ∈ mask_pos.
        let all_betas = self.betas();
        let (data_pos, mask_pos) = Self::node_layout(k, t);
        let mut slots: Vec<Option<Matrix>> = vec![None; k + t];
        for (i, &p) in data_pos.iter().enumerate() {
            slots[p] = Some(blocks[i].clone());
        }
        for &p in &mask_pos {
            slots[p] = Some(Matrix::random_uniform(
                br,
                bc,
                -self.mask_scale,
                self.mask_scale,
                rng,
            ));
        }
        let slot_blocks: Vec<Matrix> = slots.into_iter().map(|s| s.unwrap()).collect();

        let alphas = disjoint_eval_nodes(n, &all_betas);
        let signs: Vec<u32> = (0..(k + t) as u32).collect();

        // X̃ⱼ = u(αⱼ): Berrut combination of the K+T slots. Each share
        // depends only on its own node, so the per-worker fan-out runs on
        // the pool; results come back in worker order, and the nested
        // weighted_sum inside berrut_eval degrades to serial on pool
        // workers (no oversubscription).
        let pool = crate::parallel::global();
        let shares: Vec<Matrix> = pool.map_indexed(alphas.len(), |j| {
            berrut_eval(&all_betas, &signs, &slot_blocks, alphas[j])
        });

        // Decode only needs the data recovery nodes, in block order.
        let data_betas: Vec<f64> = data_pos.iter().map(|&p| all_betas[p]).collect();

        Ok(Encoded {
            shares,
            ctx: DecodeCtx {
                kind: SchemeKind::Spacdc,
                params: self.params,
                alphas,
                betas: data_betas,
                spec,
                degree: deg,
                shape: TaskShape::BlockMap,
            },
        })
    }

    fn decode_blocks(
        &self,
        ctx: &DecodeCtx,
        results: &[(usize, Matrix)],
    ) -> Result<Vec<Matrix>, CodingError> {
        decode_berrut(ctx, results)
    }
}

/// Shared Berrut decode (Eq. (18)) used by SPACDC and BACC: h(z) built on
/// the returned workers' nodes, evaluated at each recovery node βᵢ,
/// i < K. Signs are renumbered consecutively along the sorted nodes to
/// preserve the alternating-sign pole-free guarantee (see module docs).
pub fn decode_berrut(
    ctx: &DecodeCtx,
    results: &[(usize, Matrix)],
) -> Result<Vec<Matrix>, CodingError> {
    if results.is_empty() {
        return Err(CodingError::NotEnoughResults { need: 1, got: 0 });
    }
    let mut sorted = validate_results(ctx.params.n, results)?;
    let shape = sorted[0].1.shape();
    for (_, m) in &sorted {
        if m.shape() != shape {
            return Err(CodingError::ShapeMismatch(format!(
                "expected {shape:?}, got {:?}",
                m.shape()
            )));
        }
    }

    // Sort by node value (descending, matching the Chebyshev layout) and
    // renumber signs consecutively: alternation along the sorted sequence
    // keeps the Berrut denominator bounded away from zero.
    sorted.sort_by(|(i, _), (j, _)| {
        ctx.alphas[*j].partial_cmp(&ctx.alphas[*i]).expect("finite nodes")
    });
    let nodes: Vec<f64> = sorted.iter().map(|(i, _)| ctx.alphas[*i]).collect();
    let signs: Vec<u32> = (0..sorted.len() as u32).collect();
    let values: Vec<Matrix> = sorted.into_iter().map(|(_, m)| m).collect();

    let mut out = Vec::with_capacity(ctx.params.k);
    for i in 0..ctx.params.k {
        let w = berrut_weights(&nodes, &signs, ctx.betas[i]);
        out.push(super::interp::weighted_sum(&values, &w));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{gram, matmul, stack_rows};
    use crate::prop::{forall, prop_assert};
    use crate::rng::rng_from_seed;

    fn run_workers(enc: &Encoded, f: impl Fn(&Matrix) -> Matrix) -> Vec<(usize, Matrix)> {
        enc.shares.iter().enumerate().map(|(i, s)| (i, f(s))).collect()
    }

    #[test]
    fn linear_task_decodes_accurately_full_returns() {
        let mut rng = rng_from_seed(50);
        let params = CodeParams::new(30, 4, 3);
        let scheme = Spacdc::new(params);
        let x = Matrix::random_gaussian(32, 16, 0.0, 1.0, &mut rng);
        let v = Matrix::random_gaussian(16, 8, 0.0, 1.0, &mut rng);

        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let results = run_workers(&enc, |s| matmul(s, &v));
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();

        let (blocks, _) = split_rows(&x, 4);
        for (i, d) in decoded.iter().enumerate() {
            let expect = matmul(&blocks[i], &v);
            let err = d.rel_error(&expect);
            assert!(err < 0.05, "block {i}: rel err {err}");
        }
    }

    #[test]
    fn gram_task_decodes_approximately() {
        // The paper's running example: f(X) = X Xᵀ (degree 2).
        let mut rng = rng_from_seed(51);
        let params = CodeParams::new(30, 2, 1);
        let scheme = Spacdc::with_mask_scale(params, 0.5);
        let x = Matrix::random_gaussian(16, 12, 0.0, 1.0, &mut rng);

        let enc = scheme.encode_blocks(&x, 2, &mut rng).unwrap();
        let results = run_workers(&enc, gram);
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();

        let (blocks, _) = split_rows(&x, 2);
        for (i, d) in decoded.iter().enumerate() {
            let expect = gram(&blocks[i]);
            let err = d.rel_error(&expect);
            assert!(err < 0.25, "block {i}: rel err {err}");
        }
    }

    #[test]
    fn tolerates_stragglers_accuracy_degrades_gracefully() {
        let mut rng = rng_from_seed(52);
        let params = CodeParams::new(30, 4, 3);
        let scheme = Spacdc::new(params);
        let x = Matrix::random_gaussian(32, 8, 0.0, 1.0, &mut rng);
        let v = Matrix::random_gaussian(8, 8, 0.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let all = run_workers(&enc, |s| matmul(s, &v));
        let (blocks, _) = split_rows(&x, 4);
        let expect: Vec<Matrix> = blocks.iter().map(|b| matmul(b, &v)).collect();

        // Stragglers are scattered (as in the paper's random selection),
        // not a contiguous node range.
        let mut straggler_rng = rng_from_seed(99);
        let mut err_with = |stragglers: usize| -> f64 {
            let dropped = straggler_rng.choose_indices(30, stragglers);
            let subset: Vec<(usize, Matrix)> = all
                .iter()
                .filter(|(i, _)| !dropped.contains(i))
                .cloned()
                .collect();
            let decoded = scheme.decode_blocks(&enc.ctx, &subset).unwrap();
            decoded
                .iter()
                .zip(&expect)
                .map(|(d, e)| d.rel_error(e))
                .fold(0.0f64, f64::max)
        };

        let e_full = err_with(0);
        let e_5 = err_with(5);
        let e_7 = err_with(7);
        assert!(e_full < 0.10, "full-return error {e_full}");
        assert!(e_5 < 0.40, "S=5 error {e_5}");
        // Graceful: removing workers should not explode the error.
        assert!(e_7 < 1.0, "S=7 error {e_7}");
    }

    #[test]
    fn decode_succeeds_with_single_result() {
        // The headline flexibility claim: |𝓕| = 1 still decodes.
        let mut rng = rng_from_seed(53);
        let scheme = Spacdc::new(CodeParams::new(8, 2, 1));
        let x = Matrix::random_uniform(8, 4, -1.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let one = vec![(3usize, enc.shares[3].clone())];
        let decoded = scheme.decode_blocks(&enc.ctx, &one).unwrap();
        assert_eq!(decoded.len(), 2);
        assert_eq!(decoded[0].shape(), (4, 4));
    }

    #[test]
    fn t_zero_rejected_at_encode() {
        // Construction is infallible (the factory needs it); the missing
        // masks are reported as InvalidParams when encoding starts.
        let scheme = Spacdc::new(CodeParams::new(8, 2, 0));
        let x = Matrix::ones(8, 4);
        assert!(matches!(
            scheme.encode_blocks(&x, 1, &mut rng_from_seed(0)),
            Err(CodingError::InvalidParams(_))
        ));
    }

    #[test]
    fn empty_results_error() {
        let mut rng = rng_from_seed(54);
        let scheme = Spacdc::new(CodeParams::new(8, 2, 1));
        let x = Matrix::ones(8, 4);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        assert!(matches!(
            scheme.decode_blocks(&enc.ctx, &[]),
            Err(CodingError::NotEnoughResults { .. })
        ));
    }

    #[test]
    fn shares_differ_from_data_blocks() {
        // No share should equal a raw data block (the masks mix in).
        let mut rng = rng_from_seed(55);
        let scheme = Spacdc::new(CodeParams::new(10, 2, 2));
        let x = Matrix::random_uniform(8, 4, -1.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let (blocks, _) = split_rows(&x, 2);
        for share in &enc.shares {
            for block in &blocks {
                assert!(share.max_abs_diff(block) > 1e-4);
            }
        }
    }

    #[test]
    fn masks_actually_randomize_shares() {
        // Same data, different RNG → different shares (the Zᵢ differ).
        let scheme = Spacdc::new(CodeParams::new(6, 2, 1));
        let x = Matrix::ones(4, 4);
        let e1 = scheme.encode_blocks(&x, 1, &mut rng_from_seed(1)).unwrap();
        let e2 = scheme.encode_blocks(&x, 1, &mut rng_from_seed(2)).unwrap();
        assert!(e1.shares[0].max_abs_diff(&e2.shares[0]) > 1e-6);
    }

    #[test]
    fn t_colluders_attack_degrades_with_mask_scale() {
        // Empirical privacy check. The paper's Theorem 2 gives exact ITP
        // over a finite field with uniform masks; over ℝ (where this
        // reproduction — like BACC — actually computes), privacy is
        // governed by the mask amplitude: colluders near a data node βᵢ
        // see a share dominated by Xᵢ unless the masks drown it. Verify
        // (a) the strongest per-share linear attack (divide by the known
        // data-node weight) is substantially degraded at mask scale 3,
        // and (b) the attack error grows monotonically with mask scale.
        let k = 2;
        let t = 2;
        let attack_error = |mask_scale: f32, seed: u64| -> f64 {
            let mut rng = rng_from_seed(seed);
            let scheme = Spacdc::with_mask_scale(CodeParams::new(10, k, t), mask_scale);
            let trials = 20;
            let mut acc: f64 = 0.0;
            let (data_pos, _) = Spacdc::node_layout(k, t);
            for _ in 0..trials {
                let x = Matrix::random_gaussian(8, 4, 0.0, 1.0, &mut rng);
                let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
                let (blocks, _) = split_rows(&x, k);
                // Colluders (workers 0..t) each try to invert their own
                // share toward the best data block using the public
                // encode weights: est = share / w_block.
                let betas = scheme.betas();
                let mut best: f64 = f64::INFINITY;
                for j in 0..t {
                    let w = crate::coding::interp::berrut_weights(
                        &betas,
                        &(0..(k + t) as u32).collect::<Vec<_>>(),
                        enc.ctx.alphas[j],
                    );
                    for (b, block) in blocks.iter().enumerate() {
                        let wb = w[data_pos[b]];
                        if wb.abs() > 1e-6 {
                            let est = enc.shares[j].scale(1.0 / wb as f32);
                            best = best.min(est.rel_error(block));
                        }
                    }
                }
                acc += best;
            }
            acc / trials as f64
        };
        let e_small = attack_error(0.25, 56);
        let e_large = attack_error(3.0, 56);
        assert!(
            e_large > 2.0 * e_small,
            "mask scale must control privacy: {e_small} vs {e_large}"
        );
        // NOTE (DESIGN.md §3): the paper's Theorem 2 ITP is exact only
        // over a finite field with unbounded-uniform masks. Over ℝ the
        // leakage is bounded but nonzero; the assertion above pins the
        // mask-amplitude control, and the eavesdropper_demo example
        // reports the measured leakage for the default configuration.
    }

    #[test]
    fn roundtrip_stack_restores_original_rows() {
        // With f = identity (degree 1, V = I), decode + stack ≈ X.
        let mut rng = rng_from_seed(57);
        let scheme = Spacdc::new(CodeParams::new(24, 3, 2));
        let x = Matrix::random_gaussian(30, 6, 0.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
        let results = run_workers(&enc, |s| s.clone());
        let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
        let restored = stack_rows(&decoded, &enc.ctx.spec);
        assert!(restored.rel_error(&x) < 0.05, "err={}", restored.rel_error(&x));
    }

    #[test]
    fn property_decode_error_bounded_under_random_subsets() {
        forall(15, 58, |g| {
            let k = g.usize_in(2..5);
            let t = g.usize_in(1..3);
            let n = 20 + g.usize_in(0..10);
            let returned = n - g.usize_in(0..5);
            let mut rng = rng_from_seed(g.u64());
            let scheme = Spacdc::new(CodeParams::new(n, k, t));
            let x = Matrix::random_gaussian(8 * k, 6, 0.0, 1.0, &mut rng);
            let enc = scheme.encode_blocks(&x, 1, &mut rng).unwrap();
            let idx = g.subset(n, returned);
            let results: Vec<(usize, Matrix)> =
                idx.iter().map(|&i| (i, enc.shares[i].clone())).collect();
            let decoded = scheme.decode_blocks(&enc.ctx, &results).unwrap();
            let (blocks, _) = split_rows(&x, k);
            for (d, b) in decoded.iter().zip(&blocks) {
                let err = d.rel_error(b);
                if !(err.is_finite() && err < 2.0) {
                    return Err(format!(
                        "unbounded decode error {err} (n={n}, k={k}, t={t}, ret={returned})"
                    ));
                }
            }
            prop_assert(true, "")
        });
    }
}
