//! Interpolation machinery shared by every coding scheme.
//!
//! * Chebyshev node generation (the node family the BACC line of work
//!   uses for numerically stable rational interpolation over ℝ).
//! * Berrut rational basis weights — paper Def. 3 / Eqs. (6), (17), (18).
//! * Exact Lagrange interpolation of matrix-valued polynomials (decode
//!   path of the MDS/Polynomial/LCC/SecPoly baselines).
//! * A small dense linear solver (Gaussian elimination with partial
//!   pivoting) for Vandermonde coefficient extraction (MatDot decode).

use crate::matrix::Matrix;

/// Chebyshev points of the first kind: xⱼ = cos(π(2j+1)/(2n)), j=0..n−1,
/// on (−1, 1). Distinct by construction.
pub fn chebyshev_nodes(n: usize) -> Vec<f64> {
    assert!(n > 0, "need at least one node");
    (0..n)
        .map(|j| (std::f64::consts::PI * (2 * j + 1) as f64 / (2 * n) as f64).cos())
        .collect()
}

/// Chebyshev nodes scaled into [lo, hi].
pub fn chebyshev_nodes_in(n: usize, lo: f64, hi: f64) -> Vec<f64> {
    chebyshev_nodes(n)
        .into_iter()
        .map(|x| 0.5 * (lo + hi) + 0.5 * (hi - lo) * x)
        .collect()
}

/// Pick `n` evaluation nodes (α's) disjoint from the `avoid` set (β's),
/// per the paper's requirement {αᵢ} ∩ {βᵢ} = ∅.
///
/// α's live on a wider interval than the β's so collisions are already
/// unlikely; any that occur are nudged by a relative epsilon.
pub fn disjoint_eval_nodes(n: usize, avoid: &[f64]) -> Vec<f64> {
    let mut nodes = chebyshev_nodes_in(n, -0.97, 0.97);
    for x in nodes.iter_mut() {
        let mut guard = 0;
        while avoid.iter().any(|b| (*b - *x).abs() < 1e-9) {
            *x += 1e-6 * (1.0 + guard as f64);
            guard += 1;
            assert!(guard < 100, "could not separate nodes");
        }
    }
    nodes
}

/// Berrut basis weight ℓᵢ(z) for node set `nodes` with alternating signs
/// (paper Eq. (6)): ℓᵢ(z) = [(−1)^sᵢ/(z−xᵢ)] / Σⱼ (−1)^sⱼ/(z−xⱼ).
///
/// `signs[i]` is the exponent sᵢ — the paper indexes by the *global*
/// worker id, so a subset 𝓕 keeps its original signs (Eq. (18)).
/// If `z` coincides with a node, the weight degenerates to the exact
/// indicator (interpolation property).
pub fn berrut_weights(nodes: &[f64], signs: &[u32], z: f64) -> Vec<f64> {
    assert_eq!(nodes.len(), signs.len());
    // Exact-hit fast path: rational basis interpolates.
    if let Some(hit) = nodes.iter().position(|&x| (x - z).abs() < 1e-12) {
        let mut w = vec![0.0; nodes.len()];
        w[hit] = 1.0;
        return w;
    }
    let terms: Vec<f64> = nodes
        .iter()
        .zip(signs)
        .map(|(&x, &s)| {
            let sign = if s % 2 == 0 { 1.0 } else { -1.0 };
            sign / (z - x)
        })
        .collect();
    let denom: f64 = terms.iter().sum();
    assert!(
        denom.abs() > f64::MIN_POSITIVE,
        "Berrut denominator vanished at z={z}"
    );
    terms.into_iter().map(|t| t / denom).collect()
}

/// Evaluate the Berrut interpolant of matrix samples at `z`:
/// r(z) = Σᵢ ℓᵢ(z)·Yᵢ (Eq. (5) lifted to matrices).
pub fn berrut_eval(nodes: &[f64], signs: &[u32], values: &[Matrix], z: f64) -> Matrix {
    assert_eq!(nodes.len(), values.len());
    let w = berrut_weights(nodes, signs, z);
    weighted_sum(values, &w)
}

/// Lagrange basis weights for exact polynomial interpolation at `z`.
pub fn lagrange_weights(nodes: &[f64], z: f64) -> Vec<f64> {
    let n = nodes.len();
    let mut w = vec![1.0; n];
    for i in 0..n {
        for j in 0..n {
            if i != j {
                let denom = nodes[i] - nodes[j];
                assert!(denom.abs() > 1e-300, "repeated interpolation node");
                w[i] *= (z - nodes[j]) / denom;
            }
        }
    }
    w
}

/// Evaluate the exact Lagrange interpolant of matrix samples at `z`.
pub fn lagrange_eval(nodes: &[f64], values: &[Matrix], z: f64) -> Matrix {
    assert_eq!(nodes.len(), values.len());
    let w = lagrange_weights(nodes, z);
    weighted_sum(values, &w)
}

/// Element count per parallel chunk of a weighted sum: 16 KiB of output
/// per granule — big enough to amortize scheduling, small enough that a
/// 128×512 DL block (64 Ki elements) still splits 16 ways.
const SUM_CHUNK: usize = 4096;

/// Σᵢ wᵢ·Yᵢ with f64 weights over f32 matrices, row-chunked on the
/// globally configured pool.
pub fn weighted_sum(values: &[Matrix], weights: &[f64]) -> Matrix {
    weighted_sum_with(&crate::parallel::global(), values, weights)
}

/// [`weighted_sum`] on an explicit pool.
///
/// The output is split into fixed [`SUM_CHUNK`] element ranges; within a
/// chunk the samples are accumulated in input order (i = 0, 1, …), so
/// every output element sees the identical fixed-order reduction at any
/// thread count — decode stays bit-identical whatever `threads` is. The
/// per-sample `out += w·src` pass is the [`crate::simd::axpy`] kernel:
/// element-independent, lane-wise mul-then-add at every level, so SIMD
/// does not perturb the reduction either.
pub fn weighted_sum_with(
    pool: &crate::parallel::ThreadPool,
    values: &[Matrix],
    weights: &[f64],
) -> Matrix {
    assert_eq!(values.len(), weights.len());
    assert!(!values.is_empty(), "weighted_sum of nothing");
    let (r, c) = values[0].shape();
    for v in values {
        assert_eq!(v.shape(), (r, c), "inconsistent sample shapes");
    }
    let mut out = Matrix::zeros(r, c);
    pool.for_each_chunk(out.as_mut_slice(), SUM_CHUNK, |offset, chunk| {
        for (v, &w) in values.iter().zip(weights) {
            let src = &v.as_slice()[offset..offset + chunk.len()];
            crate::simd::axpy::axpy(chunk, src, w as f32);
        }
    });
    out
}

/// Solve the dense system `A x = b` for multiple right-hand sides packed
/// as matrix columns, via Gaussian elimination with partial pivoting.
/// Used for Vandermonde coefficient extraction (MatDot decode) and the
/// MDS generator inversion.
pub fn solve_dense(a: &[Vec<f64>], b: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>, String> {
    let n = a.len();
    if n == 0 {
        return Ok(vec![]);
    }
    assert!(a.iter().all(|row| row.len() == n), "A must be square");
    assert_eq!(b.len(), n, "b row count must match A");
    let width = b[0].len();

    // Augment.
    let mut m: Vec<Vec<f64>> = a
        .iter()
        .zip(b.iter())
        .map(|(ar, br)| {
            let mut row = ar.clone();
            row.extend_from_slice(br);
            row
        })
        .collect();

    for col in 0..n {
        // Pivot.
        let pivot = (col..n)
            .max_by(|&i, &j| m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap())
            .unwrap();
        if m[pivot][col].abs() < 1e-12 {
            return Err(format!("singular system at column {col}"));
        }
        m.swap(col, pivot);
        // Eliminate below.
        for row in col + 1..n {
            let factor = m[row][col] / m[col][col];
            if factor != 0.0 {
                for k in col..n + width {
                    m[row][k] -= factor * m[col][k];
                }
            }
        }
    }
    // Back substitution.
    let mut x = vec![vec![0.0; width]; n];
    for row in (0..n).rev() {
        for w in 0..width {
            let mut s = m[row][n + w];
            for k in row + 1..n {
                s -= m[row][k] * x[k][w];
            }
            x[row][w] = s / m[row][row];
        }
    }
    Ok(x)
}

/// Interpolate the coefficients of a matrix-valued polynomial of degree
/// `deg` from `deg+1` (node, value) samples: returns [C₀, …, C_deg] with
/// p(z) = Σ Cᵢ zⁱ. MatDot decode extracts the middle coefficient.
pub fn polynomial_coefficients(
    nodes: &[f64],
    values: &[Matrix],
    deg: usize,
) -> Result<Vec<Matrix>, String> {
    assert!(nodes.len() == deg + 1, "need exactly deg+1 samples");
    assert_eq!(nodes.len(), values.len());
    let (r, c) = values[0].shape();
    // Vandermonde system: V · coeffs = values, solved per element-column.
    let v: Vec<Vec<f64>> = nodes
        .iter()
        .map(|&x| (0..=deg).map(|p| x.powi(p as i32)).collect())
        .collect();
    // Pack each matrix as one wide row of RHS.
    let b: Vec<Vec<f64>> = values
        .iter()
        .map(|m| m.as_slice().iter().map(|&x| x as f64).collect())
        .collect();
    let coeffs = solve_dense(&v, b)?;
    Ok(coeffs
        .into_iter()
        .map(|flat| Matrix::from_vec(r, c, flat.into_iter().map(|x| x as f32).collect()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn chebyshev_nodes_distinct_and_bounded() {
        for n in [1usize, 2, 5, 36] {
            let xs = chebyshev_nodes(n);
            assert_eq!(xs.len(), n);
            assert!(xs.iter().all(|&x| (-1.0..=1.0).contains(&x)));
            for i in 0..n {
                for j in i + 1..n {
                    assert!((xs[i] - xs[j]).abs() > 1e-9);
                }
            }
        }
    }

    #[test]
    fn disjoint_nodes_avoid_collisions() {
        let betas = chebyshev_nodes_in(5, -0.97, 0.97);
        let alphas = disjoint_eval_nodes(5, &betas);
        for a in &alphas {
            for b in &betas {
                assert!((a - b).abs() > 1e-10);
            }
        }
    }

    #[test]
    fn berrut_weights_sum_to_one() {
        let nodes = chebyshev_nodes(7);
        let signs: Vec<u32> = (0..7).collect();
        for z in [-0.5, 0.0, 0.3, 2.0] {
            let w = berrut_weights(&nodes, &signs, z);
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "z={z}, sum={sum}");
        }
    }

    #[test]
    fn berrut_interpolates_at_nodes() {
        let nodes = chebyshev_nodes(5);
        let signs: Vec<u32> = (0..5).collect();
        let w = berrut_weights(&nodes, &signs, nodes[2]);
        assert_eq!(w[2], 1.0);
        assert!(w.iter().enumerate().filter(|(i, _)| *i != 2).all(|(_, &x)| x == 0.0));
    }

    #[test]
    fn berrut_reproduces_constants_exactly() {
        // Rational interpolant with weights summing to 1 reproduces
        // constant functions for any z.
        let nodes = chebyshev_nodes(6);
        let signs: Vec<u32> = (0..6).collect();
        let values: Vec<Matrix> = (0..6).map(|_| Matrix::ones(2, 2).scale(3.5)).collect();
        let y = berrut_eval(&nodes, &signs, &values, 0.123);
        assert!(y.max_abs_diff(&Matrix::ones(2, 2).scale(3.5)) < 1e-6);
    }

    #[test]
    fn berrut_approximates_smooth_function() {
        // Berrut's interpolant converges linearly for smooth f on
        // Chebyshev-like nodes; with 24 nodes the error should be small.
        let n = 24;
        let nodes = chebyshev_nodes(n);
        let signs: Vec<u32> = (0..n as u32).collect();
        let values: Vec<Matrix> = nodes
            .iter()
            .map(|&x| Matrix::from_vec(1, 1, vec![(x * 1.3).sin() as f32]))
            .collect();
        for z in [-0.8, -0.1, 0.42, 0.77] {
            let y = berrut_eval(&nodes, &signs, &values, z);
            let expect = (z * 1.3).sin();
            assert!(
                (y.get(0, 0) as f64 - expect).abs() < 0.02,
                "z={z}: got {} want {expect}",
                y.get(0, 0)
            );
        }
    }

    #[test]
    fn lagrange_recovers_polynomial_exactly() {
        // p(z) = 2 − z + 3z² sampled at 3 nodes → exact everywhere.
        let nodes = [0.1, 0.5, -0.7];
        let p = |z: f64| 2.0 - z + 3.0 * z * z;
        let values: Vec<Matrix> =
            nodes.iter().map(|&x| Matrix::from_vec(1, 1, vec![p(x) as f32])).collect();
        for z in [-1.0, 0.0, 0.25, 2.0] {
            let y = lagrange_eval(&nodes, &values, z);
            assert!((y.get(0, 0) as f64 - p(z)).abs() < 1e-4, "z={z}");
        }
    }

    #[test]
    fn lagrange_weights_sum_to_one() {
        let nodes = chebyshev_nodes(8);
        let w = lagrange_weights(&nodes, 0.3);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_sum_bit_identical_across_pool_widths() {
        use crate::parallel::ThreadPool;
        let mut r = rng_from_seed(33);
        let values: Vec<Matrix> =
            (0..9).map(|_| Matrix::random_gaussian(37, 23, 0.0, 1.0, &mut r)).collect();
        let weights: Vec<f64> = (0..9).map(|_| r.uniform(-1.0, 1.0)).collect();
        let serial = weighted_sum_with(&ThreadPool::new(1), &values, &weights);
        for threads in [2usize, 3, 8] {
            let par = weighted_sum_with(&ThreadPool::new(threads), &values, &weights);
            assert_eq!(serial.as_slice(), par.as_slice(), "threads={threads}");
        }
    }

    #[test]
    fn solve_dense_roundtrip() {
        let mut r = rng_from_seed(31);
        for n in [1usize, 2, 5, 9] {
            let a: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..n).map(|_| r.uniform(-1.0, 1.0)).collect())
                .collect();
            // Make diagonally dominant to guarantee solvability.
            let a: Vec<Vec<f64>> = a
                .iter()
                .enumerate()
                .map(|(i, row)| {
                    row.iter()
                        .enumerate()
                        .map(|(j, &v)| if i == j { v + 3.0 } else { v })
                        .collect()
                })
                .collect();
            let x_true: Vec<Vec<f64>> =
                (0..n).map(|_| vec![r.uniform(-2.0, 2.0)]).collect();
            let b: Vec<Vec<f64>> = (0..n)
                .map(|i| vec![(0..n).map(|j| a[i][j] * x_true[j][0]).sum()])
                .collect();
            let x = solve_dense(&a, b).unwrap();
            for i in 0..n {
                assert!((x[i][0] - x_true[i][0]).abs() < 1e-8, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn solve_dense_detects_singularity() {
        let a = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        let b = vec![vec![1.0], vec![2.0]];
        assert!(solve_dense(&a, b).is_err());
    }

    #[test]
    fn polynomial_coefficients_roundtrip() {
        // p(z) = C0 + C1 z + C2 z² with 2×2 matrix coefficients.
        let mut r = rng_from_seed(32);
        let cs: Vec<Matrix> =
            (0..3).map(|_| Matrix::random_uniform(2, 2, -1.0, 1.0, &mut r)).collect();
        let nodes = [0.2, -0.5, 0.9];
        let values: Vec<Matrix> = nodes
            .iter()
            .map(|&z| {
                let mut v = cs[0].clone();
                v.axpy(z as f32, &cs[1]);
                v.axpy((z * z) as f32, &cs[2]);
                v
            })
            .collect();
        let got = polynomial_coefficients(&nodes, &values, 2).unwrap();
        for (g, c) in got.iter().zip(&cs) {
            assert!(g.max_abs_diff(c) < 1e-4);
        }
    }
}
