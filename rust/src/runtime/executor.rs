//! The executor façade: the single entry point workers use to run their
//! task `f`. Dispatches to a PJRT artifact when one matches the op +
//! shape, otherwise to the native Rust kernel with identical numerics.

use super::pjrt::artifact_key;
use super::service::RuntimeHandle;
use crate::matrix::{gram, matmul, Matrix};
use crate::metrics::{names, MetricsRegistry};
use std::sync::Arc;

/// The worker-side operations the coordinator can dispatch.
#[derive(Clone, Debug)]
pub enum WorkerOp {
    /// `f(X̃) = X̃ X̃ᵀ` — the paper's running example (§V-A).
    Gram,
    /// `f(X̃) = X̃ · V` with a broadcast right operand — the SPACDC-DL
    /// coded gradient op (Eq. (23) matmul).
    RightMul(Arc<Matrix>),
    /// `(Ã, B̃) ↦ Ã·B̃` — MatDot's pair product.
    PairProduct,
    /// Identity (decode-path tests and echo benchmarking).
    Identity,
}

impl WorkerOp {
    /// Polynomial degree of the op in its encoded operand (drives each
    /// scheme's recovery threshold).
    pub fn degree(&self) -> u32 {
        match self {
            WorkerOp::Gram => 2,
            WorkerOp::RightMul(_) | WorkerOp::Identity => 1,
            WorkerOp::PairProduct => 2,
        }
    }

    /// Number of wire operands the op consumes (2 only for pair ops).
    pub fn operand_count(&self) -> usize {
        match self {
            WorkerOp::PairProduct => 2,
            WorkerOp::Gram | WorkerOp::RightMul(_) | WorkerOp::Identity => 1,
        }
    }

    /// Short name for metrics/artifact keys.
    pub fn name(&self) -> &'static str {
        match self {
            WorkerOp::Gram => "gram",
            WorkerOp::RightMul(_) => "rightmul",
            WorkerOp::PairProduct => "pair",
            WorkerOp::Identity => "identity",
        }
    }
}

/// Executes [`WorkerOp`]s, preferring PJRT artifacts.
#[derive(Clone)]
pub struct Executor {
    runtime: Option<RuntimeHandle>,
    metrics: Arc<MetricsRegistry>,
}

impl Executor {
    /// Native-only executor.
    pub fn native(metrics: Arc<MetricsRegistry>) -> Self {
        Self { runtime: None, metrics }
    }

    /// Executor with a PJRT runtime attached.
    pub fn with_runtime(runtime: RuntimeHandle, metrics: Arc<MetricsRegistry>) -> Self {
        Self { runtime: Some(runtime), metrics }
    }

    /// Is a PJRT runtime attached?
    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    /// The metrics sink.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Run `op` on `operands` (1 operand, or 2 for `PairProduct`).
    pub fn run(&self, op: &WorkerOp, operands: &[Matrix]) -> Matrix {
        match op {
            WorkerOp::Gram => {
                let x = &operands[0];
                let key = artifact_key("gram", &[x.rows(), x.cols()]);
                self.dispatch(&key, || vec![x.clone()], || gram(x))
            }
            WorkerOp::RightMul(v) => {
                let x = &operands[0];
                let key = artifact_key("rightmul", &[x.rows(), x.cols(), v.cols()]);
                self.dispatch(&key, || vec![x.clone(), (**v).clone()], || matmul(x, v))
            }
            WorkerOp::PairProduct => {
                let (a, b) = (&operands[0], &operands[1]);
                let key = artifact_key("rightmul", &[a.rows(), a.cols(), b.cols()]);
                self.dispatch(&key, || vec![a.clone(), b.clone()], || matmul(a, b))
            }
            WorkerOp::Identity => {
                self.metrics.inc(names::NATIVE_EXECUTIONS);
                operands[0].clone()
            }
        }
    }

    /// Try PJRT under `key`; fall back to `native` on miss or error.
    /// `inputs` is a thunk so the native path (the common case without a
    /// runtime) never materializes the operand copies PJRT would need.
    fn dispatch(
        &self,
        key: &str,
        inputs: impl FnOnce() -> Vec<Matrix>,
        native: impl FnOnce() -> Matrix,
    ) -> Matrix {
        if let Some(rt) = &self.runtime {
            if rt.has(key) {
                match rt.execute(key, inputs()) {
                    Ok(out) => {
                        self.metrics.inc(names::PJRT_EXECUTIONS);
                        return out;
                    }
                    Err(e) => {
                        eprintln!("warning: PJRT execute {key} failed ({e}); falling back to native");
                    }
                }
            }
        }
        self.metrics.inc(names::NATIVE_EXECUTIONS);
        native()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    fn exec() -> Executor {
        Executor::native(Arc::new(MetricsRegistry::new()))
    }

    #[test]
    fn gram_native_matches_kernel() {
        let mut rng = rng_from_seed(1);
        let x = Matrix::random_gaussian(8, 5, 0.0, 1.0, &mut rng);
        let e = exec();
        let out = e.run(&WorkerOp::Gram, &[x.clone()]);
        assert_eq!(out.as_slice(), gram(&x).as_slice());
        assert_eq!(e.metrics().get(names::NATIVE_EXECUTIONS), 1);
        assert_eq!(e.metrics().get(names::PJRT_EXECUTIONS), 0);
    }

    #[test]
    fn rightmul_native_matches_kernel() {
        let mut rng = rng_from_seed(2);
        let x = Matrix::random_gaussian(6, 4, 0.0, 1.0, &mut rng);
        let v = Matrix::random_gaussian(4, 3, 0.0, 1.0, &mut rng);
        let e = exec();
        let out = e.run(&WorkerOp::RightMul(Arc::new(v.clone())), &[x.clone()]);
        assert_eq!(out.as_slice(), matmul(&x, &v).as_slice());
    }

    #[test]
    fn pair_product_multiplies_operands() {
        let mut rng = rng_from_seed(3);
        let a = Matrix::random_gaussian(4, 6, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(6, 2, 0.0, 1.0, &mut rng);
        let e = exec();
        let out = e.run(&WorkerOp::PairProduct, &[a.clone(), b.clone()]);
        assert_eq!(out.as_slice(), matmul(&a, &b).as_slice());
    }

    #[test]
    fn identity_echoes() {
        let x = Matrix::ones(2, 3);
        assert_eq!(exec().run(&WorkerOp::Identity, &[x.clone()]).as_slice(), x.as_slice());
    }

    #[test]
    fn op_degrees_drive_thresholds() {
        assert_eq!(WorkerOp::Gram.degree(), 2);
        assert_eq!(WorkerOp::Identity.degree(), 1);
        assert_eq!(
            WorkerOp::RightMul(Arc::new(Matrix::ones(1, 1))).degree(),
            1
        );
    }
}
