//! The runtime service thread.
//!
//! `xla::PjRtClient` holds an `Rc` internally and is not `Send`, so the
//! engine lives on one dedicated thread; worker threads talk to it
//! through a cloneable [`RuntimeHandle`]. Requests carry their own reply
//! channel, so the service is a simple serial loop (CPU PJRT parallelizes
//! internally; serializing submissions costs little and keeps the FFI
//! single-threaded).

use super::pjrt::PjrtEngine;
use crate::matrix::Matrix;
use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;

enum Request {
    Execute {
        key: String,
        inputs: Vec<Matrix>,
        reply: mpsc::Sender<Result<Matrix, String>>,
    },
    Has {
        key: String,
        reply: mpsc::Sender<bool>,
    },
    Keys {
        reply: mpsc::Sender<Vec<String>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the runtime service.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Request>,
}

impl RuntimeHandle {
    /// Execute artifact `key`; blocks until the service replies.
    pub fn execute(&self, key: &str, inputs: Vec<Matrix>) -> Result<Matrix, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { key: key.to_string(), inputs, reply })
            .map_err(|_| "runtime service down".to_string())?;
        rx.recv().map_err(|_| "runtime service dropped reply".to_string())?
    }

    /// Is an artifact available?
    pub fn has(&self, key: &str) -> bool {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Request::Has { key: key.to_string(), reply }).is_err() {
            return false;
        }
        rx.recv().unwrap_or(false)
    }

    /// All loaded artifact keys.
    pub fn keys(&self) -> Vec<String> {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Request::Keys { reply }).is_err() {
            return vec![];
        }
        rx.recv().unwrap_or_default()
    }
}

/// The service: owns the engine thread; dropping shuts it down.
pub struct RuntimeService {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl RuntimeService {
    /// Start a service for the artifacts in `dir`. Fails if the manifest
    /// is unreadable or any artifact fails to compile.
    pub fn start(dir: &Path) -> anyhow::Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<Result<(), String>>();
        let dir = dir.to_path_buf();
        let join = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || {
                let engine = match PjrtEngine::load_dir(&dir) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                serve(engine, rx);
            })
            .expect("spawn runtime thread");
        init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("runtime thread died during init"))?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(Self { tx, join: Some(join) })
    }

    /// A cloneable handle for workers.
    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: self.tx.clone() }
    }
}

fn serve(engine: PjrtEngine, rx: mpsc::Receiver<Request>) {
    while let Ok(req) = rx.recv() {
        match req {
            Request::Execute { key, inputs, reply } => {
                let out = engine.execute(&key, &inputs).map_err(|e| e.to_string());
                let _ = reply.send(out);
            }
            Request::Has { key, reply } => {
                let _ = reply.send(engine.has(&key));
            }
            Request::Keys { reply } => {
                let _ = reply.send(engine.keys());
            }
            Request::Shutdown => break,
        }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_fails_cleanly_without_artifacts() {
        assert!(RuntimeService::start(Path::new("/nonexistent-artifacts")).is_err());
    }

    // Live service round-trips are covered by
    // rust/tests/pjrt_integration.rs (requires `make artifacts`).
}
