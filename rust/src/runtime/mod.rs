//! Execution runtime: the bridge between the L3 coordinator and the
//! AOT-compiled L2/L1 artifacts.
//!
//! * [`pjrt`] — wraps the `xla` crate: PJRT CPU client, HLO-text loading
//!   (`HloModuleProto::from_text_file` — see /opt/xla-example/README.md
//!   for why text, not serialized protos), compile + execute.
//! * [`service`] — the PJRT client is `Rc`-based (not `Send`), so a
//!   dedicated runtime thread owns the engine and serves execute requests
//!   over channels; worker threads hold a cloneable [`RuntimeHandle`].
//! * [`executor`] — the façade workers actually call: looks up an
//!   artifact matching `(op, shape)` and goes through PJRT, else runs the
//!   native Rust kernel with identical numerics. Metrics record which
//!   path served each call.

pub mod executor;
pub mod pjrt;
pub mod service;

pub use executor::{Executor, WorkerOp};
pub use pjrt::{artifact_key, PjrtEngine};
pub use service::{RuntimeHandle, RuntimeService};
