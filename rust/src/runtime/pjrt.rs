//! PJRT engine: load HLO-text artifacts, compile once, execute many.
//!
//! Interchange is HLO **text** (not serialized `HloModuleProto`): jax
//! ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.
//!
//! The real engine needs the `xla` crate and is compiled only under the
//! off-by-default `xla` cargo feature (this build environment has no
//! registry access). Without it a stub engine with the same API reports
//! every artifact as unavailable, so the [`Executor`](super::Executor)
//! transparently falls back to the native kernels.

use crate::matrix::Matrix;
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "xla")]
use std::path::PathBuf;

/// Canonical artifact key for an op + input shape, matching the names
/// `python/compile/aot.py` writes into `artifacts/manifest.txt`.
///
/// * Gram (r×c input):            `gram_{r}x{c}`
/// * Right-multiply (r×k · k×c):  `rightmul_{r}x{k}x{c}`
/// * Berrut combine (n blocks):   `berrut_{n}x{r}x{c}`
/// * MLP forward (batch b):       `mlp_fwd_b{b}`
pub fn artifact_key(op: &str, dims: &[usize]) -> String {
    let mut s = String::from(op);
    for (i, d) in dims.iter().enumerate() {
        s.push(if i == 0 { '_' } else { 'x' });
        s.push_str(&d.to_string());
    }
    s
}

/// A compiled artifact plus its declared output shape.
#[cfg(feature = "xla")]
struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    out_rows: usize,
    out_cols: usize,
}

/// The PJRT engine: one CPU client + a registry of compiled executables.
///
/// NOT `Send` (the client is `Rc`-based) — owned by the
/// [`RuntimeService`](super::service::RuntimeService) thread.
#[cfg(feature = "xla")]
pub struct PjrtEngine {
    client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
}

#[cfg(feature = "xla")]
impl PjrtEngine {
    /// Create an engine with an empty registry.
    pub fn new() -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, artifacts: HashMap::new() })
    }

    /// Load every artifact listed in `<dir>/manifest.txt`.
    ///
    /// Manifest line format: `key file out_rows out_cols`, `#` comments.
    pub fn load_dir(dir: &Path) -> anyhow::Result<Self> {
        let mut engine = Self::new()?;
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", manifest.display()))?;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(parts.len() == 4, "bad manifest line: {line}");
            let key = parts[0].to_string();
            let file: PathBuf = dir.join(parts[1]);
            let out_rows: usize = parts[2].parse()?;
            let out_cols: usize = parts[3].parse()?;
            engine.load_artifact(&key, &file, out_rows, out_cols)?;
        }
        Ok(engine)
    }

    /// Compile a single HLO-text file under `key`.
    pub fn load_artifact(
        &mut self,
        key: &str,
        path: &Path,
        out_rows: usize,
        out_cols: usize,
    ) -> anyhow::Result<()> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.artifacts.insert(key.to_string(), LoadedArtifact { exe, out_rows, out_cols });
        Ok(())
    }

    /// Keys currently loaded.
    pub fn keys(&self) -> Vec<String> {
        let mut k: Vec<String> = self.artifacts.keys().cloned().collect();
        k.sort();
        k
    }

    /// Is `key` available?
    pub fn has(&self, key: &str) -> bool {
        self.artifacts.contains_key(key)
    }

    /// Execute artifact `key` on the given matrices. Returns the single
    /// matrix output (our artifacts are lowered with `return_tuple=True`
    /// and exactly one result).
    pub fn execute(&self, key: &str, inputs: &[Matrix]) -> anyhow::Result<Matrix> {
        let art = self
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("no artifact {key}"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|m| {
                xla::Literal::vec1(m.as_slice())
                    .reshape(&[m.rows() as i64, m.cols() as i64])
                    .map_err(anyhow::Error::from)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = art.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        anyhow::ensure!(
            data.len() == art.out_rows * art.out_cols,
            "artifact {key}: expected {}x{} output, got {} elements",
            art.out_rows,
            art.out_cols,
            data.len()
        );
        Ok(Matrix::from_vec(art.out_rows, art.out_cols, data))
    }
}

/// Stub engine used when the crate is built without the `xla` feature:
/// construction fails (so [`RuntimeService::start`] reports PJRT as
/// unavailable) and no artifact is ever available.
///
/// [`RuntimeService::start`]: super::service::RuntimeService::start
#[cfg(not(feature = "xla"))]
pub struct PjrtEngine {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl PjrtEngine {
    /// Always fails: the engine needs the `xla` feature.
    pub fn new() -> anyhow::Result<Self> {
        anyhow::bail!("built without the `xla` feature; PJRT runtime unavailable")
    }

    /// Checks the manifest for a readable-diagnostics parity with the real
    /// engine, then fails because the engine cannot be constructed.
    pub fn load_dir(dir: &Path) -> anyhow::Result<Self> {
        let manifest = dir.join("manifest.txt");
        std::fs::read_to_string(&manifest)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", manifest.display()))?;
        Self::new()
    }

    /// Always fails (no engine).
    pub fn load_artifact(
        &mut self,
        key: &str,
        _path: &Path,
        _out_rows: usize,
        _out_cols: usize,
    ) -> anyhow::Result<()> {
        anyhow::bail!("cannot load artifact {key}: built without the `xla` feature")
    }

    /// No artifacts are ever loaded.
    pub fn keys(&self) -> Vec<String> {
        Vec::new()
    }

    /// No artifacts are ever available.
    pub fn has(&self, _key: &str) -> bool {
        false
    }

    /// Always fails (no engine).
    pub fn execute(&self, key: &str, _inputs: &[Matrix]) -> anyhow::Result<Matrix> {
        anyhow::bail!("no artifact {key}: built without the `xla` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_key_formats() {
        assert_eq!(artifact_key("gram", &[128, 256]), "gram_128x256");
        assert_eq!(artifact_key("rightmul", &[196, 256, 64]), "rightmul_196x256x64");
        assert_eq!(artifact_key("mlp_fwd", &[64]), "mlp_fwd_64");
    }

    #[test]
    fn load_dir_missing_manifest_errors() {
        match PjrtEngine::load_dir(Path::new("/nonexistent")) {
            Err(e) => assert!(e.to_string().contains("cannot read")),
            Ok(_) => panic!("expected error for missing manifest"),
        }
    }

    // Full PJRT execution against real artifacts is covered by
    // rust/tests/pjrt_integration.rs (requires `make artifacts` and the
    // `xla` feature).
}
