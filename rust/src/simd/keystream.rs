//! MEA-ECC keystream kernels: XOR a SplitMix64 pad over bytes (the
//! wire's seal/open-the-bytes form) or over f32 bit patterns (the
//! in-memory `SealedMatrix` mask).
//!
//! The keystream itself is *identical at every level* — one SplitMix64
//! draw per 8 bytes / per f32 pair, in stream order, exactly as the
//! scalar oracle consumes it. The vector kernels expand several draws
//! into a small pad buffer (the mixes run in instruction-level
//! parallelism; only the trivial `state += γ` chain is serial) and
//! apply them with wide XORs, then hand the sub-block tail to the
//! scalar loop *continuing the same generator* — so ciphertexts are
//! byte-identical across levels and the pad never persists anywhere.
//!
//! Byte order: pads are committed through `to_le_bytes`, matching the
//! scalar oracle's layout on every target the vector kernels exist for
//! (x86_64 and aarch64 are little-endian).

use super::Level;
use crate::rng::SplitMix64;

/// XOR `bytes` in place with the SplitMix64 keystream seeded from
/// `seed`, 8 bytes per draw. Self-inverse; no allocation.
#[inline]
pub fn xor_in_place(bytes: &mut [u8], seed: u64) {
    xor_in_place_at(super::level(), bytes, seed);
}

/// [`xor_in_place`] at an explicit level.
pub fn xor_in_place_at(level: Level, bytes: &mut [u8], seed: u64) {
    let mut ks = SplitMix64::new(seed);
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 only exists behind runtime AVX2 detection.
        Level::Avx2 => unsafe { avx2::xor_blocks(bytes, &mut ks) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Level::Neon only exists behind runtime NEON detection.
        Level::Neon => unsafe { neon::xor_blocks(bytes, &mut ks) },
        _ => xor_run(bytes, &mut ks),
    }
}

/// Per-element 32-bit XOR keystream over f32 bit patterns, in place:
/// the high half of each draw masks the even element, the low half the
/// odd one, and a trailing element takes a fresh 32-bit draw.
#[inline]
pub fn mask_f32_in_place(data: &mut [f32], seed: u64) {
    mask_f32_in_place_at(super::level(), data, seed);
}

/// [`mask_f32_in_place`] at an explicit level.
pub fn mask_f32_in_place_at(level: Level, data: &mut [f32], seed: u64) {
    let mut ks = SplitMix64::new(seed);
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 only exists behind runtime AVX2 detection.
        Level::Avx2 => unsafe { avx2::mask_blocks(data, &mut ks) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Level::Neon only exists behind runtime NEON detection.
        Level::Neon => unsafe { neon::mask_blocks(data, &mut ks) },
        _ => mask_run(data, &mut ks),
    }
}

/// The scalar byte-XOR loop — moved verbatim from
/// `ecc::mea::xor_keystream_in_place` (PR 3), parameterized on the
/// generator so the vector kernels reuse it for sub-block tails.
fn xor_run(bytes: &mut [u8], ks: &mut SplitMix64) {
    let mut chunks = bytes.chunks_exact_mut(8);
    for chunk in &mut chunks {
        let pad = ks.next_u64().to_le_bytes();
        for (b, p) in chunk.iter_mut().zip(pad.iter()) {
            *b ^= p;
        }
    }
    let rem = chunks.into_remainder();
    if !rem.is_empty() {
        let pad = ks.next_u64().to_le_bytes();
        for (b, p) in rem.iter_mut().zip(pad.iter()) {
            *b ^= p;
        }
    }
}

/// The scalar f32-mask loop — moved verbatim from
/// `ecc::mea::mask_f32_keystream_in_place` (PR 3), same
/// parameterization.
fn mask_run(data: &mut [f32], ks: &mut SplitMix64) {
    let mut chunks = data.chunks_exact_mut(2);
    for pair in &mut chunks {
        let w = ks.next_u64();
        pair[0] = f32::from_bits(pair[0].to_bits() ^ (w >> 32) as u32);
        pair[1] = f32::from_bits(pair[1].to_bits() ^ w as u32);
    }
    if let [last] = chunks.into_remainder() {
        *last = f32::from_bits(last.to_bits() ^ ks.next_u32());
    }
}

/// Expand the next `N/8` draws into an `N`-byte pad, committed in the
/// oracle's `to_le_bytes` layout.
#[inline]
fn fill_pad<const N: usize>(ks: &mut SplitMix64) -> [u8; N] {
    let mut pad = [0u8; N];
    for w in 0..N / 8 {
        pad[w * 8..w * 8 + 8].copy_from_slice(&ks.next_u64().to_le_bytes());
    }
    pad
}

/// Expand the next 4 draws into a 32-byte pad in the f32-mask word
/// order: per draw, high 32 bits first (even element), low 32 bits
/// second (odd element).
#[inline]
fn fill_mask_pad(ks: &mut SplitMix64) -> [u8; 32] {
    let mut pad = [0u8; 32];
    for w in 0..4 {
        let z = ks.next_u64();
        pad[w * 8..w * 8 + 4].copy_from_slice(&((z >> 32) as u32).to_le_bytes());
        pad[w * 8 + 4..w * 8 + 8].copy_from_slice(&(z as u32).to_le_bytes());
    }
    pad
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{fill_mask_pad, fill_pad, mask_run, xor_run};
    use crate::rng::SplitMix64;
    use std::arch::x86_64::*;

    /// XOR one 32-byte pad onto `dst`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn xor32(dst: *mut u8, pad: *const u8) {
        let v = _mm256_loadu_si256(dst as *const __m256i);
        let p = _mm256_loadu_si256(pad as *const __m256i);
        _mm256_storeu_si256(dst as *mut __m256i, _mm256_xor_si256(v, p));
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_blocks(bytes: &mut [u8], ks: &mut SplitMix64) {
        let n = bytes.len();
        let p = bytes.as_mut_ptr();
        let mut off = 0usize;
        // 64-byte blocks: 8 draws expanded together for ILP across the
        // mixes, two 256-bit XORs.
        while off + 64 <= n {
            let pad = fill_pad::<64>(ks);
            xor32(p.add(off), pad.as_ptr());
            xor32(p.add(off + 32), pad.as_ptr().add(32));
            off += 64;
        }
        if off + 32 <= n {
            let pad = fill_pad::<32>(ks);
            xor32(p.add(off), pad.as_ptr());
            off += 32;
        }
        // Sub-block tail: the scalar loop continues the same stream.
        xor_run(&mut bytes[off..], ks);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn mask_blocks(data: &mut [f32], ks: &mut SplitMix64) {
        let n = data.len();
        let p = data.as_mut_ptr() as *mut u8;
        let mut off = 0usize;
        // 8 elements (4 draws) per block; XOR on the raw bit patterns.
        while off + 8 <= n {
            let pad = fill_mask_pad(ks);
            xor32(p.add(off * 4), pad.as_ptr());
            off += 8;
        }
        mask_run(&mut data[off..], ks);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{fill_mask_pad, fill_pad, mask_run, xor_run};
    use crate::rng::SplitMix64;
    use std::arch::aarch64::*;

    /// XOR one 32-byte pad onto `dst` (two 128-bit lanes).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn xor32(dst: *mut u8, pad: *const u8) {
        let v0 = veorq_u8(vld1q_u8(dst), vld1q_u8(pad));
        let v1 = veorq_u8(vld1q_u8(dst.add(16)), vld1q_u8(pad.add(16)));
        vst1q_u8(dst, v0);
        vst1q_u8(dst.add(16), v1);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn xor_blocks(bytes: &mut [u8], ks: &mut SplitMix64) {
        let n = bytes.len();
        let p = bytes.as_mut_ptr();
        let mut off = 0usize;
        while off + 64 <= n {
            let pad = fill_pad::<64>(ks);
            xor32(p.add(off), pad.as_ptr());
            xor32(p.add(off + 32), pad.as_ptr().add(32));
            off += 64;
        }
        if off + 32 <= n {
            let pad = fill_pad::<32>(ks);
            xor32(p.add(off), pad.as_ptr());
            off += 32;
        }
        xor_run(&mut bytes[off..], ks);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn mask_blocks(data: &mut [f32], ks: &mut SplitMix64) {
        let n = data.len();
        let p = data.as_mut_ptr() as *mut u8;
        let mut off = 0usize;
        while off + 8 <= n {
            let pad = fill_mask_pad(ks);
            xor32(p.add(off * 4), pad.as_ptr());
            off += 8;
        }
        mask_run(&mut data[off..], ks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xor_all_levels_byte_identical_to_scalar() {
        for &len in &[0usize, 1, 7, 8, 9, 31, 32, 33, 63, 64, 65, 100, 1023, 4096] {
            let plain: Vec<u8> = (0..len).map(|i| (i * 7 + 3) as u8).collect();
            let mut want = plain.clone();
            xor_in_place_at(Level::Scalar, &mut want, 0xFEED_5EED);
            for level in super::super::available_levels() {
                let mut got = plain.clone();
                xor_in_place_at(level, &mut got, 0xFEED_5EED);
                assert_eq!(got, want, "level={} len={len}", level.name());
            }
        }
    }

    #[test]
    fn xor_is_self_inverse_at_every_level() {
        let plain: Vec<u8> = (0..777).map(|i| (i % 251) as u8).collect();
        for level in super::super::available_levels() {
            let mut buf = plain.clone();
            xor_in_place_at(level, &mut buf, 42);
            assert_ne!(buf, plain, "level={} must mask", level.name());
            xor_in_place_at(level, &mut buf, 42);
            assert_eq!(buf, plain, "level={} roundtrip", level.name());
        }
    }

    #[test]
    fn mask_all_levels_bit_identical_to_scalar() {
        for &len in &[0usize, 1, 2, 3, 7, 8, 9, 15, 16, 17, 100, 1001] {
            let plain: Vec<f32> = (0..len).map(|i| i as f32 * 0.37 - 3.0).collect();
            let mut want = plain.clone();
            mask_f32_in_place_at(Level::Scalar, &mut want, 0xD00D);
            for level in super::super::available_levels() {
                let mut got = plain.clone();
                mask_f32_in_place_at(level, &mut got, 0xD00D);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "level={} len={len}", level.name());
            }
        }
    }

    #[test]
    fn scalar_stream_matches_splitmix_reference() {
        // The all-zero plaintext *is* the keystream: check it against
        // direct SplitMix64 draws so refactors can't drift the stream.
        let mut buf = vec![0u8; 24];
        xor_in_place_at(Level::Scalar, &mut buf, 9);
        let mut ks = SplitMix64::new(9);
        for w in 0..3 {
            assert_eq!(&buf[w * 8..w * 8 + 8], &ks.next_u64().to_le_bytes());
        }
    }
}
