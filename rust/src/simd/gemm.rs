//! GEMM inner kernel: one A row against a panel of packed Bᵀ rows.
//!
//! `matrix::ops::matmul_tb_with` keeps its blocking (ROW_BLOCK row
//! granules on the pool × COL_BLOCK packed-Bᵀ panels) and calls
//! [`row_panel`] for the innermost `out[j] = ⟨a_row, bᵀ_row_j⟩` loop.
//!
//! **Canonical reduction order** (the determinism contract, DESIGN.md
//! §10): every output element is one dot product computed as 8-element
//! chunks folded into four accumulators — chunk `o` contributes
//! `sⱼ += x[o+j]·y[o+j] + x[o+j+4]·y[o+j+4]` for `j ∈ 0..4` — then the
//! fixed tree `(s₀+s₁) + (s₂+s₃)` plus a sequential scalar tail. The
//! AVX2 kernel computes the identical order with one 8-lane multiply
//! whose high half is folded onto its low half; NEON with two 4-lane
//! multiplies added lane-wise. No fused multiply-add anywhere: the
//! scalar oracle rounds after every multiply, so the vector kernels
//! must too. The vector win comes from lane width plus a 4-column tile
//! (four independent accumulator chains hide the add latency and reuse
//! each A chunk fourfold), not from reassociation.

use super::Level;

/// Unrolled dot product with 4 accumulators — the scalar oracle, moved
/// verbatim from `matrix::ops::dot` (PR 3).
#[inline]
pub fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 += x[o] * y[o] + x[o + 4] * y[o + 4];
        s1 += x[o + 1] * y[o + 1] + x[o + 5] * y[o + 5];
        s2 += x[o + 2] * y[o + 2] + x[o + 6] * y[o + 6];
        s3 += x[o + 3] * y[o + 3] + x[o + 7] * y[o + 7];
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += x[i] * y[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// `out[j] = ⟨arow, panel[j·k .. j·k+k]⟩` for every `j`, at the cached
/// dispatch level. `panel` holds `out.len()` consecutive packed Bᵀ rows
/// of length `k`.
#[inline]
pub fn row_panel(arow: &[f32], panel: &[f32], k: usize, out: &mut [f32]) {
    row_panel_at(super::level(), arow, panel, k, out);
}

/// [`row_panel`] at an explicit level (parity tests and the microbench
/// pin both sides).
pub fn row_panel_at(level: Level, arow: &[f32], panel: &[f32], k: usize, out: &mut [f32]) {
    debug_assert_eq!(arow.len(), k);
    debug_assert_eq!(panel.len(), k * out.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only ever produced behind a successful
        // `is_x86_feature_detected!("avx2")` (simd::native / the forced
        // override), so the target-feature kernel may execute.
        Level::Avx2 => unsafe { avx2::row_panel(arow, panel, k, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Level::Neon is only produced behind NEON detection.
        Level::Neon => unsafe { neon::row_panel(arow, panel, k, out) },
        _ => row_panel_scalar(arow, panel, k, out),
    }
}

/// The scalar panel loop — one oracle dot per output column.
pub fn row_panel_scalar(arow: &[f32], panel: &[f32], k: usize, out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        *o = dot_scalar(arow, &panel[j * k..j * k + k]);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// One canonical chunk step: 8-lane multiply, fold the high half
    /// onto the low half (`pⱼ + pⱼ₊₄` — the oracle's pairing), then
    /// accumulate onto the 4-lane `(s0..s3)` register. Each lane
    /// performs exactly the scalar oracle's op sequence.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn fold_step(acc: __m128, xv: __m256, yv: __m256) -> __m128 {
        let p = _mm256_mul_ps(xv, yv);
        let q = _mm_add_ps(_mm256_castps256_ps128(p), _mm256_extractf128_ps::<1>(p));
        _mm_add_ps(acc, q)
    }

    /// The oracle's epilogue: `(s0+s1) + (s2+s3)` plus the sequential
    /// scalar tail over `x[from..]`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn finish(acc: __m128, x: &[f32], y: &[f32], from: usize) -> f32 {
        let mut s = [0f32; 4];
        _mm_storeu_ps(s.as_mut_ptr(), acc);
        let mut tail = 0f32;
        for i in from..x.len() {
            tail += x[i] * y[i];
        }
        (s[0] + s[1]) + (s[2] + s[3]) + tail
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let chunks = x.len() / 8;
        let (xp, yp) = (x.as_ptr(), y.as_ptr());
        let mut acc = _mm_setzero_ps();
        for i in 0..chunks {
            let o = i * 8;
            acc = fold_step(acc, _mm256_loadu_ps(xp.add(o)), _mm256_loadu_ps(yp.add(o)));
        }
        finish(acc, x, y, chunks * 8)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn row_panel(arow: &[f32], panel: &[f32], k: usize, out: &mut [f32]) {
        let cols = out.len();
        let chunks = k / 8;
        let xp = arow.as_ptr();
        let bp = panel.as_ptr();
        let mut j = 0usize;
        // 4-column tile: four independent accumulator chains reuse each
        // A chunk and hide the `_mm_add_ps` latency.
        while j + 4 <= cols {
            let (b0, b1, b2, b3) =
                (bp.add(j * k), bp.add((j + 1) * k), bp.add((j + 2) * k), bp.add((j + 3) * k));
            let mut a0 = _mm_setzero_ps();
            let mut a1 = _mm_setzero_ps();
            let mut a2 = _mm_setzero_ps();
            let mut a3 = _mm_setzero_ps();
            for i in 0..chunks {
                let o = i * 8;
                let xv = _mm256_loadu_ps(xp.add(o));
                a0 = fold_step(a0, xv, _mm256_loadu_ps(b0.add(o)));
                a1 = fold_step(a1, xv, _mm256_loadu_ps(b1.add(o)));
                a2 = fold_step(a2, xv, _mm256_loadu_ps(b2.add(o)));
                a3 = fold_step(a3, xv, _mm256_loadu_ps(b3.add(o)));
            }
            let from = chunks * 8;
            out[j] = finish(a0, arow, &panel[j * k..(j + 1) * k], from);
            out[j + 1] = finish(a1, arow, &panel[(j + 1) * k..(j + 2) * k], from);
            out[j + 2] = finish(a2, arow, &panel[(j + 2) * k..(j + 3) * k], from);
            out[j + 3] = finish(a3, arow, &panel[(j + 3) * k..(j + 4) * k], from);
            j += 4;
        }
        while j < cols {
            out[j] = dot(arow, &panel[j * k..j * k + k]);
            j += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// One canonical chunk step on 4-lane registers: two 4-lane
    /// multiplies for the chunk's halves, added lane-wise
    /// (`pⱼ + pⱼ₊₄`), then accumulated. `vmulq`/`vaddq` round after
    /// every op, matching the scalar oracle (no `vfmaq`).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn fold_step(
        acc: float32x4_t,
        xlo: float32x4_t,
        xhi: float32x4_t,
        ylo: float32x4_t,
        yhi: float32x4_t,
    ) -> float32x4_t {
        let q = vaddq_f32(vmulq_f32(xlo, ylo), vmulq_f32(xhi, yhi));
        vaddq_f32(acc, q)
    }

    /// The oracle's epilogue: `(s0+s1) + (s2+s3)` plus the scalar tail.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn finish(acc: float32x4_t, x: &[f32], y: &[f32], from: usize) -> f32 {
        let (s0, s1, s2, s3) = (
            vgetq_lane_f32::<0>(acc),
            vgetq_lane_f32::<1>(acc),
            vgetq_lane_f32::<2>(acc),
            vgetq_lane_f32::<3>(acc),
        );
        let mut tail = 0f32;
        for i in from..x.len() {
            tail += x[i] * y[i];
        }
        (s0 + s1) + (s2 + s3) + tail
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let chunks = x.len() / 8;
        let (xp, yp) = (x.as_ptr(), y.as_ptr());
        let mut acc = vdupq_n_f32(0.0);
        for i in 0..chunks {
            let o = i * 8;
            acc = fold_step(
                acc,
                vld1q_f32(xp.add(o)),
                vld1q_f32(xp.add(o + 4)),
                vld1q_f32(yp.add(o)),
                vld1q_f32(yp.add(o + 4)),
            );
        }
        finish(acc, x, y, chunks * 8)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn row_panel(arow: &[f32], panel: &[f32], k: usize, out: &mut [f32]) {
        let cols = out.len();
        let chunks = k / 8;
        let xp = arow.as_ptr();
        let bp = panel.as_ptr();
        let mut j = 0usize;
        while j + 4 <= cols {
            let (b0, b1, b2, b3) =
                (bp.add(j * k), bp.add((j + 1) * k), bp.add((j + 2) * k), bp.add((j + 3) * k));
            let mut a0 = vdupq_n_f32(0.0);
            let mut a1 = vdupq_n_f32(0.0);
            let mut a2 = vdupq_n_f32(0.0);
            let mut a3 = vdupq_n_f32(0.0);
            for i in 0..chunks {
                let o = i * 8;
                let xlo = vld1q_f32(xp.add(o));
                let xhi = vld1q_f32(xp.add(o + 4));
                a0 = fold_step(a0, xlo, xhi, vld1q_f32(b0.add(o)), vld1q_f32(b0.add(o + 4)));
                a1 = fold_step(a1, xlo, xhi, vld1q_f32(b1.add(o)), vld1q_f32(b1.add(o + 4)));
                a2 = fold_step(a2, xlo, xhi, vld1q_f32(b2.add(o)), vld1q_f32(b2.add(o + 4)));
                a3 = fold_step(a3, xlo, xhi, vld1q_f32(b3.add(o)), vld1q_f32(b3.add(o + 4)));
            }
            let from = chunks * 8;
            out[j] = finish(a0, arow, &panel[j * k..(j + 1) * k], from);
            out[j + 1] = finish(a1, arow, &panel[(j + 1) * k..(j + 2) * k], from);
            out[j + 2] = finish(a2, arow, &panel[(j + 2) * k..(j + 3) * k], from);
            out[j + 3] = finish(a3, arow, &panel[(j + 3) * k..(j + 4) * k], from);
            j += 4;
        }
        while j < cols {
            out[j] = dot(arow, &panel[j * k..j * k + k]);
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    fn fill(rng: &mut crate::rng::Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(-2.0, 2.0) as f32).collect()
    }

    #[test]
    fn dot_scalar_handles_non_multiple_of_eight() {
        for n in 0..20 {
            let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let y = vec![1f32; n];
            let expect: f32 = x.iter().sum();
            assert_eq!(dot_scalar(&x, &y), expect);
        }
    }

    #[test]
    fn row_panel_all_levels_bit_identical_to_scalar() {
        let mut rng = rng_from_seed(0x51);
        // Ragged k (tails), ragged column counts (tile remainders).
        for &k in &[0usize, 1, 7, 8, 9, 16, 31, 33, 64] {
            for &cols in &[0usize, 1, 2, 3, 4, 5, 7, 8, 13] {
                let arow = fill(&mut rng, k);
                let panel = fill(&mut rng, k * cols);
                let mut want = vec![0f32; cols];
                row_panel_scalar(&arow, &panel, k, &mut want);
                for level in super::super::available_levels() {
                    let mut got = vec![0f32; cols];
                    row_panel_at(level, &arow, &panel, k, &mut got);
                    let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                    let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gb, wb, "level={} k={k} cols={cols}", level.name());
                }
            }
        }
    }

    #[test]
    fn row_panel_matches_per_column_dots() {
        let mut rng = rng_from_seed(0x52);
        let (k, cols) = (37, 11);
        let arow = fill(&mut rng, k);
        let panel = fill(&mut rng, k * cols);
        let mut out = vec![0f32; cols];
        row_panel(&arow, &panel, k, &mut out);
        for j in 0..cols {
            let d = dot_scalar(&arow, &panel[j * k..(j + 1) * k]);
            assert_eq!(out[j].to_bits(), d.to_bits(), "col {j}");
        }
    }
}
