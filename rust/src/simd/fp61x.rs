//! Slice-batched F_{2^61 − 1} lanes — the vector half of
//! `field::fp61::batch`.
//!
//! Operates on raw `u64` limbs holding canonical `Fp61` values (the
//! layout the curve layer and the keystream-seed derivation already
//! use). Two ops vectorize cleanly on 64-bit integer lanes and live
//! here:
//!
//! * [`add_assign_at`] — lane-wise modular add of canonical values:
//!   `s = a + b` (< 2^62, no overflow), one conditional subtract of p.
//! * [`reduce_assign_at`] — fold arbitrary `u64`s into canonical form:
//!   `(v & p) + (v >> 61)` (Mersenne shift-add), one conditional
//!   subtract.
//!
//! Batch *multiplication* stays scalar in `field::fp61::batch`: the
//! 61×61→122-bit product needs a full 64×64 multiply, which AVX2 lacks
//! (`vpmullq` is AVX-512); emulating it from 32×32 pieces costs more
//! µops than the scalar `mulx` + shift-add reduction it would replace.
//!
//! The conditional subtract compares via *signed* lane compares on
//! AVX2 (the only kind it has), which is sound because every compared
//! value is < 2^62 and therefore non-negative as an i64.

use super::Level;
use crate::field::fp61::P61;

/// Lane-wise `a[i] = (a[i] + b[i]) mod p` over canonical values, at
/// the cached dispatch level.
#[inline]
pub fn add_assign(a: &mut [u64], b: &[u64]) {
    add_assign_at(super::level(), a, b);
}

/// [`add_assign`] at an explicit level.
pub fn add_assign_at(level: Level, a: &mut [u64], b: &[u64]) {
    debug_assert_eq!(a.len(), b.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 only exists behind runtime AVX2 detection.
        Level::Avx2 => unsafe { avx2::add_assign(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Level::Neon only exists behind runtime NEON detection.
        Level::Neon => unsafe { neon::add_assign(a, b) },
        _ => add_assign_scalar(a, b),
    }
}

/// Lane-wise canonical reduction `a[i] = a[i] mod p` of arbitrary
/// `u64`s, at the cached dispatch level.
#[inline]
pub fn reduce_assign(a: &mut [u64]) {
    reduce_assign_at(super::level(), a);
}

/// [`reduce_assign`] at an explicit level.
pub fn reduce_assign_at(level: Level, a: &mut [u64]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 only exists behind runtime AVX2 detection.
        Level::Avx2 => unsafe { avx2::reduce_assign(a) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Level::Neon only exists behind runtime NEON detection.
        Level::Neon => unsafe { neon::reduce_assign(a) },
        _ => reduce_assign_scalar(a),
    }
}

/// Scalar oracle for the modular add — the `Fp61::add` body, lane by
/// lane.
pub fn add_assign_scalar(a: &mut [u64], b: &[u64]) {
    for (x, &y) in a.iter_mut().zip(b) {
        let mut s = *x + y; // canonical inputs: < 2^62, no overflow
        if s >= P61 {
            s -= P61;
        }
        *x = s;
    }
}

/// Scalar oracle for the canonical reduction: Mersenne shift-add.
/// `(v & p) + (v >> 61) ≤ p + 7`, so one conditional subtract
/// canonicalizes — and equals `v % p` for every `u64` (2^61 ≡ 1).
pub fn reduce_assign_scalar(a: &mut [u64]) {
    for x in a.iter_mut() {
        let mut r = (*x & P61) + (*x >> 61);
        if r >= P61 {
            r -= P61;
        }
        *x = r;
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use crate::field::fp61::P61;
    use std::arch::x86_64::*;

    /// Conditional subtract: lanes holding values ≥ p (all < 2^62, so
    /// the signed compare is exact) lose one p.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn cond_sub_p(s: __m256i) -> __m256i {
        let pm1 = _mm256_set1_epi64x((P61 - 1) as i64);
        let ge = _mm256_cmpgt_epi64(s, pm1);
        _mm256_sub_epi64(s, _mm256_and_si256(ge, _mm256_set1_epi64x(P61 as i64)))
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(a: &mut [u64], b: &[u64]) {
        let lanes = a.len() / 4 * 4;
        let ap = a.as_mut_ptr();
        let bp = b.as_ptr();
        let mut i = 0usize;
        while i < lanes {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let bv = _mm256_loadu_si256(bp.add(i) as *const __m256i);
            let s = cond_sub_p(_mm256_add_epi64(av, bv));
            _mm256_storeu_si256(ap.add(i) as *mut __m256i, s);
            i += 4;
        }
        super::add_assign_scalar(&mut a[lanes..], &b[lanes..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn reduce_assign(a: &mut [u64]) {
        let lanes = a.len() / 4 * 4;
        let ap = a.as_mut_ptr();
        let pv = _mm256_set1_epi64x(P61 as i64);
        let mut i = 0usize;
        while i < lanes {
            let v = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let lo = _mm256_and_si256(v, pv);
            let hi = _mm256_srli_epi64::<61>(v);
            let r = cond_sub_p(_mm256_add_epi64(lo, hi));
            _mm256_storeu_si256(ap.add(i) as *mut __m256i, r);
            i += 4;
        }
        super::reduce_assign_scalar(&mut a[lanes..]);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use crate::field::fp61::P61;
    use std::arch::aarch64::*;

    /// Conditional subtract on 2 unsigned 64-bit lanes.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn cond_sub_p(s: uint64x2_t) -> uint64x2_t {
        let ge = vcgtq_u64(s, vdupq_n_u64(P61 - 1));
        vsubq_u64(s, vandq_u64(ge, vdupq_n_u64(P61)))
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign(a: &mut [u64], b: &[u64]) {
        let lanes = a.len() / 2 * 2;
        let ap = a.as_mut_ptr();
        let bp = b.as_ptr();
        let mut i = 0usize;
        while i < lanes {
            let s = cond_sub_p(vaddq_u64(vld1q_u64(ap.add(i)), vld1q_u64(bp.add(i))));
            vst1q_u64(ap.add(i), s);
            i += 2;
        }
        super::add_assign_scalar(&mut a[lanes..], &b[lanes..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn reduce_assign(a: &mut [u64]) {
        let lanes = a.len() / 2 * 2;
        let ap = a.as_mut_ptr();
        let pv = vdupq_n_u64(P61);
        let mut i = 0usize;
        while i < lanes {
            let v = vld1q_u64(ap.add(i));
            let r = cond_sub_p(vaddq_u64(vandq_u64(v, pv), vshrq_n_u64::<61>(v)));
            vst1q_u64(ap.add(i), r);
            i += 2;
        }
        super::reduce_assign_scalar(&mut a[lanes..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn add_all_levels_match_scalar_and_field() {
        let mut rng = rng_from_seed(0x61);
        for &len in &[0usize, 1, 2, 3, 4, 5, 7, 8, 9, 100, 1000] {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64() % P61).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64() % P61).collect();
            let mut want = a.clone();
            add_assign_scalar(&mut want, &b);
            // Oracle of the oracle: the Fp61 element op.
            use crate::field::{FieldElement, Fp61};
            for i in 0..len {
                assert_eq!(want[i], Fp61::new(a[i]).add(&Fp61::new(b[i])).value());
            }
            for level in super::super::available_levels() {
                let mut got = a.clone();
                add_assign_at(level, &mut got, &b);
                assert_eq!(got, want, "level={} len={len}", level.name());
            }
        }
    }

    #[test]
    fn add_edge_values() {
        let edges = [0u64, 1, 2, P61 - 2, P61 - 1];
        for &x in &edges {
            for &y in &edges {
                let mut a = vec![x; 5];
                let b = vec![y; 5];
                add_assign_scalar(&mut a, &b);
                let expect = ((x as u128 + y as u128) % P61 as u128) as u64;
                assert!(a.iter().all(|&v| v == expect), "x={x} y={y}");
            }
        }
    }

    #[test]
    fn reduce_all_levels_match_modulo() {
        let mut rng = rng_from_seed(0x62);
        let mut vals: Vec<u64> =
            (0..997).map(|_| rng.next_u64()).collect();
        vals.extend_from_slice(&[0, 1, P61 - 1, P61, P61 + 1, u64::MAX, u64::MAX - 1]);
        let mut want = vals.clone();
        reduce_assign_scalar(&mut want);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(want[i], v % P61, "v={v}");
        }
        for level in super::super::available_levels() {
            let mut got = vals.clone();
            reduce_assign_at(level, &mut got);
            assert_eq!(got, want, "level={}", level.name());
        }
    }
}
