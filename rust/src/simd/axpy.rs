//! The `weighted_sum` accumulation kernel: `out[i] += w · src[i]`.
//!
//! `coding::interp::weighted_sum_with` keeps its fixed SUM_CHUNK
//! boundaries and per-chunk input-order accumulation; this kernel is
//! the per-(sample, chunk) inner loop. Elements are independent — no
//! cross-lane reduction — so vectorization is bit-exact by
//! construction as long as each lane performs the oracle's exact op
//! sequence: one rounded multiply then one rounded add (never a fused
//! multiply-add, which would skip the intermediate rounding).

use super::Level;

/// `out[i] += w * src[i]` at the cached dispatch level.
#[inline]
pub fn axpy(out: &mut [f32], src: &[f32], w: f32) {
    axpy_at(super::level(), out, src, w);
}

/// [`axpy`] at an explicit level.
pub fn axpy_at(level: Level, out: &mut [f32], src: &[f32], w: f32) {
    debug_assert_eq!(out.len(), src.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 only exists behind runtime AVX2 detection.
        Level::Avx2 => unsafe { avx2::axpy(out, src, w) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Level::Neon only exists behind runtime NEON detection.
        Level::Neon => unsafe { neon::axpy(out, src, w) },
        _ => axpy_scalar(out, src, w),
    }
}

/// The scalar oracle — the loop body `weighted_sum_with` ran before the
/// SIMD layer (PR 3), verbatim.
pub fn axpy_scalar(out: &mut [f32], src: &[f32], w: f32) {
    for (o, s) in out.iter_mut().zip(src) {
        *o += w * s;
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(out: &mut [f32], src: &[f32], w: f32) {
        let n = out.len();
        let wv = _mm256_set1_ps(w);
        let op = out.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0usize;
        // Two 8-lane strips per iteration: independent chains keep both
        // FP ports busy. Per lane: rounded mul, then rounded add —
        // exactly the scalar `*o += w * s`.
        while i + 16 <= n {
            let a0 = _mm256_add_ps(
                _mm256_loadu_ps(op.add(i)),
                _mm256_mul_ps(wv, _mm256_loadu_ps(sp.add(i))),
            );
            let a1 = _mm256_add_ps(
                _mm256_loadu_ps(op.add(i + 8)),
                _mm256_mul_ps(wv, _mm256_loadu_ps(sp.add(i + 8))),
            );
            _mm256_storeu_ps(op.add(i), a0);
            _mm256_storeu_ps(op.add(i + 8), a1);
            i += 16;
        }
        while i + 8 <= n {
            let a = _mm256_add_ps(
                _mm256_loadu_ps(op.add(i)),
                _mm256_mul_ps(wv, _mm256_loadu_ps(sp.add(i))),
            );
            _mm256_storeu_ps(op.add(i), a);
            i += 8;
        }
        super::axpy_scalar(&mut out[i..], &src[i..], w);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(out: &mut [f32], src: &[f32], w: f32) {
        let n = out.len();
        let wv = vdupq_n_f32(w);
        let op = out.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0usize;
        // vmulq + vaddq, never vfmaq: the oracle rounds between the
        // multiply and the add.
        while i + 8 <= n {
            let a0 = vaddq_f32(vld1q_f32(op.add(i)), vmulq_f32(wv, vld1q_f32(sp.add(i))));
            let a1 =
                vaddq_f32(vld1q_f32(op.add(i + 4)), vmulq_f32(wv, vld1q_f32(sp.add(i + 4))));
            vst1q_f32(op.add(i), a0);
            vst1q_f32(op.add(i + 4), a1);
            i += 8;
        }
        while i + 4 <= n {
            let a = vaddq_f32(vld1q_f32(op.add(i)), vmulq_f32(wv, vld1q_f32(sp.add(i))));
            vst1q_f32(op.add(i), a);
            i += 4;
        }
        super::axpy_scalar(&mut out[i..], &src[i..], w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn axpy_all_levels_bit_identical_to_scalar() {
        let mut rng = rng_from_seed(0x44);
        for &len in &[0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 31, 100, 4096, 4099] {
            let src: Vec<f32> = (0..len).map(|_| rng.uniform(-3.0, 3.0) as f32).collect();
            let base: Vec<f32> = (0..len).map(|_| rng.uniform(-3.0, 3.0) as f32).collect();
            let w = rng.uniform(-2.0, 2.0) as f32;
            let mut want = base.clone();
            axpy_scalar(&mut want, &src, w);
            for level in super::super::available_levels() {
                let mut got = base.clone();
                axpy_at(level, &mut got, &src, w);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "level={} len={len}", level.name());
            }
        }
    }

    #[test]
    fn axpy_accumulates_in_place() {
        let mut out = vec![1.0f32; 10];
        let src: Vec<f32> = (0..10).map(|i| i as f32).collect();
        axpy(&mut out, &src, 2.0);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 1.0 + 2.0 * i as f32);
        }
    }
}
