//! SIMD microkernels for the four hot paths, behind one-time runtime
//! dispatch — DESIGN.md §10.
//!
//! The kernels here are the only `unsafe` code in the crate (a CI
//! grep-lint enforces the confinement). Four hot paths are dispatched:
//!
//! * [`gemm`] — the packed GEMM inner kernel (`matrix::ops`): one A row
//!   against a panel of packed Bᵀ rows.
//! * [`keystream`] — the MEA-ECC SplitMix64 pads (`ecc::mea`): byte XOR
//!   for seal/open-the-bytes, 32-bit-word XOR for f32 bit patterns.
//! * [`axpy`] — the fixed-order `weighted_sum` accumulation
//!   (`coding::interp`, Berrut/Lagrange decode).
//! * [`fp61x`] — slice-batched F_{2^61−1} add/reduce lanes backing the
//!   `field::fp61::batch` helpers.
//!
//! **Determinism contract.** Every vector kernel performs the *same*
//! per-element operations in the *same* per-element order as its scalar
//! oracle — lane-wise IEEE-754 mul/add (never a fused mul-add: fusing
//! skips a rounding step the scalar code performs), same chunk
//! boundaries, same fixed reduction tree. Outputs are therefore
//! bit-identical across `Level`s, which composes with the thread-pool
//! contract (`parallel`): one result for any `(threads, SIMD level)`
//! pair. `tests/simd_parity.rs` sweeps ragged shapes and unaligned
//! tails; the CI scenario matrix pins one digest across
//! `SPACDC_SIMD=off` and auto legs.
//!
//! **Dispatch.** The active [`Level`] is resolved once into a
//! [`OnceLock`]: the `SPACDC_SIMD` environment variable, if set,
//! overrides (`off`/`scalar`, `avx2`, `neon`, `auto`); otherwise
//! `is_x86_feature_detected!("avx2")` / NEON detection picks the widest
//! supported lane width. Forcing an ISA the CPU lacks panics (executing
//! the kernel would be undefined behaviour); unknown values panic too,
//! so a typo cannot silently drop to scalar. Kernels take an explicit
//! `Level` in their `*_at` form (benches and parity tests pin both
//! sides); the plain entry points read the cached level.
//!
//! **Adding an ISA.** Implement the per-kernel `*_<isa>` functions
//! behind `#[cfg(target_arch)] + #[target_feature]`, add a `Level`
//! variant, extend `native()` detection and `parse_override`, and add
//! the ISA to the parity sweeps in `tests/simd_parity.rs`. Nothing
//! outside this module changes.

use std::sync::OnceLock;

pub mod axpy;
pub mod fp61x;
pub mod gemm;
pub mod keystream;

/// An instruction-set level the kernels can run at. `Scalar` is always
/// available and is the oracle the vector levels are tested against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// The portable scalar kernels (the verbatim pre-SIMD hot paths).
    Scalar,
    /// 256-bit AVX2 lanes (x86_64, runtime-detected).
    Avx2,
    /// 128-bit NEON lanes (aarch64, runtime-detected).
    Neon,
}

impl Level {
    /// Stable lowercase name (`scalar` / `avx2` / `neon`) — used by the
    /// microbench JSON and log lines.
    pub fn name(&self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Avx2 => "avx2",
            Level::Neon => "neon",
        }
    }
}

static ACTIVE: OnceLock<Level> = OnceLock::new();

/// The level the dispatched kernels run at, resolved once per process
/// from `SPACDC_SIMD` (override) or runtime feature detection.
#[inline]
pub fn level() -> Level {
    *ACTIVE.get_or_init(|| match std::env::var("SPACDC_SIMD") {
        Ok(raw) => parse_override(&raw).unwrap_or_else(|e| panic!("SPACDC_SIMD: {e}")),
        Err(_) => native(),
    })
}

/// Parse one `SPACDC_SIMD` value into the level it forces.
///
/// Pure so the table is testable without touching the process cache:
/// `off`/`scalar` force the oracle, `avx2`/`neon` force an ISA (error
/// if this build/CPU cannot execute it), `auto`/empty defer to
/// detection.
fn parse_override(raw: &str) -> Result<Level, String> {
    match raw.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => Ok(native()),
        "off" | "scalar" => Ok(Level::Scalar),
        "avx2" => {
            if avx2_available() {
                Ok(Level::Avx2)
            } else {
                Err("avx2 forced but not available on this CPU/arch".into())
            }
        }
        "neon" => {
            if neon_available() {
                Ok(Level::Neon)
            } else {
                Err("neon forced but not available on this CPU/arch".into())
            }
        }
        other => Err(format!(
            "unknown value {other:?} (expected off|scalar|avx2|neon|auto)"
        )),
    }
}

/// Widest level the running CPU supports.
fn native() -> Level {
    if avx2_available() {
        Level::Avx2
    } else if neon_available() {
        Level::Neon
    } else {
        Level::Scalar
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

/// Every level this build can execute (used by the parity tests to
/// sweep all reachable kernels, whatever machine the tests run on).
pub fn available_levels() -> Vec<Level> {
    let mut out = vec![Level::Scalar];
    if avx2_available() {
        out.push(Level::Avx2);
    }
    if neon_available() {
        out.push(Level::Neon);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_override_scalar_spellings() {
        assert_eq!(parse_override("off"), Ok(Level::Scalar));
        assert_eq!(parse_override("scalar"), Ok(Level::Scalar));
        assert_eq!(parse_override(" OFF "), Ok(Level::Scalar));
    }

    #[test]
    fn parse_override_auto_matches_native() {
        assert_eq!(parse_override("auto"), Ok(native()));
        assert_eq!(parse_override(""), Ok(native()));
    }

    #[test]
    fn parse_override_rejects_garbage() {
        assert!(parse_override("sse9").is_err());
        assert!(parse_override("on").is_err());
    }

    #[test]
    fn forced_isa_matches_detection() {
        // Forcing an ISA succeeds exactly when detection reports it.
        assert_eq!(parse_override("avx2").is_ok(), avx2_available());
        assert_eq!(parse_override("neon").is_ok(), neon_available());
    }

    #[test]
    fn level_is_stable_and_available() {
        let l = level();
        assert_eq!(l, level(), "cached level must not change");
        assert!(available_levels().contains(&l));
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(Level::Scalar.name(), "scalar");
        assert_eq!(Level::Avx2.name(), "avx2");
        assert_eq!(Level::Neon.name(), "neon");
    }
}
