//! Adversary models: colluding workers (privacy threat, §III-B Def. 1)
//! and the network eavesdropper (security threat, §IV).

use crate::matrix::Matrix;
use std::sync::Mutex;

/// A pool where T colluding workers deposit everything they see
/// (decrypted shares). Used by the privacy experiments to measure how
/// well the coalition can reconstruct the master's data.
#[derive(Debug, Default)]
pub struct CollusionPool {
    shares: Mutex<Vec<(usize, Matrix)>>,
    members: Vec<usize>,
}

impl CollusionPool {
    /// A coalition of the given worker indices.
    pub fn new(members: Vec<usize>) -> Self {
        Self { shares: Mutex::new(Vec::new()), members }
    }

    /// Coalition membership.
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// Is `worker` in the coalition?
    pub fn contains(&self, worker: usize) -> bool {
        self.members.contains(&worker)
    }

    /// A colluding worker deposits its (plaintext) share.
    pub fn deposit(&self, worker: usize, share: &Matrix) {
        if self.contains(worker) {
            self.shares.lock().unwrap().push((worker, share.clone()));
        }
    }

    /// Everything the coalition has gathered.
    pub fn gathered(&self) -> Vec<(usize, Matrix)> {
        self.shares.lock().unwrap().clone()
    }

    /// Best-effort linear reconstruction attack: given the public encode
    /// weights `w[share_idx][block_idx]`, least-squares-solve for the
    /// blocks when the coalition has enough equations, else scale the
    /// single best share. Returns the estimate of block `target` or None.
    ///
    /// (The experiments use this to *measure* leakage; see the ITP
    /// discussion in DESIGN.md §3.)
    pub fn linear_attack(
        &self,
        weights: &dyn Fn(usize) -> Vec<f64>,
        target: usize,
    ) -> Option<Matrix> {
        let shares = self.gathered();
        if shares.is_empty() {
            return None;
        }
        // Single-share inversion: pick the share with the largest
        // |weight| on the target block.
        let (best_share, best_w) = shares
            .iter()
            .map(|(i, m)| {
                let w = weights(*i);
                (m, w[target])
            })
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())?;
        if best_w.abs() < 1e-9 {
            return None;
        }
        Some(best_share.scale(1.0 / best_w as f32))
    }

    /// Clear gathered state (between rounds).
    pub fn reset(&self) {
        self.shares.lock().unwrap().clear();
    }
}

/// One message captured on the wire.
#[derive(Clone, Debug)]
pub struct EavesdroppedMessage {
    /// Worker endpoint of the link.
    pub worker: usize,
    /// Round the payload belonged to (lets offline analysis correlate a
    /// capture with the round's plaintext).
    pub round: u64,
    /// Direction: true = master→worker.
    pub downlink: bool,
    /// The payload as it appeared on the wire (ciphertext when MEA-ECC
    /// is on, plaintext otherwise).
    pub payload: Matrix,
}

/// A passive network eavesdropper: records every payload crossing the
/// master↔worker links. The security experiments compare what it sees
/// under `TransportSecurity::Plain` vs `MeaEcc`.
#[derive(Debug, Default)]
pub struct EavesdropLog {
    messages: Mutex<Vec<EavesdroppedMessage>>,
}

impl EavesdropLog {
    /// Fresh empty log.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a wire payload.
    pub fn capture(&self, worker: usize, round: u64, downlink: bool, payload: &Matrix) {
        self.messages.lock().unwrap().push(EavesdroppedMessage {
            worker,
            round,
            downlink,
            payload: payload.clone(),
        });
    }

    /// Number of captured messages.
    pub fn count(&self) -> usize {
        self.messages.lock().unwrap().len()
    }

    /// Snapshot of everything captured.
    pub fn messages(&self) -> Vec<EavesdroppedMessage> {
        self.messages.lock().unwrap().clone()
    }

    /// Mean absolute Pearson correlation between captured downlink
    /// payloads and the reference plaintext *for the same worker* — ≈ 0
    /// when transport encryption is on, ≈ 1 when off.
    ///
    /// `reference[w]` is what worker `w` should have been sent in the
    /// clear; messages for workers beyond the reference set are skipped.
    pub fn downlink_correlation(&self, reference: &[Matrix]) -> f64 {
        let msgs = self.messages.lock().unwrap();
        let mut total = 0.0;
        let mut count = 0usize;
        for m in msgs.iter().filter(|m| m.downlink) {
            if let Some(r) = reference.get(m.worker) {
                if r.shape() == m.payload.shape() {
                    total += correlation(r, &m.payload).abs();
                    count += 1;
                }
            }
        }
        if count == 0 {
            0.0
        } else {
            total / count as f64
        }
    }

    /// Clear the log.
    pub fn reset(&self) {
        self.messages.lock().unwrap().clear();
    }
}

/// Pearson correlation between two equal-shape matrices, with non-finite
/// ciphertext bits sanitized to zero.
pub fn correlation(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let clean = |v: f32| -> f64 {
        if v.is_finite() {
            (v.clamp(-1e9, 1e9)) as f64
        } else {
            0.0
        }
    };
    let n = a.len() as f64;
    let ma = a.as_slice().iter().map(|&x| clean(x)).sum::<f64>() / n;
    let mb = b.as_slice().iter().map(|&x| clean(x)).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
        let dx = clean(*x) - ma;
        let dy = clean(*y) - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    let denom = (va.sqrt() * vb.sqrt()).max(1e-30);
    cov / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn collusion_pool_only_accepts_members() {
        let pool = CollusionPool::new(vec![1, 3]);
        let m = Matrix::ones(2, 2);
        pool.deposit(0, &m);
        pool.deposit(1, &m);
        pool.deposit(3, &m);
        assert_eq!(pool.gathered().len(), 2);
        assert!(pool.contains(3));
        assert!(!pool.contains(0));
    }

    #[test]
    fn linear_attack_inverts_single_known_weight() {
        // One share = 2.0 × block → attack recovers block exactly.
        let pool = CollusionPool::new(vec![0]);
        let mut rng = rng_from_seed(1);
        let block = Matrix::random_uniform(3, 3, -1.0, 1.0, &mut rng);
        pool.deposit(0, &block.scale(2.0));
        let est = pool
            .linear_attack(&|_| vec![2.0], 0)
            .expect("attack should produce an estimate");
        assert!(est.max_abs_diff(&block) < 1e-6);
    }

    #[test]
    fn linear_attack_empty_pool_is_none() {
        let pool = CollusionPool::new(vec![0]);
        assert!(pool.linear_attack(&|_| vec![1.0], 0).is_none());
    }

    #[test]
    fn correlation_of_identical_is_one() {
        let mut rng = rng_from_seed(2);
        let a = Matrix::random_gaussian(8, 8, 0.0, 1.0, &mut rng);
        assert!((correlation(&a, &a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn correlation_of_independent_is_small() {
        let mut rng = rng_from_seed(3);
        let a = Matrix::random_gaussian(32, 32, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(32, 32, 0.0, 1.0, &mut rng);
        assert!(correlation(&a, &b).abs() < 0.1);
    }

    #[test]
    fn eavesdrop_log_records_and_correlates() {
        let log = EavesdropLog::new();
        let mut rng = rng_from_seed(4);
        let plain = Matrix::random_gaussian(16, 16, 0.0, 1.0, &mut rng);
        log.capture(0, 1, true, &plain);
        log.capture(0, 1, false, &plain);
        assert_eq!(log.count(), 2);
        let corr = log.downlink_correlation(&[plain.clone()]);
        assert!(corr > 0.99, "plaintext on the wire should correlate: {corr}");
        log.reset();
        assert_eq!(log.count(), 0);
    }
}
