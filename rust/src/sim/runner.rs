//! The scenario engine's driver: run a [`Scenario`] through the live
//! master/worker system for N rounds and produce a machine-readable
//! [`ScenarioReport`] (the `SCENARIO_REPORT.json` artifact CI uploads).
//!
//! The runner pre-draws every round's seeded data, then drives the
//! whole task list through
//! [`Master::run_stream`](crate::coordinator::Master::run_stream) — the
//! scenario's `[stream]` table (or an execution-knob override) sets the
//! in-flight window and speculation — and records each round's outcome:
//! results used, degradation, decode error vs the exact result,
//! wall-clock. Crashes, respawns, and wire corruption all happen
//! *inside* the coordinator, driven by the scenario's
//! [`FaultPlan`](crate::sim::FaultPlan); the runner only observes.
//!
//! **Multi-tenant soaks** (`[tenants] count > 1`, DESIGN.md §12): the
//! runner opens one session lane per tenant on the serving front end
//! ([`Master::service`](crate::coordinator::Master::service)), each fed
//! from its own iterator with its own seed stream
//! (`derive_seed(seed, 0x7E4A_0000 ^ t)`), and reports per-tenant
//! stats *and* a per-tenant digest. Every random choice a tenant's
//! rounds consume comes from its lane seed, and since the fault plan
//! re-keyed onto stable identities (DESIGN.md §13) a multi-tenant
//! scenario may also carry faults: corruption/forgery draws key on the
//! tenant's own `(lane, lane_round)` stream and crashes/jitter on
//! wall-rounds-served, so a tenant's adversarial exposure does not
//! shift when the deficit-round-robin dispatcher re-interleaves lanes.
//! When the scenario keeps the decode set round-invariant (S = 0 plus
//! next-round respawns, with speculation re-covering every written-off
//! share — the `tenants-faults` builtin's construction), each
//! per-tenant digest is a pure function of that tenant alone —
//! invariant across transports, thread widths, the global cap, and
//! lane interleaving.
//!
//! **The digest.** CI pins one hex digest per scenario across the whole
//! `{inproc, tcp} × {threads 1, 8} × inflight {1, 4, 16}` execution
//! matrix. It folds exactly the fields the determinism contract covers
//! — per-round status, results-used counts, degradation flags, every
//! decoded f32 bit, the transport byte totals credited at
//! dispatch/decode time, the speculation-recovered share count, and the
//! forged-result detections (both schedule-driven, hence deterministic)
//! — and deliberately excludes anything wall-clock-shaped (latencies,
//! throughput, late straggler counts, speculation *losers*, wire-error
//! tallies that race the soak's end, and the quarantine/rehabilitation
//! tallies, which depend on frame arrival order).

use crate::coding::CodedTask;
use crate::config::{SystemConfig, TransportKind};
use crate::coordinator::{
    ExitRecord, Master, MasterBuilder, RoundError, ServiceConfig, SessionOptions, StreamConfig,
};
use crate::matrix::{gram, split_rows, Matrix};
use crate::metrics::{names, MetricsRegistry};
use crate::rng::{derive_seed, rng_from_seed};
use crate::runtime::WorkerOp;
use crate::sim::{correlation_of, CollusionPool, EavesdropLog, Scenario, ScenarioOp};
use std::collections::HashMap;
use std::sync::Arc;

/// The seed-stream tag each tenant's lane derives from the scenario
/// seed: tenant `t` draws everything from
/// `derive_seed(sc.seed, TENANT_SEED_STREAM ^ t)`.
const TENANT_SEED_STREAM: u64 = 0x7E4A_0000;

/// How one round of a soak ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundStatus {
    /// Decoded (possibly degraded — see [`RoundRecord::degraded`]).
    Ok,
    /// `round_deadline_s` elapsed with recovery still possible.
    Deadline,
    /// Too many workers down to ever reach the threshold.
    Hopeless,
    /// The round could not even be dispatched (e.g. fewer live workers
    /// than an exact scheme's k).
    SubmitFailed,
    /// Forged results left the round uncompletable from verified
    /// results; it was refused rather than decoded wrong (DESIGN.md §11).
    Forged,
}

impl RoundStatus {
    /// Stable byte for the digest preimage.
    fn code(self) -> u8 {
        match self {
            RoundStatus::Ok => 0,
            RoundStatus::Deadline => 1,
            RoundStatus::Hopeless => 2,
            RoundStatus::SubmitFailed => 3,
            RoundStatus::Forged => 4,
        }
    }

    /// Stable token for the JSON report.
    pub fn name(self) -> &'static str {
        match self {
            RoundStatus::Ok => "ok",
            RoundStatus::Deadline => "deadline",
            RoundStatus::Hopeless => "hopeless",
            RoundStatus::SubmitFailed => "submit-failed",
            RoundStatus::Forged => "forged",
        }
    }
}

/// One round's outcome in the report.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// Round id (1-based, as the master numbers them).
    pub round: u64,
    /// How the round ended.
    pub status: RoundStatus,
    /// Results the decoder consumed (0 for failed rounds).
    pub results_used: usize,
    /// Did the round decode from fewer results than the original policy?
    pub degraded: bool,
    /// Max per-block relative decode error vs the exact computation
    /// (`None` for failed rounds).
    pub rel_err: Option<f64>,
    /// Wall-clock of the round, milliseconds (excluded from the digest).
    pub wall_ms: f64,
}

/// One tenant's slice of a multi-tenant soak (DESIGN.md §12).
#[derive(Clone, Debug)]
pub struct TenantStat {
    /// Tenant index (lane order).
    pub tenant: usize,
    /// Lane name (`tenant-<t>`).
    pub name: String,
    /// This tenant's determinism pin: a pure function of the tenant's
    /// own seed stream — identical to the tenant's solo run, on any
    /// transport, thread width, global cap, or lane interleaving.
    pub digest: String,
    /// Rounds this tenant completed (decoded + failed).
    pub rounds: u64,
    /// Rounds that decoded.
    pub decoded: u64,
    /// Decoded rounds that degraded. 0 whenever the scenario keeps the
    /// decode set round-invariant (fault-free, or faulted with
    /// speculation re-covering every written-off share, as in
    /// `tenants-faults`); nonzero means some of this tenant's rounds
    /// decoded short.
    pub degraded: u64,
    /// Rounds that failed.
    pub failed: u64,
    /// Admission refusals: the lane had window space but the global cap
    /// turned its submission away (not in any digest — scheduling).
    pub refused: u64,
    /// This tenant's completed rounds per second over the soak.
    pub rounds_per_s: f64,
    /// Median round latency, ms (not in any digest).
    pub p50_ms: f64,
    /// 99th-percentile round latency, ms (not in any digest).
    pub p99_ms: f64,
    /// Mean lane-window occupancy.
    pub occupancy_mean: f64,
    /// Peak lane-window occupancy.
    pub occupancy_max: usize,
}

/// The full soak report (serialized as `SCENARIO_REPORT.json`).
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Scenario name.
    pub scenario: String,
    /// Scheme under test (paper nomenclature).
    pub scheme: String,
    /// Per-round task token.
    pub op: String,
    /// Execution knob: which fabric carried the frames.
    pub transport: String,
    /// Execution knob: master-side pool width (0 = auto).
    pub threads: usize,
    /// Execution knob: rounds kept in flight (the stream window).
    pub inflight: usize,
    /// Was speculative re-dispatch on?
    pub speculate: bool,
    /// Scenario seed.
    pub seed: u64,
    /// Cluster size N.
    pub workers: usize,
    /// Rounds driven.
    pub rounds: u64,
    /// Per-round outcomes.
    pub records: Vec<RoundRecord>,
    /// The determinism pin: identical across transports and widths.
    pub digest: String,
    /// Fraction of rounds that decoded (status `ok`).
    pub recovery_hit_rate: f64,
    /// Round wall-clock stats, milliseconds (not in the digest).
    pub wall_mean_ms: f64,
    /// Median round wall-clock, ms.
    pub wall_p50_ms: f64,
    /// 99th-percentile round wall-clock, ms.
    pub wall_p99_ms: f64,
    /// Worst round wall-clock, ms.
    pub wall_max_ms: f64,
    /// Serialized bytes master → workers.
    pub bytes_tx: u64,
    /// Serialized bytes of the results the decoders consumed.
    pub bytes_rx: u64,
    /// Frames dropped for failing wire validation (corruption injection
    /// shows up here; excluded from the digest — late frames race the
    /// soak's end).
    pub wire_errors: u64,
    /// Results that arrived as wasted work (ditto).
    pub results_late: u64,
    /// Downlink payloads the eavesdropper charted.
    pub downlink_messages: usize,
    /// Mean (over downlink captures) of the best |correlation| between
    /// the wire payload and any of its round's plaintext blocks — ≈ 0
    /// under MEA-ECC, high when payloads travel in the clear.
    pub downlink_leak: f64,
    /// Plaintext shares the colluding coalition gathered.
    pub colluder_shares: usize,
    /// Worker crashes the master observed.
    pub crashes: u64,
    /// Incarnations respawned and re-registered.
    pub respawns: u64,
    /// Rounds that degraded to "decode from what arrived".
    pub degraded_rounds: u64,
    /// Final incarnation number per worker.
    pub final_generations: Vec<u32>,
    /// Round throughput over the whole stream (not in the digest —
    /// wall-clock-shaped; this is the number the window is for).
    pub rounds_per_s: f64,
    /// Mean in-flight occupancy over the soak (not in the digest —
    /// scheduling-shaped; the backpressure/saturation readout).
    pub occupancy_mean: f64,
    /// Peak in-flight occupancy (≤ the window / global cap).
    pub occupancy_max: usize,
    /// Concurrent tenants the soak drove (1 = the classic single-tenant
    /// stream).
    pub tenants: usize,
    /// Per-tenant session window (= `inflight` when the scenario left
    /// it 0).
    pub tenant_inflight: usize,
    /// Per-tenant stats + digests — empty at `tenants = 1`.
    pub tenant_stats: Vec<TenantStat>,
    /// Speculative work orders sent (not in the digest: the deadline
    /// checkpoint fires on wall-clock).
    pub spec_redispatched: u64,
    /// Written-off shares recovered by speculation — schedule-driven,
    /// so it *is* folded into the digest.
    pub spec_recovered: u64,
    /// Duplicate share copies discarded, first-result-wins losers (not
    /// in the digest: which copy lost is a race).
    pub spec_wasted: u64,
    /// Results whose commitment echo was checked at the collector (not
    /// in the digest — late frames race the soak's end).
    pub verify_checked: u64,
    /// Forgeries booked from the fault plan at submit — plan-pure, so it
    /// *is* folded into the digest.
    pub verify_forged_detected: u64,
    /// Executors quarantined after a verified-forged result (not in the
    /// digest: which copy tripped the check first is a race).
    pub verify_quarantined: u64,
    /// Suspects cleared by a later verified-good result (ditto).
    pub verify_rehabilitated: u64,
    /// Child-process exit records, in exit order — populated only on the
    /// process fabric (`--transport proc`), where crashes are real
    /// SIGKILLs and teardown is SIGTERM-then-SIGKILL. Includes the
    /// final-teardown exits: the master is torn down before the report
    /// is assembled. Excluded from the digest (pids and kill timing are
    /// not deterministic); the *causes* are what the testbed asserts on.
    pub process_exits: Vec<ExitRecord>,
}

/// FNV-1a, 64-bit: tiny, dependency-free, good enough to pin a CI
/// artifact (this is a determinism check, not a security boundary).
struct Fnv64(u64);

impl Fnv64 {
    fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }

    fn u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// Drive `sc` through the live system on the default execution knobs
/// for its `[stream]` table (window and speculation as the scenario
/// asks).
///
/// `transport` and `threads` may change wall-clock but must not change
/// the digest — that is the determinism contract CI enforces.
pub fn run_scenario(
    sc: &Scenario,
    transport: TransportKind,
    threads: usize,
) -> anyhow::Result<ScenarioReport> {
    run_scenario_with(sc, transport, threads, None, None)
}

/// [`run_scenario`] with explicit stream-knob overrides: CI soaks the
/// same scenario over `inflight ∈ {1, 4, 16}` and pins one digest —
/// the window is an execution knob like the transport, never part of
/// the outcome.
pub fn run_scenario_with(
    sc: &Scenario,
    transport: TransportKind,
    threads: usize,
    inflight: Option<usize>,
    speculate: Option<bool>,
) -> anyhow::Result<ScenarioReport> {
    sc.validate().map_err(|e| anyhow::anyhow!("invalid scenario {:?}: {e}", sc.name))?;
    let mut cfg = SystemConfig::default();
    cfg.workers = sc.workers;
    cfg.stragglers = sc.stragglers;
    cfg.colluders = sc.colluders;
    cfg.partitions = sc.partitions;
    cfg.scheme = sc.scheme;
    cfg.transport = transport;
    cfg.security = sc.security;
    cfg.round_deadline_s = sc.round_deadline_s;
    cfg.threads = threads;
    let inflight = inflight.unwrap_or(sc.inflight).max(1);
    let speculate = speculate.unwrap_or(sc.speculate);
    cfg.inflight = inflight;
    cfg.speculate = speculate;
    cfg.delay = sc.delay;
    cfg.seed = sc.seed;
    cfg.use_pjrt = false; // native kernels: deterministic, artifact-free

    let metrics = Arc::new(MetricsRegistry::new());
    let tap = Arc::new(EavesdropLog::new());
    let coalition = if sc.colluder_set.is_empty() {
        None
    } else {
        Some(Arc::new(CollusionPool::new(sc.colluder_set.clone())))
    };
    let mut builder = MasterBuilder::new(cfg)
        .metrics(Arc::clone(&metrics))
        .eavesdropper(Arc::clone(&tap))
        .faults(Arc::new(sc.fault_plan()));
    if let Some(c) = &coalition {
        builder = builder.collusion(Arc::clone(c));
    }
    let mut master = builder.build()?;

    // Multi-tenant soaks go through the serving front end; the
    // single-tenant path below stays byte-for-byte what PR 8 pinned.
    if sc.tenants > 1 {
        return run_multi_tenant(
            sc, transport, threads, inflight, speculate, metrics, tap, coalition, master,
        );
    }

    let mut digest = Fnv64::new();
    digest.write(b"scenario-digest-v3");
    digest.write(sc.name.as_bytes());
    digest.u64(sc.seed);
    digest.u64(sc.rounds);
    digest.u64(sc.workers as u64);

    // Pre-draw every round's data (each round's stream is derived
    // independently from the scenario seed, so pre-drawing changes no
    // bits) and keep the plaintext blocks for the decode-error and
    // eavesdropper-leak analyses.
    let worker_op = match sc.op {
        ScenarioOp::Gram => WorkerOp::Gram,
        ScenarioOp::Identity => WorkerOp::Identity,
    };
    let mut tasks = Vec::with_capacity(sc.rounds as usize);
    let mut round_blocks: Vec<Vec<Matrix>> = Vec::with_capacity(sc.rounds as usize);
    for r in 1..=sc.rounds {
        let mut data_rng = rng_from_seed(derive_seed(sc.seed, 0xDA7A_0000 + r));
        let x = Matrix::random_gaussian(sc.rows, sc.cols, 0.0, 1.0, &mut data_rng);
        let (blocks, _) = split_rows(&x, sc.partitions);
        tasks.push(CodedTask::block_map(worker_op.clone(), x));
        round_blocks.push(blocks);
    }

    // The whole soak is one windowed stream (inflight = 1 degenerates
    // to the old submit/wait-per-round loop, bit for bit).
    let stream = master.run_stream(tasks, StreamConfig { inflight, speculate })?;

    let mut records = Vec::with_capacity(sc.rounds as usize);
    for sr in &stream.rounds {
        let r = sr.index as u64 + 1;
        let record = match &sr.outcome {
            Ok(out) => {
                let exact = |b: &Matrix| match sc.op {
                    ScenarioOp::Gram => gram(b),
                    ScenarioOp::Identity => b.clone(),
                };
                let rel_err = out
                    .blocks
                    .iter()
                    .zip(&round_blocks[sr.index])
                    .map(|(d, b)| d.rel_error(&exact(b)))
                    .fold(0.0f64, f64::max);
                digest.u64(r);
                digest.write(&[RoundStatus::Ok.code(), out.degraded as u8]);
                digest.u64(out.results_used as u64);
                for m in &out.blocks {
                    digest.u64(m.rows() as u64);
                    digest.u64(m.cols() as u64);
                    for v in m.as_slice() {
                        digest.write(&v.to_bits().to_le_bytes());
                    }
                }
                metrics.record("scenario.round_wall_s", out.wall.as_secs_f64());
                RoundRecord {
                    round: r,
                    status: RoundStatus::Ok,
                    results_used: out.results_used,
                    degraded: out.degraded,
                    rel_err: Some(rel_err),
                    wall_ms: out.wall.as_secs_f64() * 1e3,
                }
            }
            Err(e) => {
                let status = match e.inner().downcast_ref::<RoundError>() {
                    Some(RoundError::Deadline { .. }) => RoundStatus::Deadline,
                    Some(RoundError::Hopeless { .. }) => RoundStatus::Hopeless,
                    Some(RoundError::Forged { .. }) => RoundStatus::Forged,
                    _ => RoundStatus::SubmitFailed,
                };
                digest.u64(r);
                digest.write(&[status.code(), 0]);
                digest.u64(0);
                RoundRecord {
                    round: r,
                    status,
                    results_used: 0,
                    degraded: false,
                    rel_err: None,
                    wall_ms: 0.0,
                }
            }
        };
        records.push(record);
    }

    // Transport totals are deterministic (credited synchronously at
    // dispatch and decode), so they belong in the digest — and so does
    // the recovered-share count, which is driven by the fault schedule,
    // not the clock. Redispatch/wasted tallies race the deadline
    // checkpoint and stay out.
    let bytes_tx = metrics.get(names::BYTES_TX);
    let bytes_rx = metrics.get(names::BYTES_RX);
    digest.u64(bytes_tx);
    digest.u64(bytes_rx);
    digest.u64(stream.recovered);
    // Forgery detections are booked at submit from the fault plan — a
    // pure function of the scenario, so they belong in the digest. The
    // quarantine/rehabilitation/checked tallies are shaped by frame
    // arrival order and stay out (CI asserts on them separately).
    digest.u64(metrics.get(names::VERIFY_FORGED_DETECTED));

    // Eavesdropper analysis: for each charted downlink payload, the best
    // |correlation| against any plaintext block of its round.
    let mut leak_sum = 0.0;
    let mut leak_n = 0usize;
    for msg in tap.messages().iter().filter(|m| m.downlink) {
        let Some(blocks) = round_blocks.get((msg.round as usize).wrapping_sub(1)) else {
            continue;
        };
        let best = blocks
            .iter()
            .filter(|b| b.shape() == msg.payload.shape())
            .map(|b| correlation_of(b, &msg.payload).abs())
            .fold(0.0f64, f64::max);
        leak_sum += best;
        leak_n += 1;
    }

    // Tear the cluster down *before* assembling the report so a process
    // fabric's teardown exits (SIGTERM → exit, or escalation) land in
    // the log too; the handle outlives the supervisor. In-process
    // fabrics have no log and report an empty list.
    let exit_log = master.exit_log();
    let final_generations = master.worker_generations();
    drop(master);
    let process_exits: Vec<ExitRecord> =
        exit_log.map_or_else(Vec::new, |log| log.lock().unwrap().clone());

    let wall = metrics.histogram("scenario.round_wall_s").unwrap_or_default();
    let ok_rounds = records.iter().filter(|r| r.status == RoundStatus::Ok).count();
    let degraded_rounds = records.iter().filter(|r| r.degraded).count() as u64;
    Ok(ScenarioReport {
        scenario: sc.name.clone(),
        scheme: sc.scheme.name().to_string(),
        op: sc.op.name().to_string(),
        transport: transport.name().to_string(),
        threads,
        inflight,
        speculate,
        seed: sc.seed,
        workers: sc.workers,
        rounds: sc.rounds,
        digest: digest.hex(),
        recovery_hit_rate: ok_rounds as f64 / sc.rounds as f64,
        wall_mean_ms: wall.mean() * 1e3,
        wall_p50_ms: wall.p50() * 1e3,
        wall_p99_ms: wall.p99() * 1e3,
        wall_max_ms: wall.max().max(0.0) * 1e3,
        bytes_tx,
        bytes_rx,
        wire_errors: metrics.get(names::WIRE_ERRORS),
        results_late: metrics.get(names::RESULTS_LATE),
        downlink_messages: leak_n,
        downlink_leak: if leak_n == 0 { 0.0 } else { leak_sum / leak_n as f64 },
        colluder_shares: coalition.map_or(0, |c| c.gathered().len()),
        crashes: metrics.get(names::WORKER_CRASHES),
        respawns: metrics.get(names::WORKER_RESPAWNS),
        degraded_rounds,
        final_generations,
        rounds_per_s: stream.rounds_per_s,
        occupancy_mean: stream.occupancy_mean,
        occupancy_max: stream.occupancy_max,
        tenants: 1,
        tenant_inflight: inflight,
        tenant_stats: Vec::new(),
        spec_redispatched: stream.redispatched,
        spec_recovered: stream.recovered,
        spec_wasted: stream.wasted,
        verify_checked: metrics.get(names::VERIFY_CHECKED),
        verify_forged_detected: metrics.get(names::VERIFY_FORGED_DETECTED),
        verify_quarantined: metrics.get(names::VERIFY_QUARANTINED),
        verify_rehabilitated: metrics.get(names::VERIFY_REHABILITATED),
        process_exits,
        records,
    })
}

/// The multi-tenant arm of [`run_scenario_with`]: one session lane per
/// tenant over one fleet through the serving front end (module docs).
/// Each lane's data, encode masks, and seal salts derive from the
/// tenant's own seed stream, so each per-tenant digest — and through
/// them the report digest — is invariant across transports, thread
/// widths, the global cap, and lane interleaving.
#[allow(clippy::too_many_arguments)]
fn run_multi_tenant(
    sc: &Scenario,
    transport: TransportKind,
    threads: usize,
    inflight: usize,
    speculate: bool,
    metrics: Arc<MetricsRegistry>,
    tap: Arc<EavesdropLog>,
    coalition: Option<Arc<CollusionPool>>,
    mut master: Master,
) -> anyhow::Result<ScenarioReport> {
    let tenants = sc.tenants;
    let tenant_inflight =
        if sc.tenant_inflight == 0 { inflight } else { sc.tenant_inflight };
    let worker_op = match sc.op {
        ScenarioOp::Gram => WorkerOp::Gram,
        ScenarioOp::Identity => WorkerOp::Identity,
    };

    // Pre-draw every tenant's data from its own seed stream (the same
    // per-round derivation the single-tenant path uses, rooted at the
    // tenant seed instead of the scenario seed).
    let mut tenant_seeds = Vec::with_capacity(tenants);
    let mut tenant_tasks: Vec<Vec<CodedTask>> = Vec::with_capacity(tenants);
    let mut tenant_blocks: Vec<Vec<Vec<Matrix>>> = Vec::with_capacity(tenants);
    for t in 0..tenants {
        let tenant_seed = derive_seed(sc.seed, TENANT_SEED_STREAM ^ t as u64);
        tenant_seeds.push(tenant_seed);
        let mut tasks = Vec::with_capacity(sc.rounds as usize);
        let mut blocks_by_round = Vec::with_capacity(sc.rounds as usize);
        for r in 1..=sc.rounds {
            let mut data_rng = rng_from_seed(derive_seed(tenant_seed, 0xDA7A_0000 + r));
            let x = Matrix::random_gaussian(sc.rows, sc.cols, 0.0, 1.0, &mut data_rng);
            let (blocks, _) = split_rows(&x, sc.partitions);
            tasks.push(CodedTask::block_map(worker_op.clone(), x));
            blocks_by_round.push(blocks);
        }
        tenant_tasks.push(tasks);
        tenant_blocks.push(blocks_by_round);
    }

    let mut svc = master.service(ServiceConfig { global_inflight: inflight, speculate });
    for (t, tasks) in tenant_tasks.into_iter().enumerate() {
        svc.open_iter(
            &format!("tenant-{t}"),
            SessionOptions {
                inflight: tenant_inflight,
                seed: Some(tenant_seeds[t]),
                ..Default::default()
            },
            tasks.into_iter(),
        );
    }
    let out = svc.run();

    // The report digest chains the per-tenant digests; each tenant's
    // digest folds its rounds by *lane-local* index, so neither moves
    // when the dispatcher interleaves lanes differently.
    let mut digest = Fnv64::new();
    digest.write(b"scenario-digest-v3");
    digest.write(sc.name.as_bytes());
    digest.u64(sc.seed);
    digest.u64(sc.rounds);
    digest.u64(sc.workers as u64);
    digest.u64(tenants as u64);

    let exact = |b: &Matrix| match sc.op {
        ScenarioOp::Gram => gram(b),
        ScenarioOp::Identity => b.clone(),
    };
    let mut records = Vec::with_capacity(tenants * sc.rounds as usize);
    let mut tenant_stats = Vec::with_capacity(tenants);
    // Global round id → (tenant, lane-local index), for the leak
    // analysis (the tap charts payloads by global round).
    let mut round_owner: HashMap<u64, (usize, usize)> = HashMap::new();
    for (t, stats) in out.tenants.iter().enumerate() {
        let mut td = Fnv64::new();
        td.write(b"tenant-digest-v1");
        td.write(sc.name.as_bytes());
        td.u64(tenant_seeds[t]);
        td.u64(sc.rounds);
        td.u64(sc.workers as u64);
        for sr in &out.rounds[t] {
            let r = sr.index as u64 + 1;
            if sr.round != 0 {
                round_owner.insert(sr.round, (t, sr.index));
            }
            match &sr.outcome {
                Ok(done) => {
                    let rel_err = done
                        .blocks
                        .iter()
                        .zip(&tenant_blocks[t][sr.index])
                        .map(|(d, b)| d.rel_error(&exact(b)))
                        .fold(0.0f64, f64::max);
                    td.u64(r);
                    td.write(&[RoundStatus::Ok.code(), done.degraded as u8]);
                    td.u64(done.results_used as u64);
                    for m in &done.blocks {
                        td.u64(m.rows() as u64);
                        td.u64(m.cols() as u64);
                        for v in m.as_slice() {
                            td.write(&v.to_bits().to_le_bytes());
                        }
                    }
                    metrics.record("scenario.round_wall_s", done.wall.as_secs_f64());
                    records.push(RoundRecord {
                        round: sr.round,
                        status: RoundStatus::Ok,
                        results_used: done.results_used,
                        degraded: done.degraded,
                        rel_err: Some(rel_err),
                        wall_ms: done.wall.as_secs_f64() * 1e3,
                    });
                }
                Err(e) => {
                    let status = match e.inner().downcast_ref::<RoundError>() {
                        Some(RoundError::Deadline { .. }) => RoundStatus::Deadline,
                        Some(RoundError::Hopeless { .. }) => RoundStatus::Hopeless,
                        Some(RoundError::Forged { .. }) => RoundStatus::Forged,
                        _ => RoundStatus::SubmitFailed,
                    };
                    td.u64(r);
                    td.write(&[status.code(), 0]);
                    td.u64(0);
                    records.push(RoundRecord {
                        round: sr.round,
                        status,
                        results_used: 0,
                        degraded: false,
                        rel_err: None,
                        wall_ms: 0.0,
                    });
                }
            }
        }
        digest.u64(td.0);
        tenant_stats.push(TenantStat {
            tenant: t,
            name: stats.name.clone(),
            digest: td.hex(),
            rounds: stats.rounds,
            decoded: stats.decoded,
            degraded: stats.degraded,
            failed: stats.failed,
            refused: stats.refused,
            rounds_per_s: stats.rounds_per_s,
            p50_ms: stats.p50_ms,
            p99_ms: stats.p99_ms,
            occupancy_mean: stats.occupancy_mean,
            occupancy_max: stats.occupancy_max,
        });
    }
    let bytes_tx = metrics.get(names::BYTES_TX);
    let bytes_rx = metrics.get(names::BYTES_RX);
    // Transport totals stay digest material: dispatch sets are
    // schedule-pure, fault bookings key on identities that do not move
    // with interleaving (lane streams and wall-rounds-served), and
    // speculative re-dispatch resends a retained payload of fixed
    // size — so the byte totals cannot move with interleaving.
    digest.u64(bytes_tx);
    digest.u64(bytes_rx);
    digest.u64(out.recovered);
    digest.u64(metrics.get(names::VERIFY_FORGED_DETECTED));

    let mut leak_sum = 0.0;
    let mut leak_n = 0usize;
    for msg in tap.messages().iter().filter(|m| m.downlink) {
        let Some(&(t, i)) = round_owner.get(&msg.round) else {
            continue;
        };
        let best = tenant_blocks[t][i]
            .iter()
            .filter(|b| b.shape() == msg.payload.shape())
            .map(|b| correlation_of(b, &msg.payload).abs())
            .fold(0.0f64, f64::max);
        leak_sum += best;
        leak_n += 1;
    }

    let exit_log = master.exit_log();
    let final_generations = master.worker_generations();
    drop(master);
    let process_exits: Vec<ExitRecord> =
        exit_log.map_or_else(Vec::new, |log| log.lock().unwrap().clone());

    let wall = metrics.histogram("scenario.round_wall_s").unwrap_or_default();
    let total_rounds = sc.rounds * tenants as u64;
    let ok_rounds = records.iter().filter(|r| r.status == RoundStatus::Ok).count();
    let degraded_rounds = records.iter().filter(|r| r.degraded).count() as u64;
    // Present the interleaved soak in global submit order.
    records.sort_by_key(|r| r.round);
    Ok(ScenarioReport {
        scenario: sc.name.clone(),
        scheme: sc.scheme.name().to_string(),
        op: sc.op.name().to_string(),
        transport: transport.name().to_string(),
        threads,
        inflight,
        speculate,
        seed: sc.seed,
        workers: sc.workers,
        rounds: total_rounds,
        digest: digest.hex(),
        recovery_hit_rate: ok_rounds as f64 / total_rounds as f64,
        wall_mean_ms: wall.mean() * 1e3,
        wall_p50_ms: wall.p50() * 1e3,
        wall_p99_ms: wall.p99() * 1e3,
        wall_max_ms: wall.max().max(0.0) * 1e3,
        bytes_tx,
        bytes_rx,
        wire_errors: metrics.get(names::WIRE_ERRORS),
        results_late: metrics.get(names::RESULTS_LATE),
        downlink_messages: leak_n,
        downlink_leak: if leak_n == 0 { 0.0 } else { leak_sum / leak_n as f64 },
        colluder_shares: coalition.map_or(0, |c| c.gathered().len()),
        crashes: metrics.get(names::WORKER_CRASHES),
        respawns: metrics.get(names::WORKER_RESPAWNS),
        degraded_rounds,
        final_generations,
        rounds_per_s: out.rounds_per_s,
        occupancy_mean: out.occupancy_mean,
        occupancy_max: out.occupancy_max,
        tenants,
        tenant_inflight,
        tenant_stats,
        spec_redispatched: out.redispatched,
        spec_recovered: out.recovered,
        spec_wasted: out.wasted,
        verify_checked: metrics.get(names::VERIFY_CHECKED),
        verify_forged_detected: metrics.get(names::VERIFY_FORGED_DETECTED),
        verify_quarantined: metrics.get(names::VERIFY_QUARANTINED),
        verify_rehabilitated: metrics.get(names::VERIFY_REHABILITATED),
        process_exits,
        records,
    })
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl ScenarioReport {
    /// Render the report as pretty-printed JSON (hand-rolled — this
    /// environment has no serde).
    pub fn to_json(&self) -> String {
        let rounds: Vec<String> = self
            .records
            .iter()
            .map(|r| {
                let rel = match r.rel_err {
                    Some(e) => format!("{e:.6}"),
                    None => "null".to_string(),
                };
                format!(
                    "    {{\"round\": {}, \"status\": \"{}\", \"results_used\": {}, \
                     \"degraded\": {}, \"rel_err\": {}, \"wall_ms\": {:.3}}}",
                    r.round,
                    r.status.name(),
                    r.results_used,
                    r.degraded,
                    rel,
                    r.wall_ms
                )
            })
            .collect();
        let generations: Vec<String> =
            self.final_generations.iter().map(|g| g.to_string()).collect();
        let exits: Vec<String> = self
            .process_exits
            .iter()
            .map(|e| {
                let code = e.code.map_or("null".to_string(), |c| c.to_string());
                let signal = e.signal.map_or("null".to_string(), |s| s.to_string());
                format!(
                    "    {{\"worker\": {}, \"generation\": {}, \"pid\": {}, \"code\": {}, \
                     \"signal\": {}, \"cause\": \"{}\"}}",
                    e.worker,
                    e.generation,
                    e.pid,
                    code,
                    signal,
                    e.cause.name()
                )
            })
            .collect();
        let sigkilled = self.process_exits.iter().filter(|e| e.sigkilled()).count();
        let process_section = format!(
            "\"process\": {{\"sigkilled\": {}, \"exits\": [\n{}\n  ]}},\n  ",
            sigkilled,
            exits.join(",\n")
        );
        let per_tenant: Vec<String> = self
            .tenant_stats
            .iter()
            .map(|t| {
                format!(
                    "    {{\"tenant\": {}, \"name\": \"{}\", \"digest\": \"{}\", \
                     \"rounds\": {}, \"decoded\": {}, \"degraded\": {}, \"failed\": {}, \
                     \"refused\": {}, \"rounds_per_s\": {:.3}, \"p50_ms\": {:.3}, \
                     \"p99_ms\": {:.3}, \"occupancy_mean\": {:.3}, \"occupancy_max\": {}}}",
                    t.tenant,
                    json_escape(&t.name),
                    t.digest,
                    t.rounds,
                    t.decoded,
                    t.degraded,
                    t.failed,
                    t.refused,
                    t.rounds_per_s,
                    t.p50_ms,
                    t.p99_ms,
                    t.occupancy_mean,
                    t.occupancy_max
                )
            })
            .collect();
        let tenants_section = format!(
            "\"tenants\": {{\"count\": {}, \"inflight\": {}, \"per_tenant\": [{}]}},\n  ",
            self.tenants,
            self.tenant_inflight,
            if per_tenant.is_empty() {
                String::new()
            } else {
                format!("\n{}\n  ", per_tenant.join(",\n"))
            }
        );
        format!(
            "{{\n  \"schema\": \"scenario-report-v4\",\n  \"scenario\": \"{}\",\n  \
             \"scheme\": \"{}\",\n  \"op\": \"{}\",\n  \"transport\": \"{}\",\n  \
             \"threads\": {},\n  \"seed\": {},\n  \"workers\": {},\n  \"rounds\": {},\n  \
             \"digest\": \"{}\",\n  \"recovery_hit_rate\": {:.4},\n  \
             \"stream\": {{\"inflight\": {}, \"speculate\": {}, \"rounds_per_s\": {:.3}, \
             \"occupancy_mean\": {:.3}, \"occupancy_max\": {}}},\n  \
             {tenants_section}\
             \"speculation\": {{\"redispatched\": {}, \"recovered\": {}, \"wasted\": {}}},\n  \
             \"verify\": {{\"checked\": {}, \"forged_detected\": {}, \"quarantined\": {}, \
             \"rehabilitated\": {}}},\n  \
             \"wall_ms\": {{\"mean\": {:.3}, \"p50\": {:.3}, \"p99\": {:.3}, \"max\": {:.3}}},\n  \
             \"comm\": {{\"bytes_tx\": {}, \"bytes_rx\": {}, \"wire_errors\": {}, \
             \"results_late\": {}}},\n  \
             \"privacy\": {{\"downlink_messages\": {}, \"downlink_leak\": {:.6}, \
             \"colluder_shares\": {}}},\n  \
             \"lifecycle\": {{\"crashes\": {}, \"respawns\": {}, \"degraded_rounds\": {}, \
             \"final_generations\": [{}]}},\n  \
             {process_section}\
             \"per_round\": [\n{}\n  ]\n}}\n",
            json_escape(&self.scenario),
            self.scheme,
            self.op,
            self.transport,
            self.threads,
            self.seed,
            self.workers,
            self.rounds,
            self.digest,
            self.recovery_hit_rate,
            self.inflight,
            self.speculate,
            self.rounds_per_s,
            self.occupancy_mean,
            self.occupancy_max,
            self.spec_redispatched,
            self.spec_recovered,
            self.spec_wasted,
            self.verify_checked,
            self.verify_forged_detected,
            self.verify_quarantined,
            self.verify_rehabilitated,
            self.wall_mean_ms,
            self.wall_p50_ms,
            self.wall_p99_ms,
            self.wall_max_ms,
            self.bytes_tx,
            self.bytes_rx,
            self.wire_errors,
            self.results_late,
            self.downlink_messages,
            self.downlink_leak,
            self.colluder_shares,
            self.crashes,
            self.respawns,
            self.degraded_rounds,
            generations.join(", "),
            rounds.join(",\n"),
        )
    }

    /// One-line-per-round console table.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "scenario {} · scheme {} · transport {} · threads {} · inflight {} · \
             speculate {} · seed {}\n",
            self.scenario,
            self.scheme,
            self.transport,
            self.threads,
            self.inflight,
            self.speculate,
            self.seed
        ));
        out.push_str(&format!(
            "{:>5}  {:<13} {:>7} {:>9} {:>10} {:>9}\n",
            "round", "status", "used", "degraded", "rel_err", "wall(ms)"
        ));
        for r in &self.records {
            let rel = r.rel_err.map_or("-".to_string(), |e| format!("{e:.4}"));
            out.push_str(&format!(
                "{:>5}  {:<13} {:>7} {:>9} {:>10} {:>9.2}\n",
                r.round,
                r.status.name(),
                r.results_used,
                r.degraded,
                rel,
                r.wall_ms
            ));
        }
        out.push_str(&format!(
            "recovery {:.0}% · degraded {} · crashes {} · respawns {} · \
             tx {} B · rx {} B · wire errors {} · leak {:.4}\n",
            self.recovery_hit_rate * 100.0,
            self.degraded_rounds,
            self.crashes,
            self.respawns,
            self.bytes_tx,
            self.bytes_rx,
            self.wire_errors,
            self.downlink_leak,
        ));
        out.push_str(&format!(
            "stream: {:.2} rounds/s · occupancy {:.2} mean / {} peak · \
             speculation redispatched {} / recovered {} / wasted {}\n",
            self.rounds_per_s,
            self.occupancy_mean,
            self.occupancy_max,
            self.spec_redispatched,
            self.spec_recovered,
            self.spec_wasted,
        ));
        for t in &self.tenant_stats {
            out.push_str(&format!(
                "tenant {}: {} rounds ({} decoded, {} failed) · {:.2} rounds/s · \
                 p50 {:.2} ms · p99 {:.2} ms · refused {} · digest {}\n",
                t.tenant,
                t.rounds,
                t.decoded,
                t.failed,
                t.rounds_per_s,
                t.p50_ms,
                t.p99_ms,
                t.refused,
                t.digest,
            ));
        }
        if self.verify_checked > 0 || self.verify_forged_detected > 0 {
            out.push_str(&format!(
                "verify: checked {} · forged detected {} · quarantined {} · rehabilitated {}\n",
                self.verify_checked,
                self.verify_forged_detected,
                self.verify_quarantined,
                self.verify_rehabilitated,
            ));
        }
        if !self.process_exits.is_empty() {
            let sigkilled = self.process_exits.iter().filter(|e| e.sigkilled()).count();
            out.push_str(&format!(
                "process: {} child exits recorded ({} by SIGKILL)\n",
                self.process_exits.len(),
                sigkilled
            ));
        }
        out.push_str(&format!("digest: {}\n", self.digest));
        out
    }
}
