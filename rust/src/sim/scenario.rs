//! Declarative scenarios: the experiment apparatus as *data*.
//!
//! A [`Scenario`] bundles everything that defines one adversity soak —
//! cluster shape, coding scheme, straggler distribution, a crash/respawn
//! schedule, a colluder set, a wire-corruption rate, the task shape, and
//! the round count — so CI can run the same condition as a matrix over
//! execution knobs (transport fabric, thread-pool width) and pin the
//! results. Scenarios load from three places, in priority order:
//!
//! 1. an explicit TOML-subset file path (`--scenario path/to/x.toml`),
//! 2. `scenarios/<name>.toml` relative to the working directory,
//! 3. a compiled-in builtin of the same name ([`Scenario::builtin`]).
//!
//! The repo ships the builtins mirrored as files under
//! `rust/scenarios/`; an integration test pins file ≡ builtin so the two
//! sources cannot drift.
//!
//! **Determinism contract** (same as `parallel/`, see DESIGN.md §7):
//! every random choice in a scenario run — per-round data, straggler
//! jitter, respawned key pairs, corruption draws — derives from
//! `Scenario::seed`, never from time or thread scheduling. Execution
//! knobs (transport, threads) may change wall-clock but must not change
//! a single decoded bit; the scenario report's digest pins exactly the
//! fields that obey this contract.
//!
//! [`FaultPlan`] is the scenario's fault schedule compiled to the form
//! the runtime consumes: worker threads ask it "do I crash on this
//! round?" / "do I corrupt this result?", and the master asks the same
//! questions to keep its partial-failure accounting in lock-step with
//! what the workers will actually do — both sides read one plan, so
//! neither needs to observe the other.
//!
//! Since PR 10 the plan's queries key on a [`FaultKey`] identity
//! (DESIGN.md §13) instead of the global round id alone: `served` keys
//! every class on `(worker, wall_rounds_served)` and `lane` keys the
//! corruption/forgery draws on `(worker, lane, lane_local_round)` — so
//! fault schedules compose with the multi-tenant serving front end,
//! where lane interleaving reassigns global round ids. The coordinates
//! ride each [`WorkOrder`](crate::coordinator::WorkOrder), so the
//! master's pre-booking and the worker's evaluation read the same
//! numbers by construction. `fault_key = "global"` reproduces the
//! pre-PR-10 draws bit for bit.

use crate::config::{parse_str, ConfigError, DelayConfig, SchemeKind, TransportSecurity};
use crate::rng::{derive_seed, rng_from_seed};

/// One scheduled worker crash, optionally followed by a respawn.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashEvent {
    /// Which worker crashes.
    pub worker: usize,
    /// The round *mid-which* it crashes: the worker receives that
    /// round's order and vanishes without replying. Under
    /// [`FaultKey::Global`] this is the global round id; under the
    /// `served`/`lane` keys it is the worker's wall-rounds-served count
    /// (its Nth serviced order) — identical numbers for any
    /// single-tenant soak where the worker was alive throughout.
    pub round: u64,
    /// Respawn `Some(d)` rounds after the crash (the new incarnation
    /// rejoins before round `round + d` is submitted); `None` = stays
    /// dead. Under the `served`/`lane` keys `d` counts *global* rounds
    /// from the round the crash actually booked on (the master keeps a
    /// due ledger), so the dead window has the same length either way.
    pub respawn_after: Option<u64>,
}

/// Which identity a [`FaultPlan`]'s queries key on (DESIGN.md §13).
///
/// `Global` is the pre-PR-10 behaviour: every class keys on the global
/// round id, which only makes sense when one tenant owns the whole
/// round sequence. `Served` keys every class on `(worker,
/// wall_rounds_served)` — stable under lane interleaving because every
/// submitted round dispatches one share to every live worker. `Lane`
/// additionally keys the corruption/forgery draws on `(worker, lane,
/// lane_local_round)`, making a tenant's adversarial exposure a pure
/// function of its own stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKey {
    /// Legacy: everything keys on the global round id.
    Global,
    /// Crashes, respawns, straggler floors, corruption, and forgery all
    /// key on `(worker, wall_rounds_served)`.
    Served,
    /// Crashes/respawns/straggler floors key on `(worker,
    /// wall_rounds_served)`; corruption/forgery draws key on `(worker,
    /// lane, lane_local_round)`.
    Lane,
}

impl FaultKey {
    /// Parse the `faults.key` / `--fault-key` token.
    pub fn from_token(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "global" => Some(Self::Global),
            "served" => Some(Self::Served),
            "lane" => Some(Self::Lane),
            _ => None,
        }
    }

    /// Canonical token.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Global => "global",
            Self::Served => "served",
            Self::Lane => "lane",
        }
    }
}

/// The per-order coordinates a fault draw may key on. The master fills
/// these at dispatch (it owns the served counters and the lane map) and
/// ships them on the [`WorkOrder`](crate::coordinator::WorkOrder), so a
/// worker evaluating the plan reads exactly the numbers the master's
/// pre-booking used — lock-step by construction, whatever the key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultCoords {
    /// Global round id (1-based).
    pub round: u64,
    /// Wall rounds served by the order's worker slot, 1-based and
    /// counting this order — cumulative across respawned incarnations.
    pub served: u64,
    /// Session lane the round belongs to (0 for single-tenant paths).
    pub lane: u32,
    /// Lane-local round index (1-based position in the lane's stream).
    pub lane_round: u64,
}

impl FaultCoords {
    /// Coordinates for a context with no lane structure and no crash
    /// history: served count and lane-local index coincide with the
    /// global round. Under `Global` and `Served` keys these give
    /// identical draws — the shape every pre-session call site had.
    pub fn global(round: u64) -> Self {
        Self { round, served: round, lane: 0, lane_round: round }
    }
}

/// The per-round task the scenario drives through the master.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioOp {
    /// `f(X) = XXᵀ` per block (degree 2 — SPACDC/BACC/LCC territory).
    Gram,
    /// `f(X) = X` per block (linear — every scheme serves it).
    Identity,
}

impl ScenarioOp {
    fn from_token(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gram" => Some(Self::Gram),
            "identity" => Some(Self::Identity),
            _ => None,
        }
    }

    /// Canonical token.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Gram => "gram",
            Self::Identity => "identity",
        }
    }
}

/// A declarative adversity scenario (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Scenario name (reported, and part of the digest preimage).
    pub name: String,
    /// Number of coded rounds in the soak.
    pub rounds: u64,
    /// Data rows per round.
    pub rows: usize,
    /// Data columns per round.
    pub cols: usize,
    /// The per-round task.
    pub op: ScenarioOp,
    /// Root seed: every random choice in the run derives from it.
    pub seed: u64,
    /// Cluster size N.
    pub workers: usize,
    /// Partitions K.
    pub partitions: usize,
    /// Privacy masks T.
    pub colluders: usize,
    /// Stragglers S (chosen by seed, delayed per `delay`).
    pub stragglers: usize,
    /// Coding scheme under test.
    pub scheme: SchemeKind,
    /// Payload sealing.
    pub security: TransportSecurity,
    /// Per-round collection deadline.
    pub round_deadline_s: f64,
    /// Straggler delay distribution.
    pub delay: DelayConfig,
    /// Colluding worker indices (deposit their plaintext shares).
    pub colluder_set: Vec<usize>,
    /// Crash/respawn schedule.
    pub crashes: Vec<CrashEvent>,
    /// Probability that a worker's result frame is corrupted on the
    /// wire (drawn deterministically per (worker, round) from `seed`).
    pub corrupt_rate: f64,
    /// Byzantine workers: members return *well-formed but wrong*
    /// results (forged payload, tampered commitment echo) on the rounds
    /// their seeded draw fires — unlike `corrupt_rate`'s bit flips,
    /// these pass every CRC and must be caught by the verification
    /// layer (DESIGN.md §11).
    pub forger_set: Vec<usize>,
    /// Probability that a forger-set worker forges a given round (drawn
    /// deterministically per (worker, round) from `seed`).
    pub forge_rate: f64,
    /// Declared Byzantine budget F (`[cluster] forgers`): how many
    /// workers the scenario *claims* are forgers. When non-zero, a
    /// non-empty `forger_set` must have exactly this many members —
    /// the mirror of the `colluder_set`/`colluders` agreement check.
    /// 0 = no declared budget (the set alone defines the adversary).
    pub forgers: usize,
    /// Round-stream window the soak drives (`[stream] inflight`; ≥ 1,
    /// 1 = synchronous). An execution knob may override it — the digest
    /// must not move when it does (DESIGN.md §8). With `tenants > 1`
    /// this is the service's *global* in-flight cap.
    pub inflight: usize,
    /// Speculative re-dispatch of outstanding shares (`[stream]
    /// speculate`).
    pub speculate: bool,
    /// Concurrent tenants sharing the fleet (`[tenants] count`; ≥ 1).
    /// Each tenant streams its own `rounds` rounds through one session
    /// lane of the serving front end (DESIGN.md §12), with per-tenant
    /// data and RNG streams derived from `seed` — so every tenant's
    /// digest is bit-identical to its solo run.
    pub tenants: usize,
    /// Per-tenant in-flight window (`[tenants] inflight`; 0 = inherit
    /// the stream window `inflight`).
    pub tenant_inflight: usize,
    /// Which identity the fault schedule keys on (`[faults] key`,
    /// default `served`). `global` reproduces the pre-PR-10 draws bit
    /// for bit but is rejected with faults under `tenants > 1`.
    pub fault_key: FaultKey,
}

impl Scenario {
    /// The skeleton every builtin starts from.
    fn base(name: &str) -> Self {
        Self {
            name: name.to_string(),
            rounds: 8,
            rows: 96,
            cols: 48,
            op: ScenarioOp::Gram,
            seed: 0x5CE0,
            workers: 8,
            partitions: 4,
            colluders: 2,
            stragglers: 0,
            scheme: SchemeKind::Spacdc,
            security: TransportSecurity::MeaEcc,
            round_deadline_s: 30.0,
            delay: DelayConfig { straggler_factor: 25.0, base_service_s: 0.002, jitter: 0.1 },
            colluder_set: Vec::new(),
            crashes: Vec::new(),
            corrupt_rate: 0.0,
            forger_set: Vec::new(),
            forge_rate: 0.0,
            forgers: 0,
            inflight: 1,
            speculate: false,
            tenants: 1,
            tenant_inflight: 0,
            fault_key: FaultKey::Served,
        }
    }

    /// The compiled-in named scenarios (mirrored under `rust/scenarios/`).
    pub fn builtin(name: &str) -> Option<Self> {
        match name {
            // Happy path: every worker healthy, every result used.
            "baseline" => Some(Self::base("baseline")),
            // Churn: two mid-round crashes with staggered respawns plus
            // a light wire-corruption rate — rounds degrade to "decode
            // from what arrived" and recover once incarnations rejoin.
            "crash-respawn" => {
                let mut sc = Self::base("crash-respawn");
                sc.rounds = 12;
                sc.seed = 0x5CE1;
                sc.workers = 10;
                sc.partitions = 3;
                sc.crashes = vec![
                    CrashEvent { worker: 2, round: 3, respawn_after: Some(2) },
                    CrashEvent { worker: 5, round: 4, respawn_after: Some(3) },
                ];
                sc.corrupt_rate = 0.06;
                Some(sc)
            }
            // The paper's adversary mix: T colluding workers pool their
            // shares while S stragglers ride the flexible threshold.
            // The digest pins the decode *set* (the N − S fast returns),
            // so the straggler delay (~500 ms vs ~2 ms fast service) is
            // deliberately enormous: even a badly descheduled CI runner
            // cannot let a straggler into the first N − S arrivals.
            "colluders-stragglers" => {
                let mut sc = Self::base("colluders-stragglers");
                sc.rounds = 10;
                sc.seed = 0x5CE2;
                sc.workers = 12;
                sc.colluders = 3;
                sc.stragglers = 3;
                sc.colluder_set = vec![1, 4, 7];
                sc.delay.straggler_factor = 250.0;
                Some(sc)
            }
            // The round-stream soak: a 16-wide in-flight window over a
            // worker fabric whose service delay dominates the master's
            // per-round work (so windowing visibly raises throughput),
            // two mid-stream crash/respawn cycles, and speculation on —
            // the crashed workers' shares are re-dispatched and
            // recovered instead of degrading their rounds. No
            // stragglers and no wire corruption: the decode set must be
            // pinned by the schedule alone, so the digest holds across
            // `inflight ∈ {1, 4, 16}`, both transports, and any
            // thread-pool width.
            "stream" => {
                let mut sc = Self::base("stream");
                sc.rounds = 12;
                sc.rows = 64;
                sc.cols = 32;
                sc.seed = 0x5CE3;
                sc.workers = 8;
                sc.partitions = 4;
                sc.colluders = 2;
                sc.stragglers = 0;
                sc.delay = DelayConfig {
                    straggler_factor: 1.0,
                    base_service_s: 0.004,
                    jitter: 0.1,
                };
                sc.crashes = vec![
                    CrashEvent { worker: 3, round: 4, respawn_after: Some(3) },
                    CrashEvent { worker: 6, round: 8, respawn_after: Some(3) },
                ];
                sc.inflight = 16;
                sc.speculate = true;
                Some(sc)
            }
            // Byzantine forgers: two workers return well-formed wrong
            // results on roughly half their rounds. The master books
            // each planned forgery as a lost share at submit and
            // re-dispatches it speculatively to a non-suspect executor;
            // the collector's commitment check is what keeps the forged
            // copy from winning the race home — every forged round must
            // decode correctly from the honest copies, never silently
            // wrong. No stragglers and no corruption, so the decode set
            // is pinned by the schedule alone and the digest holds
            // across transports, thread counts, and window widths.
            "forgers" => {
                let mut sc = Self::base("forgers");
                sc.rounds = 10;
                sc.rows = 64;
                sc.cols = 32;
                sc.seed = 0x5CE4;
                sc.workers = 8;
                sc.partitions = 4;
                sc.colluders = 2;
                sc.stragglers = 0;
                sc.delay = DelayConfig {
                    straggler_factor: 1.0,
                    base_service_s: 0.004,
                    jitter: 0.1,
                };
                sc.forger_set = vec![2, 5];
                sc.forge_rate = 0.55;
                sc.forgers = 2;
                sc.inflight = 4;
                sc.speculate = true;
                Some(sc)
            }
            // The multi-tenant saturation soak: four tenants share one
            // fleet through the serving front end, each streaming its
            // own 8 rounds at a 4-wide window under a 16-wide global
            // cap. Fault-free and straggler-free by design: every
            // tenant's decode set is then pinned by its own schedule,
            // so each per-tenant digest is bit-identical to that
            // tenant's solo run (the isolation contract the report
            // pins), while the aggregate throughput exercises admission
            // control and the deficit-round-robin dispatcher.
            "tenants" => {
                let mut sc = Self::base("tenants");
                sc.rounds = 8;
                sc.rows = 48;
                sc.cols = 24;
                sc.seed = 0x5CE5;
                sc.workers = 8;
                sc.partitions = 4;
                sc.colluders = 2;
                sc.stragglers = 0;
                sc.delay = DelayConfig {
                    straggler_factor: 1.0,
                    base_service_s: 0.002,
                    jitter: 0.1,
                };
                sc.tenants = 4;
                sc.tenant_inflight = 4;
                sc.inflight = 16;
                Some(sc)
            }
            // Faults composed with the serving front end — the proof
            // PR 10 exists for: four tenants share a 10-worker fleet
            // while one worker crashes mid-stream and respawns and one
            // Byzantine worker forges about half its rounds. The fault
            // key is `lane`, so each tenant's forgery exposure is a
            // pure function of its own (lane, lane-round) stream, and
            // crashes plus jitter floors key on wall-rounds-served —
            // stable however the lanes interleave. Two knobs keep the
            // decode set identical on every round, which is what makes
            // each per-tenant digest invariant under re-interleaving:
            // S = 0 means decode waits for every dispatched share (no
            // race between a straggling original and a speculative
            // proxy for the last decode slot), and `respawn_after = 1`
            // brings the crashed worker back before the next dispatch
            // so no round ever runs a worker short. Speculation then
            // re-covers the crashed and forged shares onto live
            // executors, so every round decodes the full N-share set
            // and both the scenario digest and every per-tenant digest
            // hold across transports, thread widths, and both window
            // knobs (global cap and per-tenant).
            "tenants-faults" => {
                let mut sc = Self::base("tenants-faults");
                sc.rounds = 8;
                sc.rows = 48;
                sc.cols = 24;
                sc.seed = 0x5CE6;
                sc.workers = 10;
                sc.partitions = 4;
                sc.colluders = 2;
                sc.stragglers = 0;
                sc.delay = DelayConfig {
                    straggler_factor: 1.0,
                    base_service_s: 0.002,
                    jitter: 0.1,
                };
                sc.crashes = vec![CrashEvent { worker: 2, round: 3, respawn_after: Some(1) }];
                sc.forger_set = vec![5];
                sc.forge_rate = 0.5;
                sc.forgers = 1;
                sc.fault_key = FaultKey::Lane;
                sc.inflight = 16;
                sc.speculate = true;
                sc.tenants = 4;
                sc.tenant_inflight = 4;
                Some(sc)
            }
            _ => None,
        }
    }

    /// Names [`Scenario::builtin`] answers to.
    pub fn builtin_names() -> &'static [&'static str] {
        &[
            "baseline",
            "crash-respawn",
            "colluders-stragglers",
            "stream",
            "forgers",
            "tenants",
            "tenants-faults",
        ]
    }

    /// Resolve a `--scenario` / `scenario =` token: an explicit file
    /// path, then `scenarios/<name>.toml`, then the builtin set.
    pub fn load(token: &str) -> anyhow::Result<Self> {
        let looks_like_path = token.ends_with(".toml") || token.contains('/');
        if looks_like_path {
            return Self::from_file(token).map_err(|e| anyhow::anyhow!(e.to_string()));
        }
        let local = format!("scenarios/{token}.toml");
        if std::path::Path::new(&local).exists() {
            return Self::from_file(&local).map_err(|e| anyhow::anyhow!(e.to_string()));
        }
        Self::builtin(token).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown scenario {token:?} (no {local}; builtins: {})",
                Self::builtin_names().join(", ")
            )
        })
    }

    /// Parse a scenario from TOML-subset text (same grammar as the
    /// config layer: `[section]`, `key = value`, `#` comments; the
    /// `crash` key may repeat).
    pub fn from_str_toml(text: &str) -> Result<Self, ConfigError> {
        let raw = parse_str(text)?;
        let mut sc = Self::base("unnamed");
        let bad = |k: &str, v: &str| ConfigError::BadValue(k.to_string(), v.to_string());
        for (section, key, value) in raw.entries() {
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            match full.as_str() {
                "name" => sc.name = value.to_string(),
                "rounds" => sc.rounds = value.parse().map_err(|_| bad(&full, value))?,
                "rows" => sc.rows = value.parse().map_err(|_| bad(&full, value))?,
                "cols" => sc.cols = value.parse().map_err(|_| bad(&full, value))?,
                "op" => {
                    sc.op = ScenarioOp::from_token(value).ok_or_else(|| bad(&full, value))?
                }
                "seed" => sc.seed = value.parse().map_err(|_| bad(&full, value))?,
                "cluster.workers" => {
                    sc.workers = value.parse().map_err(|_| bad(&full, value))?
                }
                "cluster.partitions" => {
                    sc.partitions = value.parse().map_err(|_| bad(&full, value))?
                }
                "cluster.colluders" => {
                    sc.colluders = value.parse().map_err(|_| bad(&full, value))?
                }
                "cluster.stragglers" => {
                    sc.stragglers = value.parse().map_err(|_| bad(&full, value))?
                }
                "cluster.forgers" => {
                    sc.forgers = value.parse().map_err(|_| bad(&full, value))?
                }
                "cluster.scheme" => {
                    sc.scheme =
                        SchemeKind::from_str_token(value).ok_or_else(|| bad(&full, value))?
                }
                "cluster.security" => {
                    sc.security = TransportSecurity::from_str_token(value)
                        .ok_or_else(|| bad(&full, value))?
                }
                "cluster.round_deadline_s" => {
                    sc.round_deadline_s = value.parse().map_err(|_| bad(&full, value))?
                }
                "delay.base_service_s" => {
                    sc.delay.base_service_s = value.parse().map_err(|_| bad(&full, value))?
                }
                "delay.straggler_factor" => {
                    sc.delay.straggler_factor = value.parse().map_err(|_| bad(&full, value))?
                }
                "delay.jitter" => {
                    sc.delay.jitter = value.parse().map_err(|_| bad(&full, value))?
                }
                "faults.crash" => {
                    sc.crashes.push(parse_crash(value).ok_or_else(|| bad(&full, value))?)
                }
                "faults.corrupt_rate" => {
                    sc.corrupt_rate = value.parse().map_err(|_| bad(&full, value))?
                }
                "faults.forge_rate" => {
                    sc.forge_rate = value.parse().map_err(|_| bad(&full, value))?
                }
                "faults.key" => {
                    sc.fault_key = FaultKey::from_token(value).ok_or_else(|| bad(&full, value))?
                }
                "adversary.colluder_set" => {
                    let ids: Result<Vec<usize>, _> =
                        value.split(',').map(|t| t.trim().parse()).collect();
                    sc.colluder_set = ids.map_err(|_| bad(&full, value))?;
                }
                "adversary.forger_set" => {
                    let ids: Result<Vec<usize>, _> =
                        value.split(',').map(|t| t.trim().parse()).collect();
                    sc.forger_set = ids.map_err(|_| bad(&full, value))?;
                }
                "stream.inflight" => {
                    sc.inflight = value.parse().map_err(|_| bad(&full, value))?
                }
                "stream.speculate" => {
                    sc.speculate = match value {
                        "true" | "1" | "yes" | "on" => true,
                        "false" | "0" | "no" | "off" => false,
                        _ => return Err(bad(&full, value)),
                    }
                }
                "tenants.count" => {
                    sc.tenants = value.parse().map_err(|_| bad(&full, value))?
                }
                "tenants.inflight" => {
                    sc.tenant_inflight = value.parse().map_err(|_| bad(&full, value))?
                }
                _ => return Err(ConfigError::UnknownKey(full)),
            }
        }
        sc.validate().map_err(ConfigError::Validation)?;
        Ok(sc)
    }

    /// Parse a scenario file from disk.
    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Io(path.to_string(), e.to_string()))?;
        Self::from_str_toml(&text)
    }

    /// Structural sanity checks (cluster constraints are re-validated by
    /// `SystemConfig::validate` when the runner builds the master).
    pub fn validate(&self) -> Result<(), String> {
        if self.rounds == 0 {
            return Err("scenario needs at least one round".into());
        }
        if self.workers == 0 {
            return Err("scenario needs at least one worker".into());
        }
        if !(0.0..1.0).contains(&self.corrupt_rate) {
            return Err(format!("corrupt_rate {} outside [0, 1)", self.corrupt_rate));
        }
        if self.inflight == 0 {
            return Err("stream.inflight must be ≥ 1 (1 = synchronous)".into());
        }
        // Under the served/lane keys a crash round is a wall-rounds-
        // served count, which runs to the *aggregate* round total when
        // tenants interleave.
        let crash_horizon = self.rounds * self.tenants.max(1) as u64;
        for c in &self.crashes {
            if c.worker >= self.workers {
                return Err(format!("crash event names worker {} of {}", c.worker, self.workers));
            }
            if c.round == 0 || c.round > crash_horizon {
                return Err(format!("crash round {} outside 1..={crash_horizon}", c.round));
            }
            // A respawn is scheduled *before* its round's dispatch and a
            // crash is booked *after* it, so a zero-round respawn could
            // never fire — the worker would stay dead with no warning.
            if c.respawn_after == Some(0) {
                return Err(format!(
                    "crash of worker {} at round {}: respawn_after must be ≥ 1",
                    c.worker, c.round
                ));
            }
        }
        for &w in &self.colluder_set {
            if w >= self.workers {
                return Err(format!("colluder set names worker {w} of {}", self.workers));
            }
        }
        // An explicit coalition must agree with the privacy parameter T:
        // encoding masks against `colluders` workers, so observing a
        // coalition of a different size silently measures the wrong
        // threat. (An empty set just means "no observed coalition".)
        if !self.colluder_set.is_empty() && self.colluder_set.len() != self.colluders {
            return Err(format!(
                "colluder_set has {} members but colluders = {} — the observed coalition \
                 must match the privacy parameter T",
                self.colluder_set.len(),
                self.colluders
            ));
        }
        if !(0.0..1.0).contains(&self.forge_rate) {
            return Err(format!("forge_rate {} outside [0, 1)", self.forge_rate));
        }
        for &w in &self.forger_set {
            if w >= self.workers {
                return Err(format!("forger set names worker {w} of {}", self.workers));
            }
        }
        if self.forge_rate > 0.0 && self.forger_set.is_empty() {
            return Err("forge_rate is set but forger_set is empty — name the Byzantine \
                        workers in [adversary] forger_set"
                .into());
        }
        // A declared Byzantine budget must agree with the named set —
        // the mirror of the colluder_set/colluders check above: running
        // a different adversary than the one the scenario claims
        // silently measures the wrong threat. (forgers = 0 declares no
        // budget; the set alone then defines the adversary.)
        if self.forgers != 0 && !self.forger_set.is_empty() && self.forger_set.len() != self.forgers
        {
            return Err(format!(
                "forger_set has {} members but forgers = {} — the named Byzantine set \
                 must match the declared budget F",
                self.forger_set.len(),
                self.forgers
            ));
        }
        if self.tenants == 0 {
            return Err("tenants.count must be ≥ 1 (1 = single-tenant)".into());
        }
        // Multi-tenant runs pin each tenant's digest across lane
        // interleavings. Under `fault_key = "global"` faults and
        // stragglers key on global round ids, which move when tenants
        // interleave — only that combination still needs a fault-free,
        // straggler-free cluster. The served/lane keys exist precisely
        // so adversity composes with tenants (DESIGN.md §13).
        if self.tenants > 1 && self.fault_key == FaultKey::Global {
            if !self.crashes.is_empty()
                || self.corrupt_rate > 0.0
                || self.forge_rate > 0.0
                || self.stragglers > 0
            {
                return Err(format!(
                    "tenants = {} with fault_key = \"global\" needs a fault-free, \
                     straggler-free cluster — global round ids are reassigned by lane \
                     interleaving; key the plan with faults.key = \"served\" or \"lane\"",
                    self.tenants
                ));
            }
        }
        Ok(())
    }

    /// Compile the fault schedule to the runtime's form.
    pub fn fault_plan(&self) -> FaultPlan {
        FaultPlan::new(self.crashes.clone(), self.corrupt_rate, self.seed)
            .with_forgers(self.forger_set.clone(), self.forge_rate)
            .with_key(self.fault_key)
    }
}

impl CrashEvent {
    /// Render to the token [`parse_crash`] accepts (`w@r` /
    /// `w@r+respawn`) — how the process fabric hands a worker its slice
    /// of the fault plan on the command line.
    pub fn to_token(&self) -> String {
        match self.respawn_after {
            Some(d) => format!("{}@{}+{}", self.worker, self.round, d),
            None => format!("{}@{}", self.worker, self.round),
        }
    }
}

/// Parse one crash event token: `worker@round` or `worker@round+respawn`.
pub fn parse_crash(s: &str) -> Option<CrashEvent> {
    let (worker, rest) = s.split_once('@')?;
    let worker = worker.trim().parse().ok()?;
    let (round, respawn_after) = match rest.split_once('+') {
        Some((r, d)) => (r.trim().parse().ok()?, Some(d.trim().parse().ok()?)),
        None => (rest.trim().parse().ok()?, None),
    };
    Some(CrashEvent { worker, round, respawn_after })
}

/// The fault schedule as the runtime consumes it: a pure function of
/// `(worker, `[`FaultCoords`]`)` — worker threads and the master
/// evaluate the same plan independently and stay consistent without
/// observing each other (see module docs). Which coordinate each query
/// reads is selected by the plan's [`FaultKey`].
#[derive(Clone, Debug)]
pub struct FaultPlan {
    crashes: Vec<CrashEvent>,
    corrupt_rate: f64,
    forgers: Vec<usize>,
    forge_rate: f64,
    seed: u64,
    key: FaultKey,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::new(Vec::new(), 0.0, 0)
    }
}

impl FaultPlan {
    /// Build a plan from its parts. Defaults to [`FaultKey::Global`] —
    /// the draws every pre-PR-10 call site got — so direct constructions
    /// stay bit-identical; [`Scenario::fault_plan`] overrides with the
    /// scenario's `fault_key`.
    pub fn new(crashes: Vec<CrashEvent>, corrupt_rate: f64, seed: u64) -> Self {
        Self {
            crashes,
            corrupt_rate,
            forgers: Vec::new(),
            forge_rate: 0.0,
            seed,
            key: FaultKey::Global,
        }
    }

    /// Add a Byzantine forger schedule: each `forgers` member returns a
    /// well-formed wrong result (with a tampered commitment echo) on
    /// the rounds its seeded draw fires.
    pub fn with_forgers(mut self, forgers: Vec<usize>, forge_rate: f64) -> Self {
        self.forgers = forgers;
        self.forge_rate = forge_rate;
        self
    }

    /// Select which identity the queries key on (DESIGN.md §13).
    pub fn with_key(mut self, key: FaultKey) -> Self {
        self.key = key;
        self
    }

    /// The identity the queries key on.
    pub fn key(&self) -> FaultKey {
        self.key
    }

    /// The coordinate a crash/respawn/straggler-floor query keys on:
    /// the global round under `global`, the wall-rounds-served count
    /// otherwise.
    fn lifecycle_key(&self, coords: &FaultCoords) -> u64 {
        match self.key {
            FaultKey::Global => coords.round,
            FaultKey::Served | FaultKey::Lane => coords.served,
        }
    }

    /// The `(round-part, lane-part)` pair a corruption/forgery draw
    /// mixes into its seed stream. The lane part is 0 except under the
    /// `lane` key, so `global` reproduces the legacy stream exactly and
    /// `served` coincides with it whenever served count == round.
    fn draw_key(&self, coords: &FaultCoords) -> (u64, u64) {
        match self.key {
            FaultKey::Global => (coords.round, 0),
            FaultKey::Served => (coords.served, 0),
            FaultKey::Lane => (coords.lane_round, coords.lane as u64),
        }
    }

    /// No faults at all?
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.corrupt_rate <= 0.0
            && (self.forgers.is_empty() || self.forge_rate <= 0.0)
    }

    /// The crash schedule (re-serialized onto worker-process command
    /// lines by the process fabric).
    pub fn crash_events(&self) -> &[CrashEvent] {
        &self.crashes
    }

    /// The wire-corruption probability.
    pub fn corrupt_rate(&self) -> f64 {
        self.corrupt_rate
    }

    /// The seed the corruption draws derive from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Does `worker` crash mid this order? (It receives the order and
    /// never replies.) Keys on the global round or the worker's
    /// wall-rounds-served count per the plan's [`FaultKey`].
    pub fn crashes_at(&self, worker: usize, coords: &FaultCoords) -> bool {
        self.crash_hit(worker, coords).is_some()
    }

    /// The crash event (if any) that fires for `worker` at these
    /// coordinates — the master uses the hit's `respawn_after` to post
    /// the respawn due ledger under the served/lane keys.
    pub fn crash_hit(&self, worker: usize, coords: &FaultCoords) -> Option<&CrashEvent> {
        let key = self.lifecycle_key(coords);
        self.crashes.iter().find(|c| c.worker == worker && c.round == key)
    }

    /// Workers whose respawn is due before global `round` is dispatched.
    /// Only meaningful under [`FaultKey::Global`], where a crash's
    /// booking round is the event's own round field; under the
    /// served/lane keys the master posts each respawn to a due ledger
    /// when the crash actually books (it cannot be computed from the
    /// plan alone).
    pub fn respawns_due(&self, round: u64) -> Vec<usize> {
        self.crashes
            .iter()
            .filter(|c| c.respawn_after.map(|d| c.round + d) == Some(round))
            .map(|c| c.worker)
            .collect()
    }

    /// Is `worker`'s result frame corrupted on the wire? Deterministic:
    /// a seeded draw per (worker, key), independent of everything else.
    /// A crash on the same order takes precedence (the worker dies
    /// before sending anything).
    pub fn corrupts(&self, worker: usize, coords: &FaultCoords) -> bool {
        if self.corrupt_rate <= 0.0 || self.crashes_at(worker, coords) {
            return false;
        }
        let (r, lane) = self.draw_key(coords);
        let mut rng = rng_from_seed(derive_seed(
            self.seed,
            0xC0_44_0000 ^ (r << 20) ^ (lane << 44) ^ worker as u64,
        ));
        rng.next_f64() < self.corrupt_rate
    }

    /// The Byzantine worker set (re-serialized onto worker-process
    /// command lines by the process fabric).
    pub fn forger_set(&self) -> &[usize] {
        &self.forgers
    }

    /// Does the plan schedule any forgeries at all? (Keys the master's
    /// surplus-result wait policy for exact schemes — DESIGN.md §11.)
    pub fn has_forgers(&self) -> bool {
        self.forge_rate > 0.0 && !self.forgers.is_empty()
    }

    /// The per-(forger, round) forgery probability.
    pub fn forge_rate(&self) -> f64 {
        self.forge_rate
    }

    /// Does `worker` forge this result — return a well-formed wrong
    /// payload with a tampered commitment echo? Deterministic like
    /// [`FaultPlan::corrupts`], with its own seed stream, and lowest
    /// precedence: a crash means nothing is sent, and a corruption
    /// already destroys the frame at the CRC, so forging is moot on
    /// either.
    pub fn forges_at(&self, worker: usize, coords: &FaultCoords) -> bool {
        if self.forge_rate <= 0.0
            || !self.forgers.contains(&worker)
            || self.crashes_at(worker, coords)
            || self.corrupts(worker, coords)
        {
            return false;
        }
        let (r, lane) = self.draw_key(coords);
        let mut rng = rng_from_seed(derive_seed(
            self.seed,
            0xF0_46_0000 ^ (r << 20) ^ (lane << 44) ^ worker as u64,
        ));
        rng.next_f64() < self.forge_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_and_validate() {
        for name in Scenario::builtin_names() {
            let sc = Scenario::builtin(name).unwrap();
            assert_eq!(sc.name, *name);
            sc.validate().unwrap();
        }
        assert!(Scenario::builtin("nope").is_none());
    }

    #[test]
    fn toml_round_trips_the_crash_schedule() {
        let text = r#"
name = "t"
rounds = 6
rows = 32
cols = 16
op = "identity"
seed = 99
[cluster]
workers = 6
partitions = 2
colluders = 2
stragglers = 1
scheme = "bacc"
security = "plain"
round_deadline_s = 5
[delay]
base_service_s = 0.001
straggler_factor = 10
jitter = 0.05
[faults]
crash = "1@2+2"
crash = "3@4"
corrupt_rate = 0.25
forge_rate = 0.4
[adversary]
colluder_set = "0, 2"
forger_set = "4"
[stream]
inflight = 4
speculate = "on"
"#;
        let sc = Scenario::from_str_toml(text).unwrap();
        assert_eq!(sc.name, "t");
        assert_eq!(sc.inflight, 4);
        assert!(sc.speculate);
        assert_eq!(sc.rounds, 6);
        assert_eq!(sc.op, ScenarioOp::Identity);
        assert_eq!(sc.scheme, SchemeKind::Bacc);
        assert_eq!(sc.security, TransportSecurity::Plain);
        assert_eq!(
            sc.crashes,
            vec![
                CrashEvent { worker: 1, round: 2, respawn_after: Some(2) },
                CrashEvent { worker: 3, round: 4, respawn_after: None },
            ]
        );
        assert_eq!(sc.corrupt_rate, 0.25);
        assert_eq!(sc.colluder_set, vec![0, 2]);
        assert_eq!(sc.forge_rate, 0.4);
        assert_eq!(sc.forger_set, vec![4]);
        assert_eq!(sc.delay.straggler_factor, 10.0);
    }

    #[test]
    fn colluder_set_must_agree_with_the_privacy_parameter() {
        // T = 2 but a 3-member observed coalition: inconsistent.
        let text = "[cluster]\nworkers = 8\ncolluders = 2\n\
                    [adversary]\ncolluder_set = \"0, 1, 2\"\n";
        let err = Scenario::from_str_toml(text).unwrap_err();
        assert!(
            matches!(&err, ConfigError::Validation(m) if m.contains("colluder_set")),
            "want a typed validation error naming colluder_set, got {err:?}"
        );
        // The same set sized to T passes…
        let ok = "[cluster]\nworkers = 8\ncolluders = 3\n\
                  [adversary]\ncolluder_set = \"0, 1, 2\"\n";
        assert_eq!(Scenario::from_str_toml(ok).unwrap().colluder_set, vec![0, 1, 2]);
        // …and an empty set stays valid at any T (no observed coalition).
        let mut sc = Scenario::builtin("baseline").unwrap();
        assert!(sc.colluder_set.is_empty());
        sc.validate().unwrap();
    }

    #[test]
    fn forger_config_is_validated() {
        // Out-of-range forger index.
        let ghost = "[cluster]\nworkers = 4\n[faults]\nforge_rate = 0.5\n\
                     [adversary]\nforger_set = \"9\"\n";
        assert!(Scenario::from_str_toml(ghost).is_err());
        // A rate with no named forgers is a contradiction, not "off".
        assert!(Scenario::from_str_toml("[faults]\nforge_rate = 0.5\n").is_err());
        // Rates live in [0, 1).
        let hot = "[faults]\nforge_rate = 1.0\n[adversary]\nforger_set = \"1\"\n";
        assert!(Scenario::from_str_toml(hot).is_err());
        // An inert forger set (rate 0) is fine.
        let inert = "[adversary]\nforger_set = \"1\"\n";
        assert_eq!(Scenario::from_str_toml(inert).unwrap().forger_set, vec![1]);
    }

    #[test]
    fn forger_set_must_agree_with_the_declared_budget() {
        // F = 2 but a 1-member named set: inconsistent — the mirror of
        // the colluder_set/colluders check.
        let short = "[cluster]\nworkers = 8\nforgers = 2\n\
                     [adversary]\nforger_set = \"3\"\n";
        let err = Scenario::from_str_toml(short).unwrap_err();
        assert!(
            matches!(&err, ConfigError::Validation(m) if m.contains("forger_set")),
            "want a typed validation error naming forger_set, got {err:?}"
        );
        // The same set sized to F passes…
        let ok = "[cluster]\nworkers = 8\nforgers = 2\n\
                  [adversary]\nforger_set = \"3, 5\"\n";
        let sc = Scenario::from_str_toml(ok).unwrap();
        assert_eq!(sc.forgers, 2);
        assert_eq!(sc.forger_set, vec![3, 5]);
        // …an undeclared budget (F = 0) leaves the set authoritative…
        let legacy = "[cluster]\nworkers = 8\n[adversary]\nforger_set = \"3\"\n";
        assert_eq!(Scenario::from_str_toml(legacy).unwrap().forger_set, vec![3]);
        // …and the shipped Byzantine builtin declares a matching budget.
        let builtin = Scenario::builtin("forgers").unwrap();
        assert_eq!(builtin.forgers, builtin.forger_set.len());
        builtin.validate().unwrap();
    }

    #[test]
    fn multi_tenant_fault_rules_follow_the_key() {
        // Zero tenants is a contradiction, not "off".
        assert!(Scenario::from_str_toml("[tenants]\ncount = 0\n").is_err());
        // Global-round-keyed adversity under tenants > 1 is still
        // rejected: interleaving reassigns the ids it keys on. The
        // same adversity under the served/lane keys is legal — that
        // composition is the whole point of the re-keying.
        for adversity in [
            "[faults]\ncrash = \"1@2+2\"\n",
            "[faults]\ncorrupt_rate = 0.1\n",
            "[faults]\nforge_rate = 0.5\n[adversary]\nforger_set = \"1\"\n",
            "[cluster]\nstragglers = 1\n",
        ] {
            let global = format!(
                "rounds = 4\n{adversity}[faults]\nkey = \"global\"\n[tenants]\ncount = 2\n"
            );
            let err = Scenario::from_str_toml(&global).unwrap_err();
            assert!(
                matches!(&err, ConfigError::Validation(m) if m.contains("fault-free")),
                "want the fault-free validation for {adversity:?}, got {err:?}"
            );
            for key in ["served", "lane"] {
                let text = format!(
                    "rounds = 4\n{adversity}[faults]\nkey = \"{key}\"\n[tenants]\ncount = 2\n"
                );
                Scenario::from_str_toml(&text)
                    .unwrap_or_else(|e| panic!("{key} key must allow {adversity:?}: {e:?}"));
            }
        }
        // The default key is `served`, so the bare combination passes
        // too.
        let sc =
            Scenario::from_str_toml("rounds = 4\n[cluster]\nstragglers = 1\n[tenants]\ncount = 2\n")
                .unwrap();
        assert_eq!(sc.fault_key, FaultKey::Served);
        // The shipped tenants builtin is valid and 4-wide.
        let sc = Scenario::builtin("tenants").unwrap();
        assert_eq!(sc.tenants, 4);
        assert_eq!(sc.tenant_inflight, 4);
        assert_eq!(sc.inflight, 16);
        sc.validate().unwrap();
    }

    #[test]
    fn tenants_faults_builtin_composes_adversity_with_lanes() {
        let sc = Scenario::builtin("tenants-faults").unwrap();
        assert_eq!(sc.tenants, 4);
        assert_eq!(sc.fault_key, FaultKey::Lane);
        assert_eq!(sc.crashes.len(), 1);
        assert_eq!(
            sc.crashes[0].respawn_after,
            Some(1),
            "the soak needs a respawn cycle, and it must land before the \
             next dispatch so every round runs the full fleet"
        );
        assert_eq!(sc.forger_set, vec![5]);
        assert_eq!(sc.stragglers, 0, "S = 0 pins the decode set to every dispatched share");
        assert!(sc.speculate, "speculation is what keeps faulted rounds undegraded");
        sc.validate().unwrap();
        assert_eq!(sc.fault_plan().key(), FaultKey::Lane);
    }

    #[test]
    fn fault_key_tokens_round_trip() {
        for key in [FaultKey::Global, FaultKey::Served, FaultKey::Lane] {
            assert_eq!(FaultKey::from_token(key.name()), Some(key));
        }
        assert_eq!(FaultKey::from_token("SERVED"), Some(FaultKey::Served));
        assert!(FaultKey::from_token("round").is_none());
        assert!(Scenario::from_str_toml("[faults]\nkey = \"banana\"\n").is_err());
    }

    #[test]
    fn lane_key_makes_draws_a_pure_function_of_the_lane_stream() {
        let plan =
            FaultPlan::new(Vec::new(), 0.3, 0x5CE6).with_forgers(vec![5], 0.5).with_key(FaultKey::Lane);
        // The same (lane, lane_round) must draw identically whatever
        // global round or served count it lands on — that is the
        // isolation contract for a tenant's adversarial exposure.
        for w in 0..10usize {
            for lane in 0..4u32 {
                for lr in 1..=8u64 {
                    let a = FaultCoords { round: lr, served: lr, lane, lane_round: lr };
                    let b = FaultCoords {
                        round: 100 + 7 * lr,
                        served: 31 + lr,
                        lane,
                        lane_round: lr,
                    };
                    assert_eq!(plan.corrupts(w, &a), plan.corrupts(w, &b));
                    assert_eq!(plan.forges_at(w, &a), plan.forges_at(w, &b));
                }
            }
        }
        // …and distinct lanes see distinct streams: the same local
        // round must not fire identically across all four lanes for
        // every worker (that would mean the lane id is ignored).
        let mut lanes_differ = false;
        'outer: for w in 0..10usize {
            for lr in 1..=8u64 {
                let hits: Vec<bool> = (0..4u32)
                    .map(|lane| {
                        plan.corrupts(w, &FaultCoords { round: lr, served: lr, lane, lane_round: lr })
                    })
                    .collect();
                if hits.iter().any(|&h| h != hits[0]) {
                    lanes_differ = true;
                    break 'outer;
                }
            }
        }
        assert!(lanes_differ, "lane id must enter the draw stream");
    }

    #[test]
    fn served_key_moves_lifecycle_events_off_the_global_round() {
        let plan = FaultPlan::new(
            vec![CrashEvent { worker: 2, round: 3, respawn_after: Some(2) }],
            0.0,
            7,
        )
        .with_key(FaultKey::Served);
        // The crash fires on worker 2's third serviced order, whatever
        // global round that happens to be…
        let hit = FaultCoords { round: 11, served: 3, lane: 1, lane_round: 2 };
        assert!(plan.crashes_at(2, &hit));
        assert_eq!(plan.crash_hit(2, &hit).unwrap().respawn_after, Some(2));
        // …and not on global round 3 if that is only its second.
        assert!(!plan.crashes_at(2, &FaultCoords { round: 3, served: 2, lane: 0, lane_round: 3 }));
        // Under the global key the same coordinates flip.
        let legacy = plan.clone().with_key(FaultKey::Global);
        assert!(!legacy.crashes_at(2, &hit));
        assert!(legacy.crashes_at(2, &FaultCoords { round: 3, served: 2, lane: 0, lane_round: 3 }));
    }

    #[test]
    fn forge_draws_are_deterministic_and_lowest_precedence() {
        let sc = Scenario::builtin("forgers").unwrap();
        let a = sc.fault_plan();
        let b = sc.fault_plan();
        let mut fired = 0usize;
        for w in 0..sc.workers {
            for r in 1..=sc.rounds {
                let at = FaultCoords::global(r);
                assert_eq!(a.forges_at(w, &at), b.forges_at(w, &at));
                if a.forges_at(w, &at) {
                    fired += 1;
                    assert!(sc.forger_set.contains(&w), "only forger-set members forge");
                }
            }
        }
        assert!(fired > 0, "the forgers scenario must actually forge");
        // Crash and corruption take precedence over forging.
        let plan = FaultPlan::new(
            vec![CrashEvent { worker: 2, round: 3, respawn_after: None }],
            0.999,
            0x5CE4,
        )
        .with_forgers(vec![2], 0.999);
        assert!(
            !plan.forges_at(2, &FaultCoords::global(3)),
            "a crashed worker sends nothing to forge"
        );
        assert!(
            (1..=20u64).all(|r| {
                let at = FaultCoords::global(r);
                !plan.forges_at(2, &at) || !plan.corrupts(2, &at)
            }),
            "corruption destroys the frame before a forgery could matter"
        );
        assert!(!plan.is_empty());
        assert!(FaultPlan::new(Vec::new(), 0.0, 1).with_forgers(vec![1], 0.0).is_empty());
    }

    #[test]
    fn bad_scenarios_are_rejected() {
        assert!(Scenario::from_str_toml("rounds = 0\n").is_err());
        assert!(Scenario::from_str_toml("nonsense = 1\n").is_err());
        assert!(Scenario::from_str_toml("[faults]\ncrash = \"banana\"\n").is_err());
        // Crash beyond the soak, or of a worker that does not exist.
        assert!(Scenario::from_str_toml("[faults]\ncrash = \"1@99\"\n").is_err());
        let ghost = "[cluster]\nworkers = 2\n[faults]\ncrash = \"5@1\"\n";
        assert!(Scenario::from_str_toml(ghost).is_err());
        assert!(Scenario::from_str_toml("[faults]\ncorrupt_rate = 1.5\n").is_err());
        // A same-round respawn can never fire (respawns are scheduled
        // before dispatch, crashes booked after) — reject it up front.
        assert!(Scenario::from_str_toml("[faults]\ncrash = \"1@2+0\"\n").is_err());
        // A zero stream window is a contradiction, not "off".
        assert!(Scenario::from_str_toml("[stream]\ninflight = 0\n").is_err());
        assert!(Scenario::from_str_toml("[stream]\nspeculate = \"maybe\"\n").is_err());
    }

    #[test]
    fn fault_plan_is_deterministic_and_respects_precedence() {
        let sc = Scenario::builtin("crash-respawn").unwrap();
        let a = sc.fault_plan();
        let b = sc.fault_plan();
        assert!(a.crashes_at(2, &FaultCoords::global(3)));
        assert!(!a.crashes_at(2, &FaultCoords::global(4)));
        assert_eq!(a.respawns_due(5), vec![2]);
        assert_eq!(a.respawns_due(7), vec![5]);
        assert_eq!(a.respawns_due(6), Vec::<usize>::new());
        // Corruption draws are a pure function of (worker, key)…
        for w in 0..sc.workers {
            for r in 1..=sc.rounds {
                let at = FaultCoords::global(r);
                assert_eq!(a.corrupts(w, &at), b.corrupts(w, &at));
            }
        }
        // …and never fire on a round the worker crashes in.
        assert!(!a.corrupts(2, &FaultCoords::global(3)));
    }

    #[test]
    fn corruption_rate_is_roughly_honoured() {
        let plan = FaultPlan::new(Vec::new(), 0.3, 7);
        let hits: usize = (0..50)
            .flat_map(|w| (1..=40).map(move |r| (w, r)))
            .filter(|&(w, r)| plan.corrupts(w, &FaultCoords::global(r)))
            .count();
        let rate = hits as f64 / 2000.0;
        assert!((0.2..0.4).contains(&rate), "rate {rate} far from 0.3");
        let off = FaultPlan::new(Vec::new(), 0.0, 7);
        assert!(!(0..50).any(|w| off.corrupts(w, &FaultCoords::global(1))));
    }
}
