//! Adversary and environment simulation — the paper's experimental
//! apparatus (§VII-B.1): straggler injection via artificial delays,
//! colluding workers that pool their received shares, and an
//! eavesdropper that records everything on the wire.

mod adversary;
mod straggler;

pub use adversary::{correlation as correlation_of, CollusionPool, EavesdropLog, EavesdroppedMessage};
pub use straggler::{fresh_round_model, DelayModel, WorkerProfile};
