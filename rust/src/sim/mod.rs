//! Adversary and environment simulation — the paper's experimental
//! apparatus (§VII-B.1): straggler injection via artificial delays,
//! colluding workers that pool their received shares, an eavesdropper
//! that records everything on the wire, and the declarative scenario
//! engine ([`Scenario`] + [`runner`]) that composes all of them — plus
//! worker crash/respawn churn and wire corruption — into deterministic,
//! CI-pinnable soaks (DESIGN.md §7).

mod adversary;
pub mod runner;
mod scenario;
mod straggler;

pub use adversary::{correlation as correlation_of, CollusionPool, EavesdropLog, EavesdroppedMessage};
pub use runner::{
    run_scenario, run_scenario_with, RoundRecord, RoundStatus, ScenarioReport, TenantStat,
};
pub use scenario::{
    parse_crash, CrashEvent, FaultCoords, FaultKey, FaultPlan, Scenario, ScenarioOp,
};
pub use straggler::{fresh_round_model, DelayModel, WorkerProfile};
