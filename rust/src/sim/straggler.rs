//! Straggler injection.
//!
//! The paper simulates stragglers by inserting `sleep()` into chosen
//! workers (§VII-B.1). [`DelayModel`] reproduces that: S workers chosen
//! by seed get a multiplicative service-time factor; all workers get a
//! base service time and uniform jitter. Deterministic from the seed so
//! every bench run sees the same straggler pattern.

use crate::config::DelayConfig;
use crate::rng::{derive_seed, rng_from_seed};
use std::time::Duration;

/// Per-worker service profile.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerProfile {
    /// Is this worker a straggler?
    pub straggler: bool,
    /// Multiplier applied to the nominal service time.
    pub speed_factor: f64,
}

/// Deterministic delay model for a cluster of N workers.
#[derive(Clone, Debug)]
pub struct DelayModel {
    cfg: DelayConfig,
    profiles: Vec<WorkerProfile>,
    seed: u64,
}

impl DelayModel {
    /// Choose `stragglers` random workers out of `n` (seeded) and build
    /// their profiles.
    pub fn new(n: usize, stragglers: usize, cfg: DelayConfig, seed: u64) -> Self {
        assert!(stragglers <= n, "more stragglers than workers");
        let mut rng = rng_from_seed(derive_seed(seed, 0x57A6));
        let chosen = rng.choose_indices(n, stragglers);
        let mut profiles = vec![
            WorkerProfile { straggler: false, speed_factor: 1.0 };
            n
        ];
        for &i in &chosen {
            profiles[i] = WorkerProfile { straggler: true, speed_factor: cfg.straggler_factor };
        }
        Self { cfg, profiles, seed }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.profiles.len()
    }

    /// The worker profile.
    pub fn profile(&self, worker: usize) -> WorkerProfile {
        self.profiles[worker]
    }

    /// Indices of the straggling workers.
    pub fn straggler_set(&self) -> Vec<usize> {
        self.profiles
            .iter()
            .enumerate()
            .filter(|(_, p)| p.straggler)
            .map(|(i, _)| i)
            .collect()
    }

    /// The artificial service delay for `worker` on round `round`
    /// (excludes real compute time, which happens anyway).
    ///
    /// delay = base · speed_factor · (1 ± jitter), deterministic in
    /// (seed, worker, round).
    pub fn service_delay(&self, worker: usize, round: u64) -> Duration {
        let p = self.profiles[worker];
        if self.cfg.base_service_s <= 0.0 {
            // Even with no base cost, stragglers must straggle: give them
            // a small floor so the effect exists in fast unit tests.
            if p.straggler {
                return Duration::from_micros(200);
            }
            return Duration::ZERO;
        }
        let mut r = rng_from_seed(derive_seed(
            self.seed,
            (worker as u64) << 32 | (round & 0xFFFF_FFFF),
        ));
        let jitter = 1.0 + self.cfg.jitter * (2.0 * r.next_f64() - 1.0);
        let secs = self.cfg.base_service_s * p.speed_factor * jitter.max(0.0);
        Duration::from_secs_f64(secs)
    }

    /// Expected (jitter-free) service seconds for `worker` — used by the
    /// analytical latency model in the benches.
    pub fn expected_service_s(&self, worker: usize) -> f64 {
        self.cfg.base_service_s * self.profiles[worker].speed_factor
    }
}

/// Draw a fresh straggler assignment per round (paper: "randomly select
/// S straggling workers").
pub fn fresh_round_model(
    n: usize,
    stragglers: usize,
    cfg: DelayConfig,
    seed: u64,
    round: u64,
) -> DelayModel {
    DelayModel::new(n, stragglers, cfg, derive_seed(seed, round))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(base: f64) -> DelayConfig {
        DelayConfig { straggler_factor: 5.0, base_service_s: base, jitter: 0.1 }
    }

    #[test]
    fn straggler_count_respected() {
        let m = DelayModel::new(30, 7, cfg(0.01), 42);
        assert_eq!(m.straggler_set().len(), 7);
        assert_eq!(m.n(), 30);
    }

    #[test]
    fn deterministic_from_seed() {
        let a = DelayModel::new(30, 5, cfg(0.01), 7);
        let b = DelayModel::new(30, 5, cfg(0.01), 7);
        assert_eq!(a.straggler_set(), b.straggler_set());
        for w in 0..30 {
            assert_eq!(a.service_delay(w, 3), b.service_delay(w, 3));
        }
    }

    #[test]
    fn different_seeds_move_stragglers() {
        let a = DelayModel::new(30, 5, cfg(0.01), 1);
        let b = DelayModel::new(30, 5, cfg(0.01), 2);
        assert_ne!(a.straggler_set(), b.straggler_set());
    }

    #[test]
    fn stragglers_are_slower() {
        let m = DelayModel::new(10, 3, cfg(0.01), 9);
        for w in 0..10 {
            let d = m.service_delay(w, 0).as_secs_f64();
            if m.profile(w).straggler {
                assert!(d > 0.04, "straggler {w} delay {d}");
            } else {
                assert!(d < 0.012, "normal {w} delay {d}");
            }
        }
    }

    #[test]
    fn zero_base_still_distinguishes_stragglers() {
        let m = DelayModel::new(8, 2, cfg(0.0), 3);
        for w in 0..8 {
            let d = m.service_delay(w, 0);
            if m.profile(w).straggler {
                assert!(d > Duration::ZERO);
            } else {
                assert_eq!(d, Duration::ZERO);
            }
        }
    }

    #[test]
    fn jitter_varies_by_round() {
        let m = DelayModel::new(4, 0, cfg(0.01), 5);
        let d0 = m.service_delay(0, 0);
        let d1 = m.service_delay(0, 1);
        assert_ne!(d0, d1);
    }
}
