//! Property-based testing substrate (no `proptest` in this environment).
//!
//! Provides seeded random-input generators and a `forall` runner with
//! greedy shrinking: on failure, the runner re-tries progressively
//! "smaller" versions of the failing input (halving sizes / magnitudes)
//! and reports the smallest input that still fails. Used by the coding
//! and coordinator invariant tests.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath link flags)
//! use spacdc::prop::{forall, prop_assert};
//! forall(100, 42, |g| {
//!     let xs = g.vec_f32(1..50, -10.0, 10.0);
//!     let sum: f32 = xs.iter().sum();
//!     prop_assert(sum.is_finite(), format!("sum not finite: {sum}"))
//! });
//! ```

use crate::rng::{rng_from_seed, Rng};

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Assert inside a property: `Err` carries the failure message.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two f64s are within `tol`.
pub fn prop_close(a: f64, b: f64, tol: f64) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {} > {tol}", (a - b).abs()))
    }
}

/// A seeded input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Size multiplier in (0, 1]; shrinking reruns with smaller values.
    scale: f64,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Self { rng: rng_from_seed(seed), scale }
    }

    /// Integer in [lo, hi) — the range shrinks toward `lo` under scaling.
    pub fn usize_in(&mut self, range: core::ops::Range<usize>) -> usize {
        assert!(range.start < range.end, "empty range");
        let span = range.end - range.start;
        let scaled = ((span as f64 * self.scale).ceil() as usize).max(1);
        range.start + (self.rng.next_below(scaled as u64) as usize)
    }

    /// f32 in [lo, hi) — magnitude shrinks toward the midpoint.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let mid = (lo + hi) / 2.0;
        let half = (hi - lo) / 2.0 * self.scale as f32;
        mid - half + 2.0 * half * self.rng.next_f32()
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let mid = (lo + hi) / 2.0;
        let half = (hi - lo) / 2.0 * self.scale;
        mid - half + 2.0 * half * self.rng.next_f64()
    }

    /// Raw u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Bool with probability `p` of true.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Vec of f32 with length drawn from `len`, entries in [lo, hi).
    pub fn vec_f32(&mut self, len: core::ops::Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Choose `k` distinct indices out of n.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.choose_indices(n, k)
    }

    /// Access the raw RNG (for matrix constructors etc.).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random evaluations of `property`; on failure, rerun the
/// failing seed at smaller scales and panic with the smallest failure.
pub fn forall(cases: usize, seed: u64, property: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cases {
        let case_seed = crate::rng::derive_seed(seed, case as u64);
        let mut g = Gen::new(case_seed, 1.0);
        if let Err(first_msg) = property(&mut g) {
            // Shrink: retry the same seed with smaller scales; keep the
            // smallest scale that still fails.
            let mut best = (1.0f64, first_msg);
            for shrink_step in 1..=6 {
                let scale = 1.0 / f64::powi(2.0, shrink_step);
                let mut g = Gen::new(case_seed, scale);
                if let Err(msg) = property(&mut g) {
                    best = (scale, msg);
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, smallest failing scale {}):\n  {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(50, 1, |g| {
            let x = g.f32_in(-5.0, 5.0);
            prop_assert((-5.0..=5.0).contains(&x), "out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_context() {
        forall(50, 2, |g| {
            let x = g.f32_in(0.0, 10.0);
            prop_assert(x < 5.0, format!("x={x} >= 5"))
        });
    }

    #[test]
    fn usize_in_respects_bounds() {
        forall(200, 3, |g| {
            let n = g.usize_in(3..17);
            prop_assert((3..17).contains(&n), format!("n={n}"))
        });
    }

    #[test]
    fn subset_yields_distinct() {
        forall(100, 4, |g| {
            let s = g.subset(20, 5);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            prop_assert(t.len() == 5, "subset not distinct")
        });
    }

    #[test]
    fn prop_close_tolerance() {
        assert!(prop_close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(prop_close(1.0, 2.0, 0.5).is_err());
    }
}
