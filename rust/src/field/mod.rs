//! Finite-field arithmetic substrate for the MEA-ECC layer (paper §IV).
//!
//! Two prime fields are provided:
//!
//! * [`Fp61`] — F_{2^61 − 1} (Mersenne), all arithmetic in u128. This is
//!   the default *simulation* field: fast, branch-light, and large enough
//!   that the ECDH/masking algebra of §IV runs exactly as written.
//! * [`FpBig`] over [`U256`] — arbitrary 256-bit prime moduli, used to
//!   instantiate the secp256k1 curve for a production-grade parameter set.
//!
//! The substitution of a 61-bit field for a 256-bit one in the default
//! config affects only cryptographic hardness, not any quantity the paper
//! evaluates (see DESIGN.md §3).

pub mod fp61;
pub mod u256;

pub use fp61::Fp61;
pub use u256::{FpBig, U256};

/// Common behaviour of a prime-field element, enough for Weierstrass
/// curve arithmetic (`ecc::curve`).
pub trait FieldElement:
    Copy + Clone + PartialEq + Eq + core::fmt::Debug + core::fmt::Display
{
    /// Additive identity.
    fn zero() -> Self;
    /// Multiplicative identity.
    fn one() -> Self;
    /// True iff this is the additive identity.
    fn is_zero(&self) -> bool;
    /// Field addition.
    fn add(&self, rhs: &Self) -> Self;
    /// Field subtraction.
    fn sub(&self, rhs: &Self) -> Self;
    /// Field multiplication.
    fn mul(&self, rhs: &Self) -> Self;
    /// Additive inverse.
    fn neg(&self) -> Self;
    /// Multiplicative inverse; `None` for zero.
    fn inverse(&self) -> Option<Self>;
    /// Squaring (specializable; default multiplies).
    fn square(&self) -> Self {
        self.mul(self)
    }
    /// Canonical little-endian u64 limbs (for hashing / keystreams).
    fn to_limbs(&self) -> [u64; 4];
    /// Construct from a u64 (reduced mod p).
    fn from_u64(v: u64) -> Self;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn field_axioms<F: FieldElement>(samples: &[F]) {
        for a in samples {
            // identity
            assert_eq!(a.add(&F::zero()), *a);
            assert_eq!(a.mul(&F::one()), *a);
            // inverse laws
            assert!(a.add(&a.neg()).is_zero());
            if !a.is_zero() {
                let inv = a.inverse().expect("nonzero invertible");
                assert_eq!(a.mul(&inv), F::one());
            }
            for b in samples {
                // commutativity
                assert_eq!(a.add(b), b.add(a));
                assert_eq!(a.mul(b), b.mul(a));
                for c in samples {
                    // associativity + distributivity
                    assert_eq!(a.add(&b.add(c)), a.add(b).add(c));
                    assert_eq!(a.mul(&b.mul(c)), a.mul(b).mul(c));
                    assert_eq!(a.mul(&b.add(c)), a.mul(b).add(&a.mul(c)));
                }
            }
        }
    }

    #[test]
    fn fp61_satisfies_field_axioms() {
        let xs: Vec<Fp61> =
            [0u64, 1, 2, 3, 5, 1 << 60, (1 << 61) - 2].iter().map(|&v| Fp61::new(v)).collect();
        field_axioms(&xs);
    }

    #[test]
    fn fpbig_satisfies_field_axioms_on_secp_modulus() {
        let p = U256::SECP256K1_P;
        let xs: Vec<FpBig> = [0u64, 1, 2, 7, 0xFFFF_FFFF_FFFF_FFFF]
            .iter()
            .map(|&v| FpBig::new(U256::from_u64(v), p))
            .collect();
        field_axioms(&xs);
    }
}
