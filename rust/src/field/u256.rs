//! 256-bit unsigned integers and a generic prime field over them.
//!
//! No bigint crate is available in this environment, so this is a
//! from-scratch 4×u64-limb implementation sized for ECC: constant-width
//! add/sub/cmp, shift-add modular multiplication (Russian peasant, 256
//! iterations), and inversion by the binary extended GCD. Fast enough for
//! the coordinator (scalar multiplication ≈ hundreds of microseconds),
//! and free of secret-dependent memory access, though we make no strict
//! constant-time claim — this is a systems reproduction, not a crypto
//! library.

use super::FieldElement;

/// Little-endian 4-limb 256-bit unsigned integer.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct U256(pub [u64; 4]);

impl U256 {
    /// Zero.
    pub const ZERO: U256 = U256([0, 0, 0, 0]);
    /// One.
    pub const ONE: U256 = U256([1, 0, 0, 0]);

    /// secp256k1 field modulus p = 2^256 − 2^32 − 977.
    pub const SECP256K1_P: U256 = U256([
        0xFFFF_FFFE_FFFF_FC2F,
        0xFFFF_FFFF_FFFF_FFFF,
        0xFFFF_FFFF_FFFF_FFFF,
        0xFFFF_FFFF_FFFF_FFFF,
    ]);

    /// secp256k1 group order n.
    pub const SECP256K1_N: U256 = U256([
        0xBFD2_5E8C_D036_4141,
        0xBAAE_DCE6_AF48_A03B,
        0xFFFF_FFFF_FFFF_FFFE,
        0xFFFF_FFFF_FFFF_FFFF,
    ]);

    /// Construct from a single u64.
    pub const fn from_u64(v: u64) -> Self {
        U256([v, 0, 0, 0])
    }

    /// Construct from big-endian hex (no 0x prefix). Panics on bad input;
    /// used only for constants and tests.
    pub fn from_hex(s: &str) -> Self {
        assert!(s.len() <= 64, "hex too long for U256");
        let mut limbs = [0u64; 4];
        let bytes: Vec<u8> = s.bytes().rev().collect(); // LE nibbles
        for (i, b) in bytes.iter().enumerate() {
            let nib = match b {
                b'0'..=b'9' => b - b'0',
                b'a'..=b'f' => b - b'a' + 10,
                b'A'..=b'F' => b - b'A' + 10,
                _ => panic!("bad hex digit {}", *b as char),
            } as u64;
            limbs[i / 16] |= nib << (4 * (i % 16));
        }
        U256(limbs)
    }

    /// True iff zero.
    pub fn is_zero(&self) -> bool {
        self.0 == [0, 0, 0, 0]
    }

    /// True iff the low bit is set.
    pub fn is_odd(&self) -> bool {
        self.0[0] & 1 == 1
    }

    /// Comparison.
    pub fn cmp_u(&self, other: &U256) -> core::cmp::Ordering {
        for i in (0..4).rev() {
            match self.0[i].cmp(&other.0[i]) {
                core::cmp::Ordering::Equal => continue,
                ord => return ord,
            }
        }
        core::cmp::Ordering::Equal
    }

    /// `self < other`.
    pub fn lt(&self, other: &U256) -> bool {
        self.cmp_u(other) == core::cmp::Ordering::Less
    }

    /// Wrapping add; returns (sum, carry).
    pub fn adc(&self, other: &U256) -> (U256, bool) {
        let mut out = [0u64; 4];
        let mut carry = false;
        for i in 0..4 {
            let (s1, c1) = self.0[i].overflowing_add(other.0[i]);
            let (s2, c2) = s1.overflowing_add(carry as u64);
            out[i] = s2;
            carry = c1 | c2;
        }
        (U256(out), carry)
    }

    /// Wrapping sub; returns (diff, borrow).
    pub fn sbb(&self, other: &U256) -> (U256, bool) {
        let mut out = [0u64; 4];
        let mut borrow = false;
        for i in 0..4 {
            let (d1, b1) = self.0[i].overflowing_sub(other.0[i]);
            let (d2, b2) = d1.overflowing_sub(borrow as u64);
            out[i] = d2;
            borrow = b1 | b2;
        }
        (U256(out), borrow)
    }

    /// Left shift by one bit; returns (shifted, carried-out bit).
    pub fn shl1(&self) -> (U256, bool) {
        let mut out = [0u64; 4];
        let mut carry = 0u64;
        for i in 0..4 {
            out[i] = (self.0[i] << 1) | carry;
            carry = self.0[i] >> 63;
        }
        (U256(out), carry == 1)
    }

    /// Right shift by one bit.
    pub fn shr1(&self) -> U256 {
        let mut out = [0u64; 4];
        for i in 0..4 {
            out[i] = self.0[i] >> 1;
            if i + 1 < 4 {
                out[i] |= self.0[i + 1] << 63;
            }
        }
        U256(out)
    }

    /// Bit i (0 = LSB).
    pub fn bit(&self, i: usize) -> bool {
        (self.0[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Index of the highest set bit, or None if zero.
    pub fn highest_bit(&self) -> Option<usize> {
        for i in (0..4).rev() {
            if self.0[i] != 0 {
                return Some(i * 64 + 63 - self.0[i].leading_zeros() as usize);
            }
        }
        None
    }

    /// Modular addition (inputs must be < p).
    pub fn add_mod(&self, other: &U256, p: &U256) -> U256 {
        let (s, carry) = self.adc(other);
        if carry || !s.lt(p) {
            s.sbb(p).0
        } else {
            s
        }
    }

    /// Modular subtraction (inputs must be < p).
    pub fn sub_mod(&self, other: &U256, p: &U256) -> U256 {
        let (d, borrow) = self.sbb(other);
        if borrow {
            d.adc(p).0
        } else {
            d
        }
    }

    /// Modular doubling.
    pub fn dbl_mod(&self, p: &U256) -> U256 {
        let (s, carry) = self.shl1();
        if carry || !s.lt(p) {
            s.sbb(p).0
        } else {
            s
        }
    }

    /// Modular multiplication by interleaved shift-add (Russian peasant,
    /// MSB first). Inputs must be < p. 256 iterations of dbl+add.
    pub fn mul_mod(&self, other: &U256, p: &U256) -> U256 {
        let mut acc = U256::ZERO;
        let hb = match other.highest_bit() {
            Some(h) => h,
            None => return U256::ZERO,
        };
        for i in (0..=hb).rev() {
            acc = acc.dbl_mod(p);
            if other.bit(i) {
                acc = acc.add_mod(self, p);
            }
        }
        acc
    }

    /// Reduce an arbitrary U256 mod p (repeated conditional subtract is
    /// wrong for values ≫ p; use sub-until-below via the fact that inputs
    /// here are < 2^256 < 2p only when p > 2^255 — secp moduli qualify.
    /// For general p use `rem_general`).
    pub fn reduce_once(&self, p: &U256) -> U256 {
        if self.lt(p) {
            *self
        } else {
            self.sbb(p).0
        }
    }

    /// General remainder via binary long division (used for hashing
    /// arbitrary values into the field).
    pub fn rem_general(&self, p: &U256) -> U256 {
        assert!(!p.is_zero(), "division by zero modulus");
        if self.lt(p) {
            return *self;
        }
        let mut rem = U256::ZERO;
        let hb = self.highest_bit().unwrap();
        for i in (0..=hb).rev() {
            let (r2, _) = rem.shl1();
            rem = r2;
            if self.bit(i) {
                rem.0[0] |= 1;
            }
            if !rem.lt(p) {
                rem = rem.sbb(p).0;
            }
        }
        rem
    }

    /// Modular inverse by the binary extended GCD (p odd prime, self < p).
    pub fn inv_mod(&self, p: &U256) -> Option<U256> {
        if self.is_zero() {
            return None;
        }
        // Kaliski-style binary inversion: maintain
        //   u = self, v = p, x1, x2 with  u*x? ≡ ... (mod p)
        let mut u = *self;
        let mut v = *p;
        let mut x1 = U256::ONE;
        let mut x2 = U256::ZERO;
        while !u.is_zero() && u != U256::ONE && v != U256::ONE {
            while !u.is_zero() && !u.is_odd() {
                u = u.shr1();
                x1 = if x1.is_odd() { x1.adc(p).0.shr1_carry(x1.adc(p).1) } else { x1.shr1() };
            }
            while !v.is_odd() {
                v = v.shr1();
                x2 = if x2.is_odd() { x2.adc(p).0.shr1_carry(x2.adc(p).1) } else { x2.shr1() };
            }
            if !u.lt(&v) {
                u = u.sbb(&v).0;
                x1 = x1.sub_mod(&x2, p);
            } else {
                v = v.sbb(&u).0;
                x2 = x2.sub_mod(&x1, p);
            }
        }
        if u == U256::ONE {
            Some(x1.reduce_once(p))
        } else if v == U256::ONE {
            Some(x2.reduce_once(p))
        } else {
            None // gcd != 1 (p not prime or self shares a factor)
        }
    }

    /// Helper: shift right one bit bringing in `carry` as the new MSB.
    fn shr1_carry(&self, carry: bool) -> U256 {
        let mut out = self.shr1();
        if carry {
            out.0[3] |= 1u64 << 63;
        }
        out
    }
}

impl core::fmt::Debug for U256 {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(
            f,
            "U256(0x{:016x}{:016x}{:016x}{:016x})",
            self.0[3], self.0[2], self.0[1], self.0[0]
        )
    }
}

impl core::fmt::Display for U256 {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(
            f,
            "0x{:016x}{:016x}{:016x}{:016x}",
            self.0[3], self.0[2], self.0[1], self.0[0]
        )
    }
}

/// An element of a prime field with a runtime 256-bit modulus.
///
/// The modulus travels with the element; mixing moduli is a logic error
/// and panics in debug builds.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct FpBig {
    value: U256,
    modulus: U256,
}

impl FpBig {
    /// Construct, reducing `v` into the field.
    pub fn new(v: U256, modulus: U256) -> Self {
        Self { value: v.rem_general(&modulus), modulus }
    }

    /// The canonical value.
    pub fn value(&self) -> U256 {
        self.value
    }

    /// The modulus this element lives under.
    pub fn modulus(&self) -> U256 {
        self.modulus
    }

    #[inline]
    fn check(&self, rhs: &Self) {
        debug_assert_eq!(self.modulus, rhs.modulus, "mixed moduli");
    }
}

impl FieldElement for FpBig {
    fn zero() -> Self {
        // Modulus-less zero: adopt secp256k1 by convention. Binary ops
        // adopt the other operand's modulus when one side is this zero.
        Self { value: U256::ZERO, modulus: U256::SECP256K1_P }
    }

    fn one() -> Self {
        Self { value: U256::ONE, modulus: U256::SECP256K1_P }
    }

    fn is_zero(&self) -> bool {
        self.value.is_zero()
    }

    fn add(&self, rhs: &Self) -> Self {
        self.check(rhs);
        Self { value: self.value.add_mod(&rhs.value, &self.modulus), modulus: self.modulus }
    }

    fn sub(&self, rhs: &Self) -> Self {
        self.check(rhs);
        Self { value: self.value.sub_mod(&rhs.value, &self.modulus), modulus: self.modulus }
    }

    fn mul(&self, rhs: &Self) -> Self {
        self.check(rhs);
        Self { value: self.value.mul_mod(&rhs.value, &self.modulus), modulus: self.modulus }
    }

    fn neg(&self) -> Self {
        Self { value: U256::ZERO.sub_mod(&self.value, &self.modulus), modulus: self.modulus }
    }

    fn inverse(&self) -> Option<Self> {
        self.value.inv_mod(&self.modulus).map(|v| Self { value: v, modulus: self.modulus })
    }

    fn to_limbs(&self) -> [u64; 4] {
        self.value.0
    }

    fn from_u64(v: u64) -> Self {
        Self { value: U256::from_u64(v), modulus: U256::SECP256K1_P }
    }
}

impl core::fmt::Debug for FpBig {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "FpBig({:?})", self.value)
    }
}

impl core::fmt::Display for FpBig {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "{}", self.value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    fn rand_u256(r: &mut crate::rng::Rng) -> U256 {
        U256([r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()])
    }

    #[test]
    fn hex_roundtrip() {
        let v = U256::from_hex("fffffffefffffc2f");
        assert_eq!(v.0[0], 0xFFFF_FFFE_FFFF_FC2F);
        assert_eq!(v.0[1], 0);
        let p = U256::from_hex(
            "fffffffffffffffffffffffffffffffffffffffffffffffffffffffefffffc2f",
        );
        assert_eq!(p, U256::SECP256K1_P);
    }

    #[test]
    fn adc_sbb_inverse() {
        let mut r = rng_from_seed(1);
        for _ in 0..500 {
            let a = rand_u256(&mut r);
            let b = rand_u256(&mut r);
            let (s, c) = a.adc(&b);
            let (back, br) = s.sbb(&b);
            assert_eq!(back, a);
            assert_eq!(c, br);
        }
    }

    #[test]
    fn mul_mod_matches_small_reference() {
        let p = U256::from_u64(1_000_000_007);
        let mut r = rng_from_seed(2);
        for _ in 0..500 {
            let a = r.next_u64() % 1_000_000_007;
            let b = r.next_u64() % 1_000_000_007;
            let expect = (a as u128 * b as u128 % 1_000_000_007u128) as u64;
            let got = U256::from_u64(a).mul_mod(&U256::from_u64(b), &p);
            assert_eq!(got, U256::from_u64(expect));
        }
    }

    #[test]
    fn rem_general_matches_small_reference() {
        let mut r = rng_from_seed(3);
        for _ in 0..200 {
            let a = r.next_u64();
            let m = 1 + r.next_u64() % 1_000_000;
            assert_eq!(U256::from_u64(a).rem_general(&U256::from_u64(m)), U256::from_u64(a % m));
        }
    }

    #[test]
    fn inv_mod_on_secp_modulus() {
        let p = U256::SECP256K1_P;
        let mut r = rng_from_seed(4);
        for _ in 0..20 {
            let a = rand_u256(&mut r).rem_general(&p);
            if a.is_zero() {
                continue;
            }
            let inv = a.inv_mod(&p).expect("invertible");
            assert_eq!(a.mul_mod(&inv, &p), U256::ONE);
        }
    }

    #[test]
    fn inv_of_one_is_one() {
        assert_eq!(U256::ONE.inv_mod(&U256::SECP256K1_P), Some(U256::ONE));
    }

    #[test]
    fn shl_shr_roundtrip() {
        let mut r = rng_from_seed(5);
        for _ in 0..200 {
            let a = rand_u256(&mut r);
            let (s, carry) = a.shl1();
            let back = s.shr1_carry(carry);
            assert_eq!(back, a);
        }
    }

    #[test]
    fn highest_bit_examples() {
        assert_eq!(U256::ZERO.highest_bit(), None);
        assert_eq!(U256::ONE.highest_bit(), Some(0));
        assert_eq!(U256::from_u64(0x8000_0000_0000_0000).highest_bit(), Some(63));
        assert_eq!(U256([0, 1, 0, 0]).highest_bit(), Some(64));
    }
}
