//! F_{2^61 − 1}: the Mersenne-prime field used as the default simulation
//! field for MEA-ECC. Reduction is two shift-adds; inversion is Fermat.

use super::FieldElement;

/// The Mersenne prime 2^61 − 1.
pub const P61: u64 = (1u64 << 61) - 1;

/// An element of F_{2^61 − 1}, kept in canonical form `0 <= v < P61`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fp61(u64);

impl Fp61 {
    /// Construct, reducing mod p.
    #[inline]
    pub fn new(v: u64) -> Self {
        Self(v % P61)
    }

    /// Raw canonical value.
    #[inline]
    pub fn value(&self) -> u64 {
        self.0
    }

    /// Reduce a 128-bit product into the field. For Mersenne p = 2^61−1,
    /// x ≡ (x & p) + (x >> 61) (mod p), applied twice.
    #[inline]
    fn reduce128(x: u128) -> u64 {
        let lo = (x as u64) & P61;
        let hi = (x >> 61) as u64;
        let mut s = lo + (hi & P61) + (hi >> 61);
        if s >= P61 {
            s -= P61;
        }
        if s >= P61 {
            s -= P61;
        }
        s
    }

    /// Modular exponentiation (square-and-multiply).
    pub fn pow(&self, mut e: u64) -> Self {
        let mut base = *self;
        let mut acc = Self(1);
        while e > 0 {
            if e & 1 == 1 {
                acc = acc.mul(&base);
            }
            base = base.square();
            e >>= 1;
        }
        acc
    }
}

impl FieldElement for Fp61 {
    #[inline]
    fn zero() -> Self {
        Self(0)
    }

    #[inline]
    fn one() -> Self {
        Self(1)
    }

    #[inline]
    fn is_zero(&self) -> bool {
        self.0 == 0
    }

    #[inline]
    fn add(&self, rhs: &Self) -> Self {
        let mut s = self.0 + rhs.0; // < 2^62, no overflow
        if s >= P61 {
            s -= P61;
        }
        Self(s)
    }

    #[inline]
    fn sub(&self, rhs: &Self) -> Self {
        let s = if self.0 >= rhs.0 { self.0 - rhs.0 } else { self.0 + P61 - rhs.0 };
        Self(s)
    }

    #[inline]
    fn mul(&self, rhs: &Self) -> Self {
        Self(Self::reduce128(self.0 as u128 * rhs.0 as u128))
    }

    #[inline]
    fn neg(&self) -> Self {
        if self.0 == 0 {
            *self
        } else {
            Self(P61 - self.0)
        }
    }

    fn inverse(&self) -> Option<Self> {
        if self.is_zero() {
            None
        } else {
            // Fermat: a^(p-2) mod p.
            Some(self.pow(P61 - 2))
        }
    }

    fn to_limbs(&self) -> [u64; 4] {
        [self.0, 0, 0, 0]
    }

    fn from_u64(v: u64) -> Self {
        Self::new(v)
    }
}

/// Slice-batched field ops over raw canonical `u64` limbs.
///
/// These are the field-op hot-path entry points: callers that hold many
/// `Fp61` values as plain `u64`s (wire buffers, keystream-seed
/// derivation, batched share algebra) operate on whole slices instead
/// of element-at-a-time. `add_assign` and `reduce_assign` dispatch to
/// the SIMD lanes in [`crate::simd::fp61x`]; `mul_assign` stays scalar
/// — see the `simd::fp61x` module docs for why AVX2 offers no win on a
/// 61×61-bit product — but still amortizes bounds checks and exposes
/// the multiply chain to the out-of-order core.
///
/// All three agree bit-for-bit with the element-wise [`Fp61`] ops (the
/// parity tests below and `tests/simd_parity.rs` enforce it).
pub mod batch {
    use super::Fp61;

    /// `a[i] = (a[i] + b[i]) mod p` over canonical values.
    #[inline]
    pub fn add_assign(a: &mut [u64], b: &[u64]) {
        crate::simd::fp61x::add_assign(a, b);
    }

    /// `a[i] = (a[i] * b[i]) mod p` over canonical values. Scalar on
    /// every SIMD level (documented in `simd::fp61x`).
    pub fn mul_assign(a: &mut [u64], b: &[u64]) {
        debug_assert_eq!(a.len(), b.len());
        for (x, &y) in a.iter_mut().zip(b) {
            *x = Fp61::reduce128(*x as u128 * y as u128);
        }
    }

    /// Canonicalize arbitrary `u64`s: `a[i] = a[i] mod p`.
    #[inline]
    pub fn reduce_assign(a: &mut [u64]) {
        crate::simd::fp61x::reduce_assign(a);
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::field::fp61::P61;
        use crate::field::FieldElement;
        use crate::rng::rng_from_seed;

        #[test]
        fn batch_ops_match_element_ops() {
            let mut r = rng_from_seed(0xBA7C);
            let a: Vec<u64> = (0..513).map(|_| r.next_u64() % P61).collect();
            let b: Vec<u64> = (0..513).map(|_| r.next_u64() % P61).collect();

            let mut sum = a.clone();
            add_assign(&mut sum, &b);
            let mut prod = a.clone();
            mul_assign(&mut prod, &b);
            for i in 0..a.len() {
                let (x, y) = (Fp61::new(a[i]), Fp61::new(b[i]));
                assert_eq!(sum[i], x.add(&y).value(), "add i={i}");
                assert_eq!(prod[i], x.mul(&y).value(), "mul i={i}");
            }
        }

        #[test]
        fn batch_reduce_matches_new() {
            let mut r = rng_from_seed(0xBA7D);
            let mut vals: Vec<u64> = (0..300).map(|_| r.next_u64()).collect();
            vals.extend_from_slice(&[0, P61 - 1, P61, P61 + 1, u64::MAX]);
            let raw = vals.clone();
            reduce_assign(&mut vals);
            for (i, &v) in raw.iter().enumerate() {
                assert_eq!(vals[i], Fp61::new(v).value(), "v={v}");
            }
        }
    }
}

impl core::fmt::Debug for Fp61 {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "Fp61({})", self.0)
    }
}

impl core::fmt::Display for Fp61 {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn canonical_form_after_new() {
        assert_eq!(Fp61::new(P61).value(), 0);
        assert_eq!(Fp61::new(P61 + 5).value(), 5);
        assert_eq!(Fp61::new(u64::MAX).value(), u64::MAX % P61);
    }

    #[test]
    fn mul_matches_u128_reference() {
        let mut r = rng_from_seed(101);
        for _ in 0..2000 {
            let a = r.next_u64() % P61;
            let b = r.next_u64() % P61;
            let expect = ((a as u128 * b as u128) % P61 as u128) as u64;
            assert_eq!(Fp61::new(a).mul(&Fp61::new(b)).value(), expect);
        }
    }

    #[test]
    fn inverse_roundtrip_randomized() {
        let mut r = rng_from_seed(77);
        for _ in 0..200 {
            let a = Fp61::new(r.next_u64());
            if a.is_zero() {
                continue;
            }
            let inv = a.inverse().unwrap();
            assert_eq!(a.mul(&inv), Fp61::one());
        }
    }

    #[test]
    fn zero_has_no_inverse() {
        assert!(Fp61::zero().inverse().is_none());
    }

    #[test]
    fn pow_small_cases() {
        let a = Fp61::new(3);
        assert_eq!(a.pow(0), Fp61::one());
        assert_eq!(a.pow(1), a);
        assert_eq!(a.pow(4).value(), 81);
    }

    #[test]
    fn sub_wraps_correctly() {
        let a = Fp61::new(2);
        let b = Fp61::new(5);
        assert_eq!(a.sub(&b).add(&b), a);
    }
}
