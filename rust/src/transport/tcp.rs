//! The TCP fabric: localhost sockets, one connection per worker,
//! length-prefixed frames.
//!
//! Wiring (all on 127.0.0.1, ephemeral port): the master binds a
//! listener, then for each worker dials one connection and accepts its
//! peer — dial and accept are paired serially, so link `w` is
//! unambiguous without a handshake. The accepted (worker-side) socket
//! becomes that worker's [`WorkerLink`]; the dialing (master-side)
//! socket is kept for order writes, and a clone of it feeds one *bridge
//! thread* that reads result frames off the socket into the merged
//! inbound channel. The master therefore consumes one
//! `Receiver<Vec<u8>>` regardless of fabric — the bridge is the only
//! TCP-specific reader.
//!
//! Relink (worker respawn): the listener stays bound for the fabric's
//! lifetime, so [`Transport::relink`] dials/accepts a fresh connection
//! pair for the worker, swaps the new socket into the send slot, and
//! spawns a new bridge — the same dial/accept pairing as bring-up. The
//! old connection is retired *gracefully*: the master-side handle is
//! dropped (FIN is ordered after any order frames already written, so a
//! dying incarnation still drains its queue), and the old bridge keeps
//! reading the old incarnation's in-flight result frames until that
//! worker closes its end. This mirrors the in-proc fabric, where the
//! replaced order sender disconnects only after the old receiver drains
//! — and it is what keeps round outcomes independent of *when* a
//! scheduled respawn lands relative to older in-flight rounds
//! (DESIGN.md §8).
//!
//! Shutdown: dropping the [`Tcp`] sender shuts both directions of every
//! master-side socket. Workers see EOF (`WireError::Closed`) and exit;
//! bridge threads see EOF and exit, dropping their inbound senders,
//! which disconnects the collector. Drop then joins the bridges.

use super::{Fabric, LoadBook, Transport, TransportError, WorkerLink};
use crate::config::TransportKind;
use crate::metrics::{names, MetricsRegistry};
use crate::wire;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Master-side sender over per-worker localhost sockets.
pub struct Tcp {
    /// Kept bound so respawned workers can be re-accepted.
    listener: TcpListener,
    addr: SocketAddr,
    streams: Vec<Mutex<TcpStream>>,
    /// Kept so relinked bridges can feed the same merged inbound channel.
    result_tx: Sender<Vec<u8>>,
    metrics: Arc<MetricsRegistry>,
    bridges: Mutex<Vec<JoinHandle<()>>>,
}

impl Tcp {
    /// Wire `n` socket links plus the bridged inbound channel.
    pub fn connect(n: usize, metrics: Arc<MetricsRegistry>) -> Result<Fabric, TransportError> {
        let setup = |e: std::io::Error| TransportError::Setup(e.to_string());
        let listener = TcpListener::bind(("127.0.0.1", 0)).map_err(setup)?;
        let addr = listener.local_addr().map_err(setup)?;
        let (result_tx, inbound) = mpsc::channel::<Vec<u8>>();
        let mut streams = Vec::with_capacity(n);
        let mut bridges = Vec::with_capacity(n);
        let mut links = Vec::with_capacity(n);
        for w in 0..n {
            let (master_side, worker_side) = Self::dial_pair(&listener, addr)?;
            let reader = master_side.try_clone().map_err(setup)?;
            bridges.push(spawn_bridge(w, reader, result_tx.clone()));
            streams.push(Mutex::new(master_side));
            links.push(WorkerLink::Tcp { stream: worker_side });
        }
        let transport = Box::new(Tcp {
            listener,
            addr,
            streams,
            result_tx,
            metrics,
            bridges: Mutex::new(bridges),
        });
        Ok(Fabric { transport, inbound, links, load: Arc::new(LoadBook::new(n)) })
    }

    /// Dial one connection and accept its peer — serial, so the pairing
    /// is unambiguous.
    fn dial_pair(
        listener: &TcpListener,
        addr: SocketAddr,
    ) -> Result<(TcpStream, TcpStream), TransportError> {
        let setup = |e: std::io::Error| TransportError::Setup(e.to_string());
        let master_side = TcpStream::connect(addr).map_err(setup)?;
        let (worker_side, _) = listener.accept().map_err(setup)?;
        master_side.set_nodelay(true).map_err(setup)?;
        worker_side.set_nodelay(true).map_err(setup)?;
        Ok((master_side, worker_side))
    }
}

/// One bridge per connection: result frames socket → merged channel.
/// Shared with the process fabric, whose sockets carry the same frames.
pub(super) fn spawn_bridge(w: usize, mut reader: TcpStream, tx: Sender<Vec<u8>>) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("tcp-bridge-{w}"))
        .spawn(move || loop {
            match wire::read_frame(&mut reader) {
                Ok(frame) => {
                    if tx.send(frame).is_err() {
                        break; // collector gone
                    }
                }
                Err(_) => break, // EOF, shutdown, or a poisoned stream
            }
        })
        .expect("spawn tcp bridge")
}

impl Transport for Tcp {
    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }

    fn workers(&self) -> usize {
        self.streams.len()
    }

    fn send(&self, w: usize, frame: Vec<u8>) -> Result<(), TransportError> {
        let stream = self.streams.get(w).ok_or_else(|| TransportError::WorkerDown {
            worker: w,
            detail: format!("no such link (fabric has {})", self.streams.len()),
        })?;
        let mut s = stream.lock().unwrap();
        s.write_all(&frame).map_err(|e| TransportError::WorkerDown {
            worker: w,
            detail: format!("socket write failed: {e}"),
        })?;
        self.metrics.add(names::BYTES_TX, frame.len() as u64);
        Ok(())
    }

    fn relink(&self, w: usize) -> Result<WorkerLink, TransportError> {
        let slot = self.streams.get(w).ok_or_else(|| TransportError::WorkerDown {
            worker: w,
            detail: format!("no such link (fabric has {})", self.streams.len()),
        })?;
        let (master_side, worker_side) = Self::dial_pair(&self.listener, self.addr)?;
        let reader = master_side
            .try_clone()
            .map_err(|e| TransportError::Setup(e.to_string()))?;
        {
            let mut s = slot.lock().unwrap();
            // Retire the old connection gracefully: dropping the
            // master-side handle queues a FIN *behind* any order frames
            // already written, so a dying old incarnation still drains
            // its queue; its in-flight replies keep flowing through the
            // old bridge (which holds its own clone of the socket and
            // exits on the worker-side close). An explicit
            // Shutdown::Both here would discard both — and make round
            // outcomes depend on respawn timing.
            *s = master_side;
        }
        self.bridges.lock().unwrap().push(spawn_bridge(w, reader, self.result_tx.clone()));
        Ok(WorkerLink::Tcp { stream: worker_side })
    }
}

impl Drop for Tcp {
    fn drop(&mut self) {
        for s in &self.streams {
            let _ = s.lock().unwrap().shutdown(Shutdown::Both);
        }
        for b in self.bridges.lock().unwrap().drain(..) {
            let _ = b.join();
        }
    }
}
