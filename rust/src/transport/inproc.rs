//! The in-process fabric: per-worker `mpsc` channels carrying frames.
//!
//! Functionally identical to [`Tcp`](super::Tcp) — the same serialized
//! bytes move, the same counters tick — minus the syscalls. This is the
//! default fabric for tests, benches, and single-machine runs.

use super::{Fabric, Transport, TransportError, WorkerLink};
use crate::config::TransportKind;
use crate::metrics::{names, MetricsRegistry};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;

/// Master-side sender over per-worker channels.
pub struct InProc {
    order_txs: Vec<Sender<Vec<u8>>>,
    metrics: Arc<MetricsRegistry>,
}

impl InProc {
    /// Wire `n` channel links plus the merged inbound channel.
    pub fn connect(n: usize, metrics: Arc<MetricsRegistry>) -> Fabric {
        let (result_tx, inbound) = mpsc::channel::<Vec<u8>>();
        let mut order_txs = Vec::with_capacity(n);
        let mut links = Vec::with_capacity(n);
        for _ in 0..n {
            let (order_tx, order_rx) = mpsc::channel::<Vec<u8>>();
            order_txs.push(order_tx);
            links.push(WorkerLink::InProc { orders: order_rx, results: result_tx.clone() });
        }
        let transport = Box::new(InProc { order_txs, metrics });
        Fabric { transport, inbound, links }
    }
}

impl Transport for InProc {
    fn kind(&self) -> TransportKind {
        TransportKind::InProc
    }

    fn workers(&self) -> usize {
        self.order_txs.len()
    }

    fn send(&self, w: usize, frame: Vec<u8>) -> Result<(), TransportError> {
        let tx = self.order_txs.get(w).ok_or_else(|| TransportError::WorkerDown {
            worker: w,
            detail: format!("no such link (fabric has {})", self.order_txs.len()),
        })?;
        let len = frame.len() as u64;
        tx.send(frame).map_err(|_| TransportError::WorkerDown {
            worker: w,
            detail: "order channel disconnected".into(),
        })?;
        self.metrics.add(names::BYTES_TX, len);
        Ok(())
    }
}
