//! The in-process fabric: per-worker `mpsc` channels carrying frames.
//!
//! Functionally identical to [`Tcp`](super::Tcp) — the same serialized
//! bytes move, the same counters tick — minus the syscalls. This is the
//! default fabric for tests, benches, and single-machine runs.

use super::{Fabric, LoadBook, Transport, TransportError, WorkerLink};
use crate::config::TransportKind;
use crate::metrics::{names, MetricsRegistry};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};

/// Master-side sender over per-worker channels.
pub struct InProc {
    /// Per-worker order senders. Mutexed so [`Transport::relink`] can
    /// swap in a fresh channel for a respawned worker while the sender
    /// half stays shareable across threads.
    order_txs: Vec<Mutex<Sender<Vec<u8>>>>,
    /// Kept so respawned links can feed the same merged inbound channel.
    /// Dropped with the transport at shutdown, which (once every worker
    /// clone is gone too) disconnects the collector.
    result_tx: Sender<Vec<u8>>,
    metrics: Arc<MetricsRegistry>,
}

impl InProc {
    /// Wire `n` channel links plus the merged inbound channel.
    pub fn connect(n: usize, metrics: Arc<MetricsRegistry>) -> Fabric {
        let (result_tx, inbound) = mpsc::channel::<Vec<u8>>();
        let mut order_txs = Vec::with_capacity(n);
        let mut links = Vec::with_capacity(n);
        for _ in 0..n {
            let (order_tx, order_rx) = mpsc::channel::<Vec<u8>>();
            order_txs.push(Mutex::new(order_tx));
            links.push(WorkerLink::InProc { orders: order_rx, results: result_tx.clone() });
        }
        let transport = Box::new(InProc { order_txs, result_tx, metrics });
        Fabric { transport, inbound, links, load: Arc::new(LoadBook::new(n)) }
    }
}

impl Transport for InProc {
    fn kind(&self) -> TransportKind {
        TransportKind::InProc
    }

    fn workers(&self) -> usize {
        self.order_txs.len()
    }

    fn send(&self, w: usize, frame: Vec<u8>) -> Result<(), TransportError> {
        let tx = self.order_txs.get(w).ok_or_else(|| TransportError::WorkerDown {
            worker: w,
            detail: format!("no such link (fabric has {})", self.order_txs.len()),
        })?;
        let len = frame.len() as u64;
        tx.lock().unwrap().send(frame).map_err(|_| TransportError::WorkerDown {
            worker: w,
            detail: "order channel disconnected".into(),
        })?;
        self.metrics.add(names::BYTES_TX, len);
        Ok(())
    }

    fn relink(&self, w: usize) -> Result<WorkerLink, TransportError> {
        let slot = self.order_txs.get(w).ok_or_else(|| TransportError::WorkerDown {
            worker: w,
            detail: format!("no such link (fabric has {})", self.order_txs.len()),
        })?;
        let (order_tx, order_rx) = mpsc::channel::<Vec<u8>>();
        // Swapping the sender drops the old one; a dead worker's orphaned
        // receiver (if any) disconnects cleanly.
        *slot.lock().unwrap() = order_tx;
        Ok(WorkerLink::InProc { orders: order_rx, results: self.result_tx.clone() })
    }
}
