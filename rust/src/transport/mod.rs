//! Pluggable master↔worker transports — DESIGN.md §5.
//!
//! The coordinator never touches channels or sockets directly: it sends
//! framed bytes ([`crate::wire`]) through a [`Transport`] and receives
//! result frames from a single merged inbound channel. Two fabrics
//! implement the contract, selected by the `transport` config key /
//! `--transport` CLI flag ([`TransportKind`](crate::config::TransportKind)):
//!
//! * [`InProc`] — per-worker `mpsc` channels carrying the *same frames*
//!   TCP would carry. The default: zero syscalls, but every byte is still
//!   serialized, checksummed, and counted.
//! * [`Tcp`] — localhost sockets, one connection per worker,
//!   length-prefixed frames. One bridge thread per connection reads
//!   result frames off its socket into the merged inbound channel, so
//!   the master side is transport-agnostic. This is the gateway to
//!   out-of-process workers: the worker loop already speaks only bytes.
//! * [`Proc`] — real child processes (DESIGN.md §9): each worker is a
//!   `spacdc worker` process that dials the master's listener and is
//!   identified by the first frame it sends (its `Register`). A
//!   [`Supervisor`](crate::coordinator::Supervisor) tracks every
//!   child's pid, generation, and exit status; respawn is a real
//!   SIGKILL + re-exec, not a thread swap.
//!
//! [`connect`] wires a whole fabric at once and returns the three parts:
//! the master-side sender ([`Transport`]), the merged inbound receiver,
//! and one [`WorkerLink`] endpoint per worker (moved into the worker
//! threads by [`WorkerPool`](crate::coordinator::WorkerPool)).
//!
//! Byte accounting: `Transport::send` counts `comm.bytes_tx` at the
//! moment a frame enters the fabric; the master's collector thread
//! counts `comm.bytes_rx` as frames leave it (`coordinator/master.rs`),
//! so both counters measure real serialized frames, whatever the fabric.

mod inproc;
mod proc;
mod tcp;

pub use inproc::InProc;
pub use proc::{Proc, ProcConfig, WORKER_EXE_ENV};
pub use tcp::Tcp;

use crate::coordinator::ExitLog;

use crate::config::TransportKind;
use crate::metrics::MetricsRegistry;
use crate::wire::{self, WireError};
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Transport failure modes.
#[derive(Debug)]
pub enum TransportError {
    /// The link to one worker is down (thread dead / socket closed).
    /// The coordinator treats such a worker as a permanent straggler.
    WorkerDown {
        /// Which worker's link failed.
        worker: usize,
        /// Underlying cause.
        detail: String,
    },
    /// The fabric could not be wired up.
    Setup(String),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::WorkerDown { worker, detail } => {
                write!(f, "link to worker {worker} is down: {detail}")
            }
            TransportError::Setup(msg) => write!(f, "transport setup failed: {msg}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// The master-side sender half of a wired fabric: delivers one frame to
/// one worker. Implementations count every sent byte into
/// `comm.bytes_tx`.
///
/// `send` takes the frame by value: the dispatch path builds one owned
/// frame per worker anyway, and the in-proc fabric can move it straight
/// into the channel without a copy (TCP writes from the buffer either
/// way).
pub trait Transport: Send + Sync {
    /// Which fabric this is.
    fn kind(&self) -> TransportKind;

    /// Number of worker links.
    fn workers(&self) -> usize;

    /// Send one complete frame to worker `w`.
    fn send(&self, w: usize, frame: Vec<u8>) -> Result<(), TransportError>;

    /// Tear down worker `w`'s link and wire a fresh one in its place,
    /// returning the new worker-side endpoint (moved into the respawned
    /// worker thread by
    /// [`WorkerPool::respawn`](crate::coordinator::WorkerPool::respawn)).
    /// The old endpoint — wherever it is — sees its link as closed.
    fn relink(&self, w: usize) -> Result<WorkerLink, TransportError>;

    /// Does this fabric run workers as separate OS processes? When
    /// true, the pool spawns no worker threads (the fabric's `links`
    /// are empty) and respawn goes through [`respawn_process`]
    /// (Transport::respawn_process) instead of [`relink`]
    /// (Transport::relink).
    fn out_of_process(&self) -> bool {
        false
    }

    /// Process fabrics only: SIGKILL/reap worker `w`'s child, spawn a
    /// replacement incarnation of `generation`, and forward its
    /// `Register` frame into the merged inbound channel (the master's
    /// collector installs it). Thread fabrics never route here.
    fn respawn_process(&self, w: usize, generation: u32) -> Result<(), TransportError> {
        let _ = (w, generation);
        Err(TransportError::Setup("not a process fabric".into()))
    }

    /// Process fabrics only: a live handle to the supervisor's
    /// per-child exit records. The testbed reads it *after* teardown,
    /// when shutdown kills have been recorded too.
    fn exit_records(&self) -> Option<ExitLog> {
        None
    }
}

/// A worker's endpoint of the fabric: a blocking source of order frames
/// and a sink for result frames. Moved into the worker thread.
pub enum WorkerLink {
    /// In-process channel pair.
    InProc {
        /// Order frames from the master.
        orders: Receiver<Vec<u8>>,
        /// Result frames back to the master (merged inbound channel).
        results: Sender<Vec<u8>>,
    },
    /// The worker side of one TCP connection.
    Tcp {
        /// Full-duplex socket: orders are read from it, results written.
        stream: TcpStream,
    },
}

impl WorkerLink {
    /// Block for the next order frame. [`WireError::Closed`] means the
    /// master hung up and the worker loop should exit.
    pub fn recv(&mut self) -> Result<Vec<u8>, WireError> {
        match self {
            WorkerLink::InProc { orders, .. } => {
                orders.recv().map_err(|_| WireError::Closed)
            }
            WorkerLink::Tcp { stream } => wire::read_frame(stream),
        }
    }

    /// Send one result frame to the master. Errors mean the master side
    /// is gone and the worker loop should exit.
    pub fn send(&mut self, frame: &[u8]) -> Result<(), WireError> {
        match self {
            WorkerLink::InProc { results, .. } => {
                results.send(frame.to_vec()).map_err(|_| WireError::Closed)
            }
            WorkerLink::Tcp { stream } => {
                stream.write_all(frame)?;
                Ok(())
            }
        }
    }
}

/// Per-worker backlog signal riding alongside the fabric: how many work
/// orders each link is carrying that have not been settled yet. The
/// master's dispatch paths tick [`note_sent`](LoadBook::note_sent) per
/// order and [`settle`](LoadBook::settle) the whole batch when the
/// round retires, so `outstanding(w) == 0` means "worker `w` owes
/// nothing on its link" — the idle-worker signal the speculative
/// re-dispatcher keys on. All updates happen on the master thread, so
/// readings there are deterministic; the counters are atomics only so
/// the book can be shared with observers on other threads.
///
/// Settling is per *result* since wire v2: result frames carry the
/// executor id, so the collector settles one order against the worker
/// that actually ran it the moment its result lands
/// ([`settle_one`](LoadBook::settle_one)). Orders whose results never
/// come home — crashed workers, corrupted frames, speculation losers
/// that died — are settled as a batch when their round retires
/// ([`settle`](LoadBook::settle) over the unsettled remainder), so the
/// book always returns to "idle" once a round is done.
#[derive(Debug)]
pub struct LoadBook {
    outstanding: Vec<AtomicU64>,
}

impl LoadBook {
    /// A book of `n` idle workers.
    pub fn new(n: usize) -> Self {
        Self { outstanding: (0..n).map(|_| AtomicU64::new(0)).collect() }
    }

    /// One order went out to worker `w`.
    pub fn note_sent(&self, w: usize) {
        if let Some(c) = self.outstanding.get(w) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Settle one order against worker `w` — the per-result path, taken
    /// by the collector the moment a result frame lands, keyed on the
    /// frame's executor id.
    pub fn settle_one(&self, w: usize) {
        if let Some(c) = self.outstanding.get(w) {
            // Saturating: a double-settle must not wrap the signal.
            let _ = c.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
        }
    }

    /// Settle a retired round's orders: one per entry in `targets`.
    pub fn settle(&self, targets: &[usize]) {
        for &w in targets {
            if let Some(c) = self.outstanding.get(w) {
                // Saturating: a double-settle must not wrap the signal.
                let _ = c.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    Some(v.saturating_sub(1))
                });
            }
        }
    }

    /// Orders worker `w` is still carrying.
    pub fn outstanding(&self, w: usize) -> u64 {
        self.outstanding.get(w).map_or(0, |c| c.load(Ordering::Relaxed))
    }

    /// Is worker `w` idle (nothing outstanding on its link)?
    pub fn is_idle(&self, w: usize) -> bool {
        self.outstanding(w) == 0
    }

    /// The least-loaded worker among those `eligible`, ties broken by
    /// the lowest index (deterministic). `None` when nothing is
    /// eligible.
    pub fn least_loaded(&self, eligible: impl Iterator<Item = usize>) -> Option<usize> {
        eligible.map(|w| (self.outstanding(w), w)).min().map(|(_, w)| w)
    }
}

/// A fully wired fabric, ready to hand to the worker pool.
pub struct Fabric {
    /// Master-side sender.
    pub transport: Box<dyn Transport>,
    /// Merged worker→master result frames (consumed by the collector).
    pub inbound: Receiver<Vec<u8>>,
    /// One endpoint per worker, index-aligned.
    pub links: Vec<WorkerLink>,
    /// Per-worker backlog signal (see [`LoadBook`]).
    pub load: Arc<LoadBook>,
}

/// Wire up a fabric of `n` worker links of the given kind.
pub fn connect(
    kind: TransportKind,
    n: usize,
    metrics: Arc<MetricsRegistry>,
) -> Result<Fabric, TransportError> {
    match kind {
        TransportKind::InProc => Ok(InProc::connect(n, metrics)),
        TransportKind::Tcp => Tcp::connect(n, metrics),
        // The process fabric needs the worker harness parameters (seed,
        // master pk, fault plan) for its children's command lines —
        // WorkerPool::spawn wires it via Proc::connect directly.
        TransportKind::Proc => Err(TransportError::Setup(
            "the process fabric needs spawn parameters; wire it through WorkerPool::spawn".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::names;
    use crate::wire::{frame, MsgKind};

    fn echo_fabric_check(kind: TransportKind) {
        let metrics = Arc::new(MetricsRegistry::new());
        let fabric = connect(kind, 3, Arc::clone(&metrics)).unwrap();
        // Workers echo every order frame back as-is.
        let joins: Vec<_> = fabric
            .links
            .into_iter()
            .map(|mut link| {
                std::thread::spawn(move || {
                    while let Ok(f) = link.recv() {
                        if link.send(&f).is_err() {
                            break;
                        }
                    }
                })
            })
            .collect();
        let frames: Vec<Vec<u8>> = (0..3)
            .map(|w| frame(MsgKind::Order, format!("order for {w}").as_bytes()))
            .collect();
        for (w, f) in frames.iter().enumerate() {
            fabric.transport.send(w, f.clone()).unwrap();
        }
        let mut got: Vec<Vec<u8>> = (0..3)
            .map(|_| {
                fabric
                    .inbound
                    .recv_timeout(std::time::Duration::from_secs(5))
                    .expect("echo frame")
            })
            .collect();
        got.sort();
        let mut want = frames.clone();
        want.sort();
        assert_eq!(got, want);
        let tx: u64 = frames.iter().map(|f| f.len() as u64).sum();
        assert_eq!(metrics.get(names::BYTES_TX), tx);
        drop(fabric.transport); // closes the links → workers exit
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn inproc_fabric_echoes_frames_and_counts_bytes() {
        echo_fabric_check(TransportKind::InProc);
    }

    #[test]
    fn tcp_fabric_echoes_frames_and_counts_bytes() {
        echo_fabric_check(TransportKind::Tcp);
    }

    fn relink_check(kind: TransportKind) {
        let metrics = Arc::new(MetricsRegistry::new());
        let mut fabric = connect(kind, 2, Arc::clone(&metrics)).unwrap();
        // Worker 0 dies: its endpoint is simply dropped.
        let dead = fabric.links.remove(0);
        drop(dead);
        // Revive it on a fresh link and run an echo loop there.
        let mut link = fabric.transport.relink(0).unwrap();
        let j = std::thread::spawn(move || {
            while let Ok(f) = link.recv() {
                if link.send(&f).is_err() {
                    break;
                }
            }
        });
        let f = frame(MsgKind::Order, b"after respawn");
        fabric.transport.send(0, f.clone()).unwrap();
        let got = fabric
            .inbound
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("echo from respawned link");
        assert_eq!(got, f);
        drop(fabric.transport);
        drop(fabric.links); // remaining worker endpoint
        j.join().unwrap();
    }

    #[test]
    fn inproc_relink_revives_a_worker() {
        relink_check(TransportKind::InProc);
    }

    #[test]
    fn tcp_relink_revives_a_worker() {
        relink_check(TransportKind::Tcp);
    }

    #[test]
    fn send_to_dead_worker_is_a_typed_error() {
        let metrics = Arc::new(MetricsRegistry::new());
        let fabric = connect(TransportKind::InProc, 2, metrics).unwrap();
        drop(fabric.links); // every worker endpoint gone
        let f = frame(MsgKind::Order, b"x");
        match fabric.transport.send(0, f) {
            Err(TransportError::WorkerDown { worker: 0, .. }) => {}
            other => panic!("expected WorkerDown, got {other:?}"),
        }
    }
}
