//! The process fabric: real `spacdc worker` child processes over
//! localhost TCP, under a [`Supervisor`] — DESIGN.md §9.
//!
//! The TCP fabric ([`super::tcp`]) exercises the wire format but both
//! endpoints still live in the master's address space, so "crash" means
//! a thread returning and "respawn" means swapping a socket. Here the
//! endpoints are genuinely separate OS processes: the master binds a
//! listener, forks `n` children of its own executable running the
//! `worker` subcommand, and each child dials back and *introduces
//! itself* — the first frame on every inbound connection must be a
//! `Register { worker, generation, pk }` control frame, which both
//! identifies the connection (no dial/accept pairing trick works across
//! processes) and is forwarded verbatim into the merged inbound channel
//! so the pool's bring-up drain and the collector's directory see the
//! exact handshake the in-proc fabrics produce.
//!
//! Respawn is the real thing: [`Transport::respawn_process`] SIGKILLs
//! the old child through the [`Supervisor`] (capturing its exit status
//! — signal 9 — in the shared [`ExitLog`]), spawns a replacement with
//! the bumped generation on its command line, and waits for the new
//! child's `Register` before swapping the send slot. Crashed children
//! *park* rather than exit ([`crate::coordinator::WorkerHarness`]), so
//! the SIGKILL is the actual cause of death and the exit log is
//! evidence the fault plan ran at the OS level.
//!
//! A connection that dies (or stalls, or talks junk) before completing
//! its `Register` is reaped: the socket is dropped and the accept loop
//! keeps going until the deadline. That makes half-open sockets a
//! bounded nuisance rather than a bring-up wedge.
//!
//! Teardown: the supervisor SIGTERMs (then SIGKILLs) every child;
//! workers that lost their master earlier already exited on socket EOF.
//! The supervisor's `Drop` is the backstop for panics and Ctrl-C paths
//! that skip orderly shutdown, so the testbed never leaks children.

use super::tcp::spawn_bridge;
use super::{Fabric, LoadBook, Transport, TransportError, WorkerLink};
use crate::config::TransportKind;
use crate::coordinator::{ControlMsg, ExitLog, Supervisor};
use crate::ecc::Point;
use crate::field::Fp61;
use crate::metrics::{names, MetricsRegistry};
use crate::sim::FaultPlan;
use crate::wire::{self, WireMessage};
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::Command;
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Env override for the worker executable (used by CI and tests whose
/// own binary is not `spacdc`, e.g. `cargo test` runners).
pub const WORKER_EXE_ENV: &str = "SPACDC_WORKER_EXE";

/// Everything a child process needs on its command line.
#[derive(Clone)]
pub struct ProcConfig {
    /// Master seed; children derive per-worker noise exactly like
    /// in-proc incarnations do.
    pub seed: u64,
    /// Master's public key, hex-encoded onto the child's command line
    /// so sealed results verify.
    pub master_pk: Point<Fp61>,
    /// Fault plan forwarded to children (`--crashes`/`--corrupt-rate`);
    /// `None` means a clean run.
    pub faults: Option<Arc<FaultPlan>>,
}

/// Master-side sender over per-child localhost sockets.
pub struct Proc {
    /// Kept bound so respawned children can dial back in.
    listener: TcpListener,
    addr: SocketAddr,
    streams: Vec<Mutex<TcpStream>>,
    result_tx: Sender<Vec<u8>>,
    metrics: Arc<MetricsRegistry>,
    bridges: Mutex<Vec<JoinHandle<()>>>,
    supervisor: Mutex<Supervisor>,
    exe: PathBuf,
    cfg: ProcConfig,
}

/// How long bring-up waits for all `n` children to register.
const BRINGUP_DEADLINE: Duration = Duration::from_secs(30);
/// How long a respawned child gets to dial back and register.
const RESPAWN_DEADLINE: Duration = Duration::from_secs(10);
/// Per-connection cap on reading the identifying `Register` frame — a
/// half-open socket can stall at most this long before being reaped.
const IDENT_TIMEOUT: Duration = Duration::from_secs(1);

impl Proc {
    /// Fork `n` worker processes and wait for each to register.
    ///
    /// The returned fabric has *no* [`WorkerLink`]s — the workers run in
    /// their own processes, so [`crate::coordinator::WorkerPool`] spawns
    /// no threads. Each child's `Register` frame is forwarded into the
    /// inbound channel before this returns, so the pool's usual
    /// bring-up drain sees `n` registrations just like any other fabric.
    pub fn connect(
        n: usize,
        cfg: ProcConfig,
        metrics: Arc<MetricsRegistry>,
    ) -> Result<Fabric, TransportError> {
        let setup = |e: std::io::Error| TransportError::Setup(e.to_string());
        let listener = TcpListener::bind(("127.0.0.1", 0)).map_err(setup)?;
        let addr = listener.local_addr().map_err(setup)?;
        let exe = worker_exe()?;
        let (result_tx, inbound) = mpsc::channel::<Vec<u8>>();

        let mut supervisor = Supervisor::new(n);
        for w in 0..n {
            let mut cmd = worker_command(&exe, addr, w, 0, &cfg);
            supervisor
                .spawn(w, 0, &mut cmd)
                .map_err(|e| TransportError::Setup(format!("spawn worker {w}: {e}")))?;
        }

        // Children dial back in arrival order, not worker order: sort
        // them out by the worker id each one registers with.
        let deadline = Instant::now() + BRINGUP_DEADLINE;
        let mut slots: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        let mut bridges = Vec::with_capacity(n);
        while slots.iter().any(Option::is_none) {
            let (stream, frame, worker, generation) = accept_registered(&listener, deadline)?;
            if worker >= n || generation != 0 || slots[worker].is_some() {
                // Not a child of ours (or a duplicate): reap it.
                let _ = stream.shutdown(Shutdown::Both);
                continue;
            }
            let reader = stream.try_clone().map_err(setup)?;
            bridges.push(spawn_bridge(worker, reader, result_tx.clone()));
            slots[worker] = Some(stream);
            result_tx
                .send(frame)
                .map_err(|_| TransportError::Setup("inbound channel closed during bring-up".into()))?;
        }
        let streams = slots.into_iter().map(|s| Mutex::new(s.unwrap())).collect();

        let transport = Box::new(Proc {
            listener,
            addr,
            streams,
            result_tx,
            metrics,
            bridges: Mutex::new(bridges),
            supervisor: Mutex::new(supervisor),
            exe,
            cfg,
        });
        Ok(Fabric { transport, inbound, links: Vec::new(), load: Arc::new(LoadBook::new(n)) })
    }
}

/// Accept connections until one completes a `Register` handshake;
/// reap any that die, stall, or talk junk before identifying.
///
/// Returns the socket, the raw `Register` frame (for forwarding), and
/// the claimed worker id + generation.
fn accept_registered(
    listener: &TcpListener,
    deadline: Instant,
) -> Result<(TcpStream, Vec<u8>, usize, u32), TransportError> {
    let setup = |e: std::io::Error| TransportError::Setup(e.to_string());
    listener.set_nonblocking(true).map_err(setup)?;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            listener.set_nonblocking(false).map_err(setup)?;
            return Err(TransportError::Setup(
                "timed out waiting for a worker process to register".into(),
            ));
        }
        match listener.accept() {
            Ok((stream, _)) => {
                listener.set_nonblocking(false).map_err(setup)?;
                match identify(stream, remaining) {
                    Some(registered) => return Ok(registered),
                    None => {
                        // Connect-then-die, half-open stall, or junk:
                        // the socket was dropped. Keep accepting.
                        listener.set_nonblocking(true).map_err(setup)?;
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                listener.set_nonblocking(false).map_err(setup)?;
                return Err(setup(e));
            }
        }
    }
}

/// Read and validate the identifying first frame off a fresh
/// connection. `None` (socket dropped) if the peer hangs up, stalls
/// past the ident timeout, or sends anything but a `Register`.
fn identify(stream: TcpStream, remaining: Duration) -> Option<(TcpStream, Vec<u8>, usize, u32)> {
    stream.set_nonblocking(false).ok()?;
    stream.set_nodelay(true).ok()?;
    stream.set_read_timeout(Some(remaining.min(IDENT_TIMEOUT))).ok()?;
    let mut reader = stream.try_clone().ok()?;
    let frame = wire::read_frame(&mut reader).ok()?;
    let (worker, generation) = match wire::decode_message(&frame) {
        Ok(WireMessage::Control(ControlMsg::Register { worker, generation, .. })) => {
            (worker, generation)
        }
        _ => return None,
    };
    stream.set_read_timeout(None).ok()?;
    Some((stream, frame, worker, generation))
}

/// Resolve the `spacdc` executable to fork workers from: the
/// `SPACDC_WORKER_EXE` env override, the current executable if it *is*
/// `spacdc`, or a sibling `spacdc` next to (or above, for
/// `target/debug/deps/` test runners) the current executable.
fn worker_exe() -> Result<PathBuf, TransportError> {
    if let Ok(p) = std::env::var(WORKER_EXE_ENV) {
        let p = PathBuf::from(p);
        if p.is_file() {
            return Ok(p);
        }
        return Err(TransportError::Setup(format!(
            "{WORKER_EXE_ENV}={} is not a file",
            p.display()
        )));
    }
    let me = std::env::current_exe()
        .map_err(|e| TransportError::Setup(format!("current_exe: {e}")))?;
    if me.file_name().and_then(|f| f.to_str()) == Some("spacdc") {
        return Ok(me);
    }
    // Test binaries live in target/<profile>/deps/; the spacdc binary
    // sits one or two directories up.
    for dir in me.ancestors().skip(1).take(3) {
        let candidate = dir.join("spacdc");
        if candidate.is_file() {
            return Ok(candidate);
        }
    }
    Err(TransportError::Setup(format!(
        "cannot find the spacdc worker executable near {} — set {WORKER_EXE_ENV}",
        me.display()
    )))
}

/// Build the command line for one child incarnation.
fn worker_command(
    exe: &PathBuf,
    addr: SocketAddr,
    w: usize,
    generation: u32,
    cfg: &ProcConfig,
) -> Command {
    let mut cmd = Command::new(exe);
    cmd.arg("worker")
        .arg("--connect")
        .arg(addr.to_string())
        .arg("--worker")
        .arg(w.to_string())
        .arg("--generation")
        .arg(generation.to_string())
        .arg("--seed")
        .arg(cfg.seed.to_string())
        .arg("--master-pk")
        .arg(wire::point_to_hex(&cfg.master_pk));
    if let Some(plan) = &cfg.faults {
        let tokens: Vec<String> = plan.crash_events().iter().map(|c| c.to_token()).collect();
        if !tokens.is_empty() {
            cmd.arg("--crashes").arg(tokens.join(","));
        }
        if plan.corrupt_rate() > 0.0 {
            cmd.arg("--corrupt-rate").arg(plan.corrupt_rate().to_string());
        }
        if plan.forge_rate() > 0.0 && !plan.forger_set().is_empty() {
            let ids: Vec<String> = plan.forger_set().iter().map(|w| w.to_string()).collect();
            cmd.arg("--forgers").arg(ids.join(","));
            cmd.arg("--forge-rate").arg(plan.forge_rate().to_string());
        }
        cmd.arg("--fault-seed").arg(plan.seed().to_string());
        // The key decides which identities the child's draws consume
        // (global round vs served count vs lane stream) — it must match
        // the master's plan or the two sides book different faults.
        cmd.arg("--fault-key").arg(plan.key().name());
    }
    cmd
}

impl Transport for Proc {
    fn kind(&self) -> TransportKind {
        TransportKind::Proc
    }

    fn workers(&self) -> usize {
        self.streams.len()
    }

    fn send(&self, w: usize, frame: Vec<u8>) -> Result<(), TransportError> {
        let stream = self.streams.get(w).ok_or_else(|| TransportError::WorkerDown {
            worker: w,
            detail: format!("no such link (fabric has {})", self.streams.len()),
        })?;
        let mut s = stream.lock().unwrap();
        s.write_all(&frame).map_err(|e| TransportError::WorkerDown {
            worker: w,
            detail: format!("socket write failed: {e}"),
        })?;
        self.metrics.add(names::BYTES_TX, frame.len() as u64);
        Ok(())
    }

    fn relink(&self, w: usize) -> Result<WorkerLink, TransportError> {
        let _ = w;
        Err(TransportError::Setup(
            "the process fabric relinks via respawn_process, not relink".into(),
        ))
    }

    fn out_of_process(&self) -> bool {
        true
    }

    fn respawn_process(&self, w: usize, generation: u32) -> Result<(), TransportError> {
        if w >= self.streams.len() {
            return Err(TransportError::WorkerDown {
                worker: w,
                detail: format!("no such link (fabric has {})", self.streams.len()),
            });
        }
        // Kill the old incarnation for real. A crashed child is parked,
        // not exited, so this SIGKILL is its actual cause of death and
        // the exit record carries signal 9. Results it already wrote
        // survive in the socket buffer and drain through the old bridge
        // until EOF.
        self.supervisor.lock().unwrap().kill(w);

        let mut cmd = worker_command(&self.exe, self.addr, w, generation, &self.cfg);
        self.supervisor
            .lock()
            .unwrap()
            .spawn(w, generation, &mut cmd)
            .map_err(|e| TransportError::Setup(format!("respawn worker {w}: {e}")))?;

        let deadline = Instant::now() + RESPAWN_DEADLINE;
        let setup = |e: std::io::Error| TransportError::Setup(e.to_string());
        loop {
            let (stream, frame, worker, gen) = accept_registered(&self.listener, deadline)?;
            if worker != w || gen != generation {
                // A stale or foreign connection — reap it and wait for
                // the incarnation we just spawned.
                let _ = stream.shutdown(Shutdown::Both);
                continue;
            }
            let reader = stream.try_clone().map_err(setup)?;
            // Swap the send slot *before* forwarding the Register:
            // once the directory flips the worker to Alive, dispatch
            // must land on the new socket, never the corpse's.
            {
                let mut s = self.streams[w].lock().unwrap();
                *s = stream;
            }
            self.bridges.lock().unwrap().push(spawn_bridge(w, reader, self.result_tx.clone()));
            self.result_tx.send(frame).map_err(|_| {
                TransportError::Setup("inbound channel closed during respawn".into())
            })?;
            return Ok(());
        }
    }

    fn exit_records(&self) -> Option<ExitLog> {
        Some(self.supervisor.lock().unwrap().log())
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        // Orderly teardown first: TERM then KILL every child, recording
        // exits. Workers still alive see EOF when their sockets shut.
        self.supervisor.lock().unwrap().shutdown(Duration::from_secs(2));
        for s in &self.streams {
            let _ = s.lock().unwrap().shutdown(Shutdown::Both);
        }
        for b in self.bridges.lock().unwrap().drain(..) {
            let _ = b.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecc::Point;
    use std::io::Write as _;

    fn register_frame(worker: usize, generation: u32) -> Vec<u8> {
        wire::encode_control(&ControlMsg::Register {
            worker,
            generation,
            pk: Point::Infinity,
        })
    }

    /// Registration edge case: a peer that connects and dies before
    /// sending its Register is reaped, and a well-behaved peer that
    /// arrives later still gets through.
    #[test]
    fn connect_then_die_before_register_is_reaped() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();

        let ghost = TcpStream::connect(addr).unwrap();
        drop(ghost); // dies before registering

        let good = std::thread::spawn(move || {
            // Give the ghost a head start so the accept loop meets it first.
            std::thread::sleep(Duration::from_millis(50));
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&register_frame(3, 1)).unwrap();
            s
        });

        let deadline = Instant::now() + Duration::from_secs(5);
        let (_stream, frame, worker, generation) =
            accept_registered(&listener, deadline).expect("good peer registers");
        assert_eq!(worker, 3);
        assert_eq!(generation, 1);
        match wire::decode_message(&frame).unwrap() {
            WireMessage::Control(ControlMsg::Register { worker, .. }) => assert_eq!(worker, 3),
            other => panic!("forwarded frame decodes wrong: {other:?}"),
        }
        good.join().unwrap();
    }

    /// Registration edge case: a half-open socket (connected, silent)
    /// stalls the accept loop for at most the ident timeout, then is
    /// reaped; it cannot wedge bring-up past the deadline.
    #[test]
    fn half_open_socket_is_reaped_after_the_ident_timeout() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();

        let _half_open = TcpStream::connect(addr).unwrap(); // never speaks

        let good = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&register_frame(0, 0)).unwrap();
            s
        });

        let start = Instant::now();
        let deadline = start + Duration::from_secs(10);
        let (_stream, _frame, worker, _gen) =
            accept_registered(&listener, deadline).expect("good peer registers");
        assert_eq!(worker, 0);
        // The half-open peer cost at most one ident timeout, not the
        // whole deadline.
        assert!(start.elapsed() < Duration::from_secs(5), "half-open socket wedged the accept loop");
        good.join().unwrap();
    }

    /// The whole contract end to end: the crash-respawn scenario on the
    /// process fabric — real forked children, real SIGKILLs — produces
    /// the same digest as the in-process run, and the exit log shows
    /// the fault plan ran at the OS level. Skips with a note when no
    /// `spacdc` binary is on disk (e.g. `cargo test` in a tree that was
    /// never built); the CI testbed job covers this path
    /// unconditionally.
    #[test]
    fn proc_fabric_matches_the_inproc_digest() {
        if worker_exe().is_err() {
            eprintln!(
                "skipping: no spacdc binary found (cargo build first, or set {WORKER_EXE_ENV})"
            );
            return;
        }
        use crate::config::TransportKind;
        use crate::sim::{run_scenario_with, Scenario};

        let mut sc = Scenario::builtin("crash-respawn").unwrap();
        sc.rounds = 8; // both respawns (due rounds 5 and 7) still fire

        let proc_run = run_scenario_with(&sc, TransportKind::Proc, 2, None, None).unwrap();
        let inproc = run_scenario_with(&sc, TransportKind::InProc, 2, None, None).unwrap();

        assert_eq!(
            proc_run.digest, inproc.digest,
            "digest diverges across the process boundary"
        );
        assert_eq!(proc_run.final_generations, inproc.final_generations);
        assert!(
            proc_run.process_exits.iter().any(|e| e.sigkilled()),
            "no SIGKILL in the exit log — the fault plan never ran at the OS level"
        );
        // In-process runs have no supervisor and report no exits.
        assert!(inproc.process_exits.is_empty());
    }

    /// A peer that sends junk instead of a Register is reaped too.
    #[test]
    fn junk_first_frame_is_reaped() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();

        let mut junk = TcpStream::connect(addr).unwrap();
        junk.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();

        let good = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&register_frame(1, 2)).unwrap();
            s
        });

        let deadline = Instant::now() + Duration::from_secs(10);
        let (_stream, _frame, worker, generation) =
            accept_registered(&listener, deadline).expect("good peer registers");
        assert_eq!((worker, generation), (1, 2));
        good.join().unwrap();
    }
}
