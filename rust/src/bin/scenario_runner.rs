//! `scenario_runner` — drive one declarative adversity scenario through
//! the live master/worker system and emit `SCENARIO_REPORT.json`.
//!
//! The CI matrix runs this over `{inproc, tcp} × {threads 1, 8}` per
//! scenario and asserts every combination prints the same digest — the
//! determinism contract (DESIGN.md §7). `--expect-digest` makes the
//! assertion self-contained: the process exits non-zero on mismatch.
//!
//! ```text
//! scenario_runner --scenario baseline
//! scenario_runner --scenario crash-respawn --transport tcp --threads 8
//! scenario_runner --scenario stream --inflight 16 --speculate on
//! scenario_runner --scenario scenarios/baseline.toml --rounds 4 --json /tmp/r.json
//! ```
//!
//! `--inflight` and `--speculate` override the scenario's `[stream]`
//! table: the window is an execution knob like the transport — the CI
//! matrix soaks `inflight ∈ {1, 4, 16}` and pins one digest.
//! `--tenants`/`--tenant-inflight` override the `[tenants]` table to
//! drive the multi-tenant serving front end (DESIGN.md §12); the
//! per-tenant digests in the report are execution-knob-invariant too.

use spacdc::cli::{parse, usage, ArgSpec};
use spacdc::config::{parse_threads_token, TransportKind};
use spacdc::sim::{run_scenario_with, Scenario};

fn specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::required("scenario", "scenario name (builtin or scenarios/<name>.toml) or path"),
        ArgSpec::opt("transport", "inproc", "worker link fabric: inproc|tcp|proc"),
        ArgSpec::opt("threads", "auto", "master-side thread-pool width (auto = one per core)"),
        ArgSpec::opt("inflight", "", "override the scenario's stream window (rounds in flight)"),
        ArgSpec::opt("speculate", "", "override the scenario's speculation: on|off"),
        ArgSpec::opt("rounds", "", "override the scenario's round count"),
        ArgSpec::opt("tenants", "", "override the scenario's concurrent session tenants (≥ 1)"),
        ArgSpec::opt("tenant-inflight", "", "override the per-tenant session window"),
        ArgSpec::opt("json", "SCENARIO_REPORT.json", "where to write the JSON report"),
        ArgSpec::opt("expect-digest", "", "fail unless the run's digest equals this hex value"),
        ArgSpec::flag("quiet", "suppress the per-round table"),
        ArgSpec::flag("help", "show usage"),
    ]
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let specs = specs();
    let parsed = match parse(&args, &specs) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if parsed.has_flag("help") || parsed.get("scenario").is_none() {
        print!("{}", usage("scenario_runner", &specs));
        return Ok(());
    }

    let mut scenario = Scenario::load(parsed.get_str("scenario"))?;
    if let Some(rounds) = parsed.get("rounds").filter(|s| !s.is_empty()) {
        scenario.rounds =
            rounds.parse().map_err(|_| anyhow::anyhow!("--rounds {rounds}: not a number"))?;
    }
    if let Some(raw) = parsed.get("tenants").filter(|s| !s.is_empty()) {
        scenario.tenants =
            raw.parse().map_err(|_| anyhow::anyhow!("--tenants {raw}: not a number"))?;
    }
    if let Some(raw) = parsed.get("tenant-inflight").filter(|s| !s.is_empty()) {
        scenario.tenant_inflight = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("--tenant-inflight {raw}: not a number"))?;
    }
    let transport = TransportKind::from_str_token(parsed.get_str("transport"))
        .ok_or_else(|| anyhow::anyhow!("unknown transport {}", parsed.get_str("transport")))?;
    let threads = parse_threads_token(parsed.get_str("threads")).ok_or_else(|| {
        anyhow::anyhow!(
            "--threads {}: pool width must be ≥ 1, or 'auto'",
            parsed.get_str("threads")
        )
    })?;
    let inflight = match parsed.get("inflight").filter(|s| !s.is_empty()) {
        None => None,
        Some(raw) => {
            let n: usize = raw
                .parse()
                .map_err(|_| anyhow::anyhow!("--inflight {raw}: not a number"))?;
            anyhow::ensure!(n >= 1, "--inflight {n}: stream window must be ≥ 1");
            Some(n)
        }
    };
    let speculate = match parsed.get("speculate").filter(|s| !s.is_empty()) {
        None => None,
        Some("on" | "true" | "1" | "yes") => Some(true),
        Some("off" | "false" | "0" | "no") => Some(false),
        Some(other) => anyhow::bail!("--speculate {other}: expected on|off"),
    };

    let report = run_scenario_with(&scenario, transport, threads, inflight, speculate)?;
    if !parsed.has_flag("quiet") {
        print!("{}", report.render_table());
    } else {
        println!("digest: {}", report.digest);
    }

    let json_path = parsed.get_str("json");
    if !json_path.is_empty() {
        std::fs::write(json_path, report.to_json())
            .map_err(|e| anyhow::anyhow!("writing {json_path}: {e}"))?;
        println!("wrote {json_path}");
    }

    let expected = parsed.get_str("expect-digest");
    if !expected.is_empty() && expected != report.digest {
        eprintln!("digest mismatch: expected {expected}, got {}", report.digest);
        std::process::exit(1);
    }
    Ok(())
}
