//! `testbed` — the cluster testbed orchestrator: one scenario file, a
//! real master plus N real `spacdc worker` processes on localhost TCP,
//! OS-level fault injection, one `SCENARIO_REPORT.json`.
//!
//! The testbed process *is* the master: it loads the scenario, runs it
//! on the process fabric (`--transport proc`), which forks one
//! `spacdc worker` child per worker slot, injects the scenario's crash
//! plan as real SIGKILLs through the process supervisor, and re-execs
//! fresh incarnations on schedule. When the run finishes the cluster is
//! torn down (SIGTERM, then SIGKILL after a grace period), every
//! child's exit status is collected, and the report is written with a
//! `process` section recording each exit — worker, generation, pid,
//! code, signal, cause.
//!
//! Then it holds the run to the determinism contract: the same scenario
//! is replayed on the in-process fabric and the deterministic report
//! fields — the digest (decoded bits, per-round statuses, byte totals,
//! recovered shares), recovery rate, and final generations — must match
//! bit for bit. A crashed worker is a real process dying mid-round; the
//! round must recover (degraded decode or speculative re-dispatch) and
//! must never be silently wrong.
//!
//! Teardown is clean on every path: success and assertion failure run
//! the orderly shutdown; on Ctrl-C the children (same foreground
//! process group) receive the SIGINT too and exit on their own, and the
//! supervisor's drop backstop reaps whatever is left.
//!
//! ```text
//! testbed --scenario rust/scenarios/crash-respawn.toml
//! testbed --scenario baseline --threads 4 --json /tmp/report.json
//! ```

use spacdc::cli::{parse, usage, ArgSpec};
use spacdc::config::{parse_threads_token, TransportKind};
use spacdc::coordinator::ExitCause;
use spacdc::sim::{run_scenario_with, RoundStatus, Scenario, ScenarioReport};

fn specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::required("scenario", "scenario name (builtin or scenarios/<name>.toml) or path"),
        ArgSpec::opt("threads", "auto", "master-side thread-pool width (auto = one per core)"),
        ArgSpec::opt("rounds", "", "override the scenario's round count"),
        ArgSpec::opt("json", "SCENARIO_REPORT.json", "where to write the JSON report"),
        ArgSpec::opt("worker-exe", "", "explicit spacdc binary to fork workers from"),
        ArgSpec::opt("expect-digest", "", "fail unless the run's digest equals this hex value"),
        ArgSpec::flag("no-parity", "skip the in-process replay / digest-parity check"),
        ArgSpec::flag("quiet", "suppress the per-round table"),
        ArgSpec::flag("help", "show usage"),
    ]
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let specs = specs();
    let parsed = match parse(&args, &specs) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if parsed.has_flag("help") || parsed.get("scenario").is_none() {
        print!("{}", usage("testbed --scenario <name|file>", &specs));
        return Ok(());
    }
    if let Some(exe) = parsed.get("worker-exe").filter(|s| !s.is_empty()) {
        std::env::set_var(spacdc::transport::WORKER_EXE_ENV, exe);
    }

    let mut scenario = Scenario::load(parsed.get_str("scenario"))?;
    if let Some(rounds) = parsed.get("rounds").filter(|s| !s.is_empty()) {
        scenario.rounds =
            rounds.parse().map_err(|_| anyhow::anyhow!("--rounds {rounds}: not a number"))?;
    }
    let threads = parse_threads_token(parsed.get_str("threads")).ok_or_else(|| {
        anyhow::anyhow!(
            "--threads {}: pool width must be ≥ 1, or 'auto'",
            parsed.get_str("threads")
        )
    })?;

    println!(
        "testbed: scenario {:?} — master + {} worker processes on localhost TCP",
        scenario.name, scenario.workers
    );
    let report = run_scenario_with(&scenario, TransportKind::Proc, threads, None, None)?;
    if !parsed.has_flag("quiet") {
        print!("{}", report.render_table());
    }

    let json_path = parsed.get_str("json");
    if !json_path.is_empty() {
        std::fs::write(json_path, report.to_json())
            .map_err(|e| anyhow::anyhow!("writing {json_path}: {e}"))?;
        println!("wrote {json_path}");
    }

    let mut failures: Vec<String> = Vec::new();
    check_exits(&scenario, &report, &mut failures);
    check_verify(&scenario, &report, &mut failures);

    let expected = parsed.get_str("expect-digest");
    if !expected.is_empty() && expected != report.digest {
        failures
            .push(format!("digest mismatch: expected {expected}, got {}", report.digest));
    }

    if !parsed.has_flag("no-parity") {
        println!("testbed: replaying {:?} in-process for the parity check", scenario.name);
        match run_scenario_with(&scenario, TransportKind::InProc, threads, None, None) {
            Ok(inproc) => check_parity(&report, &inproc, &mut failures),
            Err(e) => failures.push(format!("in-process replay failed: {e}")),
        }
    }

    if failures.is_empty() {
        println!("testbed: OK — digest {}", report.digest);
        Ok(())
    } else {
        for f in &failures {
            eprintln!("testbed: FAIL — {f}");
        }
        std::process::exit(1);
    }
}

/// Hold the process fabric to the scenario's fault plan: scheduled
/// respawns must show up as real SIGKILLed children, and teardown must
/// have accounted for every worker slot.
fn check_exits(sc: &Scenario, report: &ScenarioReport, failures: &mut Vec<String>) {
    if report.process_exits.is_empty() {
        failures.push("no process exit records — the run did not fork real workers".into());
        return;
    }
    // Every worker slot owes at least one exit record: mid-run kills for
    // the crash schedule, and the teardown reap for the final
    // incarnations.
    for w in 0..sc.workers {
        if !report.process_exits.iter().any(|e| e.worker == w) {
            failures.push(format!("worker {w} has no exit record — a child leaked"));
        }
    }
    let plan = sc.fault_plan();
    let scheduled_respawns =
        plan.crash_events().iter().filter(|c| c.respawn_after.is_some()).count();
    if scheduled_respawns > 0 {
        let sigkilled_respawns = report
            .process_exits
            .iter()
            .filter(|e| e.cause == ExitCause::Killed && e.sigkilled())
            .count();
        if sigkilled_respawns == 0 {
            failures.push(format!(
                "the plan schedules {scheduled_respawns} respawn(s) but no child was \
                 SIGKILLed mid-run — the fault plan never ran at the OS level"
            ));
        } else {
            println!(
                "testbed: {sigkilled_respawns} SIGKILL-driven respawn(s) observed \
                 (signal 9 captured from the dead children)"
            );
        }
        if report.respawns == 0 {
            failures.push(
                "scheduled respawns produced no re-registered incarnation".to_string(),
            );
        }
    }
}

/// Hold a Byzantine plan to the verification layer (DESIGN.md §11):
/// scheduled forgeries must be detected, their senders quarantined, and
/// every decoded round must be right — never silently wrong.
fn check_verify(sc: &Scenario, report: &ScenarioReport, failures: &mut Vec<String>) {
    if !sc.fault_plan().has_forgers() {
        return;
    }
    if report.verify_forged_detected == 0 {
        failures.push("the plan schedules forgeries but none was detected".into());
    }
    if report.verify_checked == 0 {
        failures
            .push("a forger plan ran but the collector verified no commitments".into());
    }
    if report.verify_quarantined == 0 {
        failures.push("no forging executor was quarantined".into());
    }
    for r in &report.records {
        if r.status != RoundStatus::Ok {
            continue;
        }
        match r.rel_err {
            Some(e) if e.is_finite() && e < 1.0 => {}
            other => failures.push(format!(
                "round {}: decode error {other:?} under a forger plan — a forged \
                 result may have reached the decoder",
                r.round
            )),
        }
    }
    if failures.is_empty() {
        println!(
            "testbed: verification OK — {} forged, {} quarantined, {} rehabilitated",
            report.verify_forged_detected,
            report.verify_quarantined,
            report.verify_rehabilitated
        );
    }
}

/// The determinism contract across the process boundary: everything the
/// digest folds (decoded bits, statuses, byte totals, recovered shares)
/// plus the named deterministic fields must match the in-process run.
fn check_parity(proc_run: &ScenarioReport, inproc: &ScenarioReport, failures: &mut Vec<String>) {
    let before = failures.len();
    if proc_run.digest != inproc.digest {
        failures.push(format!(
            "digest diverges across the process boundary: proc {} vs inproc {}",
            proc_run.digest, inproc.digest
        ));
    }
    if proc_run.recovery_hit_rate != inproc.recovery_hit_rate {
        failures.push(format!(
            "recovery rate diverges: proc {} vs inproc {}",
            proc_run.recovery_hit_rate, inproc.recovery_hit_rate
        ));
    }
    if proc_run.final_generations != inproc.final_generations {
        failures.push(format!(
            "final generations diverge: proc {:?} vs inproc {:?}",
            proc_run.final_generations, inproc.final_generations
        ));
    }
    if proc_run.verify_forged_detected != inproc.verify_forged_detected {
        failures.push(format!(
            "forged detections diverge: proc {} vs inproc {} — the booking is \
             plan-pure and must not depend on the fabric",
            proc_run.verify_forged_detected, inproc.verify_forged_detected
        ));
    }
    for (p, i) in proc_run.records.iter().zip(&inproc.records) {
        if (p.status, p.results_used, p.degraded) != (i.status, i.results_used, i.degraded) {
            failures.push(format!(
                "round {} diverges: proc ({}, {}, degraded {}) vs inproc ({}, {}, degraded {})",
                p.round,
                p.status.name(),
                p.results_used,
                p.degraded,
                i.status.name(),
                i.results_used,
                i.degraded
            ));
        }
    }
    if failures.len() == before {
        println!("testbed: parity OK — proc and in-process runs agree on every pinned field");
    }
}
