//! Hand-rolled data parallelism for the master-side hot paths —
//! DESIGN.md §6.
//!
//! No rayon/crossbeam in this environment, so this module provides a
//! small *scoped, chunk-based* thread pool built on
//! [`std::thread::scope`]. Three primitives cover every hot path:
//!
//! * [`ThreadPool::map_indexed`] — `f(0..n)` in parallel, results
//!   returned **in index order** (per-worker encode fan-out).
//! * [`ThreadPool::map_vec`] — the same, but consuming a `Vec` so each
//!   item's ownership moves into exactly one closure call (the seal
//!   fan-out moves each share instead of cloning it).
//! * [`ThreadPool::for_each_chunk`] — split one `&mut [T]` into
//!   fixed-granularity chunks and run `f(offset, chunk)` on each
//!   (row-chunked GEMM output, row-chunked `weighted_sum`).
//!
//! **Determinism contract:** every primitive performs the *identical*
//! per-element computation in the *identical* per-element order at any
//! thread count — parallelism only changes which OS thread runs which
//! chunk, never how a chunk is computed or how results are combined.
//! Chunk boundaries are a function of (input length, granularity) alone,
//! and reductions happen inside a chunk in fixed order, so outputs are
//! bit-identical for `threads ∈ {1, 2, …}` (asserted by
//! `tests/parallel_determinism.rs`).
//!
//! **Nesting guard:** a closure already running on a pool worker sees an
//! effective width of 1, so nested parallel regions (e.g. a parallel
//! encode whose per-share `weighted_sum` is itself parallel) degrade to
//! serial instead of oversubscribing the machine with thread explosions.
//!
//! Threads are spawned per region and joined before the call returns
//! (scoped); there is no persistent worker state. Spawn cost (~tens of
//! µs) is amortized by only splitting work that is large enough to
//! matter — callers pick granularities in the tens-of-KiB range.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide thread budget set from `SystemConfig::threads` /
/// `--threads`. 0 = one thread per available core.
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True on threads spawned by a pool region — nested regions run
    /// serially instead of spawning again.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Set the process-wide thread budget (0 = auto). Called by
/// `MasterBuilder::build` from the `threads` config key / `--threads`
/// CLI flag, and directly by the benches when pinning a width; safe to
/// call repeatedly.
pub fn configure(threads: usize) {
    CONFIGURED.store(threads, Ordering::Relaxed);
}

/// The number of threads [`global`] currently resolves to.
pub fn configured_threads() -> usize {
    resolve(CONFIGURED.load(Ordering::Relaxed))
}

/// The pool the hot paths use: sized by [`configure`], auto by default.
pub fn global() -> ThreadPool {
    ThreadPool::new(CONFIGURED.load(Ordering::Relaxed))
}

/// Permanently mark the calling thread as serial-only: every parallel
/// region started on it runs inline. The worker fabric calls this from
/// each worker thread — a simulated worker models one remote node, and
/// N workers each fanning out kernel threads would oversubscribe the
/// machine N-fold. Master-side threads (encode/seal/decode) stay
/// parallel.
pub fn mark_serial_thread() {
    IN_POOL_WORKER.with(|c| c.set(true));
}

fn resolve(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// A scoped, chunk-based thread pool of a fixed width.
///
/// Cheap to construct (it is just the resolved width); the actual OS
/// threads are scoped to each parallel region.
#[derive(Clone, Copy, Debug)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// Pool of `threads` workers; 0 = one per available core.
    pub fn new(threads: usize) -> Self {
        Self { threads: resolve(threads).max(1) }
    }

    /// The resolved width.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Width actually used for a region with `work_items` independent
    /// pieces: 1 when nested inside another region or when there is
    /// nothing to split.
    fn effective(&self, work_items: usize) -> usize {
        if IN_POOL_WORKER.with(|c| c.get()) {
            1
        } else {
            self.threads.min(work_items).max(1)
        }
    }

    /// Apply `f` to every index in `0..n` and return the results in
    /// index order. Each index is computed exactly once; the split into
    /// contiguous index ranges never affects any single result.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let threads = self.effective(n);
        if threads <= 1 {
            return (0..n).map(f).collect();
        }
        let per = n.div_ceil(threads);
        let f = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|t| ((t * per).min(n), ((t + 1) * per).min(n)))
                .filter(|(lo, hi)| lo < hi)
                .map(|(lo, hi)| {
                    s.spawn(move || {
                        IN_POOL_WORKER.with(|c| c.set(true));
                        (lo..hi).map(f).collect::<Vec<T>>()
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(n);
            for h in handles {
                out.extend(h.join().expect("parallel worker panicked"));
            }
            out
        })
    }

    /// Like [`map_indexed`](Self::map_indexed) but consuming `items`:
    /// `f(i, item)` receives each item by value exactly once, so callers
    /// can move heavy payloads instead of cloning them. Results are in
    /// item order.
    pub fn map_vec<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send,
        T: Send,
        F: Fn(usize, I) -> T + Sync,
    {
        let n = items.len();
        let threads = self.effective(n);
        if threads <= 1 {
            return items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
        }
        // Carve into contiguous owned segments, remembering each base
        // index so results keep their original positions.
        let per = n.div_ceil(threads);
        let mut segments: Vec<(usize, Vec<I>)> = Vec::with_capacity(threads);
        let mut rest = items;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let tail = rest.split_off(take);
            segments.push((base, rest));
            base += take;
            rest = tail;
        }
        let f = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = segments
                .into_iter()
                .map(|(seg_base, seg)| {
                    s.spawn(move || {
                        IN_POOL_WORKER.with(|c| c.set(true));
                        seg.into_iter()
                            .enumerate()
                            .map(|(i, item)| f(seg_base + i, item))
                            .collect::<Vec<T>>()
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(n);
            for h in handles {
                out.extend(h.join().expect("parallel worker panicked"));
            }
            out
        })
    }

    /// Split `data` into consecutive chunks of `granularity` elements
    /// (the last may be shorter) and call `f(element_offset, chunk)` on
    /// every chunk. Chunk boundaries depend only on
    /// `(data.len(), granularity)` — never on the thread count — and
    /// each chunk is written by exactly one closure call, so any
    /// fixed-order reduction inside a chunk is deterministic.
    pub fn for_each_chunk<T, F>(&self, data: &mut [T], granularity: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if data.is_empty() {
            return;
        }
        assert!(granularity > 0, "for_each_chunk needs a positive granularity");
        let granules = data.len().div_ceil(granularity);
        let threads = self.effective(granules);
        if threads <= 1 {
            let mut off = 0usize;
            for chunk in data.chunks_mut(granularity) {
                let len = chunk.len();
                f(off, chunk);
                off += len;
            }
            return;
        }
        // Deal the granules to threads round-robin (granule g → thread
        // g mod threads): for uniform work this is as good as contiguous
        // runs, and for triangular work (gram's upper-triangle rows) it
        // balances the load instead of front-loading thread 0. The
        // assignment never affects results — each chunk is still
        // computed by exactly one call with the same (offset, slice).
        let mut per_thread: Vec<Vec<(usize, &mut [T])>> =
            (0..threads).map(|_| Vec::with_capacity(granules.div_ceil(threads))).collect();
        let mut off = 0usize;
        for (g, chunk) in data.chunks_mut(granularity).enumerate() {
            let len = chunk.len();
            per_thread[g % threads].push((off, chunk));
            off += len;
        }
        let f = &f;
        std::thread::scope(|s| {
            for list in per_thread {
                s.spawn(move || {
                    IN_POOL_WORKER.with(|c| c.set(true));
                    for (o, chunk) in list {
                        f(o, chunk);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_indexed_preserves_order_at_any_width() {
        for threads in [1usize, 2, 3, 8, 16] {
            let pool = ThreadPool::new(threads);
            let got = pool.map_indexed(37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn map_indexed_handles_empty_and_tiny() {
        let pool = ThreadPool::new(8);
        assert_eq!(pool.map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map_indexed(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn map_vec_moves_each_item_once_in_order() {
        for threads in [1usize, 2, 5, 8] {
            let pool = ThreadPool::new(threads);
            let items: Vec<String> = (0..23).map(|i| format!("item-{i}")).collect();
            let got = pool.map_vec(items, |i, s| format!("{i}:{s}"));
            for (i, s) in got.iter().enumerate() {
                assert_eq!(s, &format!("{i}:item-{i}"), "threads={threads}");
            }
        }
    }

    #[test]
    fn for_each_chunk_covers_every_element_exactly_once() {
        for threads in [1usize, 2, 4, 8] {
            for (len, gran) in [(100usize, 7usize), (64, 64), (65, 64), (5, 100), (1, 1)] {
                let pool = ThreadPool::new(threads);
                let mut data = vec![0u32; len];
                pool.for_each_chunk(&mut data, gran, |off, chunk| {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v += (off + i) as u32 + 1;
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, i as u32 + 1, "threads={threads} len={len} gran={gran}");
                }
            }
        }
    }

    #[test]
    fn for_each_chunk_offsets_align_with_granularity() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u8; 103];
        let offsets = std::sync::Mutex::new(Vec::new());
        pool.for_each_chunk(&mut data, 10, |off, chunk| {
            offsets.lock().unwrap().push((off, chunk.len()));
        });
        let mut seen = offsets.into_inner().unwrap();
        seen.sort_unstable();
        let want: Vec<(usize, usize)> =
            (0..11).map(|g| (g * 10, if g == 10 { 3 } else { 10 })).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn empty_slice_is_a_noop_even_with_zero_granularity() {
        let pool = ThreadPool::new(4);
        let mut data: Vec<u8> = Vec::new();
        pool.for_each_chunk(&mut data, 0, |_, _| panic!("must not be called"));
    }

    #[test]
    fn nested_regions_degrade_to_serial() {
        let pool = ThreadPool::new(8);
        let outer = pool.map_indexed(4, |i| {
            // Inside a pool worker the effective width is 1, so this
            // nested region must run inline without spawning.
            let inner = global().map_indexed(5, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..4).map(|i| (0..5).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(outer, want);
    }

    #[test]
    fn width_resolution() {
        // Never asserts on actual machine parallelism.
        assert!(ThreadPool::new(0).threads() >= 1);
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }
}
