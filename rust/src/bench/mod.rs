//! Benchmark harness substrate (no `criterion` in this environment).
//!
//! Provides warmup + timed iteration with mean/σ/p50/p99 statistics and
//! aligned table output. Every `rust/benches/*.rs` harness (one per paper
//! table/figure) builds on this. Deterministic: no adaptive sampling, so
//! two runs on the same machine produce comparable rows.

use crate::metrics::Histogram;
use std::time::Instant;

/// Configuration for one measured benchmark.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Untimed warmup iterations.
    pub warmup_iters: usize,
    /// Timed iterations.
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 3, iters: 10 }
    }
}

impl BenchConfig {
    /// Quick preset for expensive end-to-end scenarios.
    pub fn quick() -> Self {
        Self { warmup_iters: 1, iters: 3 }
    }
}

/// Result of a measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Label for reporting.
    pub name: String,
    /// Per-iteration wall-clock samples (seconds).
    pub samples: Histogram,
}

impl BenchResult {
    /// Mean seconds per iteration.
    pub fn mean(&self) -> f64 {
        self.samples.mean()
    }

    /// Render one aligned row: name, mean, σ, p50, p99 (ms).
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10.3} {:>9.3} {:>10.3} {:>10.3}",
            self.name,
            self.mean() * 1e3,
            self.samples.std_dev() * 1e3,
            self.samples.p50() * 1e3,
            self.samples.p99() * 1e3,
        )
    }
}

/// Header matching [`BenchResult::row`].
pub fn header() -> String {
    format!(
        "{:<44} {:>10} {:>9} {:>10} {:>10}",
        "benchmark", "mean(ms)", "sd(ms)", "p50(ms)", "p99(ms)"
    )
}

/// Measure `f` under `cfg`, returning per-iteration statistics.
///
/// `f` receives the iteration index so scenarios can vary seeds while
/// staying deterministic.
pub fn run(name: &str, cfg: BenchConfig, mut f: impl FnMut(usize)) -> BenchResult {
    for i in 0..cfg.warmup_iters {
        f(i);
    }
    let mut samples = Histogram::new();
    for i in 0..cfg.iters {
        let t0 = Instant::now();
        f(cfg.warmup_iters + i);
        samples.record(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), samples }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty-print a section banner for bench output.
pub fn banner(title: &str) {
    let line = "=".repeat(title.len() + 8);
    println!("\n{line}\n==  {title}  ==\n{line}");
}

/// Simple aligned series printer: one labelled row of f64s, for
/// figure-series output (x → y per scheme).
pub fn print_series(label: &str, xs: &[f64]) {
    print!("{label:<28}");
    for x in xs {
        print!(" {x:>12.4}");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_records_requested_iterations() {
        let r = run("noop", BenchConfig { warmup_iters: 2, iters: 5 }, |_| {
            black_box(3 + 4);
        });
        assert_eq!(r.samples.count(), 5);
        assert!(r.mean() >= 0.0);
    }

    #[test]
    fn run_passes_increasing_iteration_index() {
        let mut seen = Vec::new();
        let cfg = BenchConfig { warmup_iters: 1, iters: 3 };
        // Collect indices through a RefCell-free trick: accumulate in a
        // local because FnMut allows mutation.
        let r = run("idx", cfg, |i| seen.push(i));
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert_eq!(r.samples.count(), 3);
    }

    #[test]
    fn row_is_aligned_with_header() {
        let r = run("x", BenchConfig::quick(), |_| {});
        // Rows and header columns should be non-empty and parseable.
        assert!(header().contains("mean(ms)"));
        assert!(r.row().starts_with('x'));
    }
}
