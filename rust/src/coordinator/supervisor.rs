//! Child-process supervision for the process fabric — DESIGN.md §9.
//!
//! One [`Supervisor`] owns every worker child the
//! [`Proc`](crate::transport::Proc) fabric spawns. Per worker it tracks
//! the live [`Child`] handle, its pid, and its generation; every exit —
//! a fault-injection SIGKILL, a teardown, or a child dying on its own —
//! is reaped (no zombies) and appended to a shared [`ExitLog`] with the
//! exit code or terminating signal captured. The testbed serializes
//! that log into SCENARIO_REPORT.json as the OS-level evidence that
//! crashes really were crashes (signal 9, not a polite return).
//!
//! State machine per slot: `Empty → Running → (killed | reaped) →
//! Empty`, re-entered by every respawn with the generation bumped by
//! the caller ([`WorkerPool::respawn`](super::WorkerPool::respawn) via
//! `Proc::respawn_process`). Teardown escalates: SIGTERM first, a
//! bounded grace poll, then SIGKILL — so a hung child can stall
//! shutdown only for the grace window, never forever.

use std::io;
use std::os::unix::process::ExitStatusExt;
use std::process::{Child, Command, ExitStatus};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Why the supervisor recorded an exit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExitCause {
    /// Fault injection: the supervisor SIGKILLed the child to make way
    /// for a respawned incarnation.
    Killed,
    /// The child was already dead when the supervisor went to reap it
    /// (it exited on its own — clean shutdown or a crash of its own).
    Exited,
    /// Teardown: SIGTERM, grace, then SIGKILL if it lingered.
    Shutdown,
}

impl ExitCause {
    /// Stable lowercase name (serialized into SCENARIO_REPORT.json).
    pub fn name(self) -> &'static str {
        match self {
            ExitCause::Killed => "killed",
            ExitCause::Exited => "exited",
            ExitCause::Shutdown => "shutdown",
        }
    }
}

/// One reaped child: who it was and how it ended.
#[derive(Clone, Debug)]
pub struct ExitRecord {
    /// Worker index.
    pub worker: usize,
    /// Incarnation the child was running.
    pub generation: u32,
    /// OS process id.
    pub pid: u32,
    /// Exit code, when the child exited normally.
    pub code: Option<i32>,
    /// Terminating signal, when it was killed (9 for the supervisor's
    /// own SIGKILLs).
    pub signal: Option<i32>,
    /// Why the supervisor reaped it.
    pub cause: ExitCause,
}

impl ExitRecord {
    /// Did this child die by SIGKILL?
    pub fn sigkilled(&self) -> bool {
        self.signal == Some(9)
    }
}

/// Shared, append-only view of the supervisor's exit records. Handed
/// out live so the testbed can read it *after* the fabric (and the
/// supervisor inside it) has been torn down.
pub type ExitLog = Arc<Mutex<Vec<ExitRecord>>>;

struct Slot {
    child: Option<Child>,
    generation: u32,
    pid: u32,
}

/// Spawns, kills, reaps, and respawns the worker children of one
/// process fabric.
pub struct Supervisor {
    slots: Vec<Slot>,
    log: ExitLog,
}

impl Supervisor {
    /// A supervisor with `n` empty slots.
    pub fn new(n: usize) -> Self {
        Self {
            slots: (0..n).map(|_| Slot { child: None, generation: 0, pid: 0 }).collect(),
            log: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// The shared exit log (alive after the supervisor is gone).
    pub fn log(&self) -> ExitLog {
        Arc::clone(&self.log)
    }

    /// Number of slots.
    pub fn n(&self) -> usize {
        self.slots.len()
    }

    /// Is worker `w`'s child handle still held (spawned, not reaped)?
    pub fn running(&self, w: usize) -> bool {
        self.slots.get(w).is_some_and(|s| s.child.is_some())
    }

    /// The generation of the child currently in slot `w`.
    pub fn generation(&self, w: usize) -> u32 {
        self.slots.get(w).map_or(0, |s| s.generation)
    }

    /// Launch `cmd` as worker `w`'s incarnation `generation`. The slot
    /// must be empty (kill/reap the predecessor first). Returns the
    /// child's pid.
    pub fn spawn(&mut self, w: usize, generation: u32, cmd: &mut Command) -> io::Result<u32> {
        let slot = &mut self.slots[w];
        assert!(slot.child.is_none(), "slot {w} still holds a child; reap it first");
        let child = cmd.spawn()?;
        let pid = child.id();
        *slot = Slot { child: Some(child), generation, pid };
        Ok(pid)
    }

    /// SIGKILL worker `w`'s child and reap it — the fault-injection
    /// kill. If the child already exited on its own, its real status is
    /// reaped and recorded as [`ExitCause::Exited`] instead. No-op when
    /// the slot is empty.
    pub fn kill(&mut self, w: usize) -> Option<ExitRecord> {
        let slot = self.slots.get_mut(w)?;
        let mut child = slot.child.take()?;
        let (status, cause) = match child.try_wait() {
            Ok(Some(status)) => (status, ExitCause::Exited),
            _ => {
                // Child::kill is SIGKILL on unix; wait() reaps.
                let _ = child.kill();
                match child.wait() {
                    Ok(status) => (status, ExitCause::Killed),
                    Err(_) => return None,
                }
            }
        };
        Some(self.record(w, slot_info(&self.slots[w]), status, cause))
    }

    /// Teardown kill with escalation: SIGTERM, poll up to `grace`, then
    /// SIGKILL + blocking reap. No-op when the slot is empty.
    pub fn terminate(&mut self, w: usize, grace: Duration) -> Option<ExitRecord> {
        let slot = self.slots.get_mut(w)?;
        let mut child = slot.child.take()?;
        sigterm(slot.pid);
        let deadline = Instant::now() + grace;
        let status = loop {
            match child.try_wait() {
                Ok(Some(status)) => break status,
                Ok(None) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                _ => {
                    // Grace expired (or try_wait failed): escalate.
                    let _ = child.kill();
                    match child.wait() {
                        Ok(status) => break status,
                        Err(_) => return None,
                    }
                }
            }
        };
        Some(self.record(w, slot_info(&self.slots[w]), status, ExitCause::Shutdown))
    }

    /// Tear every remaining child down (TERM → grace → KILL each).
    pub fn shutdown(&mut self, grace: Duration) {
        for w in 0..self.slots.len() {
            self.terminate(w, grace);
        }
    }

    fn record(
        &mut self,
        worker: usize,
        (generation, pid): (u32, u32),
        status: ExitStatus,
        cause: ExitCause,
    ) -> ExitRecord {
        let rec = ExitRecord {
            worker,
            generation,
            pid,
            code: status.code(),
            signal: status.signal(),
            cause,
        };
        self.log.lock().unwrap().push(rec.clone());
        rec
    }
}

fn slot_info(slot: &Slot) -> (u32, u32) {
    (slot.generation, slot.pid)
}

/// Best-effort SIGTERM without a libc dependency: the one process
/// primitive std does not expose. Failure is harmless — the caller
/// escalates to `Child::kill` (SIGKILL) after the grace window anyway.
fn sigterm(pid: u32) {
    let _ = Command::new("kill").arg("-TERM").arg(pid.to_string()).status();
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        // Backstop: never leak children, even on panic paths. Normal
        // teardown already emptied every slot via shutdown().
        self.shutdown(Duration::from_millis(500));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sleeper() -> Command {
        let mut cmd = Command::new("sleep");
        cmd.arg("600");
        cmd
    }

    #[test]
    fn kill_reaps_with_signal_nine() {
        let mut sup = Supervisor::new(2);
        let pid = sup.spawn(0, 0, &mut sleeper()).unwrap();
        assert!(sup.running(0));
        let rec = sup.kill(0).expect("a record");
        assert_eq!((rec.worker, rec.generation, rec.pid), (0, 0, pid));
        assert_eq!(rec.signal, Some(9), "Child::kill must be SIGKILL");
        assert!(rec.sigkilled());
        assert_eq!(rec.cause, ExitCause::Killed);
        assert!(!sup.running(0), "slot must be empty after the reap");
        assert!(sup.kill(0).is_none(), "empty slot: nothing to kill");
    }

    #[test]
    fn a_child_that_already_exited_is_reaped_as_exited() {
        let mut sup = Supervisor::new(1);
        let mut cmd = Command::new("true");
        sup.spawn(0, 3, &mut cmd).unwrap();
        // Give the child time to exit on its own.
        std::thread::sleep(Duration::from_millis(200));
        let rec = sup.kill(0).expect("a record");
        assert_eq!(rec.cause, ExitCause::Exited);
        assert_eq!(rec.code, Some(0));
        assert_eq!(rec.signal, None);
        assert_eq!(rec.generation, 3);
    }

    #[test]
    fn respawn_cycle_tracks_generations_and_log() {
        let mut sup = Supervisor::new(1);
        let log = sup.log();
        sup.spawn(0, 0, &mut sleeper()).unwrap();
        sup.kill(0).unwrap();
        sup.spawn(0, 1, &mut sleeper()).unwrap();
        assert_eq!(sup.generation(0), 1);
        sup.shutdown(Duration::from_millis(300));
        let log = log.lock().unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].generation, 0);
        assert_eq!(log[0].cause, ExitCause::Killed);
        assert_eq!(log[1].generation, 1);
        assert_eq!(log[1].cause, ExitCause::Shutdown);
        // `sleep` has no TERM handler, so the graceful leg suffices.
        assert!(log[1].signal.is_some());
    }
}
