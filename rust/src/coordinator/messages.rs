//! Wire messages between master and workers.
//!
//! These are the *typed* forms; what actually crosses a transport link
//! is their serialized frame (see [`crate::wire`]). A payload is either
//! plaintext or MEA-ECC *seal-the-bytes*: the serialized matrix data
//! masked byte-by-byte under the recipient's key
//! ([`SealedPayload`]), with the ephemeral point and the shape in the
//! clear — framing needs the shape, and it is exactly what a real
//! length-prefixed protocol would leak anyway.

use crate::ecc::{KeyPair, MeaEcc, Point, SealedBytes};
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::rng::Rng;
use crate::runtime::WorkerOp;
use crate::wire::{matrix_from_le_bytes, matrix_to_le_bytes, WireError};
use std::time::Duration;

/// A matrix sealed for the wire: MEA-ECC over its serialized bytes.
#[derive(Clone, Debug)]
pub struct SealedPayload {
    /// Ephemeral point + masked row-major f32 data bytes.
    pub sealed: SealedBytes<Fp61>,
    /// Plaintext row count (cleartext framing metadata).
    pub rows: usize,
    /// Plaintext column count (cleartext framing metadata).
    pub cols: usize,
}

impl SealedPayload {
    /// Seal `m` to the holder of `recipient_pk`. The serialized buffer
    /// is masked in place ([`MeaEcc::seal_bytes_owned`]) — one
    /// allocation for the wire bytes, nothing else.
    pub fn seal(mea: &MeaEcc<Fp61>, m: &Matrix, recipient_pk: &Point<Fp61>, rng: &mut Rng) -> Self {
        let bytes = matrix_to_le_bytes(m);
        Self {
            sealed: mea.seal_bytes_owned(bytes, recipient_pk, rng),
            rows: m.rows(),
            cols: m.cols(),
        }
    }

    /// Open with the recipient's key pair. Fails (typed) when the byte
    /// count disagrees with the cleartext shape — corruption that
    /// slipped past framing must not panic the worker/collector.
    pub fn open(&self, mea: &MeaEcc<Fp61>, keys: &KeyPair<Fp61>) -> Result<Matrix, WireError> {
        let bytes = mea.open_bytes(&self.sealed, keys);
        matrix_from_le_bytes(self.rows, self.cols, &bytes)
    }

    /// [`SealedPayload::open`] consuming the payload: the ciphertext
    /// buffer is unmasked in place instead of being copied — the
    /// worker/collector hot path, where the payload is owned anyway.
    pub fn open_owned(self, mea: &MeaEcc<Fp61>, keys: &KeyPair<Fp61>) -> Result<Matrix, WireError> {
        let (rows, cols) = (self.rows, self.cols);
        let bytes = mea.open_bytes_owned(self.sealed, keys);
        matrix_from_le_bytes(rows, cols, &bytes)
    }

    /// Symbol count (f32 elements) for the communication accounting.
    pub fn symbols(&self) -> usize {
        self.rows * self.cols
    }

    /// The ciphertext as an eavesdropper would chart it: the masked
    /// bytes reinterpreted as f32s in the plaintext's shape.
    pub fn wire_matrix(&self) -> Matrix {
        let data: Vec<f32> = self
            .sealed
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }
}

/// A payload as it travels the wire: sealed under MEA-ECC or in the
/// clear, depending on [`TransportSecurity`]
/// (crate::config::TransportSecurity).
#[derive(Clone, Debug)]
pub enum WirePayload {
    /// Plaintext matrix (baseline schemes).
    Plain(Matrix),
    /// MEA-ECC seal-the-bytes ciphertext (SPACDC default).
    Sealed(SealedPayload),
}

impl WirePayload {
    /// The bytes-on-the-wire view an eavesdropper records, as a matrix
    /// (ciphertext bytes reinterpreted as f32s when sealed).
    pub fn wire_matrix(&self) -> Matrix {
        match self {
            WirePayload::Plain(m) => m.clone(),
            WirePayload::Sealed(s) => s.wire_matrix(),
        }
    }

    /// Symbol count (f32 elements) for the communication accounting.
    pub fn symbols(&self) -> usize {
        match self {
            WirePayload::Plain(m) => m.len(),
            WirePayload::Sealed(s) => s.symbols(),
        }
    }
}

/// A work order for one worker in one round.
///
/// The payload vector carries one sealed operand per matrix in the
/// worker's [`EncodedJob`](crate::coding::EncodedJob) slot — 1 for the
/// row-partition schemes, 2 for MatDot's operand pairs — so every scheme
/// shares this wire format. Orders from different rounds may interleave
/// in a worker's queue; the round id routes each result back.
#[derive(Clone, Debug)]
pub struct WorkOrder {
    /// Monotone round id.
    pub round: u64,
    /// Destination worker index.
    pub worker: usize,
    /// Session lane the round belongs to (wire v4; 0 on single-tenant
    /// paths). Together with `lane_round` and `served` these are the
    /// [`FaultCoords`](crate::sim::FaultCoords) the destination's fault
    /// plan keys on: the master fills them at dispatch, so its
    /// pre-booking and the worker's own evaluation read identical
    /// numbers whatever the plan's key (DESIGN.md §13).
    pub lane: u32,
    /// Lane-local round index, 1-based (wire v4; equals `round` on
    /// single-tenant paths).
    pub lane_round: u64,
    /// Wall rounds served by the order's *executor* slot, 1-based and
    /// counting this order (wire v4). For a speculative re-dispatch
    /// this is the executor's current count, not the share owner's.
    pub served: u64,
    /// The operation to apply.
    pub op: WorkerOp,
    /// Operand payloads (1, or 2 for pair ops).
    pub payloads: Vec<WirePayload>,
    /// Injected service delay (straggler simulation).
    pub delay: Duration,
    /// Share commitment (wire v3): [`share_commitment`] over the
    /// plaintext operands, computed master-side at encode time. An
    /// honest worker echoes it verbatim on its [`ResultMsg`]; the
    /// collector refuses any result whose echo disagrees with the
    /// round's encode-time ledger.
    pub commitment: u64,
}

/// FNV-1a 64 commitment over a share's plaintext operands: shape and
/// f32 bit patterns, folded in operand order. Both dispatch copies of a
/// share (the owner's and a speculative re-dispatch's) carry the same
/// plaintext, so they commit identically even though their sealed bytes
/// differ — the collector can verify either copy against one ledger
/// entry.
pub fn share_commitment<'a, I>(operands: I) -> u64
where
    I: IntoIterator<Item = &'a Matrix>,
{
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut fold = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    for m in operands {
        fold(&(m.rows() as u64).to_le_bytes());
        fold(&(m.cols() as u64).to_le_bytes());
        for v in m.as_slice() {
            fold(&v.to_bits().to_le_bytes());
        }
    }
    h
}

/// A lifecycle control message (see [`crate::coordinator`] module docs
/// for the worker state machine).
///
/// * `Register` travels worker → master: every worker incarnation —
///   the initial spawn and every respawn — announces its index,
///   generation, and freshly generated public key before serving
///   (§IV-B step 1, re-run on rejoin). The master's collector installs
///   it in the [`WorkerDirectory`](super::WorkerDirectory).
/// * `Crash` travels master → worker: a fault-injection order telling
///   the worker thread to vanish silently (no reply, no cleanup), the
///   scenario engine's wire-level kill switch.
#[derive(Clone, Debug, PartialEq)]
pub enum ControlMsg {
    /// Master → worker: die silently, mid-protocol.
    Crash {
        /// Which worker the kill is addressed to.
        worker: usize,
    },
    /// Worker → master: this incarnation is alive and keyed.
    Register {
        /// Worker index.
        worker: usize,
        /// Incarnation number: 0 for the initial spawn, +1 per respawn.
        generation: u32,
        /// The incarnation's public key (master seals shares to it).
        pk: Point<Fp61>,
    },
}

/// A worker's result for one round.
///
/// `worker` is the *share* id — which coded share this result carries —
/// and routes decoding; under speculative re-dispatch a share may be
/// computed by a different worker than it is named after. `executor` is
/// the worker that actually ran the order: the collector settles that
/// worker's [`LoadBook`](crate::transport::LoadBook) entry per result
/// and attributes speculation winners by it (wire v2).
#[derive(Clone, Debug)]
pub struct ResultMsg {
    /// Round the result belongs to.
    pub round: u64,
    /// Share id the result carries (routes decoding).
    pub worker: usize,
    /// Worker that actually executed the order (settles load).
    pub executor: usize,
    /// The computed (possibly sealed) result.
    pub payload: WirePayload,
    /// Echo of the order's share commitment (wire v3). A forged result
    /// carries a tampered echo; the collector drops it on mismatch and
    /// quarantines the executor (DESIGN.md §11).
    pub commitment: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecc::{sim_curve, MaskMode};
    use crate::rng::rng_from_seed;

    #[test]
    fn plain_payload_views_and_counts() {
        let m = Matrix::ones(3, 4);
        let p = WirePayload::Plain(m.clone());
        assert_eq!(p.symbols(), 12);
        assert_eq!(p.wire_matrix().as_slice(), m.as_slice());
    }

    #[test]
    fn sealed_payload_round_trips_bit_exact() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(31);
        let recipient = KeyPair::generate(&curve, &mut rng);
        let mea = MeaEcc::new(curve, MaskMode::Keystream);
        let m = Matrix::random_gaussian(9, 5, 0.0, 2.0, &mut rng);
        let sealed = SealedPayload::seal(&mea, &m, &recipient.public(), &mut rng);
        assert_eq!(sealed.symbols(), 45);
        assert_eq!(sealed.sealed.len(), 45 * 4);
        let opened = sealed.open(&mea, &recipient).unwrap();
        assert_eq!(opened, m, "seal-the-bytes must open bit-exact");
    }

    #[test]
    fn sealed_wire_view_is_not_the_plaintext() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(32);
        let recipient = KeyPair::generate(&curve, &mut rng);
        let mea = MeaEcc::new(curve, MaskMode::Keystream);
        let m = Matrix::random_gaussian(8, 8, 0.0, 1.0, &mut rng);
        let sealed = SealedPayload::seal(&mea, &m, &recipient.public(), &mut rng);
        let view = sealed.wire_matrix();
        assert_eq!(view.shape(), m.shape());
        let same = view
            .as_slice()
            .iter()
            .zip(m.as_slice())
            .filter(|(a, b)| a.to_bits() == b.to_bits())
            .count();
        assert!(same < 4, "{same}/64 wire elements equal plaintext");
    }

    #[test]
    fn share_commitment_is_shape_and_bit_sensitive() {
        let mut rng = rng_from_seed(34);
        let a = Matrix::random_gaussian(4, 3, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(4, 3, 0.0, 1.0, &mut rng);
        let c = share_commitment([&a, &b]);
        assert_eq!(c, share_commitment([&a, &b]), "commitment must be pure");
        assert_ne!(c, share_commitment([&b, &a]), "operand order must matter");
        assert_ne!(c, share_commitment([&a]), "arity must matter");
        // One flipped mantissa bit must change the commitment.
        let mut data: Vec<f32> = a.as_slice().to_vec();
        data[5] = f32::from_bits(data[5].to_bits() ^ 1);
        let tweaked = Matrix::from_vec(4, 3, data);
        assert_ne!(c, share_commitment([&tweaked, &b]));
        // Same bits reshaped must not collide.
        let flat = Matrix::from_vec(3, 4, a.as_slice().to_vec());
        assert_ne!(share_commitment([&a]), share_commitment([&flat]));
    }

    #[test]
    fn sealed_shape_mismatch_is_typed() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(33);
        let recipient = KeyPair::generate(&curve, &mut rng);
        let mea = MeaEcc::new(curve, MaskMode::Keystream);
        let m = Matrix::ones(4, 4);
        let mut sealed = SealedPayload::seal(&mea, &m, &recipient.public(), &mut rng);
        sealed.rows = 5; // corrupted cleartext shape
        assert!(matches!(sealed.open(&mea, &recipient), Err(WireError::Malformed(_))));
    }
}
