//! Wire messages between master and workers.

use crate::ecc::SealedMatrix;
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::runtime::WorkerOp;
use std::time::Duration;

/// A payload as it travels the (simulated) network: sealed under MEA-ECC
/// or in the clear, depending on [`TransportSecurity`]
/// (crate::config::TransportSecurity).
#[derive(Clone, Debug)]
pub enum WirePayload {
    /// Plaintext matrix (baseline schemes).
    Plain(Matrix),
    /// MEA-ECC ciphertext (SPACDC default).
    Sealed(SealedMatrix<Fp61>),
}

impl WirePayload {
    /// The bytes-on-the-wire view an eavesdropper records.
    pub fn wire_view(&self) -> &Matrix {
        match self {
            WirePayload::Plain(m) => m,
            WirePayload::Sealed(s) => &s.payload,
        }
    }

    /// Symbol count (f32 elements) for the communication accounting.
    pub fn symbols(&self) -> usize {
        self.wire_view().len()
    }
}

/// A work order for one worker in one round.
///
/// The payload vector carries one sealed operand per matrix in the
/// worker's [`EncodedJob`](crate::coding::EncodedJob) slot — 1 for the
/// row-partition schemes, 2 for MatDot's operand pairs — so every scheme
/// shares this wire format. Orders from different rounds may interleave
/// in a worker's queue; the round id routes each result back.
#[derive(Clone, Debug)]
pub struct WorkOrder {
    /// Monotone round id.
    pub round: u64,
    /// Destination worker index.
    pub worker: usize,
    /// The operation to apply.
    pub op: WorkerOp,
    /// Operand payloads (1, or 2 for pair ops).
    pub payloads: Vec<WirePayload>,
    /// Injected service delay (straggler simulation).
    pub delay: Duration,
}

/// A worker's result for one round.
#[derive(Debug)]
pub struct ResultMsg {
    /// Round the result belongs to.
    pub round: u64,
    /// Originating worker.
    pub worker: usize,
    /// The computed (possibly sealed) result.
    pub payload: WirePayload,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_payload_views_and_counts() {
        let m = Matrix::ones(3, 4);
        let p = WirePayload::Plain(m.clone());
        assert_eq!(p.symbols(), 12);
        assert_eq!(p.wire_view().as_slice(), m.as_slice());
    }
}
