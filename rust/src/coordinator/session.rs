//! Multi-tenant serving front end — DESIGN.md §12.
//!
//! One [`Master`] (and its worker fleet) serves many concurrent
//! tenants: [`Master::service`] opens a [`Service`], each tenant opens
//! a session lane fed from an iterator ([`Service::open_iter`]), a
//! bounded channel ([`Service::open_channel`]), or one synchronous call
//! at a time ([`Service::open`] + [`Service::round`]). The service owns
//! the dispatch point and multiplexes every lane over the one round
//! pipeline:
//!
//! * **Streaming sources, no epoch buffering.** Tasks are pulled from
//!   the source one at a time, only when the scheduler is ready to
//!   submit them — a tenant streaming a whole training epoch never
//!   materializes it encoded. Bounded channels give producers
//!   backpressure for free; per-window occupancy is surfaced in the
//!   stats so saturation is observable.
//! * **Admission control.** Each lane caps its own in-flight rounds
//!   (`SessionOptions::inflight`) and the service caps the global
//!   total (`ServiceConfig::global_inflight`). A lane with window
//!   space that is blocked only by the global cap counts a refusal
//!   (`tenant.refused`) — the admission signal a saturated fleet emits
//!   instead of queueing without bound.
//! * **Deficit-round-robin fairness.** The scheduler sweeps lanes
//!   round-robin; each sweep credits a lane `weight` submissions and
//!   carries at most one unused quantum forward, so a greedy tenant
//!   with a wide window cannot starve a polite one — bandwidth
//!   converges to the weight ratio whenever both lanes have work.
//! * **Per-tenant deadlines and metrics.** Every lane may override the
//!   round deadline (`SessionOptions::deadline_s`); per-lane
//!   [`SessionStats`] report rounds, throughput, p50/p99 round
//!   latency, degraded/refused/failed counts, and window occupancy.
//!
//! **Tenant isolation and determinism.** Round ids are global (the
//! registry and sharded collector already route purely by id), but
//! every *random* choice a lane's rounds consume — encode privacy
//! masks and the per-round seal salt — comes from the lane's own RNG
//! stream when `SessionOptions::seed` is set. A tenant's decoded bits
//! are then a pure function of its own seed and task list: bit-equal
//! whether the tenant runs alone or interleaved with any number of
//! other tenants (asserted by `tests/multi_tenant.rs`). With `seed:
//! None` the lane draws from the master's root RNG — exactly the
//! pre-session behaviour, which is how [`Master::run`] and
//! [`Master::run_stream`] stay bit-identical wrappers.

use super::master::{Master, RoundHandle, RoundOutcome};
use crate::coding::CodedTask;
use crate::config::SystemConfig;
use crate::metrics::{names, Histogram};
use crate::rng::{derive_seed, rng_from_seed, Rng};
use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::time::{Duration, Instant};

/// The stream index every lane RNG derives from its tenant seed:
/// `rng_from_seed(derive_seed(seed, LANE_RNG_STREAM))`. One fixed
/// derivation means a tenant's mask/salt draws depend only on its own
/// seed — the solo-vs-interleaved bit-parity contract.
const LANE_RNG_STREAM: u64 = 0x5E55_000A;

/// Service-wide knobs (the config keys `inflight` / `speculate` map
/// here for the single-tenant wrappers; a multi-tenant caller sets
/// them directly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Cap on rounds in flight across *all* lanes (0 = no global cap;
    /// each lane is still bounded by its own window).
    pub global_inflight: usize,
    /// Speculative re-dispatch of outstanding shares, service-wide
    /// (restored to the master's prior setting by [`Service::finish`]).
    pub speculate: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { global_inflight: 0, speculate: false }
    }
}

impl ServiceConfig {
    /// The service knobs a system config asks for: the config's stream
    /// window becomes the global cap.
    pub fn from_config(cfg: &SystemConfig) -> Self {
        Self { global_inflight: cfg.inflight.max(1), speculate: cfg.speculate }
    }
}

/// Per-tenant session knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SessionOptions {
    /// This lane's in-flight window (≥ 1; 1 = synchronous).
    pub inflight: usize,
    /// Per-round collection deadline override (None = the master
    /// config's `round_deadline_s`).
    pub deadline_s: Option<f64>,
    /// Deficit-round-robin weight (≥ 1): submissions credited per
    /// scheduler sweep. A weight-2 lane gets twice the dispatch
    /// bandwidth of a weight-1 lane when both have work queued.
    pub weight: u32,
    /// Tenant RNG stream: `Some(seed)` gives this lane's rounds their
    /// own mask/salt draws (solo-vs-interleaved bit-parity); `None`
    /// draws from the master's root RNG (the single-tenant wrappers'
    /// compatibility mode).
    pub seed: Option<u64>,
}

impl Default for SessionOptions {
    fn default() -> Self {
        Self { inflight: 1, deadline_s: None, weight: 1, seed: None }
    }
}

/// Handle to one tenant's lane (an index; lanes live as long as the
/// service).
pub type SessionId = usize;

/// One completed round of a session, in lane-local submission order.
#[derive(Debug)]
pub struct SessionRound {
    /// Position in the lane's submission sequence (0-based).
    pub index: usize,
    /// The master's global round id (0 when the submit itself failed
    /// before an id was exposed).
    pub round: u64,
    /// The round's fate: a decoded outcome, or the typed error `wait`
    /// (or `submit`) produced. One round failing never stops the lane.
    pub outcome: anyhow::Result<RoundOutcome>,
}

/// Per-tenant statistics at service close.
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// Lane id.
    pub id: SessionId,
    /// Tenant name (as passed to `open*`).
    pub name: String,
    /// Rounds completed (decoded + failed).
    pub rounds: u64,
    /// Rounds that decoded.
    pub decoded: u64,
    /// Decoded rounds that lost workers and decoded from fewer results.
    pub degraded: u64,
    /// Rounds that failed (typed round errors and failed submits).
    pub failed: u64,
    /// Times this lane had window space but the global cap turned its
    /// next submission away (admission-control pressure).
    pub refused: u64,
    /// Completed rounds per second over the service wall-clock.
    pub rounds_per_s: f64,
    /// Median round latency (submit → decode), milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile round latency, milliseconds.
    pub p99_ms: f64,
    /// Mean lane-window occupancy, sampled at every submit and wait.
    pub occupancy_mean: f64,
    /// Peak lane-window occupancy.
    pub occupancy_max: usize,
}

/// What a whole service run did.
#[derive(Debug)]
pub struct ServiceOutcome {
    /// Per-lane completed rounds, sorted by lane-local index (empty
    /// for lanes whose rounds were consumed by [`Service::round`] or a
    /// [`Service::run_with`] sink).
    pub rounds: Vec<Vec<SessionRound>>,
    /// Per-tenant statistics, indexed by [`SessionId`].
    pub tenants: Vec<SessionStats>,
    /// Wall-clock from service open to finish.
    pub wall: Duration,
    /// Aggregate completed rounds per second across all tenants.
    pub rounds_per_s: f64,
    /// Speculative work orders sent during the service.
    pub redispatched: u64,
    /// Written-off shares recovered by speculation during the service.
    pub recovered: u64,
    /// Duplicate share copies discarded (speculation losers).
    pub wasted: u64,
    /// Mean total rounds in flight, sampled at every scheduler event.
    pub occupancy_mean: f64,
    /// Peak total rounds in flight.
    pub occupancy_max: usize,
}

impl ServiceOutcome {
    /// How many rounds decoded successfully, across all tenants.
    pub fn decoded(&self) -> usize {
        self.tenants.iter().map(|t| t.decoded as usize).sum()
    }
}

/// Where a lane's tasks come from.
enum TaskSource {
    /// Pulled lazily from an iterator — one task at a time, only when
    /// the scheduler is ready to submit it.
    Iter(Box<dyn Iterator<Item = CodedTask>>),
    /// Received from a bounded channel: producers block when the
    /// channel is full (backpressure), the lane drains as capacity
    /// allows, and a dropped sender ends the session.
    Channel(Receiver<CodedTask>),
    /// Fed one task at a time through [`Service::round`].
    Manual,
}

/// One round in flight on a lane.
struct InFlight {
    index: usize,
    round: u64,
    handle: RoundHandle,
}

/// One tenant's lane: source, window, RNG stream, DRR state, stats.
struct Lane {
    name: String,
    opts: SessionOptions,
    source: TaskSource,
    /// The next task, pulled but not yet admitted.
    next: Option<CodedTask>,
    /// Source drained (iterator done / channel disconnected).
    exhausted: bool,
    window: VecDeque<InFlight>,
    rng: Option<Rng>,
    deficit: f64,
    submitted: usize,
    decoded: u64,
    degraded: u64,
    failed: u64,
    refused: u64,
    latency: Histogram,
    occ_sum: u64,
    occ_samples: u64,
    occ_max: usize,
}

impl Lane {
    fn sample_occupancy(&mut self) {
        let o = self.window.len();
        self.occ_sum += o as u64;
        self.occ_samples += 1;
        self.occ_max = self.occ_max.max(o);
    }

    /// Nothing left to pull, submit, or wait on. Manual lanes count as
    /// drained whenever their window is empty — they only carry work
    /// during a [`Service::round`] call.
    fn drained(&self) -> bool {
        self.next.is_none()
            && self.window.is_empty()
            && (self.exhausted || matches!(self.source, TaskSource::Manual))
    }

    /// A connected channel lane with nothing pulled yet: the only case
    /// where the scheduler must block for outside input.
    fn awaiting_channel(&self) -> bool {
        matches!(self.source, TaskSource::Channel(_)) && !self.exhausted && self.next.is_none()
    }
}

/// Pull the lane's next task without blocking (no-op if one is already
/// peeked, the source is drained, or the channel is momentarily empty).
fn pull_ready(lane: &mut Lane) {
    if lane.next.is_some() || lane.exhausted {
        return;
    }
    match &mut lane.source {
        TaskSource::Iter(it) => match it.next() {
            Some(task) => lane.next = Some(task),
            None => lane.exhausted = true,
        },
        TaskSource::Channel(rx) => match rx.try_recv() {
            Ok(task) => lane.next = Some(task),
            Err(TryRecvError::Empty) => {}
            Err(TryRecvError::Disconnected) => lane.exhausted = true,
        },
        TaskSource::Manual => {}
    }
}

/// Submit one task on a lane. A successful submit joins the lane's
/// window; a failed submit is a completed (failed) round, returned for
/// delivery.
///
/// Seeded lanes submit with their fault coordinates — `(lane id,
/// 1-based lane-local round)` — so a fault plan keyed on `lane`
/// (DESIGN.md §13) draws per-lane streams invariant under tenant
/// interleaving. Unseeded (compatibility) lanes pass the `(0, 0)`
/// sentinel: the lane-local round *is* the global round, which keeps
/// the single-tenant wrappers' orders carrying exactly the legacy
/// coordinates.
fn submit_task(
    master: &mut Master,
    sid: SessionId,
    lane: &mut Lane,
    task: CodedTask,
) -> Option<SessionRound> {
    let index = lane.submitted;
    lane.submitted += 1;
    let (lane_id, lane_round) =
        if lane.rng.is_some() { (sid as u32, index as u64 + 1) } else { (0, 0) };
    match master.submit_in_lane(task, lane.rng.as_mut(), lane_id, lane_round) {
        Ok(handle) => {
            let round = handle.round_id();
            lane.window.push_back(InFlight { index, round, handle });
            lane.sample_occupancy();
            None
        }
        Err(e) => {
            lane.failed += 1;
            Some(SessionRound { index, round: 0, outcome: Err(e) })
        }
    }
}

/// The multi-tenant serving front end over one [`Master`] (see module
/// docs). Open lanes, then either drive them to completion with
/// [`Service::run`] / [`Service::run_with`], or feed rounds one at a
/// time with [`Service::round`]; close with [`Service::finish`].
pub struct Service<'m> {
    master: &'m mut Master,
    cfg: ServiceConfig,
    lanes: Vec<Lane>,
    /// Completed rounds not yet handed to a caller, per lane.
    collected: Vec<Vec<SessionRound>>,
    cursor: usize,
    prev_speculation: bool,
    spec0: (u64, u64, u64),
    started: Instant,
    completed: u64,
    occ_sum: u64,
    occ_samples: u64,
    occ_max: usize,
}

impl Master {
    /// Open the multi-tenant serving front end over this master:
    /// speculation is set per `cfg` for the service's lifetime (and
    /// restored by [`Service::finish`]), and every lane opened on the
    /// returned [`Service`] shares this master's worker fleet,
    /// registry, and collector.
    pub fn service(&mut self, cfg: ServiceConfig) -> Service<'_> {
        let prev_speculation = self.speculation();
        self.set_speculation(cfg.speculate);
        let spec0 = (
            self.metrics().get(names::SPEC_REDISPATCHED),
            self.metrics().get(names::SPEC_RECOVERED),
            self.metrics().get(names::SPEC_WASTED),
        );
        Service {
            master: self,
            cfg,
            lanes: Vec::new(),
            collected: Vec::new(),
            cursor: 0,
            prev_speculation,
            spec0,
            started: Instant::now(),
            completed: 0,
            occ_sum: 0,
            occ_samples: 0,
            occ_max: 0,
        }
    }
}

impl<'m> Service<'m> {
    /// Open a manual lane: tasks are fed one at a time through
    /// [`Service::round`].
    pub fn open(&mut self, name: &str, opts: SessionOptions) -> SessionId {
        self.add_lane(name, opts, TaskSource::Manual)
    }

    /// Open a lane fed from an iterator. Tasks are pulled lazily — one
    /// at a time, only when the scheduler is ready to submit — so a
    /// whole-epoch source is never materialized.
    pub fn open_iter(
        &mut self,
        name: &str,
        opts: SessionOptions,
        tasks: impl Iterator<Item = CodedTask> + 'static,
    ) -> SessionId {
        self.add_lane(name, opts, TaskSource::Iter(Box::new(tasks)))
    }

    /// Open a lane fed from a bounded channel (capacity ≥ 1). The
    /// returned sender blocks when the channel is full — producer
    /// backpressure — and dropping it ends the session once the queue
    /// drains.
    pub fn open_channel(
        &mut self,
        name: &str,
        opts: SessionOptions,
        capacity: usize,
    ) -> (SessionId, SyncSender<CodedTask>) {
        let (tx, rx) = sync_channel(capacity.max(1));
        (self.add_lane(name, opts, TaskSource::Channel(rx)), tx)
    }

    fn add_lane(&mut self, name: &str, opts: SessionOptions, source: TaskSource) -> SessionId {
        let rng = opts.seed.map(|s| rng_from_seed(derive_seed(s, LANE_RNG_STREAM)));
        self.lanes.push(Lane {
            name: name.to_string(),
            opts,
            source,
            next: None,
            exhausted: false,
            window: VecDeque::with_capacity(opts.inflight.max(1)),
            rng,
            deficit: 0.0,
            submitted: 0,
            decoded: 0,
            degraded: 0,
            failed: 0,
            refused: 0,
            latency: Histogram::new(),
            occ_sum: 0,
            occ_samples: 0,
            occ_max: 0,
        });
        self.collected.push(Vec::new());
        self.lanes.len() - 1
    }

    /// Rounds currently in flight across all lanes.
    fn outstanding(&self) -> usize {
        self.lanes.iter().map(|l| l.window.len()).sum()
    }

    /// The lane holding the globally-oldest in-flight round (round ids
    /// are monotone in submission order, so the minimum front id is the
    /// oldest round — the FIFO wait target).
    fn oldest_lane(&self) -> Option<usize> {
        self.lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| l.window.front().map(|f| (f.round, i)))
            .min()
            .map(|(_, i)| i)
    }

    fn sample_global(&mut self) {
        let o = self.outstanding();
        self.occ_sum += o as u64;
        self.occ_samples += 1;
        self.occ_max = self.occ_max.max(o);
    }

    /// Wait the front of lane `li`'s window (its oldest round) under the
    /// lane's deadline and record its stats.
    fn wait_front(&mut self, li: usize) -> SessionRound {
        let default_deadline = self.master.config().round_deadline_s;
        let lane = &mut self.lanes[li];
        let inflight = lane.window.pop_front().expect("wait_front on an empty window");
        let deadline = lane.opts.deadline_s.unwrap_or(default_deadline);
        let outcome = self.master.wait_with_deadline(inflight.handle, deadline);
        match &outcome {
            Ok(out) => {
                lane.decoded += 1;
                if out.degraded {
                    lane.degraded += 1;
                    self.master.metrics().inc(names::TENANT_DEGRADED);
                }
                lane.latency.record(out.wall.as_secs_f64() * 1e3);
            }
            Err(_) => lane.failed += 1,
        }
        lane.sample_occupancy();
        SessionRound { index: inflight.index, round: inflight.round, outcome }
    }

    /// Book a completed round for later collection.
    fn deliver(&mut self, li: usize, r: SessionRound) {
        self.completed += 1;
        self.master.metrics().inc(names::TENANT_ROUNDS);
        self.collected[li].push(r);
    }

    /// One deficit-round-robin sweep over the lanes: credit each lane
    /// its quantum and submit while the deficit, the lane window, and
    /// the global cap allow. Returns whether anything was submitted.
    fn sweep(&mut self) -> bool {
        let n = self.lanes.len();
        if n == 0 {
            return false;
        }
        let mut any = false;
        let mut outstanding = self.outstanding();
        let mut failed: Vec<(usize, SessionRound)> = Vec::new();
        for step in 0..n {
            let li = (self.cursor + step) % n;
            pull_ready(&mut self.lanes[li]);
            let lane = &mut self.lanes[li];
            if lane.next.is_none() {
                // Classic DRR: an empty queue forfeits its credit —
                // otherwise an idle lane would bank bandwidth and burst
                // later, which is latency unfairness in disguise.
                lane.deficit = 0.0;
                continue;
            }
            let quantum = lane.opts.weight.max(1) as f64;
            // Carry at most one unused quantum: enough to realize the
            // weight ratio, never enough to burst past it.
            lane.deficit = (lane.deficit + quantum).min(2.0 * quantum);
            // Refusal accounting invariant (shared with `round`): a
            // lane counts at most ONE refusal per admission attempt —
            // here, per sweep — no matter how many submissions its
            // deficit would have allowed or how often the cap is
            // re-checked. `lane.refused` and the TENANT_REFUSED metric
            // move in lock step (the flag below gates both), so the
            // two never drift into a double count.
            let mut refused_this_sweep = false;
            while lane.deficit >= 1.0 && lane.next.is_some() {
                if lane.window.len() >= lane.opts.inflight.max(1) {
                    break; // the lane's own window binds — not a refusal
                }
                if self.cfg.global_inflight > 0 && outstanding >= self.cfg.global_inflight {
                    if !refused_this_sweep {
                        lane.refused += 1;
                        refused_this_sweep = true;
                    }
                    break;
                }
                let task = lane.next.take().expect("checked is_some");
                lane.deficit -= 1.0;
                match submit_task(&mut *self.master, li, lane, task) {
                    None => outstanding += 1,
                    Some(r) => failed.push((li, r)),
                }
                any = true;
                pull_ready(lane);
            }
            if refused_this_sweep {
                self.master.metrics().inc(names::TENANT_REFUSED);
            }
        }
        self.cursor = (self.cursor + 1) % n;
        for (li, r) in failed {
            self.deliver(li, r);
        }
        any
    }

    /// Block briefly on one awaiting channel lane (all sources idle,
    /// nothing in flight): the only point the scheduler sleeps.
    fn block_on_channels(&mut self) {
        for lane in self.lanes.iter_mut().filter(|l| l.awaiting_channel()) {
            let TaskSource::Channel(rx) = &lane.source else { continue };
            match rx.recv_timeout(Duration::from_millis(2)) {
                Ok(task) => {
                    lane.next = Some(task);
                    return;
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => lane.exhausted = true,
            }
        }
    }

    /// One scheduler step: submit what admission allows; otherwise wait
    /// the globally-oldest round; otherwise block for channel input.
    /// Returns false when every lane is drained.
    fn step(&mut self, pull: bool) -> bool {
        if pull && self.sweep() {
            self.sample_global();
            return true;
        }
        if let Some(li) = self.oldest_lane() {
            let r = self.wait_front(li);
            self.deliver(li, r);
            self.sample_global();
            return true;
        }
        if pull && self.lanes.iter().any(Lane::awaiting_channel) {
            self.block_on_channels();
            return true;
        }
        // Nothing to submit, nothing in flight, no channel pending:
        // every lane is drained (a lane with a peeked task always has
        // either window space — the sweep takes it — or an in-flight
        // round the wait branch retires first).
        debug_assert!(self.lanes.iter().all(Lane::drained));
        false
    }

    /// Run one round synchronously on lane `sid`: admit (waiting out
    /// older rounds if the lane window or global cap is full), submit,
    /// and wait for *this* round's outcome. This is the feed path for
    /// callers whose next task depends on the previous result — the DL
    /// trainer's gradient products — where lookahead is impossible and
    /// memory must stay flat.
    pub fn round(&mut self, sid: SessionId, task: CodedTask) -> anyhow::Result<RoundOutcome> {
        // Same refusal invariant as `sweep`: this call is ONE admission
        // attempt, so it books at most one refusal even when the
        // admission loop has to wait out several older rounds (each
        // iteration re-checks the cap) before space opens.
        let mut counted_refusal = false;
        loop {
            let lane = &self.lanes[sid];
            let lane_full = lane.window.len() >= lane.opts.inflight.max(1);
            let global_full =
                self.cfg.global_inflight > 0 && self.outstanding() >= self.cfg.global_inflight;
            if !lane_full && !global_full {
                break;
            }
            if global_full && !lane_full && !counted_refusal {
                self.lanes[sid].refused += 1;
                self.master.metrics().inc(names::TENANT_REFUSED);
                counted_refusal = true;
            }
            let li = self.oldest_lane().expect("a full window implies an outstanding round");
            let r = self.wait_front(li);
            self.deliver(li, r);
        }
        if let Some(r) = submit_task(&mut *self.master, sid, &mut self.lanes[sid], task) {
            self.completed += 1;
            self.master.metrics().inc(names::TENANT_ROUNDS);
            return r.outcome;
        }
        self.sample_global();
        let target = self.lanes[sid].window.back().expect("just submitted").round;
        loop {
            let r = self.wait_front(sid);
            if r.round == target {
                self.completed += 1;
                self.master.metrics().inc(names::TENANT_ROUNDS);
                self.sample_global();
                return r.outcome;
            }
            self.deliver(sid, r);
        }
    }

    /// Drive every lane's source to exhaustion and every window dry,
    /// then [`finish`](Service::finish). Per-lane rounds come back in
    /// the outcome, sorted by lane-local index. Blocks until channel
    /// senders are dropped.
    pub fn run(mut self) -> ServiceOutcome {
        while self.step(true) {}
        self.finish()
    }

    /// Like [`run`](Service::run), but each completed round is handed
    /// to `sink` as soon as it finishes instead of being buffered —
    /// memory stays flat no matter how long the streams are.
    pub fn run_with(
        mut self,
        sink: &mut dyn FnMut(SessionId, SessionRound),
    ) -> ServiceOutcome {
        while self.step(true) {
            self.flush(sink);
        }
        self.flush(sink);
        self.finish()
    }

    fn flush(&mut self, sink: &mut dyn FnMut(SessionId, SessionRound)) {
        for li in 0..self.collected.len() {
            for r in self.collected[li].drain(..) {
                sink(li, r);
            }
        }
    }

    /// Close the service: wait out every in-flight round (without
    /// pulling new tasks), restore the master's speculation setting,
    /// and report per-tenant stats plus the speculation deltas.
    pub fn finish(mut self) -> ServiceOutcome {
        while let Some(li) = self.oldest_lane() {
            let r = self.wait_front(li);
            self.deliver(li, r);
        }
        self.master.set_speculation(self.prev_speculation);
        let wall = self.started.elapsed();
        let wall_s = wall.as_secs_f64();
        let tenants: Vec<SessionStats> = self
            .lanes
            .iter()
            .enumerate()
            .map(|(id, lane)| SessionStats {
                id,
                name: lane.name.clone(),
                rounds: lane.decoded + lane.failed,
                decoded: lane.decoded,
                degraded: lane.degraded,
                failed: lane.failed,
                refused: lane.refused,
                rounds_per_s: if wall_s > 0.0 {
                    (lane.decoded + lane.failed) as f64 / wall_s
                } else {
                    0.0
                },
                p50_ms: lane.latency.p50(),
                p99_ms: lane.latency.p99(),
                occupancy_mean: if lane.occ_samples > 0 {
                    lane.occ_sum as f64 / lane.occ_samples as f64
                } else {
                    0.0
                },
                occupancy_max: lane.occ_max,
            })
            .collect();
        let mut rounds = std::mem::take(&mut self.collected);
        for lane in &mut rounds {
            lane.sort_by_key(|r| r.index);
        }
        let metrics = self.master.metrics();
        ServiceOutcome {
            rounds,
            tenants,
            wall,
            rounds_per_s: if wall_s > 0.0 { self.completed as f64 / wall_s } else { 0.0 },
            redispatched: metrics.get(names::SPEC_REDISPATCHED) - self.spec0.0,
            recovered: metrics.get(names::SPEC_RECOVERED) - self.spec0.1,
            wasted: metrics.get(names::SPEC_WASTED) - self.spec0.2,
            occupancy_mean: if self.occ_samples > 0 {
                self.occ_sum as f64 / self.occ_samples as f64
            } else {
                0.0
            },
            occupancy_max: self.occ_max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchemeKind;
    use crate::matrix::Matrix;
    use crate::rng::rng_from_seed;
    use crate::runtime::WorkerOp;
    use std::sync::Arc;

    fn cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.workers = 10;
        cfg.partitions = 3;
        cfg.colluders = 2;
        cfg.stragglers = 2;
        cfg.scheme = SchemeKind::Spacdc;
        cfg.delay.base_service_s = 0.0;
        cfg
    }

    fn tasks(n: usize, seed: u64) -> Vec<CodedTask> {
        let mut rng = rng_from_seed(seed);
        let v = Arc::new(Matrix::random_gaussian(6, 4, 0.0, 1.0, &mut rng));
        (0..n)
            .map(|_| {
                let x = Matrix::random_gaussian(12, 6, 0.0, 1.0, &mut rng);
                CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x)
            })
            .collect()
    }

    #[test]
    fn single_lane_rounds_match_submit_wait_bitwise() {
        let mut direct = Master::from_config(cfg()).unwrap();
        let mut expect = Vec::new();
        for t in tasks(4, 77) {
            let h = direct.submit(t).unwrap();
            expect.push(direct.wait(h).unwrap().blocks);
        }
        let mut master = Master::from_config(cfg()).unwrap();
        let mut svc = master.service(ServiceConfig { global_inflight: 1, speculate: false });
        let sid = svc.open("solo", SessionOptions::default());
        for (i, t) in tasks(4, 77).into_iter().enumerate() {
            let out = svc.round(sid, t).unwrap();
            assert_eq!(
                out.blocks, expect[i],
                "a compatibility-mode session must be bit-identical to submit/wait"
            );
        }
        let outcome = svc.finish();
        assert_eq!(outcome.tenants[sid].decoded, 4);
        assert_eq!(outcome.tenants[sid].failed, 0);
        assert!(outcome.tenants[sid].p99_ms >= outcome.tenants[sid].p50_ms);
    }

    #[test]
    fn drr_alternates_two_equal_tenants() {
        let mut master = Master::from_config(cfg()).unwrap();
        let mut svc = master.service(ServiceConfig { global_inflight: 0, speculate: false });
        let opts = SessionOptions { inflight: 1, seed: Some(1), ..Default::default() };
        let a = svc.open_iter("a", opts, tasks(4, 101).into_iter());
        let b = svc.open_iter(
            "b",
            SessionOptions { seed: Some(2), ..opts },
            tasks(4, 102).into_iter(),
        );
        let out = svc.run();
        // Round ids are global and monotone in submission order: strict
        // alternation is exactly a:1,3,5,7 / b:2,4,6,8.
        let ids = |sid: usize| -> Vec<u64> { out.rounds[sid].iter().map(|r| r.round).collect() };
        assert_eq!(ids(a), vec![1, 3, 5, 7], "lane a must get every other dispatch slot");
        assert_eq!(ids(b), vec![2, 4, 6, 8], "lane b must get every other dispatch slot");
        assert_eq!(out.decoded(), 8);
        assert_eq!(out.tenants[a].refused, 0, "no global cap, no refusals");
    }

    #[test]
    fn admission_refuses_beyond_the_global_cap() {
        let mut master = Master::from_config(cfg()).unwrap();
        let mut svc = master.service(ServiceConfig { global_inflight: 4, speculate: false });
        let opts = SessionOptions { inflight: 4, seed: Some(3), ..Default::default() };
        let a = svc.open_iter("greedy-a", opts, tasks(6, 201).into_iter());
        let b = svc.open_iter(
            "greedy-b",
            SessionOptions { seed: Some(4), ..opts },
            tasks(6, 202).into_iter(),
        );
        let out = svc.run();
        assert_eq!(out.decoded(), 12, "admission defers work, never drops it");
        assert!(out.occupancy_max <= 4, "the global cap binds: {}", out.occupancy_max);
        assert!(
            out.tenants[a].refused + out.tenants[b].refused > 0,
            "two 4-wide lanes into a 4-wide fleet must hit admission control"
        );
    }

    #[test]
    fn refusals_count_admission_attempts_not_recheck_iterations() {
        // One lane, weight 2, window 2, two tasks, into a global cap of
        // 1: every sweep that finds the cap full while the lane still
        // has work and window space books exactly one refusal — never
        // one per deficit credit, never one per re-check. The schedule
        // is deterministic: sweep 1 submits t1 and is refused t2;
        // sweep 2 is refused t2 again (t1 still in flight), the
        // scheduler then retires t1; sweep 3 submits t2 unrefused.
        let mut master = Master::from_config(cfg()).unwrap();
        let mut svc = master.service(ServiceConfig { global_inflight: 1, speculate: false });
        let opts =
            SessionOptions { inflight: 2, weight: 2, seed: Some(9), ..Default::default() };
        let sid = svc.open_iter("pushy", opts, tasks(2, 401).into_iter());
        let out = svc.run();
        assert_eq!(out.decoded(), 2, "admission defers work, never drops it");
        assert_eq!(
            out.tenants[sid].refused, 2,
            "one refusal per cap-blocked sweep: a weight-2 deficit must not double-book"
        );
        assert_eq!(
            master.metrics().get(names::TENANT_REFUSED),
            2,
            "the metric moves in lock step with the per-lane counter"
        );
    }

    #[test]
    fn channel_source_streams_with_backpressure() {
        let mut master = Master::from_config(cfg()).unwrap();
        let mut svc = master.service(ServiceConfig { global_inflight: 0, speculate: false });
        let (sid, tx) = svc.open_channel(
            "feed",
            SessionOptions { inflight: 2, seed: Some(5), ..Default::default() },
            2,
        );
        let feeder = std::thread::spawn(move || {
            for t in tasks(6, 301) {
                tx.send(t).unwrap();
            }
            // Sender drops here: the session ends once the queue drains.
        });
        let mut seen = 0usize;
        let out = svc.run_with(&mut |id, r| {
            assert_eq!(id, sid);
            assert!(r.outcome.is_ok(), "round {}: {:?}", r.index, r.outcome);
            seen += 1;
        });
        feeder.join().unwrap();
        assert_eq!(seen, 6, "every fed round must come back through the sink");
        assert_eq!(out.rounds[sid].len(), 0, "sink mode buffers nothing");
        assert!(out.tenants[sid].occupancy_max <= 2, "lane window bounds occupancy");
    }

    #[test]
    fn service_config_comes_from_the_system_config() {
        let mut c = cfg();
        c.inflight = 8;
        c.speculate = true;
        assert_eq!(
            ServiceConfig::from_config(&c),
            ServiceConfig { global_inflight: 8, speculate: true }
        );
    }
}
