//! The worker fabric: N long-lived threads, one per worker, each with its
//! own ECC key pair, speaking *only serialized frames* over a pluggable
//! [`Transport`](crate::transport::Transport). Workers receive framed
//! [`WorkOrder`]s, decode them ([`crate::wire`]), simulate their service
//! delay, unseal, compute through the [`Executor`], re-seal, and write
//! the framed result back — the paper's "task computing" phase (§III-A
//! step 2).
//!
//! Each worker drains its link in FIFO order, so when the master
//! pipelines several rounds (`Master::submit` before `Master::wait`) the
//! orders of round r+1 are already queued while round r computes.
//! Results carry their round id; the master's collector thread routes
//! them back to the right in-flight round.
//!
//! A worker whose link is down surfaces as a typed
//! [`TransportError::WorkerDown`] from [`WorkerPool::dispatch`] — the
//! master degrades it into a permanent straggler instead of panicking.
//! A complete frame that fails wire validation is counted
//! (`comm.wire_errors`) and dropped, and the worker keeps serving;
//! header-level stream corruption (frame sync lost) is also counted,
//! but kills the link — the master sees the worker as dead at its next
//! dispatch.

use super::messages::{ResultMsg, SealedPayload, WirePayload, WorkOrder};
use crate::config::TransportKind;
use crate::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc, Point};
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::metrics::{names, MetricsRegistry};
use crate::rng::{derive_seed, rng_from_seed};
use crate::runtime::Executor;
use crate::sim::CollusionPool;
use crate::transport::{self, Transport, TransportError, WorkerLink};
use crate::wire;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread::JoinHandle;

/// A pool of worker threads plus the master-side transport sender.
pub struct WorkerPool {
    transport: Option<Box<dyn Transport>>,
    worker_pks: Vec<Point<Fp61>>,
    joins: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Wire a fabric of `kind` and spawn `n` workers on it. Each worker
    /// generates its own key pair (§IV-B step 1) and publishes the
    /// public key to the master. Returns the pool plus the merged
    /// inbound channel of result *frames* (consumed by the master's
    /// collector thread).
    ///
    /// * `master_pk` — the master's public key (workers encrypt results
    ///   to it).
    /// * `executor` — shared execution façade (PJRT or native).
    /// * `collusion` — optional coalition tap; colluding workers deposit
    ///   their decrypted shares there.
    /// * `metrics` — sink for the transport byte counters.
    pub fn spawn(
        kind: TransportKind,
        n: usize,
        master_pk: Point<Fp61>,
        executor: Executor,
        collusion: Option<Arc<CollusionPool>>,
        seed: u64,
        metrics: Arc<MetricsRegistry>,
    ) -> Result<(Self, Receiver<Vec<u8>>), TransportError> {
        let curve = sim_curve();
        let fabric = transport::connect(kind, n, metrics)?;
        let mut worker_pks = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);

        for (w, link) in fabric.links.into_iter().enumerate() {
            let mut rng = rng_from_seed(derive_seed(seed, 0xBEEF_0000 + w as u64));
            let keys = KeyPair::generate(&curve, &mut rng);
            worker_pks.push(keys.public());

            let executor = executor.clone();
            let collusion = collusion.clone();
            let join = std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || {
                    worker_loop(w, keys, master_pk, link, executor, collusion, seed)
                })
                .expect("spawn worker");
            joins.push(join);
        }

        Ok((Self { transport: Some(fabric.transport), worker_pks, joins }, fabric.inbound))
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.worker_pks.len()
    }

    /// Worker public keys, indexed by worker id.
    pub fn worker_pks(&self) -> &[Point<Fp61>] {
        &self.worker_pks
    }

    /// Which fabric the pool runs on.
    pub fn transport_kind(&self) -> TransportKind {
        self.transport.as_ref().expect("pool not shut down").kind()
    }

    /// Serialize an order and send it to its worker. A down link
    /// surfaces as [`TransportError::WorkerDown`]; the caller treats
    /// that worker as a permanent straggler.
    pub fn dispatch(&self, order: &WorkOrder) -> Result<(), TransportError> {
        let frame = wire::encode_order(order);
        self.transport.as_ref().expect("pool not shut down").send(order.worker, frame)
    }

    /// Tear the fabric down and join the workers. Called by `Drop`;
    /// idempotent.
    pub(crate) fn shutdown(&mut self) {
        self.transport.take(); // closes every link
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    w: usize,
    keys: KeyPair<Fp61>,
    master_pk: Point<Fp61>,
    mut link: WorkerLink,
    executor: Executor,
    collusion: Option<Arc<CollusionPool>>,
    seed: u64,
) {
    // One worker thread models one remote node: its kernels run serial
    // so N workers use N cores, not N × pool-width.
    crate::parallel::mark_serial_thread();
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    let mut rng = rng_from_seed(derive_seed(seed, 0xD0_0000 + w as u64));
    // Result frames are serialized into this scratch buffer; after the
    // first round it is already at frame size and sending allocates
    // nothing (the TCP path writes from it directly, the in-proc path
    // copies it into the channel).
    let mut frame_buf: Vec<u8> = Vec::new();
    loop {
        // A clean close (master gone / fabric torn down) ends the loop
        // silently; a poisoned stream (header-level corruption, socket
        // error) is counted before the link dies, since frame sync is
        // unrecoverable at that point.
        let frame = match link.recv() {
            Ok(f) => f,
            Err(wire::WireError::Closed) => break,
            Err(e) => {
                executor.metrics().inc(names::WIRE_ERRORS);
                eprintln!("worker {w}: link failed ({e}); shutting down");
                break;
            }
        };
        let order = match wire::decode_order(&frame) {
            Ok(o) => o,
            Err(e) => {
                executor.metrics().inc(names::WIRE_ERRORS);
                eprintln!("worker {w}: dropping undecodable frame: {e}");
                continue;
            }
        };

        // Straggler simulation — the paper's sleep() injection.
        if !order.delay.is_zero() {
            std::thread::sleep(order.delay);
        }
        let WorkOrder { round, op, payloads, .. } = order;

        // Decrypt operands (§IV-B step 4), consuming the decoded order:
        // plain operands move straight through and sealed ones are
        // unmasked in place — the worker never clones a matrix it
        // already owns.
        let sealed_round = matches!(payloads.first(), Some(WirePayload::Sealed(_)));
        let mut operands: Vec<Matrix> = Vec::with_capacity(payloads.len());
        let mut poisoned = false;
        for p in payloads {
            match p {
                WirePayload::Plain(m) => operands.push(m),
                WirePayload::Sealed(s) => match s.open_owned(&mea, &keys) {
                    Ok(m) => operands.push(m),
                    Err(e) => {
                        executor.metrics().inc(names::WIRE_ERRORS);
                        eprintln!("worker {w}: sealed payload failed to open: {e}");
                        poisoned = true;
                        break;
                    }
                },
            }
        }
        if poisoned {
            continue;
        }

        // Colluding workers leak their plaintext shares to the pool.
        if let Some(pool) = &collusion {
            for m in &operands {
                pool.deposit(w, m);
            }
        }

        // Compute f (PJRT artifact or native kernel).
        let out = executor.run(&op, &operands);

        // Encrypt the result back to the master when the share arrived
        // sealed (symmetric policy — §V-B step 2).
        let payload = if sealed_round {
            WirePayload::Sealed(SealedPayload::seal(&mea, &out, &master_pk, &mut rng))
        } else {
            WirePayload::Plain(out)
        };

        let msg = ResultMsg { round, worker: w, payload };
        wire::encode_result_into(&msg, &mut frame_buf);
        if link.send(&frame_buf).is_err() {
            break; // master gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::WorkerOp;
    use crate::wire::MsgKind;
    use std::time::Duration;

    fn pool(n: usize) -> (WorkerPool, Receiver<Vec<u8>>, KeyPair<Fp61>) {
        let curve = sim_curve();
        let mut rng = rng_from_seed(0xAA);
        let master = KeyPair::generate(&curve, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let exec = Executor::native(Arc::clone(&metrics));
        let (p, rx) = WorkerPool::spawn(
            TransportKind::InProc,
            n,
            master.public(),
            exec,
            None,
            7,
            metrics,
        )
        .unwrap();
        (p, rx, master)
    }

    fn recv_result(rx: &Receiver<Vec<u8>>) -> ResultMsg {
        let frame = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        wire::decode_result(&frame).unwrap()
    }

    #[test]
    fn workers_echo_identity_orders() {
        let (pool, rx, _master) = pool(4);
        for w in 0..4 {
            pool.dispatch(&WorkOrder {
                round: 1,
                worker: w,
                op: WorkerOp::Identity,
                payloads: vec![WirePayload::Plain(Matrix::ones(2, 2).scale(w as f32))],
                delay: Duration::ZERO,
            })
            .unwrap();
        }
        let mut seen = vec![false; 4];
        for _ in 0..4 {
            let r = recv_result(&rx);
            assert_eq!(r.round, 1);
            match r.payload {
                WirePayload::Plain(m) => {
                    assert_eq!(m.get(0, 0), r.worker as f32);
                }
                _ => panic!("expected plain"),
            }
            seen[r.worker] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sealed_roundtrip_through_worker() {
        let (pool, rx, master) = pool(2);
        let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
        let mut rng = rng_from_seed(1);
        let x = Matrix::random_gaussian(4, 4, 0.0, 1.0, &mut rng);
        let sealed = SealedPayload::seal(&mea, &x, &pool.worker_pks()[0], &mut rng);
        pool.dispatch(&WorkOrder {
            round: 9,
            worker: 0,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Sealed(sealed)],
            delay: Duration::ZERO,
        })
        .unwrap();
        let r = recv_result(&rx);
        match r.payload {
            WirePayload::Sealed(s) => {
                let opened = s.open(&mea, &master).unwrap();
                assert_eq!(opened, x, "worker must echo the decrypted plaintext, re-sealed");
            }
            _ => panic!("expected sealed result for a sealed order"),
        }
    }

    #[test]
    fn colluders_deposit_plaintext() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(0xBB);
        let master = KeyPair::generate(&curve, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let exec = Executor::native(Arc::clone(&metrics));
        let coalition = Arc::new(CollusionPool::new(vec![1]));
        let (pool, rx) = WorkerPool::spawn(
            TransportKind::InProc,
            3,
            master.public(),
            exec,
            Some(Arc::clone(&coalition)),
            7,
            metrics,
        )
        .unwrap();
        for w in 0..3 {
            pool.dispatch(&WorkOrder {
                round: 1,
                worker: w,
                op: WorkerOp::Identity,
                payloads: vec![WirePayload::Plain(Matrix::ones(2, 2))],
                delay: Duration::ZERO,
            })
            .unwrap();
        }
        for _ in 0..3 {
            recv_result(&rx);
        }
        let gathered = coalition.gathered();
        assert_eq!(gathered.len(), 1, "only worker 1 colludes");
        assert_eq!(gathered[0].0, 1);
    }

    #[test]
    fn straggler_delay_orders_arrival() {
        let (pool, rx, _master) = pool(2);
        // Worker 0 delayed, worker 1 immediate → 1 arrives first.
        pool.dispatch(&WorkOrder {
            round: 1,
            worker: 0,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Plain(Matrix::ones(1, 1))],
            delay: Duration::from_millis(150),
        })
        .unwrap();
        pool.dispatch(&WorkOrder {
            round: 1,
            worker: 1,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Plain(Matrix::ones(1, 1))],
            delay: Duration::ZERO,
        })
        .unwrap();
        let first = recv_result(&rx);
        assert_eq!(first.worker, 1, "non-straggler must arrive first");
    }

    #[test]
    fn undecodable_frame_is_dropped_not_fatal() {
        let (pool, rx, _master) = pool(1);
        // A structurally valid frame with a garbage body: the worker must
        // count it, drop it, and keep serving.
        let junk = wire::frame(MsgKind::Order, b"not an order body");
        pool.transport.as_ref().unwrap().send(0, junk).unwrap();
        pool.dispatch(&WorkOrder {
            round: 2,
            worker: 0,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Plain(Matrix::ones(1, 1))],
            delay: Duration::ZERO,
        })
        .unwrap();
        let r = recv_result(&rx);
        assert_eq!(r.round, 2, "worker must survive the junk frame");
    }

    #[test]
    fn tcp_pool_round_trips_orders() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(0xCC);
        let master = KeyPair::generate(&curve, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let exec = Executor::native(Arc::clone(&metrics));
        let (pool, rx) = WorkerPool::spawn(
            TransportKind::Tcp,
            2,
            master.public(),
            exec,
            None,
            7,
            Arc::clone(&metrics),
        )
        .unwrap();
        for w in 0..2 {
            pool.dispatch(&WorkOrder {
                round: 5,
                worker: w,
                op: WorkerOp::Identity,
                payloads: vec![WirePayload::Plain(Matrix::ones(3, 3))],
                delay: Duration::ZERO,
            })
            .unwrap();
        }
        for _ in 0..2 {
            let r = recv_result(&rx);
            assert_eq!(r.round, 5);
        }
        assert!(metrics.get(names::BYTES_TX) > 0, "socket bytes must be counted");
    }
}
