//! The worker fabric: N long-lived threads, one per worker, each with its
//! own ECC key pair. Workers receive [`WorkOrder`]s on a private channel,
//! simulate their service delay, decrypt, compute through the
//! [`Executor`], re-encrypt, and push the result onto the shared return
//! channel — the paper's "task computing" phase (§III-A step 2).
//!
//! Each worker drains its order queue in FIFO order, so when the master
//! pipelines several rounds (`Master::submit` before `Master::wait`) the
//! orders of round r+1 are already queued while round r computes — the
//! overlap the `pipelining` bench measures. Results carry their round id
//! and the master routes them back to the right in-flight round.

use super::messages::{ResultMsg, WirePayload, WorkOrder};
use crate::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc, Point};
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::rng::{derive_seed, rng_from_seed};
use crate::runtime::Executor;
use crate::sim::CollusionPool;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// A pool of worker threads plus the master-side channel ends.
pub struct WorkerPool {
    order_txs: Vec<Sender<WorkOrder>>,
    result_rx: Receiver<ResultMsg>,
    worker_pks: Vec<Point<Fp61>>,
    joins: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers. Each generates its own key pair (§IV-B step 1)
    /// and publishes the public key to the master.
    ///
    /// * `master_pk` — the master's public key (workers encrypt results
    ///   to it).
    /// * `executor` — shared execution façade (PJRT or native).
    /// * `collusion` — optional coalition tap; colluding workers deposit
    ///   their decrypted shares there.
    pub fn spawn(
        n: usize,
        master_pk: Point<Fp61>,
        executor: Executor,
        collusion: Option<Arc<CollusionPool>>,
        seed: u64,
    ) -> Self {
        let curve = sim_curve();
        let (result_tx, result_rx) = mpsc::channel::<ResultMsg>();
        let mut order_txs = Vec::with_capacity(n);
        let mut worker_pks = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);

        for w in 0..n {
            let mut rng = rng_from_seed(derive_seed(seed, 0xBEEF_0000 + w as u64));
            let keys = KeyPair::generate(&curve, &mut rng);
            worker_pks.push(keys.public());

            let (order_tx, order_rx) = mpsc::channel::<WorkOrder>();
            order_txs.push(order_tx);

            let result_tx = result_tx.clone();
            let executor = executor.clone();
            let collusion = collusion.clone();
            let master_pk = master_pk;
            let join = std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || {
                    worker_loop(
                        w, keys, master_pk, order_rx, result_tx, executor, collusion, seed,
                    )
                })
                .expect("spawn worker");
            joins.push(join);
        }

        Self { order_txs, result_rx, worker_pks, joins }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.order_txs.len()
    }

    /// Worker public keys, indexed by worker id.
    pub fn worker_pks(&self) -> &[Point<Fp61>] {
        &self.worker_pks
    }

    /// Send an order to its worker.
    pub fn dispatch(&self, order: WorkOrder) {
        let w = order.worker;
        self.order_txs[w].send(order).expect("worker alive");
    }

    /// The master-side result receiver.
    pub fn results(&self) -> &Receiver<ResultMsg> {
        &self.result_rx
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the order channels ends the worker loops.
        self.order_txs.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    w: usize,
    keys: KeyPair<Fp61>,
    master_pk: Point<Fp61>,
    orders: Receiver<WorkOrder>,
    results: Sender<ResultMsg>,
    executor: Executor,
    collusion: Option<Arc<CollusionPool>>,
    seed: u64,
) {
    let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
    let mut rng = rng_from_seed(derive_seed(seed, 0xD0_0000 + w as u64));
    while let Ok(order) = orders.recv() {
        // Straggler simulation — the paper's sleep() injection.
        if !order.delay.is_zero() {
            std::thread::sleep(order.delay);
        }

        // Decrypt operands (§IV-B step 4).
        let operands: Vec<Matrix> = order
            .payloads
            .iter()
            .map(|p| match p {
                WirePayload::Plain(m) => m.clone(),
                WirePayload::Sealed(s) => mea.decrypt(s, &keys),
            })
            .collect();

        // Colluding workers leak their plaintext shares to the pool.
        if let Some(pool) = &collusion {
            for m in &operands {
                pool.deposit(w, m);
            }
        }

        // Compute f (PJRT artifact or native kernel).
        let out = executor.run(&order.op, &operands);

        // Encrypt the result back to the master when the share arrived
        // sealed (symmetric policy — §V-B step 2).
        let sealed_round = matches!(order.payloads.first(), Some(WirePayload::Sealed(_)));
        let payload = if sealed_round {
            WirePayload::Sealed(mea.encrypt(&out, &master_pk, &mut rng))
        } else {
            WirePayload::Plain(out)
        };

        if results.send(ResultMsg { round: order.round, worker: w, payload }).is_err() {
            break; // master gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricsRegistry;
    use crate::runtime::WorkerOp;
    use std::time::Duration;

    fn pool(n: usize) -> (WorkerPool, KeyPair<Fp61>) {
        let curve = sim_curve();
        let mut rng = rng_from_seed(0xAA);
        let master = KeyPair::generate(&curve, &mut rng);
        let exec = Executor::native(Arc::new(MetricsRegistry::new()));
        let p = WorkerPool::spawn(n, master.public(), exec, None, 7);
        (p, master)
    }

    #[test]
    fn workers_echo_identity_orders() {
        let (pool, _master) = pool(4);
        for w in 0..4 {
            pool.dispatch(WorkOrder {
                round: 1,
                worker: w,
                op: WorkerOp::Identity,
                payloads: vec![WirePayload::Plain(Matrix::ones(2, 2).scale(w as f32))],
                delay: Duration::ZERO,
            });
        }
        let mut seen = vec![false; 4];
        for _ in 0..4 {
            let r = pool.results().recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(r.round, 1);
            match r.payload {
                WirePayload::Plain(m) => {
                    assert_eq!(m.get(0, 0), r.worker as f32);
                }
                _ => panic!("expected plain"),
            }
            seen[r.worker] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sealed_roundtrip_through_worker() {
        let (pool, master) = pool(2);
        let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
        let mut rng = rng_from_seed(1);
        let x = Matrix::random_gaussian(4, 4, 0.0, 1.0, &mut rng);
        let sealed = mea.encrypt(&x, &pool.worker_pks()[0], &mut rng);
        pool.dispatch(WorkOrder {
            round: 9,
            worker: 0,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Sealed(sealed)],
            delay: Duration::ZERO,
        });
        let r = pool.results().recv_timeout(Duration::from_secs(5)).unwrap();
        match r.payload {
            WirePayload::Sealed(s) => {
                let opened = mea.decrypt(&s, &master);
                assert_eq!(opened, x, "worker must echo the decrypted plaintext, re-sealed");
            }
            _ => panic!("expected sealed result for a sealed order"),
        }
    }

    #[test]
    fn colluders_deposit_plaintext() {
        let curve = sim_curve();
        let mut rng = rng_from_seed(0xBB);
        let master = KeyPair::generate(&curve, &mut rng);
        let exec = Executor::native(Arc::new(MetricsRegistry::new()));
        let coalition = Arc::new(CollusionPool::new(vec![1]));
        let pool =
            WorkerPool::spawn(3, master.public(), exec, Some(Arc::clone(&coalition)), 7);
        for w in 0..3 {
            pool.dispatch(WorkOrder {
                round: 1,
                worker: w,
                op: WorkerOp::Identity,
                payloads: vec![WirePayload::Plain(Matrix::ones(2, 2))],
                delay: Duration::ZERO,
            });
        }
        for _ in 0..3 {
            pool.results().recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let gathered = coalition.gathered();
        assert_eq!(gathered.len(), 1, "only worker 1 colludes");
        assert_eq!(gathered[0].0, 1);
    }

    #[test]
    fn straggler_delay_orders_arrival() {
        let (pool, _master) = pool(2);
        // Worker 0 delayed, worker 1 immediate → 1 arrives first.
        pool.dispatch(WorkOrder {
            round: 1,
            worker: 0,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Plain(Matrix::ones(1, 1))],
            delay: Duration::from_millis(150),
        });
        pool.dispatch(WorkOrder {
            round: 1,
            worker: 1,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Plain(Matrix::ones(1, 1))],
            delay: Duration::ZERO,
        });
        let first = pool.results().recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(first.worker, 1, "non-straggler must arrive first");
    }
}
