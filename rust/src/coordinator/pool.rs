//! The worker fabric: N long-lived threads, one per worker, each with its
//! own ECC key pair, speaking *only serialized frames* over a pluggable
//! [`Transport`](crate::transport::Transport). Workers receive framed
//! [`WorkOrder`]s, decode them ([`crate::wire`]), simulate their service
//! delay, unseal, compute through the [`Executor`], re-seal, and write
//! the framed result back — the paper's "task computing" phase (§III-A
//! step 2).
//!
//! **Lifecycle.** Every worker incarnation — initial spawn and every
//! respawn — generates its own key pair in-thread (seeded by
//! `(seed, worker, generation)`, so the whole lifecycle is
//! deterministic) and *registers* by sending a
//! [`ControlMsg::Register`] frame before serving. At bring-up the pool
//! drains those N registrations synchronously; after a
//! [`respawn`](WorkerPool::respawn) the master's collector installs the
//! frame into the shared [`WorkerDirectory`] — the rejoin handshake of
//! the state machine in `coordinator/lifecycle.rs`. Crashes come in two
//! deterministic flavors: a [`FaultPlan`] the worker consults itself
//! (crash mid-round: the order arrives, the reply never does), and a
//! [`ControlMsg::Crash`] frame ([`WorkerPool::crash`]) that kills the
//! worker at a frame boundary. The plan can also corrupt a result frame
//! on the way out, which the master's collector counts and drops.
//!
//! Each worker drains its link in FIFO order, so when the master
//! pipelines several rounds (`Master::submit` before `Master::wait`) the
//! orders of round r+1 are already queued while round r computes.
//! Results carry their round id; the master's collector thread routes
//! them back to the right in-flight round.
//!
//! A worker whose link is down surfaces as a typed
//! [`TransportError::WorkerDown`] from [`WorkerPool::dispatch`] — the
//! master degrades it into a permanent straggler instead of panicking.
//! A complete frame that fails wire validation is counted
//! (`comm.wire_errors`) and dropped, and the worker keeps serving;
//! header-level stream corruption (frame sync lost) is also counted,
//! but kills the link — the master sees the worker as dead at its next
//! dispatch.

use super::lifecycle::WorkerDirectory;
use super::messages::{ControlMsg, ResultMsg, SealedPayload, WirePayload, WorkOrder};
use super::supervisor::ExitLog;
use crate::config::TransportKind;
use crate::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc, Point};
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::metrics::{names, MetricsRegistry};
use crate::rng::{derive_seed, rng_from_seed};
use crate::runtime::Executor;
use crate::sim::{CollusionPool, FaultCoords, FaultPlan};
use crate::transport::{self, LoadBook, Transport, TransportError, WorkerLink};
use crate::wire::{self, WireMessage};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// A pool of worker threads plus the master-side transport sender and
/// the shared lifecycle directory.
pub struct WorkerPool {
    transport: Option<Box<dyn Transport>>,
    directory: Arc<WorkerDirectory>,
    load: Arc<LoadBook>,
    joins: Vec<JoinHandle<()>>,
    // Respawn ingredients: a new incarnation is built from the same
    // parts as the original.
    master_pk: Point<Fp61>,
    executor: Executor,
    collusion: Option<Arc<CollusionPool>>,
    faults: Option<Arc<FaultPlan>>,
    seed: u64,
}

impl WorkerPool {
    /// Wire a fabric of `kind` and spawn `n` workers on it. Each worker
    /// generates its own key pair in-thread (§IV-B step 1) and registers
    /// it over the wire; the pool drains all `n` registrations before
    /// returning, so the directory is fully populated. Returns the pool
    /// plus the merged inbound channel of result *frames* (consumed by
    /// the master's collector thread).
    ///
    /// * `master_pk` — the master's public key (workers encrypt results
    ///   to it).
    /// * `executor` — shared execution façade (PJRT or native).
    /// * `collusion` — optional coalition tap; colluding workers deposit
    ///   their decrypted shares there.
    /// * `faults` — optional deterministic crash/corruption schedule
    ///   (the scenario engine's plan).
    /// * `metrics` — sink for the transport byte counters.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        kind: TransportKind,
        n: usize,
        master_pk: Point<Fp61>,
        executor: Executor,
        collusion: Option<Arc<CollusionPool>>,
        faults: Option<Arc<FaultPlan>>,
        seed: u64,
        metrics: Arc<MetricsRegistry>,
    ) -> Result<(Self, Receiver<Vec<u8>>), TransportError> {
        // The process fabric spawns real children, which need the worker
        // harness parameters on their command lines — so it is wired
        // here, where those parameters live, not in `transport::connect`.
        let fabric = if kind == TransportKind::Proc {
            transport::Proc::connect(
                n,
                transport::ProcConfig { seed, master_pk, faults: faults.clone() },
                metrics,
            )?
        } else {
            transport::connect(kind, n, metrics)?
        };
        let directory = Arc::new(WorkerDirectory::new(n));
        let mut pool = Self {
            transport: Some(fabric.transport),
            directory,
            load: Arc::clone(&fabric.load),
            joins: Vec::with_capacity(n),
            master_pk,
            executor,
            collusion,
            faults,
            seed,
        };
        for (w, link) in fabric.links.into_iter().enumerate() {
            let join = pool.spawn_incarnation(w, 0, link);
            pool.joins.push(join);
        }
        // Bring-up registration wave: no orders are out yet, so the next
        // n inbound frames are exactly the workers' Register frames.
        for _ in 0..n {
            let frame = fabric
                .inbound
                .recv_timeout(Duration::from_secs(10))
                .map_err(|_| TransportError::Setup("worker registration timed out".into()))?;
            match wire::decode_message(&frame) {
                Ok(WireMessage::Control(ControlMsg::Register { worker, generation, pk })) => {
                    pool.directory.register(worker, generation, pk);
                }
                Ok(other) => {
                    return Err(TransportError::Setup(format!(
                        "expected a Register frame during pool bring-up, got a {} frame",
                        other.kind_name()
                    )))
                }
                Err(e) => {
                    return Err(TransportError::Setup(format!(
                        "undecodable frame during pool bring-up: {e}"
                    )))
                }
            }
        }
        Ok((pool, fabric.inbound))
    }

    /// Spawn one incarnation of worker `w` on `link`.
    fn spawn_incarnation(&self, w: usize, generation: u32, link: WorkerLink) -> JoinHandle<()> {
        let harness = WorkerHarness {
            worker: w,
            generation,
            seed: self.seed,
            master_pk: self.master_pk,
            executor: self.executor.clone(),
            collusion: self.collusion.clone(),
            faults: self.faults.clone(),
            park_on_crash: false,
        };
        std::thread::Builder::new()
            .name(format!("worker-{w}.g{generation}"))
            .spawn(move || harness.run(link))
            .expect("spawn worker")
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.directory.n()
    }

    /// The shared lifecycle directory (states, generations, current
    /// public keys).
    pub fn directory(&self) -> &Arc<WorkerDirectory> {
        &self.directory
    }

    /// Current incarnations' public keys, indexed by worker id.
    pub fn worker_pks(&self) -> Vec<Point<Fp61>> {
        self.directory.pks()
    }

    /// Which fabric the pool runs on.
    pub fn transport_kind(&self) -> TransportKind {
        self.transport.as_ref().expect("pool not shut down").kind()
    }

    /// The process fabric's child exit log (`None` on in-process
    /// fabrics). The handle stays readable after the pool shuts down,
    /// so teardown exits are observable too — the testbed reports them.
    pub fn exit_records(&self) -> Option<ExitLog> {
        self.transport.as_ref().and_then(|t| t.exit_records())
    }

    /// The fabric's per-worker backlog signal (orders sent minus rounds
    /// settled) — the idle-worker signal for speculative re-dispatch.
    pub fn load(&self) -> &Arc<LoadBook> {
        &self.load
    }

    /// Serialize an order and send it to its owning worker
    /// (`order.worker`). A down link surfaces as
    /// [`TransportError::WorkerDown`]; the caller treats that worker as
    /// a permanent straggler.
    pub fn dispatch(&self, order: &WorkOrder) -> Result<(), TransportError> {
        self.dispatch_to(order.worker, order)
    }

    /// Serialize an order and send it to `target`, which may differ
    /// from `order.worker`: a speculative re-dispatch ships share
    /// `order.worker`'s work to another live worker, and the result
    /// comes home tagged with the *share* id so the decoder never needs
    /// to know who computed it.
    pub fn dispatch_to(&self, target: usize, order: &WorkOrder) -> Result<(), TransportError> {
        let frame = wire::encode_order(order);
        self.transport.as_ref().expect("pool not shut down").send(target, frame)?;
        self.load.note_sent(target);
        Ok(())
    }

    /// Inject a crash over the wire: worker `w` dies silently at its
    /// next frame boundary (orders already queued behind the kill are
    /// lost with it). The caller is responsible for the master-side
    /// bookkeeping (`Master::crash_worker` does both).
    pub fn crash(&self, w: usize) -> Result<(), TransportError> {
        let frame = wire::encode_control(&ControlMsg::Crash { worker: w });
        self.transport.as_ref().expect("pool not shut down").send(w, frame)
    }

    /// Respawn worker `w`: tear down whatever is left of the old link,
    /// wire a fresh one, and start a new incarnation on it (generation
    /// bumped). Returns the new generation; the incarnation is serving
    /// once its `Register` frame lands in the directory (the master
    /// waits for that — [`Master::respawn_worker`](super::Master::respawn_worker)).
    pub fn respawn(&mut self, w: usize) -> Result<u32, TransportError> {
        if self.transport.as_ref().expect("pool not shut down").out_of_process() {
            // A replacement child carries its generation on the command
            // line, so the bump must precede the relink; the fabric
            // kills/reaps the old child and runs the new one itself —
            // no thread to spawn here.
            let generation = self.directory.begin_respawn(w);
            self.transport.as_ref().expect("pool not shut down").respawn_process(w, generation)?;
            Ok(generation)
        } else {
            let link = self.transport.as_ref().expect("pool not shut down").relink(w)?;
            let generation = self.directory.begin_respawn(w);
            let join = self.spawn_incarnation(w, generation, link);
            self.joins.push(join);
            Ok(generation)
        }
    }

    /// Tear the fabric down and join the workers. Called by `Drop`;
    /// idempotent.
    pub(crate) fn shutdown(&mut self) {
        self.transport.take(); // closes every link
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Everything one worker incarnation needs before it can serve: the
/// body of every in-process worker thread, and of the standalone
/// `spacdc worker` process (which dials the master, wraps the socket in
/// a [`WorkerLink::Tcp`], and hands it to [`run`](WorkerHarness::run)).
pub struct WorkerHarness {
    /// Worker index.
    pub worker: usize,
    /// Incarnation number (0 initial, +1 per respawn).
    pub generation: u32,
    /// Root seed; keys and seal randomness derive from
    /// `(seed, worker, generation)`.
    pub seed: u64,
    /// Master's public key (results are sealed to it).
    pub master_pk: Point<Fp61>,
    /// Execution façade (PJRT or native).
    pub executor: Executor,
    /// Optional coalition tap (in-process workers only — a process
    /// worker cannot share the master's memory).
    pub collusion: Option<Arc<CollusionPool>>,
    /// Optional deterministic crash/corruption schedule.
    pub faults: Option<Arc<FaultPlan>>,
    /// On a scheduled or injected crash, park (hang without serving)
    /// instead of returning. Worker *threads* return — a dead thread is
    /// what a dead node looks like in-process. Worker *processes* park:
    /// the process must stay alive so the supervisor's real SIGKILL is
    /// what actually ends it, with the signal captured in its exit
    /// status. Either way no reply is ever sent, so round outcomes are
    /// identical.
    pub park_on_crash: bool,
}

impl WorkerHarness {
    /// Run the incarnation over an established link until the master
    /// hangs up, the link poisons, or a crash event fires.
    pub fn run(self, link: WorkerLink) {
        let WorkerHarness {
            worker: w,
            generation,
            seed,
            master_pk,
            executor,
            collusion,
            faults,
            park_on_crash,
        } = self;
        worker_loop(
            w, generation, seed, master_pk, link, executor, collusion, faults, park_on_crash,
        )
    }
}

/// A crashed process worker stops serving but must not exit — the
/// supervisor's SIGKILL is the real cause of death (see
/// [`WorkerHarness::park_on_crash`]).
fn park_forever() -> ! {
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    w: usize,
    generation: u32,
    seed: u64,
    master_pk: Point<Fp61>,
    mut link: WorkerLink,
    executor: Executor,
    collusion: Option<Arc<CollusionPool>>,
    faults: Option<Arc<FaultPlan>>,
    park_on_crash: bool,
) {
    // One worker thread models one remote node: its kernels run serial
    // so N workers use N cores, not N × pool-width.
    crate::parallel::mark_serial_thread();
    let curve = sim_curve();
    // Every incarnation keys itself deterministically from
    // (seed, worker, generation): a respawn is a *new* identity, but a
    // reproducible one.
    let gen_stream = |base: u64| base ^ ((generation as u64) << 32) ^ w as u64;
    let keys = {
        let mut rng = rng_from_seed(derive_seed(seed, gen_stream(0xBEEF_0000)));
        KeyPair::generate(&curve, &mut rng)
    };
    let mea = MeaEcc::new(curve, MaskMode::Keystream);
    let mut rng = rng_from_seed(derive_seed(seed, gen_stream(0x00D0_0000)));
    // Result frames are serialized into this scratch buffer; after the
    // first round it is already at frame size and sending allocates
    // nothing (the TCP path writes from it directly, the in-proc path
    // copies it into the channel).
    let mut frame_buf: Vec<u8> = Vec::new();
    // Register this incarnation (§IV-B step 1; re-run on every rejoin):
    // the master seals subsequent shares to this key.
    wire::encode_control_into(
        &ControlMsg::Register { worker: w, generation, pk: keys.public() },
        &mut frame_buf,
    );
    if link.send(&frame_buf).is_err() {
        return; // master gone before we even joined
    }
    loop {
        // A clean close (master gone / fabric torn down) ends the loop
        // silently; a poisoned stream (header-level corruption, socket
        // error) is counted before the link dies, since frame sync is
        // unrecoverable at that point.
        let frame = match link.recv() {
            Ok(f) => f,
            Err(wire::WireError::Closed) => break,
            Err(e) => {
                executor.metrics().inc(names::WIRE_ERRORS);
                eprintln!("worker {w}: link failed ({e}); shutting down");
                break;
            }
        };
        let order = match wire::decode_message(&frame) {
            Ok(WireMessage::Order(o)) => o,
            Ok(WireMessage::Control(ControlMsg::Crash { .. })) => {
                // Injected kill: vanish mid-protocol, no reply, no
                // cleanup — exactly what a dead node looks like.
                if park_on_crash {
                    park_forever();
                }
                return;
            }
            Ok(other) => {
                executor.metrics().inc(names::WIRE_ERRORS);
                eprintln!("worker {w}: dropping unexpected {} frame", other.kind_name());
                continue;
            }
            Err(e) => {
                executor.metrics().inc(names::WIRE_ERRORS);
                eprintln!("worker {w}: dropping undecodable frame: {e}");
                continue;
            }
        };

        // The fault coordinates ride the order (wire v4): the worker
        // evaluates the plan on exactly the numbers the master
        // pre-booked with — no local counters a respawn would reset,
        // no divergence between fabrics. Zeroed fields are the
        // hand-made-order fallback (tests, external drivers): the
        // coordinate collapses to the global round, which is also what
        // the legacy `global` key reads.
        let coords = FaultCoords {
            round: order.round,
            served: if order.served == 0 { order.round } else { order.served },
            lane: order.lane,
            lane_round: if order.lane_round == 0 { order.round } else { order.lane_round },
        };

        // Scheduled crash: the order arrived, the reply never will. The
        // master runs the same plan and books the round as degraded.
        // Crashing *here* — after draining every earlier order FIFO —
        // is what keeps the set of results this incarnation did send
        // independent of crash-signal timing.
        if let Some(plan) = &faults {
            if plan.crashes_at(w, &coords) {
                if park_on_crash {
                    park_forever();
                }
                return;
            }
        }

        // Straggler simulation — the paper's sleep() injection.
        if !order.delay.is_zero() {
            std::thread::sleep(order.delay);
        }
        // `share` is the order's own worker field: normally this
        // worker's index, but a speculative re-dispatch carries another
        // worker's share here — the reply must be tagged with the share
        // id, not the executor, so the master routes it to the right
        // interpolation point.
        let WorkOrder { round, worker: share, op, payloads, commitment, .. } = order;

        // Decrypt operands (§IV-B step 4), consuming the decoded order:
        // plain operands move straight through and sealed ones are
        // unmasked in place — the worker never clones a matrix it
        // already owns.
        let sealed_round = matches!(payloads.first(), Some(WirePayload::Sealed(_)));
        let mut operands: Vec<Matrix> = Vec::with_capacity(payloads.len());
        let mut poisoned = false;
        for p in payloads {
            match p {
                WirePayload::Plain(m) => operands.push(m),
                WirePayload::Sealed(s) => match s.open_owned(&mea, &keys) {
                    Ok(m) => operands.push(m),
                    Err(e) => {
                        executor.metrics().inc(names::WIRE_ERRORS);
                        eprintln!("worker {w}: sealed payload failed to open: {e}");
                        poisoned = true;
                        break;
                    }
                },
            }
        }
        if poisoned {
            continue;
        }

        // Colluding workers leak their plaintext shares to the pool.
        if let Some(pool) = &collusion {
            for m in &operands {
                pool.deposit(w, m);
            }
        }

        // Compute f (PJRT artifact or native kernel).
        let mut out = executor.run(&op, &operands);

        // Scheduled forgery (Byzantine worker): replace the result with
        // a well-formed wrong one and tamper the commitment echo. The
        // frame stays structurally perfect — CRC, shapes, seal all
        // check out — so only the master's verification layer can tell
        // (DESIGN.md §11). The tamper is keyed on the *executor*, so a
        // speculative re-dispatch of this share to an honest worker
        // produces a clean echo and the round recovers.
        let forged = faults.as_ref().is_some_and(|plan| plan.forges_at(w, &coords));
        if forged {
            out = out.scale(-1.375);
        }
        let echo = if forged {
            commitment ^ (0x0BAD_C0DE_0000_0000 | (w as u64 + 1))
        } else {
            commitment
        };

        // Encrypt the result back to the master when the share arrived
        // sealed (symmetric policy — §V-B step 2).
        let payload = if sealed_round {
            WirePayload::Sealed(SealedPayload::seal(&mea, &out, &master_pk, &mut rng))
        } else {
            WirePayload::Plain(out)
        };

        let msg = ResultMsg { round, worker: share, executor: w, payload, commitment: echo };
        wire::encode_result_into(&msg, &mut frame_buf);
        // Scheduled wire corruption: flip one body byte so the frame
        // fails its CRC at the master — the result is lost in transit,
        // deterministically.
        if faults.as_ref().is_some_and(|plan| plan.corrupts(w, &coords)) {
            frame_buf[wire::HEADER_LEN] ^= 0xA5;
        }
        if link.send(&frame_buf).is_err() {
            break; // master gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lifecycle::WorkerState;
    use crate::runtime::WorkerOp;
    use crate::sim::CrashEvent;
    use crate::wire::MsgKind;
    use std::time::Instant;

    fn pool_with(
        kind: TransportKind,
        n: usize,
        collusion: Option<Arc<CollusionPool>>,
        faults: Option<Arc<FaultPlan>>,
    ) -> (WorkerPool, Receiver<Vec<u8>>, KeyPair<Fp61>, Arc<MetricsRegistry>) {
        let curve = sim_curve();
        let mut rng = rng_from_seed(0xAA);
        let master = KeyPair::generate(&curve, &mut rng);
        let metrics = Arc::new(MetricsRegistry::new());
        let exec = Executor::native(Arc::clone(&metrics));
        let (p, rx) = WorkerPool::spawn(
            kind,
            n,
            master.public(),
            exec,
            collusion,
            faults,
            7,
            Arc::clone(&metrics),
        )
        .unwrap();
        (p, rx, master, metrics)
    }

    fn pool(n: usize) -> (WorkerPool, Receiver<Vec<u8>>, KeyPair<Fp61>) {
        let (p, rx, master, _) = pool_with(TransportKind::InProc, n, None, None);
        (p, rx, master)
    }

    fn recv_result(rx: &Receiver<Vec<u8>>) -> ResultMsg {
        let frame = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        wire::decode_result(&frame).unwrap()
    }

    fn identity_order(round: u64, worker: usize, m: Matrix) -> WorkOrder {
        let commitment = super::messages::share_commitment([&m]);
        WorkOrder {
            round,
            worker,
            lane: 0,
            lane_round: round,
            served: round,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Plain(m)],
            delay: Duration::ZERO,
            commitment,
        }
    }

    #[test]
    fn workers_echo_identity_orders() {
        let (pool, rx, _master) = pool(4);
        for w in 0..4 {
            pool.dispatch(&identity_order(1, w, Matrix::ones(2, 2).scale(w as f32))).unwrap();
        }
        let mut seen = vec![false; 4];
        for _ in 0..4 {
            let r = recv_result(&rx);
            assert_eq!(r.round, 1);
            match r.payload {
                WirePayload::Plain(m) => {
                    assert_eq!(m.get(0, 0), r.worker as f32);
                }
                _ => panic!("expected plain"),
            }
            seen[r.worker] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sealed_roundtrip_through_worker() {
        let (pool, rx, master) = pool(2);
        let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
        let mut rng = rng_from_seed(1);
        let x = Matrix::random_gaussian(4, 4, 0.0, 1.0, &mut rng);
        let sealed = SealedPayload::seal(&mea, &x, &pool.worker_pks()[0], &mut rng);
        pool.dispatch(&WorkOrder {
            round: 9,
            worker: 0,
            lane: 0,
            lane_round: 9,
            served: 9,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Sealed(sealed)],
            delay: Duration::ZERO,
            commitment: super::messages::share_commitment([&x]),
        })
        .unwrap();
        let r = recv_result(&rx);
        match r.payload {
            WirePayload::Sealed(s) => {
                let opened = s.open(&mea, &master).unwrap();
                assert_eq!(opened, x, "worker must echo the decrypted plaintext, re-sealed");
            }
            _ => panic!("expected sealed result for a sealed order"),
        }
    }

    #[test]
    fn colluders_deposit_plaintext() {
        let coalition = Arc::new(CollusionPool::new(vec![1]));
        let (pool, rx, _master, _) =
            pool_with(TransportKind::InProc, 3, Some(Arc::clone(&coalition)), None);
        for w in 0..3 {
            pool.dispatch(&identity_order(1, w, Matrix::ones(2, 2))).unwrap();
        }
        for _ in 0..3 {
            recv_result(&rx);
        }
        let gathered = coalition.gathered();
        assert_eq!(gathered.len(), 1, "only worker 1 colludes");
        assert_eq!(gathered[0].0, 1);
    }

    #[test]
    fn straggler_delay_orders_arrival() {
        let (pool, rx, _master) = pool(2);
        // Worker 0 delayed, worker 1 immediate → 1 arrives first.
        pool.dispatch(&WorkOrder {
            round: 1,
            worker: 0,
            lane: 0,
            lane_round: 1,
            served: 1,
            op: WorkerOp::Identity,
            payloads: vec![WirePayload::Plain(Matrix::ones(1, 1))],
            delay: Duration::from_millis(150),
            commitment: 0,
        })
        .unwrap();
        pool.dispatch(&identity_order(1, 1, Matrix::ones(1, 1))).unwrap();
        let first = recv_result(&rx);
        assert_eq!(first.worker, 1, "non-straggler must arrive first");
    }

    #[test]
    fn undecodable_frame_is_dropped_not_fatal() {
        let (pool, rx, _master) = pool(1);
        // A structurally valid frame with a garbage body: the worker must
        // count it, drop it, and keep serving.
        let junk = wire::frame(MsgKind::Order, b"not an order body");
        pool.transport.as_ref().unwrap().send(0, junk).unwrap();
        pool.dispatch(&identity_order(2, 0, Matrix::ones(1, 1))).unwrap();
        let r = recv_result(&rx);
        assert_eq!(r.round, 2, "worker must survive the junk frame");
    }

    #[test]
    fn tcp_pool_round_trips_orders() {
        let (pool, rx, _master, metrics) = pool_with(TransportKind::Tcp, 2, None, None);
        for w in 0..2 {
            pool.dispatch(&identity_order(5, w, Matrix::ones(3, 3))).unwrap();
        }
        for _ in 0..2 {
            let r = recv_result(&rx);
            assert_eq!(r.round, 5);
        }
        assert!(metrics.get(names::BYTES_TX) > 0, "socket bytes must be counted");
    }

    fn crash_respawn_check(kind: TransportKind) {
        let (mut pool, rx, _master, _) = pool_with(kind, 2, None, None);
        let pk_gen0 = pool.worker_pks()[0];
        // Kill worker 0 over the wire, then bring up a new incarnation.
        pool.crash(0).unwrap();
        let gen = pool.respawn(0).unwrap();
        assert_eq!(gen, 1);
        // The rejoin handshake: the new incarnation's Register frame
        // flows through the normal inbound channel (in the live system
        // the collector consumes it; here the test plays collector).
        let frame = rx.recv_timeout(Duration::from_secs(5)).expect("register frame");
        match wire::decode_message(&frame).unwrap() {
            WireMessage::Control(ControlMsg::Register { worker, generation, pk }) => {
                assert_eq!((worker, generation), (0, 1));
                pool.directory().register(worker, generation, pk);
            }
            other => panic!("expected the respawn registration, got {other:?}"),
        }
        assert!(pool.directory().wait_registered(0, gen, Instant::now()));
        assert_eq!(pool.directory().state(0), WorkerState::Alive);
        assert_ne!(pool.worker_pks()[0], pk_gen0, "rejoin must re-key");
        // The respawned incarnation serves orders on the fresh link.
        pool.dispatch(&identity_order(3, 0, Matrix::ones(2, 2))).unwrap();
        let r = recv_result(&rx);
        assert_eq!((r.round, r.worker), (3, 0));
    }

    #[test]
    fn inproc_worker_crashes_and_respawns() {
        crash_respawn_check(TransportKind::InProc);
    }

    #[test]
    fn tcp_worker_crashes_and_respawns() {
        crash_respawn_check(TransportKind::Tcp);
    }

    #[test]
    fn planned_crash_swallows_the_round() {
        let plan = Arc::new(FaultPlan::new(
            vec![CrashEvent { worker: 0, round: 2, respawn_after: None }],
            0.0,
            7,
        ));
        let (pool, rx, _master, _) = pool_with(TransportKind::InProc, 2, None, Some(plan));
        // Round 1: both reply. Round 2: worker 0 crashes mid-round.
        for round in 1..=2u64 {
            for w in 0..2 {
                pool.dispatch(&identity_order(round, w, Matrix::ones(1, 1))).unwrap();
            }
        }
        let mut got: Vec<(u64, usize)> = (0..3)
            .map(|_| {
                let r = recv_result(&rx);
                (r.round, r.worker)
            })
            .collect();
        got.sort_unstable();
        assert_eq!(got, vec![(1, 0), (1, 1), (2, 1)], "worker 0's round-2 reply must vanish");
        assert!(
            rx.recv_timeout(Duration::from_millis(100)).is_err(),
            "nothing further may arrive"
        );
    }

    #[test]
    fn planned_corruption_poisons_the_result_frame() {
        let plan = Arc::new(FaultPlan::new(Vec::new(), 0.999, 7));
        let (pool, rx, _master, _) = pool_with(TransportKind::InProc, 1, None, Some(plan));
        pool.dispatch(&identity_order(1, 0, Matrix::ones(2, 2))).unwrap();
        let frame = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(
            wire::decode_result(&frame).is_err(),
            "corrupted frame must fail wire validation at the master"
        );
    }

    #[test]
    fn honest_workers_echo_the_order_commitment() {
        let (pool, rx, _master) = pool(1);
        let order = identity_order(4, 0, Matrix::ones(3, 2).scale(2.5));
        let want = order.commitment;
        pool.dispatch(&order).unwrap();
        let r = recv_result(&rx);
        assert_eq!(r.commitment, want, "an honest result must echo the order's commitment");
    }

    #[test]
    fn planned_forgery_perturbs_the_result_and_tampers_the_echo() {
        let plan = Arc::new(FaultPlan::new(Vec::new(), 0.0, 7).with_forgers(vec![0], 0.999));
        let (pool, rx, _master, _) = pool_with(TransportKind::InProc, 1, None, Some(plan));
        let m = Matrix::ones(2, 2).scale(3.0);
        let order = identity_order(1, 0, m.clone());
        let want = order.commitment;
        pool.dispatch(&order).unwrap();
        // The frame is structurally perfect — it decodes cleanly —
        // but the payload is wrong and the echo does not match.
        let r = recv_result(&rx);
        assert_ne!(r.commitment, want, "a forged result must carry a tampered echo");
        match r.payload {
            WirePayload::Plain(out) => {
                assert_eq!(out.shape(), m.shape(), "forgery must stay well-formed");
                assert_ne!(out, m, "forged identity must not echo the operand");
            }
            _ => panic!("expected plain"),
        }
    }
}
