//! The L3 coordinator: master/worker runtime implementing the paper's
//! three-phase protocol (Fig. 1):
//!
//! 1. **Data process** — master encodes with the configured scheme,
//!    seals every share with MEA-ECC (§IV), dispatches to workers.
//! 2. **Task computing** — worker threads decrypt, execute `f` through
//!    the [`Executor`](crate::runtime::Executor) (PJRT artifact or native
//!    kernel), encrypt the result, return it.
//! 3. **Result recovering** — master collects until the scheme's wait
//!    policy is satisfied, decrypts, decodes `{Yᵢ}`.
//!
//! Stragglers are injected per [`sim::DelayModel`](crate::sim::DelayModel);
//! colluders and eavesdroppers observe through the [`sim`](crate::sim)
//! taps. Every symbol crossing a link is counted in the metrics registry
//! (the Fig. 6 accounting).

mod master;
mod messages;
mod pool;

pub use master::{Master, MasterBuilder, RoundOutcome};
pub use messages::{ResultMsg, WirePayload, WorkOrder};
pub use pool::WorkerPool;
