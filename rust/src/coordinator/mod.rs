//! The L3 coordinator: master/worker runtime implementing the paper's
//! three-phase protocol (Fig. 1):
//!
//! 1. **Data process** — master encodes a typed
//!    [`CodedTask`](crate::coding::CodedTask) with the configured scheme,
//!    seals every payload with MEA-ECC (§IV), dispatches to workers.
//! 2. **Task computing** — worker threads decrypt, execute `f` through
//!    the [`Executor`](crate::runtime::Executor) (PJRT artifact or native
//!    kernel), encrypt the result, return it.
//! 3. **Result recovering** — master collects until the scheme's wait
//!    policy is satisfied, decrypts, decodes.
//!
//! One pipeline serves all eight schemes: [`Master::run`] executes a
//! round synchronously, and [`Master::submit`] / [`Master::wait`] keep
//! several rounds in flight at once (results are routed to their round
//! by id, so rounds may complete out of order).
//!
//! Stragglers are injected per [`sim::DelayModel`](crate::sim::DelayModel);
//! colluders and eavesdroppers observe through the [`sim`](crate::sim)
//! taps. Every symbol crossing a link is counted in the metrics registry
//! (the Fig. 6 accounting).

mod master;
mod messages;
mod pool;

pub use master::{Master, MasterBuilder, RoundHandle, RoundOutcome};
pub use messages::{ResultMsg, WirePayload, WorkOrder};
pub use pool::WorkerPool;
