//! The L3 coordinator: master/worker runtime implementing the paper's
//! three-phase protocol (Fig. 1):
//!
//! 1. **Data process** — master encodes a typed
//!    [`CodedTask`](crate::coding::CodedTask) with the configured scheme,
//!    seals every payload's serialized bytes with MEA-ECC (§IV), and
//!    dispatches framed work orders over the configured transport.
//! 2. **Task computing** — worker threads decode the frame
//!    ([`crate::wire`]), unseal, execute `f` through the
//!    [`Executor`](crate::runtime::Executor) (PJRT artifact or native
//!    kernel), re-seal the result, and write the framed result back.
//! 3. **Result recovering** — a dedicated collector thread on the master
//!    deserializes and unseals arriving results and routes them to their
//!    in-flight round (`registry`); `Master::wait` decodes once the
//!    scheme's wait policy is satisfied, under a per-round deadline.
//!
//! One pipeline serves all eight schemes: [`Master::submit`] /
//! [`Master::wait`] keep several rounds in flight at once (results are
//! routed to their round by id, so rounds may complete out of order;
//! dropping a [`RoundHandle`] abandons its round), and the
//! multi-tenant serving front end ([`Master::service`] → [`Service`],
//! DESIGN.md §12) multiplexes many independent session lanes —
//! iterator-, channel-, or manually-fed — over that pipeline with
//! admission control, deficit-round-robin fairness, and per-tenant
//! deadlines/metrics. [`Master::run`] (one synchronous round) and
//! [`Master::run_stream`] (one windowed stream with optional
//! speculative re-dispatch, DESIGN.md §8) remain as thin single-tenant
//! convenience wrappers over the session API.
//!
//! Stragglers are injected per [`sim::DelayModel`](crate::sim::DelayModel);
//! colluders and eavesdroppers observe through the [`sim`](crate::sim)
//! taps. Every frame crossing a link is counted twice over: symbols for
//! the analytic Fig. 6 accounting, serialized bytes for the measured one.
//!
//! **Worker lifecycle** (DESIGN.md §7): every worker slot walks
//! alive → crashed → respawning → rejoined. Crashes are injected
//! deterministically (a [`FaultPlan`](crate::sim::FaultPlan) the worker
//! consults, or a [`ControlMsg::Crash`] frame); a respawned incarnation
//! re-keys itself and re-registers over the wire
//! ([`ControlMsg::Register`], installed into the shared
//! [`WorkerDirectory`] by the collector). Rounds that lose workers
//! mid-flight degrade to "decode from what arrived" when the scheme's
//! threshold allows it, or fail fast with a typed [`RoundError`]. Under
//! the process fabric (`--transport proc`, DESIGN.md §9) each worker is
//! a real `spacdc worker` child process, a [`Supervisor`] captures
//! every exit status, and respawn is a real SIGKILL + re-exec.

mod lifecycle;
mod master;
mod messages;
mod pool;
mod registry;
mod session;
mod stream;
mod supervisor;

pub use lifecycle::{WorkerDirectory, WorkerState};
pub use master::{Master, MasterBuilder, RoundError, RoundHandle, RoundOutcome};
pub use messages::{share_commitment, ControlMsg, ResultMsg, SealedPayload, WirePayload, WorkOrder};
pub use pool::{WorkerHarness, WorkerPool};
pub use session::{
    Service, ServiceConfig, ServiceOutcome, SessionId, SessionOptions, SessionRound, SessionStats,
};
pub use stream::{StreamConfig, StreamOutcome, StreamRound};
pub use supervisor::{ExitCause, ExitLog, ExitRecord, Supervisor};
