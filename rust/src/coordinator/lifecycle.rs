//! The worker lifecycle directory: the master's live view of every
//! worker slot.
//!
//! Each worker index moves through the state machine
//!
//! ```text
//!            crash (injected, scheduled, or link death)
//!   Alive ────────────────────────────────────────────▶ Crashed
//!     ▲                                                    │
//!     │ Register received              WorkerPool::respawn │
//!     │ (generation bump)                                  ▼
//!   (rejoined) ◀───────────────────────────────────── Respawning
//! ```
//!
//! A respawned worker is a *new incarnation*: it generates a fresh key
//! pair (seeded by `(seed, worker, generation)`, so the whole lifecycle
//! is deterministic) and re-registers by sending a
//! [`ControlMsg::Register`](super::ControlMsg) frame over its new link.
//! The master's collector thread installs the registration here; the
//! submit path seals every share to the *current* incarnation's key.
//!
//! The directory is the rendezvous between three parties: the pool
//! (spawns/respawns incarnations), the collector (installs
//! registrations), and the master (reads keys and aliveness at submit
//! time, waits for a respawn's registration to land).

use crate::ecc::Point;
use crate::field::Fp61;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Lifecycle state of one worker slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// Registered and serving (the initial state once bring-up
    /// registration completes; re-entered on rejoin).
    Alive,
    /// Known dead: crashed by fault injection or a failed link. No
    /// orders are dispatched to it, no results expected from it.
    Crashed,
    /// A new incarnation was spawned but its `Register` frame has not
    /// landed yet.
    Respawning,
}

#[derive(Clone, Debug)]
struct Entry {
    pk: Point<Fp61>,
    generation: u32,
    state: WorkerState,
    /// Quarantined after a verified forgery (DESIGN.md §11). A suspect
    /// stays `Alive` — its own shares are still dispatched, so the
    /// round schedule never depends on detection timing — but it is
    /// excluded from speculative picks until a verified-good result
    /// rehabilitates it. A fresh incarnation starts unsuspected.
    suspected: bool,
}

/// Shared directory of worker incarnations (see module docs).
#[derive(Debug)]
pub struct WorkerDirectory {
    entries: Mutex<Vec<Entry>>,
    cv: Condvar,
}

impl WorkerDirectory {
    /// A directory of `n` unregistered slots (state `Respawning`,
    /// generation 0): bring-up is just the first registration wave.
    pub fn new(n: usize) -> Self {
        let entries = vec![
            Entry {
                pk: Point::Infinity,
                generation: 0,
                state: WorkerState::Respawning,
                suspected: false,
            };
            n
        ];
        Self { entries: Mutex::new(entries), cv: Condvar::new() }
    }

    /// Number of worker slots.
    pub fn n(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Install a registration: the incarnation's key becomes current and
    /// the slot goes `Alive`. Called by the pool at bring-up and by the
    /// collector thread for respawns.
    ///
    /// First-register-wins, per generation: a registration is installed
    /// only if its generation is *newer* than the slot's, or matches it
    /// while the slot is still waiting (`Respawning`/`Crashed`). Stale
    /// generations (an older incarnation racing a newer respawn) and
    /// duplicate same-generation registrations for an `Alive` worker are
    /// silently ignored — a replayed or duplicated `Register` frame must
    /// not re-key a live incarnation mid-round, or shares sealed to its
    /// installed key would stop opening.
    pub fn register(&self, worker: usize, generation: u32, pk: Point<Fp61>) {
        let mut es = self.entries.lock().unwrap();
        if let Some(e) = es.get_mut(worker) {
            let accept = generation > e.generation
                || (generation == e.generation && e.state != WorkerState::Alive);
            if accept {
                // A new incarnation is a new identity: suspicion dies
                // with the incarnation that earned it.
                *e = Entry { pk, generation, state: WorkerState::Alive, suspected: false };
                self.cv.notify_all();
            }
        }
    }

    /// Mark a worker crashed (fault injection or link death).
    pub fn mark_crashed(&self, worker: usize) {
        let mut es = self.entries.lock().unwrap();
        if let Some(e) = es.get_mut(worker) {
            e.state = WorkerState::Crashed;
        }
    }

    /// Begin a respawn: bump the generation, mark the slot `Respawning`,
    /// and return the new generation the incarnation must register with.
    pub fn begin_respawn(&self, worker: usize) -> u32 {
        let mut es = self.entries.lock().unwrap();
        let e = &mut es[worker];
        e.generation += 1;
        e.state = WorkerState::Respawning;
        e.generation
    }

    /// Block until `worker` has registered generation ≥ `generation`
    /// (true), or until `deadline` (false).
    pub fn wait_registered(&self, worker: usize, generation: u32, deadline: Instant) -> bool {
        let mut es = self.entries.lock().unwrap();
        loop {
            match es.get(worker) {
                Some(e) if e.state == WorkerState::Alive && e.generation >= generation => {
                    return true;
                }
                None => return false,
                _ => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(es, deadline - now).unwrap();
            es = guard;
        }
    }

    /// The worker's current lifecycle state.
    pub fn state(&self, worker: usize) -> WorkerState {
        self.entries.lock().unwrap()[worker].state
    }

    /// Snapshot of every worker's state.
    pub fn states(&self) -> Vec<WorkerState> {
        self.entries.lock().unwrap().iter().map(|e| e.state).collect()
    }

    /// The worker's current incarnation number.
    pub fn generation(&self, worker: usize) -> u32 {
        self.entries.lock().unwrap()[worker].generation
    }

    /// Snapshot of every worker's incarnation number.
    pub fn generations(&self) -> Vec<u32> {
        self.entries.lock().unwrap().iter().map(|e| e.generation).collect()
    }

    /// Per-worker "may I dispatch to it" mask (`Alive` only).
    pub fn alive_mask(&self) -> Vec<bool> {
        self.entries.lock().unwrap().iter().map(|e| e.state == WorkerState::Alive).collect()
    }

    /// Snapshot of the current incarnations' public keys, indexed by
    /// worker (the seal targets for the next round).
    pub fn pks(&self) -> Vec<Point<Fp61>> {
        self.entries.lock().unwrap().iter().map(|e| e.pk).collect()
    }

    /// Quarantine `worker` after a verified forgery: excluded from
    /// speculative picks until rehabilitated. Returns `true` when the
    /// worker was not already suspected (the caller counts new
    /// quarantines, not repeat offenses).
    pub fn mark_suspected(&self, worker: usize) -> bool {
        let mut es = self.entries.lock().unwrap();
        match es.get_mut(worker) {
            Some(e) if !e.suspected => {
                e.suspected = true;
                true
            }
            _ => false,
        }
    }

    /// Readmit `worker` after a verified-good result. Returns `true`
    /// when it was actually suspected (the caller counts real
    /// rehabilitations, not no-ops).
    pub fn rehabilitate(&self, worker: usize) -> bool {
        let mut es = self.entries.lock().unwrap();
        match es.get_mut(worker) {
            Some(e) if e.suspected => {
                e.suspected = false;
                true
            }
            _ => false,
        }
    }

    /// Is `worker` currently quarantined?
    pub fn is_suspected(&self, worker: usize) -> bool {
        self.entries.lock().unwrap()[worker].suspected
    }

    /// Per-worker quarantine mask (parallel to [`alive_mask`](Self::alive_mask)).
    pub fn suspected_mask(&self) -> Vec<bool> {
        self.entries.lock().unwrap().iter().map(|e| e.suspected).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn pk(x: u64) -> Point<Fp61> {
        Point::affine(crate::field::Fp61::new(x), crate::field::Fp61::new(x + 1))
    }

    #[test]
    fn bring_up_registers_every_slot() {
        let d = WorkerDirectory::new(3);
        assert_eq!(d.states(), vec![WorkerState::Respawning; 3]);
        for w in 0..3 {
            d.register(w, 0, pk(w as u64));
        }
        assert_eq!(d.states(), vec![WorkerState::Alive; 3]);
        assert_eq!(d.pks()[2], pk(2));
        assert_eq!(d.alive_mask(), vec![true; 3]);
    }

    #[test]
    fn crash_respawn_rejoin_walks_the_state_machine() {
        let d = WorkerDirectory::new(2);
        d.register(0, 0, pk(1));
        d.register(1, 0, pk(2));
        d.mark_crashed(1);
        assert_eq!(d.state(1), WorkerState::Crashed);
        assert_eq!(d.alive_mask(), vec![true, false]);
        let gen = d.begin_respawn(1);
        assert_eq!(gen, 1);
        assert_eq!(d.state(1), WorkerState::Respawning);
        d.register(1, gen, pk(9));
        assert_eq!(d.state(1), WorkerState::Alive);
        assert_eq!(d.generation(1), 1);
        assert_eq!(d.pks()[1], pk(9), "rejoin must install the new incarnation's key");
    }

    #[test]
    fn stale_generation_registrations_are_ignored() {
        let d = WorkerDirectory::new(1);
        d.register(0, 0, pk(1));
        let gen = d.begin_respawn(0);
        // A late frame from the dead generation must not resurrect it.
        d.register(0, 0, pk(7));
        assert_eq!(d.state(0), WorkerState::Respawning);
        d.register(0, gen, pk(8));
        assert_eq!(d.pks()[0], pk(8));
    }

    #[test]
    fn duplicate_register_for_a_live_worker_is_ignored() {
        let d = WorkerDirectory::new(1);
        d.register(0, 0, pk(1));
        assert_eq!(d.state(0), WorkerState::Alive);
        // Same generation, different key, while Alive: a replayed or
        // forged Register must not re-key the live incarnation.
        d.register(0, 0, pk(42));
        assert_eq!(d.pks()[0], pk(1), "first registration wins for a generation");
        assert_eq!(d.generation(0), 0);
        // But the same generation *does* land while the slot waits —
        // bring-up and respawn both rely on it.
        d.mark_crashed(0);
        d.register(0, 0, pk(7));
        assert_eq!(d.pks()[0], pk(7), "a crashed slot accepts its generation again");
        assert_eq!(d.state(0), WorkerState::Alive);
    }

    #[test]
    fn stale_register_after_respawn_cannot_resurrect_the_old_incarnation() {
        let d = WorkerDirectory::new(2);
        d.register(0, 0, pk(1));
        d.register(1, 0, pk(2));
        d.mark_crashed(0);
        let gen = d.begin_respawn(0);
        assert_eq!(gen, 1);
        // The new incarnation registers first; then a stale frame from
        // the killed generation 0 arrives (half-drained socket). It must
        // change nothing: not the key, not the state, not the generation.
        d.register(0, gen, pk(10));
        d.register(0, 0, pk(66));
        assert_eq!(d.pks()[0], pk(10));
        assert_eq!(d.generation(0), 1);
        assert_eq!(d.state(0), WorkerState::Alive);
    }

    #[test]
    fn quarantine_flags_once_and_rehab_clears_it() {
        let d = WorkerDirectory::new(3);
        for w in 0..3 {
            d.register(w, 0, pk(w as u64));
        }
        assert_eq!(d.suspected_mask(), vec![false; 3]);
        assert!(d.mark_suspected(1), "first verified forgery is a new quarantine");
        assert!(!d.mark_suspected(1), "repeat offenses are not new quarantines");
        assert!(d.is_suspected(1));
        assert_eq!(d.suspected_mask(), vec![false, true, false]);
        // Quarantine does not touch the lifecycle state: the suspect's
        // own shares are still dispatched.
        assert_eq!(d.state(1), WorkerState::Alive);
        assert_eq!(d.alive_mask(), vec![true; 3]);
        assert!(d.rehabilitate(1), "a verified-good result readmits the suspect");
        assert!(!d.rehabilitate(1), "rehabilitating an unsuspected worker is a no-op");
        assert!(!d.is_suspected(1));
        assert!(!d.mark_suspected(99), "out-of-range workers are ignored");
        assert!(!d.rehabilitate(99));
    }

    #[test]
    fn a_fresh_incarnation_starts_unsuspected() {
        let d = WorkerDirectory::new(1);
        d.register(0, 0, pk(1));
        assert!(d.mark_suspected(0));
        d.mark_crashed(0);
        assert!(d.is_suspected(0), "crashing does not clear suspicion by itself");
        let gen = d.begin_respawn(0);
        d.register(0, gen, pk(2));
        assert!(!d.is_suspected(0), "suspicion dies with the incarnation that earned it");
        // But a stale frame from the dead generation must not launder a
        // live suspect's reputation.
        assert!(d.mark_suspected(0));
        d.register(0, gen, pk(3));
        assert!(d.is_suspected(0), "a rejected registration must not clear suspicion");
    }

    #[test]
    fn wait_registered_blocks_until_the_frame_lands() {
        let d = Arc::new(WorkerDirectory::new(1));
        let gen = {
            d.register(0, 0, pk(1));
            d.mark_crashed(0);
            d.begin_respawn(0)
        };
        let d2 = Arc::clone(&d);
        let j = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            d2.register(0, gen, pk(5));
        });
        assert!(d.wait_registered(0, gen, Instant::now() + Duration::from_secs(5)));
        j.join().unwrap();
        assert!(
            !d.wait_registered(0, gen + 1, Instant::now() + Duration::from_millis(10)),
            "a never-arriving generation must time out"
        );
    }
}
