//! The master: drives encoded rounds end-to-end (encode → seal →
//! dispatch → collect → decrypt → decode) and owns all accounting.
//!
//! One pipeline serves every scheme and task shape: [`Master::run`]
//! executes a typed [`CodedTask`] synchronously, and the split-phase
//! [`Master::submit`] / [`Master::wait`] pair keeps several rounds in
//! flight against the worker pool at once.
//!
//! Results come home through a *sharded background collector*: a router
//! thread drains the transport's inbound frame channel and fans result
//! frames out by round id to [`COLLECTOR_SHARDS`] shard threads, which
//! deserialize and unseal in parallel and route each result to its
//! in-flight round through the shared
//! [`RoundRegistry`](super::registry::RoundRegistry). The submit path
//! therefore never competes with result intake — encode/seal/dispatch
//! of round r+1 overlaps both the workers' compute *and* the unsealing
//! of round r's results (see the `pipelining` bench) — and inbound
//! unsealing itself is no longer a single-thread bottleneck when many
//! rounds land at once ([`Master::run_stream`](super::stream)). Every
//! round gets its own collection deadline (`config.round_deadline_s`).
//!
//! Failure semantics: a worker whose link is down is remembered as dead
//! and skipped — it degrades into a permanent straggler that the wait
//! policy rides out (or a typed error when an exact-threshold scheme can
//! no longer be satisfied). Dropping a [`RoundHandle`] without waiting
//! abandons its round, so in-flight buffers can never leak.

use super::lifecycle::{WorkerDirectory, WorkerState};
use super::messages::{share_commitment, ControlMsg, SealedPayload, WirePayload, WorkOrder};
use super::pool::WorkerPool;
use super::registry::{RoundRegistry, SoftWait, WaitError};
use crate::coding::{make_scheme, CodeParams, CodedTask, DecodeCtx, Scheme, TaskShape, Threshold};
use crate::config::{SystemConfig, TransportSecurity};
use crate::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::metrics::{names, MetricsRegistry};
use crate::rng::{derive_seed, rng_from_seed, Rng};
use crate::runtime::{Executor, WorkerOp};
use crate::sim::{CollusionPool, DelayModel, EavesdropLog, FaultCoords, FaultKey, FaultPlan};
use crate::transport::LoadBook;
use crate::wire::{self, MsgKind, WireMessage};
use std::collections::{HashMap, HashSet};
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How many result-routing shards the collector fans inbound frames out
/// to. Frames are sharded by round id, so one slow unseal never blocks
/// the other rounds' intake, while every round still sees its own
/// results in arrival order (the per-shard channel is FIFO) — the
/// property the frozen-buffer determinism rests on.
const COLLECTOR_SHARDS: usize = 4;

/// Fraction of `round_deadline_s` after which a still-unsatisfied wait
/// duplicates its pending shares onto idle workers (when speculation is
/// on). Written-off shares are re-dispatched immediately and never wait
/// for this checkpoint.
const SPEC_DEADLINE_FRACTION: f64 = 0.5;

/// Tolerance for the decode residual check (DESIGN.md §11): an honest
/// surplus result re-encoded from the decoded blocks differs only by
/// f32 round-off (observed ~1e-6 relative); a forged result is off by
/// O(1).
const RESIDUAL_TOL: f64 = 1e-3;

/// What an honest result for `share` must look like, predicted from the
/// decoded blocks alone — the redundancy residual of verified decode
/// (DESIGN.md §11). Predictable only for the exact, non-private, linear
/// block codes: there f∘u has degree K−1 and the K decoded blocks pin
/// it completely, so its value at the share's evaluation node is
/// forced. Privacy masks (T > 0) add unknown mask images, approximate
/// schemes carry a nonzero baseline residual, higher degrees need more
/// than K points, and pair products restack before this sees them — all
/// of those return `None` and rely on the commitment layer instead.
fn predict_share_result(ctx: &DecodeCtx, blocks: &[Matrix], share: usize) -> Option<Matrix> {
    if !matches!(ctx.shape, TaskShape::BlockMap)
        || ctx.degree != 1
        || ctx.params.t != 0
        || ctx.betas.len() != ctx.params.k
        || blocks.len() != ctx.params.k
        || share >= ctx.alphas.len()
    {
        return None;
    }
    Some(crate::coding::interp::lagrange_eval(&ctx.betas, blocks, ctx.alphas[share]))
}

/// Result of one coded round.
#[derive(Debug)]
pub struct RoundOutcome {
    /// Decoded results: per-block `Yᵢ ≈ f(Xᵢ)` for block-map rounds, or
    /// a single full product for pair-product rounds.
    pub blocks: Vec<Matrix>,
    /// Wall-clock for the whole round (submit → decode done).
    pub wall: Duration,
    /// How many worker results the decoder consumed.
    pub results_used: usize,
    /// Did the round lose workers mid-flight and decode from fewer
    /// results than the original wait policy asked for?
    pub degraded: bool,
}

/// Why a round failed — the typed failure surface of [`Master::wait`]
/// (reachable from the opaque error via
/// `err.inner().downcast_ref::<RoundError>()`).
///
/// The two terminal variants are deliberately distinct: `Deadline`
/// means enough workers were still live for k-of-n recovery — they were
/// just slower than the budget — while `Hopeless` means the recovery
/// threshold can *never* be met because too many workers are down, so
/// the wait was cut short instead of burning the deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundError {
    /// `round_deadline_s` elapsed with `got` of `need` results buffered.
    /// The missing workers were still believed live: k-of-n recovery was
    /// still possible, just slow.
    Deadline {
        /// The abandoned round.
        round: u64,
        /// Results buffered when the deadline hit.
        got: usize,
        /// Results the wait policy wanted.
        need: usize,
    },
    /// Too many workers are down for the threshold to ever be reached;
    /// the round was abandoned immediately (no deadline ride-down).
    Hopeless {
        /// The abandoned round.
        round: u64,
        /// Results that could still have arrived.
        possible: usize,
        /// The scheme's hard minimum.
        need: usize,
    },
    /// The round is not in flight (never submitted, already waited on,
    /// or abandoned).
    Unknown {
        /// The unknown round id.
        round: u64,
    },
    /// Forged results made the round fail: either the shortfall traces
    /// back to results dropped at the collector's commitment check
    /// (recovery could not outrun them), or the decode residual check
    /// caught a forged result that slipped into the decode set. Either
    /// way the round is refused rather than returned silently wrong —
    /// the core guarantee of verified decode (DESIGN.md §11).
    Forged {
        /// The abandoned round.
        round: u64,
        /// Forged results implicated (per the fault bookings; at least 1
        /// when the decode residual check itself fired).
        forged: usize,
    },
}

impl std::fmt::Display for RoundError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RoundError::Deadline { round, got, need } => write!(
                f,
                "round {round} timed out with {got}/{need} results buffered — enough \
                 workers remain live, k-of-n recovery was still possible"
            ),
            RoundError::Hopeless { round, possible, need } => write!(
                f,
                "round {round}: only {possible} results can still arrive but the scheme \
                 needs {need} — too many workers are down"
            ),
            RoundError::Unknown { round } => write!(f, "round {round} is not in flight"),
            RoundError::Forged { round, forged } => write!(
                f,
                "round {round}: {forged} forged result(s) detected — the round could not \
                 be completed from verified results and was refused rather than decoded wrong"
            ),
        }
    }
}

impl std::error::Error for RoundError {}

/// A round in flight: returned by [`Master::submit`], consumed by
/// [`Master::wait`] (or released by [`Master::abandon`]). Deliberately
/// neither `Clone` nor constructible outside this module, so every
/// submitted round is waited on at most once.
///
/// Dropping a handle without waiting *abandons* its round: the buffered
/// results are counted as wasted work and the in-flight buffer is freed
/// immediately (not when the master drops). The explicit
/// [`Master::abandon`] does the same and reads better when the intent is
/// deliberate.
#[derive(Debug)]
pub struct RoundHandle {
    round: u64,
    registry: Weak<RoundRegistry>,
    defused: bool,
}

impl RoundHandle {
    /// The monotone round id this handle tracks.
    pub fn round_id(&self) -> u64 {
        self.round
    }

    /// Consume the handle without triggering the drop-abandon.
    fn defuse(mut self) -> u64 {
        self.defused = true;
        self.round
    }
}

impl Drop for RoundHandle {
    fn drop(&mut self) {
        if !self.defused {
            if let Some(registry) = self.registry.upgrade() {
                registry.abandon(self.round);
            }
        }
    }
}

/// Builder for [`Master`].
pub struct MasterBuilder {
    cfg: SystemConfig,
    executor: Option<Executor>,
    eavesdropper: Option<Arc<EavesdropLog>>,
    collusion: Option<Arc<CollusionPool>>,
    faults: Option<Arc<FaultPlan>>,
    metrics: Option<Arc<MetricsRegistry>>,
}

impl MasterBuilder {
    /// Start from a config.
    pub fn new(cfg: SystemConfig) -> Self {
        Self {
            cfg,
            executor: None,
            eavesdropper: None,
            collusion: None,
            faults: None,
            metrics: None,
        }
    }

    /// Attach an executor (default: native with the master's metrics).
    pub fn executor(mut self, e: Executor) -> Self {
        self.executor = Some(e);
        self
    }

    /// Attach an eavesdropper tap.
    pub fn eavesdropper(mut self, tap: Arc<EavesdropLog>) -> Self {
        self.eavesdropper = Some(tap);
        self
    }

    /// Attach a collusion pool (its members leak their shares).
    pub fn collusion(mut self, pool: Arc<CollusionPool>) -> Self {
        self.collusion = Some(pool);
        self
    }

    /// Attach a deterministic fault schedule (the scenario engine's
    /// plan): workers crash mid-round and corrupt result frames per the
    /// plan, and the master drives the matching bookkeeping — crash
    /// accounting at submit time, respawns on schedule.
    pub fn faults(mut self, plan: Arc<FaultPlan>) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Share a metrics registry.
    pub fn metrics(mut self, m: Arc<MetricsRegistry>) -> Self {
        self.metrics = Some(m);
        self
    }

    /// Wire the transport, spawn the worker pool and the collector
    /// thread, and build the master.
    pub fn build(self) -> anyhow::Result<Master> {
        self.cfg.validate().map_err(|e| anyhow::anyhow!(e.to_string()))?;
        // Size the process-wide pool from the config: every parallel hot
        // path (encode fan-out, seal fan-out, GEMM, decode) reads it.
        // The width is process-global (last build wins — see DESIGN.md
        // §6); thread count never affects results, only wall-clock.
        crate::parallel::configure(self.cfg.threads);
        let metrics = self.metrics.unwrap_or_else(|| Arc::new(MetricsRegistry::new()));
        let executor =
            self.executor.unwrap_or_else(|| Executor::native(Arc::clone(&metrics)));
        let curve = sim_curve();
        let mut rng = rng_from_seed(derive_seed(self.cfg.seed, 0x3A57E2));
        let keys = KeyPair::generate(&curve, &mut rng);
        let (pool, inbound) = WorkerPool::spawn(
            self.cfg.transport,
            self.cfg.workers,
            keys.public(),
            executor,
            self.collusion.clone(),
            self.faults.clone(),
            self.cfg.seed,
            Arc::clone(&metrics),
        )
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let directory = Arc::clone(pool.directory());
        let params =
            CodeParams::new(self.cfg.workers, self.cfg.partitions, self.cfg.colluders);
        // Total over every SchemeKind — MatDot included; no Option field,
        // no second code path.
        let scheme = make_scheme(self.cfg.scheme, params);
        let delays = DelayModel::new(
            self.cfg.workers,
            self.cfg.stragglers,
            self.cfg.delay,
            self.cfg.seed,
        );
        let registry = Arc::new(RoundRegistry::new(Arc::clone(&metrics)));
        let load = Arc::clone(pool.load());
        let round_settled: RoundSettled = Arc::new(Mutex::new(HashMap::new()));
        let commit_book: CommitBook = Arc::new(Mutex::new(HashMap::new()));
        let collector = spawn_collector(
            inbound,
            Arc::clone(&registry),
            Arc::clone(&directory),
            Arc::clone(&metrics),
            Arc::new(keys),
            self.eavesdropper.clone(),
            Arc::clone(&load),
            Arc::clone(&round_settled),
            Arc::clone(&commit_book),
        );
        let speculate = self.cfg.speculate;
        let workers = self.cfg.workers;
        Ok(Master {
            cfg: self.cfg,
            scheme,
            pool,
            mea: MeaEcc::new(curve, MaskMode::Keystream),
            metrics,
            eavesdropper: self.eavesdropper,
            faults: self.faults,
            delays,
            round: 0,
            served: vec![0; workers],
            pending_respawns: Vec::new(),
            round_lanes: HashMap::new(),
            rng,
            registry,
            directory,
            load,
            round_settled,
            commit_book,
            forge_booked: HashMap::new(),
            speculate,
            spec_rounds: HashMap::new(),
            round_targets: HashMap::new(),
            collector,
        })
    }
}

/// What the master retains about an in-flight round so a share can be
/// re-sealed and re-sent to another worker: the round's seal salt, the
/// op, and each share's plaintext operands. Only populated while
/// speculation is on; dropped when the round retires.
struct SpecRound {
    salt: u64,
    op: WorkerOp,
    operands: Vec<Option<Vec<Matrix>>>,
}

/// Executors whose results already came home, per in-flight round —
/// shared between the master thread and the collector shards. The
/// master opens a round's entry *before* its first order goes out and
/// removes it at retirement, settling the remainder (dispatch targets
/// minus recorded executors) wholesale; each shard records a result's
/// executor and settles its load-book slot the moment the result
/// arrives (wire v2 carries the executor id). An absent entry means the
/// round already retired — the remainder settle covered it, so late
/// results must not settle again.
type RoundSettled = Arc<Mutex<HashMap<u64, Vec<usize>>>>;

/// Per-share commitments of every in-flight round, booked at encode
/// time (wire v3) — shared with the collector shards, which verify each
/// arriving result's echo against the booked value before it may count
/// toward the round. Removed when the round settles; an absent entry
/// means the round retired and the frame is about to be rejected as
/// late anyway.
type CommitBook = Arc<Mutex<HashMap<u64, Vec<u64>>>>;

/// The background result collector, sharded (DESIGN.md §8): one *router*
/// thread drains the transport's merged inbound channel, peeks each
/// frame's kind and round id from the fixed header (no body decode, no
/// CRC), handles `Register` control frames inline (the respawn
/// handshake's master side), and forwards result frames to one of
/// [`COLLECTOR_SHARDS`] shard threads keyed by `round % shards`. The
/// shards do the expensive work — full decode, CRC validation, MEA-ECC
/// unsealing — in parallel, and route decoded results into the shared
/// [`RoundRegistry`]. Sharding by round id keeps each round's arrivals
/// in FIFO order (one shard, one channel), so the frozen-buffer
/// determinism is untouched. Everything exits when the inbound channel
/// disconnects (pool shutdown): the router drops the shard senders and
/// the shards drain out.
#[allow(clippy::too_many_arguments)]
fn spawn_collector(
    inbound: Receiver<Vec<u8>>,
    registry: Arc<RoundRegistry>,
    directory: Arc<WorkerDirectory>,
    metrics: Arc<MetricsRegistry>,
    keys: Arc<KeyPair<Fp61>>,
    tap: Option<Arc<EavesdropLog>>,
    load: Arc<LoadBook>,
    settled: RoundSettled,
    commits: CommitBook,
) -> Vec<JoinHandle<()>> {
    let mut joins = Vec::with_capacity(COLLECTOR_SHARDS + 1);
    let mut shard_txs = Vec::with_capacity(COLLECTOR_SHARDS);
    for shard in 0..COLLECTOR_SHARDS {
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        shard_txs.push(tx);
        joins.push(spawn_collector_shard(
            shard,
            rx,
            Arc::clone(&registry),
            Arc::clone(&directory),
            Arc::clone(&metrics),
            Arc::clone(&keys),
            tap.clone(),
            Arc::clone(&load),
            Arc::clone(&settled),
            Arc::clone(&commits),
        ));
    }
    let router = std::thread::Builder::new()
        .name("collector-router".into())
        .spawn(move || {
            while let Ok(frame) = inbound.recv() {
                match wire::peek_kind(&frame) {
                    Some(MsgKind::Control) => match wire::decode_message(&frame) {
                        Ok(WireMessage::Control(ControlMsg::Register {
                            worker,
                            generation,
                            pk,
                        })) => {
                            // A respawned incarnation rejoining: install
                            // its key and wake whoever waits on the
                            // handshake.
                            directory.register(worker, generation, pk);
                        }
                        Ok(other) => {
                            metrics.inc(names::WIRE_ERRORS);
                            eprintln!(
                                "collector: dropping unexpected {} frame",
                                other.kind_name()
                            );
                        }
                        Err(e) => {
                            metrics.inc(names::WIRE_ERRORS);
                            eprintln!("collector: dropping undecodable control frame: {e}");
                        }
                    },
                    Some(MsgKind::Result) => {
                        let round = wire::peek_result_round(&frame).unwrap_or(0);
                        let shard = (round % COLLECTOR_SHARDS as u64) as usize;
                        if shard_txs[shard].send(frame).is_err() {
                            break;
                        }
                    }
                    // Anything else — a misrouted order, garbled magic —
                    // goes to shard 0, whose full decoder produces the
                    // typed error and the wire-error tick.
                    _ => {
                        if shard_txs[0].send(frame).is_err() {
                            break;
                        }
                    }
                }
            }
            // Dropping shard_txs here disconnects the shards.
        })
        .expect("spawn collector router");
    joins.push(router);
    joins
}

/// One collector shard: full decode + unseal + registry delivery for
/// the result frames of its round-id residue class.
#[allow(clippy::too_many_arguments)]
fn spawn_collector_shard(
    shard: usize,
    frames: Receiver<Vec<u8>>,
    registry: Arc<RoundRegistry>,
    directory: Arc<WorkerDirectory>,
    metrics: Arc<MetricsRegistry>,
    keys: Arc<KeyPair<Fp61>>,
    tap: Option<Arc<EavesdropLog>>,
    load: Arc<LoadBook>,
    settled: RoundSettled,
    commits: CommitBook,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("collector-{shard}"))
        .spawn(move || {
            let mea = MeaEcc::new(sim_curve(), MaskMode::Keystream);
            while let Ok(frame) = frames.recv() {
                let msg = match wire::decode_message(&frame) {
                    Ok(WireMessage::Result(m)) => m,
                    Ok(other) => {
                        metrics.inc(names::WIRE_ERRORS);
                        eprintln!(
                            "collector: dropping unexpected {} frame",
                            other.kind_name()
                        );
                        continue;
                    }
                    Err(e) => {
                        metrics.inc(names::WIRE_ERRORS);
                        eprintln!("collector: dropping undecodable frame: {e}");
                        continue;
                    }
                };
                // Results that will not be buffered (late or spilled) are
                // settled without unsealing: no wasted crypto, and the
                // comm counters stay a deterministic function of the
                // decode inputs (they are credited at decode time in
                // `Master::wait`).
                if !registry.would_accept(msg.round) {
                    registry.note_rejected(msg.round);
                    continue;
                }
                let (round, worker, executor) = (msg.round, msg.worker, msg.executor);
                // Settle the executor's load-book slot now — it finished
                // this order whatever becomes of the payload (even a
                // corrupt seal was computed and sent). Recording it under
                // the round keeps retirement's remainder-settle exact.
                {
                    let mut map = settled.lock().unwrap();
                    if let Some(recorded) = map.get_mut(&round) {
                        recorded.push(executor);
                        load.settle_one(executor);
                    }
                }
                // Wire-v3 result verification (DESIGN.md §11): the
                // commitment echo is checked against the value booked at
                // encode time *before* the result may count toward the
                // round. A mismatch is a forged result: drop it — it must
                // never win the first-result-wins race against the honest
                // re-dispatch copy — and quarantine the executor. A
                // matching result from a suspect is the evidence that
                // rehabilitates it. The counts here are timing-shaped
                // (late frames skip the check entirely) and are never
                // folded into the determinism digest; the deterministic
                // forgery count lives in the master's fault bookings.
                let expected = {
                    let book = commits.lock().unwrap();
                    book.get(&round).and_then(|c| c.get(worker)).copied()
                };
                if let Some(expected) = expected {
                    metrics.inc(names::VERIFY_CHECKED);
                    if expected != msg.commitment {
                        if directory.mark_suspected(executor) {
                            metrics.inc(names::VERIFY_QUARANTINED);
                        }
                        continue;
                    }
                    if directory.rehabilitate(executor) {
                        metrics.inc(names::VERIFY_REHABILITATED);
                    }
                }
                let symbols = msg.payload.symbols() as u64;
                // The eavesdropper's ciphertext view has to be charted
                // before the payload is consumed; only materialized when
                // a tap is actually attached.
                let wire_view = tap.as_ref().map(|_| msg.payload.wire_matrix());
                // Unseal by value: the ciphertext buffer is unmasked in
                // place instead of copied.
                let result = match msg.payload {
                    WirePayload::Plain(m) => m,
                    WirePayload::Sealed(s) => match s.open_owned(&mea, &keys) {
                        Ok(m) => m,
                        Err(e) => {
                            metrics.inc(names::WIRE_ERRORS);
                            eprintln!("collector: sealed result failed to open: {e}");
                            continue;
                        }
                    },
                };
                let buffered =
                    registry.deliver(round, worker, result, symbols, frame.len() as u64);
                if buffered {
                    // A buffered result computed by someone other than
                    // the share's owner is a speculative race won by the
                    // re-dispatch copy (wire v2 attribution).
                    if executor != worker {
                        metrics.inc(names::SPEC_WON_BY_PROXY);
                    }
                    if let (Some(tap), Some(view)) = (&tap, &wire_view) {
                        tap.capture(worker, round, false, view);
                    }
                }
            }
        })
        .expect("spawn collector shard")
}

/// The master node.
pub struct Master {
    cfg: SystemConfig,
    scheme: Box<dyn Scheme>,
    pool: WorkerPool,
    mea: MeaEcc<Fp61>,
    metrics: Arc<MetricsRegistry>,
    eavesdropper: Option<Arc<EavesdropLog>>,
    faults: Option<Arc<FaultPlan>>,
    delays: DelayModel,
    round: u64,
    /// Wall rounds served per worker slot, 1-based and counting the
    /// order being dispatched. Ticks on *directory aliveness at
    /// dispatch* — exactly the workers the seal fan-out produced
    /// payloads for — never on send success, which can differ between
    /// fabrics (a TCP send to a corpse may buffer where an in-process
    /// send fails). This is the `served` fault coordinate: a respawned
    /// worker resumes its own service clock where it left off instead
    /// of inheriting whatever the global round counter reached while it
    /// was dead (DESIGN.md §13). Speculative orders carry the
    /// executor's current count without ticking it — proxy work is
    /// extra load, not a wall round of its own.
    served: Vec<u64>,
    /// Scheduled respawns booked under the `served`/`lane` fault keys:
    /// `(worker, due global round)`. Under the legacy `global` key the
    /// plan itself answers [`FaultPlan::respawns_due`]; under the
    /// re-keyed modes a crash fires on the worker's served clock at
    /// whatever global round that happens to be, so the due round is
    /// only known when the crash is booked.
    pending_respawns: Vec<(usize, u64)>,
    /// The session lane each in-flight round was submitted under:
    /// `(lane id, lane-local round)`, `(0, round)` on single-tenant
    /// paths. Speculative re-dispatch reads the original coordinates
    /// here so a proxy's order carries the same fault coordinates the
    /// owner's did. Cleaned at retirement.
    round_lanes: HashMap<u64, (u32, u64)>,
    rng: Rng,
    /// Shared with the collector shards and every live round handle.
    registry: Arc<RoundRegistry>,
    /// Shared with the pool and the collector: lifecycle states,
    /// generations, and current public keys.
    directory: Arc<WorkerDirectory>,
    /// Per-worker backlog signal (orders sent − results settled): the
    /// idle-worker signal speculative re-dispatch keys its executor
    /// choice on. Sends book on the master thread; since wire v2 the
    /// collector shards settle each result's *executor* the moment it
    /// arrives, so readings track real completion instead of round
    /// retirement. (Executor choice may therefore see arrival timing —
    /// which worker computes a share never changes the decoded bits.)
    load: Arc<LoadBook>,
    /// Executors already settled per in-flight round — see
    /// [`RoundSettled`]; retirement settles the remainder.
    round_settled: RoundSettled,
    /// Per-share commitments per in-flight round — see [`CommitBook`];
    /// the collector shards verify every result echo against it.
    commit_book: CommitBook,
    /// Forgeries booked per in-flight round (from the fault plan, at
    /// submit time). A round that fails with bookings here reports
    /// [`RoundError::Forged`] instead of a generic timeout/hopeless.
    forge_booked: HashMap<u64, usize>,
    /// Re-dispatch outstanding shares to other workers (config
    /// `speculate`, overridable per stream — see
    /// [`Master::run_stream`](super::stream)).
    speculate: bool,
    /// Retained share operands for speculative re-seal, per in-flight
    /// round (populated only while `speculate` is on).
    spec_rounds: HashMap<u64, SpecRound>,
    /// Physical dispatch targets per in-flight round (original owners
    /// plus speculative executors), settled into `load` at retirement.
    round_targets: HashMap<u64, Vec<usize>>,
    /// Collector shard + router threads, joined at drop.
    collector: Vec<JoinHandle<()>>,
}

impl Master {
    /// Convenience: build with defaults from a config.
    pub fn from_config(cfg: SystemConfig) -> anyhow::Result<Self> {
        MasterBuilder::new(cfg).build()
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// The active config.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The configured coding scheme.
    pub fn scheme(&self) -> &dyn Scheme {
        &*self.scheme
    }

    /// The straggler set chosen for this scenario.
    pub fn straggler_set(&self) -> Vec<usize> {
        self.delays.straggler_set()
    }

    /// Workers currently unable to serve (crashed or mid-respawn), by
    /// index.
    pub fn dead_workers(&self) -> Vec<usize> {
        self.directory
            .states()
            .into_iter()
            .enumerate()
            .filter(|(_, s)| *s != WorkerState::Alive)
            .map(|(w, _)| w)
            .collect()
    }

    /// Every worker's lifecycle state, by index.
    pub fn worker_states(&self) -> Vec<WorkerState> {
        self.directory.states()
    }

    /// Every worker's incarnation number, by index (0 = never respawned).
    pub fn worker_generations(&self) -> Vec<u32> {
        self.directory.generations()
    }

    /// The process fabric's child exit log (`None` on in-process
    /// fabrics). Clone the handle before dropping the master to observe
    /// teardown exits as well — the testbed does.
    pub fn exit_log(&self) -> Option<super::ExitLog> {
        self.pool.exit_records()
    }

    /// Kill worker `w` over the wire: it dies silently at its next frame
    /// boundary. Orders already queued to it are still served first (the
    /// kill is a frame like any other), so in-flight rounds keep their
    /// expected results; from the next submit on, the worker is skipped.
    pub fn crash_worker(&mut self, w: usize) -> anyhow::Result<()> {
        self.pool.crash(w).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        self.directory.mark_crashed(w);
        self.metrics.inc(names::WORKER_CRASHES);
        Ok(())
    }

    /// Record that worker `w` died *hard*, mid-round: nothing more will
    /// arrive from it. Every in-flight round that still expected its
    /// result re-evaluates (degrade or go hopeless — see
    /// [`RoundError`]); future submits skip the worker. This is also the
    /// path a failed dispatch takes (dead link = dead queue).
    pub fn note_worker_crashed(&mut self, w: usize) {
        self.directory.mark_crashed(w);
        self.registry.note_worker_down(w);
        self.metrics.inc(names::WORKER_CRASHES);
    }

    /// Record that worker `w`'s result for `round` was lost in transit
    /// (e.g. a corrupted frame) while the worker itself is fine. The
    /// scheduled-fault booking in [`Master::submit`] goes through here.
    pub fn note_result_lost(&mut self, round: u64, w: usize) {
        self.registry.note_lost(round, w);
    }

    /// The active fault key — `Global` when no plan is attached (the
    /// legacy bits by construction).
    fn fault_key(&self) -> FaultKey {
        self.faults.as_deref().map_or(FaultKey::Global, FaultPlan::key)
    }

    /// The delay-model round key for worker `w`: the global round under
    /// the legacy `global` fault key (bit-identical jitter streams), the
    /// worker's wall-rounds-served count otherwise — a respawned
    /// worker's jitter stream resumes from its own service history
    /// instead of jumping to wherever the global clock got to
    /// (DESIGN.md §13).
    fn delay_key(&self, w: usize, round: u64) -> u64 {
        match self.fault_key() {
            FaultKey::Global => round,
            FaultKey::Served | FaultKey::Lane => self.served[w],
        }
    }

    /// The lane coordinates `round` was submitted under — `(0, round)`
    /// for rounds that predate the lane map or came through the
    /// single-tenant path.
    fn round_coords(&self, round: u64) -> (u32, u64) {
        self.round_lanes.get(&round).copied().unwrap_or((0, round))
    }

    /// The full fault coordinates of worker `w`'s order for `round`:
    /// the same four numbers the dispatched [`WorkOrder`] carries, so
    /// master-side pre-booking and the worker loop evaluate the plan on
    /// identical inputs by construction.
    fn fault_coords(&self, w: usize, round: u64, lane: u32, lane_round: u64) -> FaultCoords {
        FaultCoords { round, served: self.served[w], lane, lane_round }
    }

    /// Book this round's scheduled faults, mirroring what the workers
    /// will actually do with the same plan. Crash state is recorded even
    /// when the round itself is being abandoned (`note_registry =
    /// false`): the worker received its order and died, whatever became
    /// of the round — skipping the booking would leave it `Alive`
    /// forever and silently cancel its scheduled respawn.
    fn book_scheduled_faults(
        &mut self,
        round: u64,
        lane: u32,
        lane_round: u64,
        sent: &[usize],
        note_registry: bool,
    ) {
        let Some(plan) = self.faults.clone() else { return };
        for &w in sent {
            let coords = self.fault_coords(w, round, lane, lane_round);
            if let Some(ev) = plan.crash_hit(w, &coords) {
                self.directory.mark_crashed(w);
                self.metrics.inc(names::WORKER_CRASHES);
                // Under the re-keyed modes the plan cannot answer
                // "whose respawn is due at global round r" — the crash
                // fired on the worker's served clock at whatever global
                // round that happened to be. Book the respawn here,
                // due `respawn_after` submits from now.
                if plan.key() != FaultKey::Global {
                    if let Some(after) = ev.respawn_after {
                        self.pending_respawns.push((w, round + after));
                    }
                }
                if note_registry {
                    self.note_result_lost(round, w);
                }
            } else if plan.corrupts(w, &coords) && note_registry {
                self.note_result_lost(round, w);
            } else if plan.forges_at(w, &coords) && note_registry {
                // A planned forgery is booked like a transit loss: the
                // collector's commitment check will drop the forged
                // frame, so the share must be re-dispatched to an honest
                // executor now (the speculation pass that follows this
                // booking). Counting detections here — from the same
                // plan the worker executes — keeps the metric a pure
                // function of the scenario, in lock step with the crash
                // accounting, instead of a race between late frames and
                // run-end metric reads.
                self.metrics.inc(names::VERIFY_FORGED_DETECTED);
                *self.forge_booked.entry(round).or_insert(0) += 1;
                self.note_result_lost(round, w);
            }
        }
    }

    /// Respawn a crashed worker: wire a fresh link, start a new
    /// incarnation (generation bumped, fresh deterministic keys), and
    /// block until its `Register` frame lands — after this returns the
    /// worker is `Alive` and the next round seals to its new key.
    pub fn respawn_worker(&mut self, w: usize) -> anyhow::Result<()> {
        if w >= self.directory.n() {
            anyhow::bail!("worker {w} out of range (pool has {})", self.directory.n());
        }
        if self.directory.state(w) == WorkerState::Alive {
            anyhow::bail!("worker {w} is alive; nothing to respawn");
        }
        // A manual respawn knows nothing about why the worker died, so
        // it is pessimistic: whatever the old incarnation still owed is
        // written off (rounds re-evaluate — degrade or fail fast), and a
        // written-off result that makes it home anyway is still
        // welcomed by the registry.
        self.respawn_now(w, true)
    }

    /// Wire a fresh link and start a new incarnation. `write_off`
    /// controls whether the old incarnation's outstanding shares are
    /// abandoned: a *scheduled* respawn skips it — the relink is
    /// graceful on both fabrics (the old incarnation drains its queued
    /// orders and its in-flight replies keep flowing), and the fault
    /// plan already wrote off exactly the crash round at submit time, so
    /// writing off again would make older rounds' outcomes depend on
    /// when the respawn lands relative to them (i.e. on the stream
    /// window width — DESIGN.md §8).
    fn respawn_now(&mut self, w: usize, write_off: bool) -> anyhow::Result<()> {
        if write_off {
            self.registry.note_worker_down(w);
        }
        let generation = self.pool.respawn(w).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let deadline = Instant::now() + Duration::from_secs(10);
        if !self.directory.wait_registered(w, generation, deadline) {
            anyhow::bail!(
                "worker {w} respawn: registration for generation {generation} never arrived"
            );
        }
        self.metrics.inc(names::WORKER_RESPAWNS);
        Ok(())
    }

    /// Run one coded round synchronously: encode `task` with the
    /// configured scheme, dispatch, collect, decode.
    ///
    /// This is a convenience wrapper over the session front end
    /// (DESIGN.md §12): one throwaway single-tenant lane in
    /// compatibility mode (no tenant seed, the config deadline,
    /// speculation untouched), so its bits are exactly one
    /// [`Service::round`](super::Service::round).
    pub fn run(&mut self, task: CodedTask) -> anyhow::Result<RoundOutcome> {
        let speculate = self.speculation();
        let mut svc =
            self.service(super::ServiceConfig { global_inflight: 1, speculate });
        let sid = svc.open("run", super::SessionOptions::default());
        let out = svc.round(sid, task);
        svc.finish();
        out
    }

    /// Phase 1+2 of a round: encode `task`, seal the per-worker payloads,
    /// and dispatch the framed work orders. Returns immediately with a
    /// [`RoundHandle`]; several rounds may be in flight at once — the
    /// collector thread routes interleaved results to the right round.
    ///
    /// Draws encode masks and the round salt from the master's root
    /// RNG — the single-tenant path. The session layer submits through
    /// [`submit_seeded`](Master::submit_seeded) to give each tenant its
    /// own stream.
    pub fn submit(&mut self, task: CodedTask) -> anyhow::Result<RoundHandle> {
        self.submit_seeded(task, None)
    }

    /// [`submit`](Master::submit) with an optional tenant RNG lane:
    /// when `lane_rng` is `Some`, the encode privacy masks and the
    /// round's seal salt are drawn from it instead of the master's root
    /// RNG, so a tenant's round bits are a pure function of its own
    /// seed and task — never of how other tenants' rounds interleave
    /// (the session layer's isolation contract, DESIGN.md §12). `None`
    /// is the compatibility path every pre-session caller takes.
    pub(crate) fn submit_seeded(
        &mut self,
        task: CodedTask,
        lane_rng: Option<&mut Rng>,
    ) -> anyhow::Result<RoundHandle> {
        self.submit_in_lane(task, lane_rng, 0, 0)
    }

    /// [`submit_seeded`](Master::submit_seeded) with explicit fault
    /// coordinates: `lane` is the session lane id and `lane_round` its
    /// 1-based lane-local round counter — the numbers the dispatched
    /// orders carry so the fault plan's `lane` key draws per-lane
    /// streams (DESIGN.md §13). `lane_round == 0` is the single-tenant
    /// sentinel: the lane-local round *is* the global round.
    pub(crate) fn submit_in_lane(
        &mut self,
        task: CodedTask,
        mut lane_rng: Option<&mut Rng>,
        lane: u32,
        lane_round: u64,
    ) -> anyhow::Result<RoundHandle> {
        if !self.scheme.supports(&task) {
            anyhow::bail!(
                "{} does not support {} tasks",
                self.scheme.kind().name(),
                task.name()
            );
        }
        // Rounds can retire behind the master's back (a dropped handle
        // abandons in place): reclaim their bookkeeping first.
        self.sweep_retired();
        self.round += 1;
        let round = self.round;
        let lane_round = if lane_round == 0 { round } else { lane_round };
        // Scheduled respawns land before the round's orders go out, so a
        // rejoined incarnation serves this round with its new key. The
        // legacy key asks the plan (crash round + respawn_after is a
        // pure function of the global clock); the re-keyed modes drain
        // the ledger the crash bookings posted.
        if let Some(plan) = self.faults.clone() {
            let due: Vec<usize> = if plan.key() == FaultKey::Global {
                plan.respawns_due(round)
            } else {
                let mut due = Vec::new();
                self.pending_respawns.retain(|&(w, at)| {
                    if at <= round {
                        due.push(w);
                        false
                    } else {
                        true
                    }
                });
                due
            };
            for w in due {
                if self.directory.state(w) == WorkerState::Crashed {
                    if let Err(e) = self.respawn_now(w, false) {
                        eprintln!("master: scheduled respawn of worker {w} failed: {e}");
                    }
                }
            }
        }
        let started = Instant::now();

        // Encode (+T masks) — §V-B "data process".
        let job = {
            let _t = self.metrics.time_phase("phase.encode");
            match lane_rng.as_deref_mut() {
                Some(rng) => self.scheme.encode(&task, rng)?,
                None => self.scheme.encode(&task, &mut self.rng)?,
            }
        };
        let threshold = self.scheme.threshold(&task);
        let crate::coding::EncodedJob { payloads: shares, op, ctx } = job;

        // Book every share's commitment before the shares move into the
        // seal fan-out: the collector verifies each result's echo
        // against these (wire v3), and a speculative re-seal recomputes
        // the same value from the retained plaintext. Commitments are
        // over plaintext operands, so the owner's copy and a proxy's
        // copy agree even though their sealed bytes differ.
        let commitments: Vec<u64> = shares.iter().map(|ops| share_commitment(ops)).collect();

        // Open the round *before* any order goes out so the collector
        // can never race the registration.
        self.registry.register(round, ctx, threshold, started);

        // Seal every live worker's operand payloads on the thread pool:
        // each worker's MEA-ECC scalar multiplications and keystream are
        // independent of every other worker's, so the fan-out is
        // embarrassingly parallel. Each worker's seal RNG is derived
        // from a per-round salt and the worker index — ciphertexts are a
        // pure function of (seed, round, worker), never of thread count
        // or scheduling.
        //
        // Ownership depends on the speculation mode: off, the shares are
        // *moved* into the fan-out (plain payloads travel without a
        // clone); on, the fan-out seals from borrows and the shares are
        // retained for re-sealing to another worker — no per-round deep
        // copy of the input either way (MEA-ECC copies only the bytes it
        // masks; the plain+speculate combination clones, which the wire
        // payload needs an owned matrix for regardless).
        let round_salt = match lane_rng.as_deref_mut() {
            Some(rng) => rng.next_u64(),
            None => self.rng.next_u64(),
        };
        // Seal to the *current incarnations'* keys: a respawned worker
        // re-registered with a fresh key pair.
        let pks = self.directory.pks();
        let alive = self.directory.alive_mask();
        let (sealed, retained): (Vec<Option<Vec<WirePayload>>>, Vec<Option<Vec<Matrix>>>) = {
            let _t = self.metrics.time_phase("phase.seal");
            let security = self.cfg.security;
            let mea = &self.mea;
            if self.speculate {
                let shares_ref = &shares;
                let pks_ref = &pks;
                let alive_ref = &alive;
                let sealed = crate::parallel::global().map_indexed(shares.len(), |w| {
                    if !alive_ref[w] {
                        return None;
                    }
                    let mut seal_rng = rng_from_seed(derive_seed(round_salt, w as u64));
                    Some(
                        shares_ref[w]
                            .iter()
                            .map(|m| match security {
                                TransportSecurity::Plain => WirePayload::Plain(m.clone()),
                                TransportSecurity::MeaEcc => WirePayload::Sealed(
                                    SealedPayload::seal(mea, m, &pks_ref[w], &mut seal_rng),
                                ),
                            })
                            .collect(),
                    )
                });
                // Dead workers' shares are never dispatched, so they can
                // never be written off and re-dispatched: drop them.
                let retained = shares
                    .into_iter()
                    .enumerate()
                    .map(|(w, operands)| if alive[w] { Some(operands) } else { None })
                    .collect();
                (sealed, retained)
            } else {
                let sealed = crate::parallel::global().map_vec(shares, |w, operands| {
                    if !alive[w] {
                        return None;
                    }
                    let mut seal_rng = rng_from_seed(derive_seed(round_salt, w as u64));
                    Some(
                        operands
                            .into_iter()
                            .map(|m| match security {
                                TransportSecurity::Plain => WirePayload::Plain(m),
                                TransportSecurity::MeaEcc => WirePayload::Sealed(
                                    SealedPayload::seal(mea, &m, &pks[w], &mut seal_rng),
                                ),
                            })
                            .collect(),
                    )
                });
                (sealed, Vec::new())
            }
        };

        // Open the round's settle and commitment ledgers *before* any
        // order goes out so the collector shards can never race them: a
        // result that arrives while the entries exist settles its
        // executor and is verified against its share's commitment.
        self.round_settled.lock().unwrap().insert(round, Vec::new());
        self.commit_book.lock().unwrap().insert(round, commitments.clone());

        // Dispatch serially in worker order (frame serialization is
        // cheap next to sealing, and ordered sends keep the transport
        // deterministic). A dead link is a typed condition, not a panic:
        // the worker becomes a permanent straggler and the round
        // proceeds without it.
        let mut sent: Vec<usize> = Vec::new();
        {
            let metrics = Arc::clone(&self.metrics);
            let _t = metrics.time_phase("phase.dispatch");
            for (w, payloads) in sealed.into_iter().enumerate() {
                let Some(payloads) = payloads else { continue };
                // The served clock ticks on the aliveness the seal
                // fan-out used (payloads exist ⇔ directory said alive),
                // before the send — whether the frame then lands is a
                // transport matter the fault coordinates must not
                // depend on.
                self.served[w] += 1;
                let delay_round = self.delay_key(w, round);
                let order = WorkOrder {
                    round,
                    worker: w,
                    lane,
                    lane_round,
                    served: self.served[w],
                    op: op.clone(),
                    payloads,
                    delay: self.delays.service_delay(w, delay_round),
                    commitment: commitments[w],
                };
                match self.pool.dispatch(&order) {
                    Ok(()) => {
                        sent.push(w);
                        self.metrics.inc(names::TASKS_DISPATCHED);
                        for p in &order.payloads {
                            self.capture(w, round, true, p);
                            self.metrics.add(names::SYMBOLS_TO_WORKERS, p.symbols() as u64);
                        }
                    }
                    Err(e) => {
                        // A dead link means the thread is gone and its
                        // queue with it: nothing more will arrive from
                        // this worker for *any* in-flight round.
                        eprintln!("master: worker {w} marked dead: {e}");
                        self.note_worker_crashed(w);
                    }
                }
            }
        }
        let dispatched = sent.len();
        self.round_targets.insert(round, sent.clone());
        self.round_lanes.insert(round, (lane, lane_round));

        // The wait policy over the orders that actually went out.
        let (wait_for, min_required) = match threshold {
            Threshold::Exact(k) => {
                if dispatched < k {
                    self.registry.abandon(round);
                    self.settle_round(round);
                    // The abandoned round's orders are out: crashes
                    // scheduled on it still happen worker-side and must
                    // still be booked.
                    self.book_scheduled_faults(round, lane, lane_round, &sent, false);
                    anyhow::bail!(
                        "round {round}: only {dispatched} live workers but {} needs exactly {k}",
                        self.scheme.kind().name()
                    );
                }
                // Verified decode (DESIGN.md §11): under an active
                // forger plan, hold one surplus result past the exact
                // threshold when dispatch left slack — the redundancy
                // the decode residual check needs to bite. Keyed on the
                // static plan, so the wait target stays a pure function
                // of the scenario, never of arrival timing.
                let forger_plan =
                    self.faults.as_deref().is_some_and(FaultPlan::has_forgers);
                let wait_for = if forger_plan { (k + 1).min(dispatched) } else { k };
                (wait_for, k)
            }
            Threshold::Flexible { min } => {
                if dispatched < min {
                    self.registry.abandon(round);
                    self.settle_round(round);
                    self.book_scheduled_faults(round, lane, lane_round, &sent, false);
                    anyhow::bail!(
                        "round {round}: only {dispatched} live workers, below the flexible minimum {min}"
                    );
                }
                // Paper's experimental policy: decode when the fast
                // workers are in, without waiting out the stragglers.
                ((self.cfg.workers - self.cfg.stragglers).max(min).min(dispatched), min)
            }
        };
        self.registry.finalize(round, wait_for, min_required, &sent);
        if self.speculate {
            self.spec_rounds.insert(round, SpecRound { salt: round_salt, op, operands: retained });
        }
        // Scheduled faults for this round, booked from the same plan the
        // workers execute: a crashed worker received its order but will
        // never reply (and serves nothing afterwards); a corrupted
        // result is lost in transit while the worker lives on. Either
        // way the round's pending set shrinks now, so it degrades or
        // fails fast instead of riding the deadline.
        self.book_scheduled_faults(round, lane, lane_round, &sent, true);
        // Reclaim what the bookings just wrote off — for this round and
        // any older in-flight round a crash straddled.
        self.speculation_pass();
        Ok(RoundHandle {
            round,
            registry: Arc::downgrade(&self.registry),
            defused: false,
        })
    }

    /// Phase 3 of a round: block until the scheme's wait policy is
    /// satisfied (the collector buffers results for *all* in-flight
    /// rounds concurrently, so rounds may be waited on in any order),
    /// then decode. A round that loses workers mid-flight degrades to
    /// "decode from what arrived" when the scheme allows it; otherwise
    /// the wait fails with a typed [`RoundError`] — [`RoundError::Hopeless`]
    /// as soon as the threshold is unreachable, [`RoundError::Deadline`]
    /// when live-but-slow workers exhaust `round_deadline_s`.
    pub fn wait(&mut self, handle: RoundHandle) -> anyhow::Result<RoundOutcome> {
        let deadline_s = self.cfg.round_deadline_s;
        self.wait_with_deadline(handle, deadline_s)
    }

    /// [`wait`](Master::wait) under an explicit deadline budget instead
    /// of the config's `round_deadline_s` — the session layer's
    /// per-tenant deadline hook (DESIGN.md §12). The speculation
    /// checkpoint scales with the same budget.
    pub(crate) fn wait_with_deadline(
        &mut self,
        handle: RoundHandle,
        deadline_s: f64,
    ) -> anyhow::Result<RoundOutcome> {
        let round = handle.defuse();
        let deadline = Instant::now() + Duration::from_secs_f64(deadline_s);
        // Recover anything already known lost before blocking (covers
        // losses noted since the last submit-time pass).
        self.speculation_pass();
        let done = {
            let metrics = Arc::clone(&self.metrics);
            let _t = metrics.time_phase("phase.wait");
            // With speculation on, the wait runs in two legs: a soft leg
            // to the checkpoint — if the round is still short then, its
            // pending shares are duplicated onto the least-loaded live
            // workers (first result per share wins) — then the hard leg
            // to the deadline.
            let mut early = None;
            if self.speculate {
                let checkpoint = (Instant::now()
                    + Duration::from_secs_f64(deadline_s * SPEC_DEADLINE_FRACTION))
                .min(deadline);
                match self.registry.wait_soft(round, checkpoint) {
                    SoftWait::Done(done) => early = Some(done),
                    SoftWait::Gone => {} // the hard leg reports Unknown
                    SoftWait::Blocked { pending, hopeless } => {
                        // Duplicating pending shares cannot rescue a
                        // hopeless round (it adds copies, not shares) —
                        // let the hard leg fail fast instead.
                        if !hopeless {
                            for share in pending {
                                self.duplicate_share(round, share);
                            }
                        }
                    }
                }
            }
            let outcome = match early {
                Some(done) => Ok(done),
                None => self.registry.wait_done(round, deadline),
            };
            match outcome {
                Ok(done) => done,
                Err(e) => {
                    let forged = self.forge_booked.get(&round).copied().unwrap_or(0);
                    self.settle_round(round);
                    return Err(match e {
                        WaitError::Unknown(round) => RoundError::Unknown { round },
                        // A failed round with forgeries booked is
                        // reported as Forged, not as a generic
                        // timeout/hopeless: the caller must know the
                        // shortfall traces back to results dropped as
                        // forged — the round failed *typed*, it was
                        // never at risk of decoding silently wrong.
                        WaitError::TimedOut { round, .. } if forged > 0 => {
                            RoundError::Forged { round, forged }
                        }
                        WaitError::Hopeless { round, .. } if forged > 0 => {
                            RoundError::Forged { round, forged }
                        }
                        WaitError::TimedOut { round, got, need } => {
                            RoundError::Deadline { round, got, need }
                        }
                        WaitError::Hopeless { round, possible, need } => {
                            RoundError::Hopeless { round, possible, need }
                        }
                    }
                    .into());
                }
            }
        };
        let forged_booked = self.forge_booked.get(&round).copied().unwrap_or(0);
        self.settle_round(round);
        // Credit the uplink comm counters with exactly the decode
        // inputs (results beyond the wait policy were rejected before
        // unsealing and never charged — deterministic accounting).
        let (symbols_rx, bytes_rx) = done.received_totals();
        self.metrics.add(names::SYMBOLS_TO_MASTER, symbols_rx);
        self.metrics.add(names::BYTES_RX, bytes_rx);
        // The buffer is frozen at `wait_for`, so every buffered result
        // is consumed by the decoder (exact schemes' surplus spills into
        // the wasted-work accounting at delivery time instead).
        let used = match done.threshold {
            Threshold::Exact(k) => k.min(done.results.len()),
            Threshold::Flexible { .. } => done.results.len(),
        };
        self.metrics.add(names::RESULTS_USED, used as u64);
        let decoded = {
            let _t = self.metrics.time_phase("phase.decode");
            self.scheme.decode(&done.ctx, &done.results)?
        };
        // Verified decode, second layer (DESIGN.md §11): when the buffer
        // holds surplus results beyond an exact threshold, re-encode the
        // decoded blocks at each surplus share's node and compare. An
        // exact decoder consumes the first `k` results in worker order,
        // so any later-indexed buffered result is pure redundancy — a
        // residual there means a result the commitment layer did not
        // catch poisoned the decode set, and the round is refused rather
        // than returned silently wrong.
        if let Threshold::Exact(k) = done.threshold {
            if done.results.len() > k {
                let mut order: Vec<usize> = (0..done.results.len()).collect();
                order.sort_by_key(|&i| done.results[i].0);
                for &i in &order[k..] {
                    let (share, result) = &done.results[i];
                    let Some(expect) = predict_share_result(&done.ctx, &decoded, *share)
                    else {
                        continue;
                    };
                    if expect.rel_error(result) > RESIDUAL_TOL {
                        return Err(RoundError::Forged {
                            round,
                            forged: forged_booked.max(1),
                        }
                        .into());
                    }
                }
            }
        }
        Ok(RoundOutcome {
            blocks: decoded,
            wall: done.started.elapsed(),
            results_used: used,
            degraded: done.degraded,
        })
    }

    /// Give up on a submitted round without decoding it: its buffered
    /// results are counted as wasted work and its entry is dropped, so
    /// later arrivals go through the late-result accounting instead of
    /// being buffered forever. Dropping the handle does the same; the
    /// explicit form reads better when a batch is cancelled part-way.
    pub fn abandon(&mut self, handle: RoundHandle) {
        let round = handle.defuse();
        self.registry.abandon(round);
        self.settle_round(round);
    }

    /// Turn speculative re-dispatch on or off for the rounds submitted
    /// from here on (the builder seeds this from `config.speculate`;
    /// [`Master::service`] overrides it per service — and through it,
    /// [`run_stream`](Master::run_stream) per stream).
    pub fn set_speculation(&mut self, on: bool) {
        self.speculate = on;
    }

    /// Is speculative re-dispatch currently on?
    pub fn speculation(&self) -> bool {
        self.speculate
    }

    /// Re-dispatch every written-off share of every in-flight round to
    /// another live worker. Runs after fault bookings at submit time and
    /// before blocking in [`wait`](Master::wait); a no-op when
    /// speculation is off. Candidate order is deterministic (rounds
    /// ascending, shares as written off), and so is the executor choice
    /// (least-loaded per the [`LoadBook`], lowest index on ties).
    fn speculation_pass(&mut self) {
        if !self.speculate {
            return;
        }
        for (round, lost) in self.registry.speculation_candidates() {
            for share in lost {
                self.respeculate_share(round, share);
            }
        }
    }

    /// Re-send the work order for a written-off `share` of `round` to
    /// the least-loaded live worker: the share's operands are re-sealed
    /// to the executor's key on a dedicated seal stream, the order keeps
    /// the *share* id (so the result routes to the right interpolation
    /// point whoever computes it), and the registry moves the share back
    /// to pending — restoring the round's wait target, or rescinding a
    /// hopeless verdict the loss had caused.
    fn respeculate_share(&mut self, round: u64, share: usize) -> bool {
        let Some((salt, op, operands)) = self.spec_round_parts(round, share) else {
            return false;
        };
        let Some(executor) = self.pick_executor(round, share) else { return false };
        // The registry entry goes back to pending *before* the order
        // leaves, so the result can never race its own bookkeeping.
        if !self.registry.respeculate(round, share) {
            return false;
        }
        self.send_speculative(round, share, executor, salt, op, operands)
    }

    /// Near-deadline duplication of a still-pending `share` (the
    /// original owner is alive but slow): first result wins, the loser
    /// is discarded deterministically by share id.
    fn duplicate_share(&mut self, round: u64, share: usize) -> bool {
        let Some((salt, op, operands)) = self.spec_round_parts(round, share) else {
            return false;
        };
        // Don't hand the duplicate back to the slow owner.
        let Some(executor) = self.pick_executor(round, share) else { return false };
        if !self.registry.respeculate_dup(round, share) {
            return false;
        }
        self.send_speculative(round, share, executor, salt, op, operands)
    }

    /// The retained seal salt, op, and operands for `share` of `round`.
    fn spec_round_parts(&self, round: u64, share: usize) -> Option<(u64, WorkerOp, Vec<Matrix>)> {
        let spec = self.spec_rounds.get(&round)?;
        let operands = spec.operands.get(share)?.clone()?;
        Some((spec.salt, spec.op.clone(), operands))
    }

    /// The least-loaded live worker other than `share`'s original owner
    /// (deterministic: the load book only moves on the master thread,
    /// ties break to the lowest index). Workers whose scheduled
    /// corruption or forgery coin is true for `round` are skipped
    /// outright: the worker loop corrupts/forges *every* result frame it
    /// sends for that round — the copy would be lost in transit (or
    /// dropped at the commitment check), and unlike the original owners'
    /// frames, speculative copies are never booked lost at submit time,
    /// so the share would wedge in `pending` until the deadline.
    /// Quarantined workers are skipped too: a suspect keeps serving its
    /// own shares, but it earns no proxy work until a verified-good
    /// result rehabilitates it (DESIGN.md §11).
    fn pick_executor(&self, round: u64, share: usize) -> Option<usize> {
        let alive = self.directory.alive_mask();
        let suspected = self.directory.suspected_mask();
        let plan = self.faults.as_deref();
        let (lane, lane_round) = self.round_coords(round);
        self.load.least_loaded((0..alive.len()).filter(|&w| {
            alive[w]
                && w != share
                && !suspected[w]
                && plan.map_or(true, |p| {
                    // The coordinates the speculative order would carry
                    // for this candidate — the executor's *current*
                    // served count, the round's original lane pair.
                    let coords = self.fault_coords(w, round, lane, lane_round);
                    !p.corrupts(w, &coords) && !p.forges_at(w, &coords)
                })
        }))
    }

    /// Seal and ship one speculative order to `executor`.
    fn send_speculative(
        &mut self,
        round: u64,
        share: usize,
        executor: usize,
        salt: u64,
        op: WorkerOp,
        operands: Vec<Matrix>,
    ) -> bool {
        let pks = self.directory.pks();
        // Commitments are over the plaintext operands, so the proxy's
        // order carries the same commitment the owner's did — recomputed
        // from the retained operands rather than read back from the
        // ledger (provably equal, and no lock on the collector's path).
        let commitment = share_commitment(&operands);
        // A dedicated seal stream per (round, executor, share): never
        // reuses the original owner's keystream, and never collides with
        // the executor's own share of the round.
        let mut seal_rng = rng_from_seed(derive_seed(
            salt,
            0x5BEC_0000 ^ ((executor as u64) << 32) ^ share as u64,
        ));
        let payloads: Vec<WirePayload> = operands
            .into_iter()
            .map(|m| match self.cfg.security {
                TransportSecurity::Plain => WirePayload::Plain(m),
                TransportSecurity::MeaEcc => WirePayload::Sealed(SealedPayload::seal(
                    &self.mea,
                    &m,
                    &pks[executor],
                    &mut seal_rng,
                )),
            })
            .collect();
        // The proxy's order keeps the round's original lane pair (the
        // share's draw identity) and carries the executor's current
        // served count *without* ticking it — proxy work is extra load,
        // not a wall round.
        let (lane, lane_round) = self.round_coords(round);
        let order = WorkOrder {
            round,
            worker: share,
            lane,
            lane_round,
            served: self.served[executor],
            op,
            payloads,
            delay: self.delays.service_delay(executor, self.delay_key(executor, round)),
            commitment,
        };
        match self.pool.dispatch_to(executor, &order) {
            Ok(()) => {
                self.round_targets.entry(round).or_default().push(executor);
                self.metrics.inc(names::SPEC_REDISPATCHED);
                for p in &order.payloads {
                    self.capture(executor, round, true, p);
                    self.metrics.add(names::SYMBOLS_TO_WORKERS, p.symbols() as u64);
                }
                true
            }
            Err(e) => {
                eprintln!(
                    "master: speculative re-dispatch of share {share} (round {round}) to \
                     worker {executor} failed: {e}"
                );
                // The order never left: the share returns to lost (or
                // stays pending for a duplicate) and the dead executor
                // is booked like any other dead link.
                self.registry.respeculate_failed(round, share);
                self.note_worker_crashed(executor);
                false
            }
        }
    }

    /// Settle a retired round's bookkeeping: close its settle ledger,
    /// release whatever load-book orders the collector shards have *not*
    /// already settled per-result (the multiset difference of dispatch
    /// targets minus recorded executors — workers that never replied),
    /// and drop its retained operands. Removing the ledger entry under
    /// the lock is what makes this exact: a result landing afterwards
    /// finds no entry and settles nothing, because its slot was just
    /// settled here.
    fn settle_round(&mut self, round: u64) {
        let recorded =
            self.round_settled.lock().unwrap().remove(&round).unwrap_or_default();
        if let Some(targets) = self.round_targets.remove(&round) {
            let mut owed: HashMap<usize, usize> = HashMap::new();
            for w in targets {
                *owed.entry(w).or_insert(0) += 1;
            }
            for w in recorded {
                if let Some(c) = owed.get_mut(&w) {
                    *c = c.saturating_sub(1);
                }
            }
            let remainder: Vec<usize> = owed
                .into_iter()
                .flat_map(|(w, c)| std::iter::repeat(w).take(c))
                .collect();
            self.load.settle(&remainder);
        }
        self.spec_rounds.remove(&round);
        self.round_lanes.remove(&round);
        self.commit_book.lock().unwrap().remove(&round);
        self.forge_booked.remove(&round);
    }

    /// Reclaim bookkeeping for rounds that left the registry without
    /// passing through [`wait`](Master::wait)/[`abandon`](Master::abandon)
    /// (a dropped [`RoundHandle`] abandons in place).
    fn sweep_retired(&mut self) {
        if self.round_targets.is_empty() && self.spec_rounds.is_empty() {
            return;
        }
        let live: HashSet<u64> = self.registry.inflight_ids().into_iter().collect();
        let stale: Vec<u64> =
            self.round_targets.keys().filter(|r| !live.contains(r)).copied().collect();
        for round in stale {
            self.settle_round(round);
        }
        self.spec_rounds.retain(|round, _| live.contains(round));
    }

    /// Record an eavesdropped wire payload.
    fn capture(&self, worker: usize, round: u64, downlink: bool, p: &WirePayload) {
        if let Some(tap) = &self.eavesdropper {
            tap.capture(worker, round, downlink, &p.wire_matrix());
        }
    }
}

impl Drop for Master {
    fn drop(&mut self) {
        // Tear the fabric down first so the inbound channel disconnects,
        // then join the router and the shards.
        self.pool.shutdown();
        for j in self.collector.drain(..) {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::BlockCode;
    use crate::config::{SchemeKind, TransportKind};
    use crate::matrix::{matmul, split_rows};
    use crate::runtime::WorkerOp;

    fn base_cfg(scheme: SchemeKind) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.workers = 12;
        cfg.partitions = 3;
        cfg.colluders = 2;
        cfg.stragglers = 2;
        cfg.scheme = scheme;
        cfg.delay.base_service_s = 0.0; // fast tests
        cfg
    }

    #[test]
    fn spacdc_round_end_to_end_sealed() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(1);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        let v = Arc::new(Matrix::random_gaussian(8, 4, 0.0, 1.0, &mut rng));
        let out = master
            .run(CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone()))
            .unwrap();
        assert_eq!(out.blocks.len(), 3);
        assert_eq!(out.results_used, 10); // N − S
        let (blocks, _) = split_rows(&x, 3);
        for (d, b) in out.blocks.iter().zip(&blocks) {
            let err = d.rel_error(&matmul(b, &v));
            // Approximate decode at N=12, S=2, with privacy masks: the
            // bound here is coarse; accuracy-vs-returns is characterized
            // precisely in the coding-layer tests.
            assert!(err < 0.5, "err={err}");
        }
        // Transport accounting is live — symbols AND serialized bytes.
        assert!(master.metrics().get(names::SYMBOLS_TO_WORKERS) > 0);
        assert!(master.metrics().get(names::SYMBOLS_TO_MASTER) > 0);
        assert!(master.metrics().get(names::BYTES_TX) > 0);
        assert!(master.metrics().get(names::BYTES_RX) > 0);
    }

    #[test]
    fn mds_round_exact_decode() {
        let mut cfg = base_cfg(SchemeKind::Mds);
        cfg.security = TransportSecurity::Plain;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(2);
        let x = Matrix::random_gaussian(24, 6, 0.0, 1.0, &mut rng);
        let v = Arc::new(Matrix::random_gaussian(6, 5, 0.0, 1.0, &mut rng));
        let out = master
            .run(CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone()))
            .unwrap();
        assert_eq!(out.results_used, 3); // threshold K
        let (blocks, _) = split_rows(&x, 3);
        for (d, b) in out.blocks.iter().zip(&blocks) {
            assert!(d.rel_error(&matmul(b, &v)) < 1e-2);
        }
    }

    #[test]
    fn uncoded_round_waits_for_everyone() {
        let mut cfg = base_cfg(SchemeKind::Uncoded);
        cfg.partitions = 12;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(3);
        let x = Matrix::random_gaussian(24, 4, 0.0, 1.0, &mut rng);
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        assert_eq!(out.results_used, 12);
    }

    #[test]
    fn matdot_round_full_product() {
        let mut cfg = base_cfg(SchemeKind::MatDot);
        cfg.partitions = 3;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(4);
        let a = Matrix::random_gaussian(8, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(9, 7, 0.0, 1.0, &mut rng);
        let out = master.run(CodedTask::pair_product(a.clone(), b.clone())).unwrap();
        assert_eq!(out.results_used, 5); // 2K−1
        assert_eq!(out.blocks.len(), 1);
        assert!(out.blocks[0].rel_error(&matmul(&a, &b)) < 1e-2);
    }

    #[test]
    fn pair_product_through_a_row_partition_scheme() {
        // The unified surface: the same task MatDot serves natively runs
        // on SPACDC by encode(A) + broadcast right-multiply.
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(40);
        let a = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(8, 5, 0.0, 1.0, &mut rng);
        let out = master.run(CodedTask::pair_product(a.clone(), b.clone())).unwrap();
        assert_eq!(out.blocks.len(), 1);
        assert_eq!(out.blocks[0].shape(), (24, 5));
        assert!(out.blocks[0].rel_error(&matmul(&a, &b)) < 0.5);
    }

    #[test]
    fn blockmap_on_matdot_config_is_an_error() {
        let mut master = Master::from_config(base_cfg(SchemeKind::MatDot)).unwrap();
        let x = Matrix::ones(6, 4);
        assert!(master.run(CodedTask::block_map(WorkerOp::Identity, x)).is_err());
    }

    #[test]
    fn mds_rejects_gram_tasks() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Mds)).unwrap();
        let x = Matrix::ones(6, 4);
        assert!(master.run(CodedTask::block_map(WorkerOp::Gram, x)).is_err());
    }

    #[test]
    fn submitted_rounds_interleave_without_bleed() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(41);
        let x1 = Matrix::random_gaussian(12, 4, 0.0, 1.0, &mut rng);
        let x2 = Matrix::random_gaussian(12, 4, 0.0, 1.0, &mut rng);
        let h1 = master.submit(CodedTask::block_map(WorkerOp::Identity, x1.clone())).unwrap();
        let h2 = master.submit(CodedTask::block_map(WorkerOp::Identity, x2.clone())).unwrap();
        assert_ne!(h1.round_id(), h2.round_id());
        // Wait in reverse submission order: round 1 results arriving
        // while we wait on round 2 must be buffered, not dropped.
        let out2 = master.wait(h2).unwrap();
        let out1 = master.wait(h1).unwrap();
        let (b1, _) = split_rows(&x1, 3);
        let (b2, _) = split_rows(&x2, 3);
        for ((d1, e1), (d2, e2)) in
            out1.blocks.iter().zip(&b1).zip(out2.blocks.iter().zip(&b2))
        {
            assert!(d1.rel_error(e1) < 0.5, "round 1 decode off: {}", d1.rel_error(e1));
            assert!(d2.rel_error(e2) < 0.5, "round 2 decode off: {}", d2.rel_error(e2));
        }
    }

    #[test]
    fn abandoned_rounds_settle_their_accounting() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let x = Matrix::ones(12, 4);
        let h = master.submit(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        master.abandon(h);
        // The abandoned round's results now land through the stale path;
        // the next full round must still work and count them late.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        assert_eq!(out.blocks.len(), 3);
        assert!(master.metrics().get(names::RESULTS_LATE) > 0);
    }

    #[test]
    fn dropping_a_handle_abandons_its_round() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let x = Matrix::ones(12, 4);
        let h = master.submit(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        let round = h.round_id();
        drop(h); // no wait, no explicit abandon
        // The in-flight buffer is freed immediately, not at master drop.
        assert!(!master.registry.is_inflight(round));
        // Late arrivals for the dropped round are settled as wasted work
        // and the next round is unaffected.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        assert_eq!(out.blocks.len(), 3);
        assert!(master.metrics().get(names::RESULTS_LATE) > 0);
    }

    #[test]
    fn waiting_twice_is_impossible_and_unknown_round_errors() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let x = Matrix::ones(12, 4);
        let h = master.submit(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        master.wait(h).unwrap();
        // The handle is consumed by wait; there is no second handle to
        // wait on — the closest misuse is an abandoned round's id, which
        // the registry reports as unknown (covered in registry tests).
    }

    #[test]
    fn round_deadline_times_out_with_a_typed_error() {
        let mut cfg = base_cfg(SchemeKind::Spacdc);
        cfg.round_deadline_s = 0.05;
        cfg.delay.base_service_s = 0.3; // every worker far slower than the deadline
        let mut master = Master::from_config(cfg).unwrap();
        let x = Matrix::ones(12, 4);
        let err = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "got: {err}");
    }

    #[test]
    fn tcp_transport_runs_a_full_round() {
        let mut cfg = base_cfg(SchemeKind::Spacdc);
        cfg.transport = TransportKind::Tcp;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(50);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        assert_eq!(out.blocks.len(), 3);
        assert!(master.metrics().get(names::BYTES_TX) > 0);
        assert!(master.metrics().get(names::BYTES_RX) > 0);
    }

    #[test]
    fn eavesdropper_sees_only_ciphertext_under_mea() {
        let tap = Arc::new(EavesdropLog::new());
        let cfg = base_cfg(SchemeKind::Spacdc);
        let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
        let mut rng = rng_from_seed(5);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        assert!(tap.count() > 0);
        // Reconstruct what the shares would be and check decorrelation.
        let params = CodeParams::new(12, 3, 2);
        let scheme = crate::coding::Spacdc::new(params);
        let enc = scheme.encode_blocks(&x, 1, &mut rng_from_seed(999)).unwrap();
        let corr = tap.downlink_correlation(&enc.shares);
        assert!(corr < 0.2, "wire payloads correlate with shares: {corr}");
    }

    #[test]
    fn plain_transport_leaks_to_eavesdropper() {
        let tap = Arc::new(EavesdropLog::new());
        let mut cfg = base_cfg(SchemeKind::Bacc);
        cfg.security = TransportSecurity::Plain;
        cfg.seed = 77;
        let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
        let mut rng = rng_from_seed(6);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        // BACC encode is deterministic → the true shares are exactly
        // reproducible, and the plaintext wire bytes must match them.
        let scheme = crate::coding::Bacc::new(CodeParams::new(12, 3, 0));
        let enc = scheme.encode_blocks(&x, 1, &mut rng_from_seed(0)).unwrap();
        let corr = tap.downlink_correlation(&enc.shares);
        assert!(corr > 0.5, "plaintext transport should leak: {corr}");
    }

    #[test]
    fn planned_crash_degrades_then_respawn_restores() {
        use crate::sim::CrashEvent;
        // N = 12, S = 0: the policy wants all 12. Worker 0 crashes
        // mid-round 1 and rejoins before round 3.
        let mut cfg = base_cfg(SchemeKind::Spacdc);
        cfg.stragglers = 0;
        let plan = Arc::new(FaultPlan::new(
            vec![CrashEvent { worker: 0, round: 1, respawn_after: Some(2) }],
            0.0,
            cfg.seed,
        ));
        let mut master = MasterBuilder::new(cfg).faults(plan).build().unwrap();
        let x = Matrix::ones(12, 4);

        // Round 1: 12 dispatched, one never replies → degrade to 11.
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        assert_eq!(out.results_used, 11);
        assert!(out.degraded);
        assert_eq!(master.dead_workers(), vec![0]);
        assert_eq!(master.metrics().get(names::ROUNDS_DEGRADED), 1);

        // Round 2: the dead worker is skipped up front → no degradation.
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        assert_eq!(out.results_used, 11);
        assert!(!out.degraded);

        // Round 3: the scheduled respawn rejoined the worker first.
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        assert_eq!(out.results_used, 12);
        assert!(!out.degraded);
        assert!(master.dead_workers().is_empty());
        assert_eq!(master.worker_generations()[0], 1, "worker 0 is its second incarnation");
        assert_eq!(master.metrics().get(names::WORKER_RESPAWNS), 1);
    }

    #[test]
    fn unreachable_threshold_fails_fast_with_a_hopeless_error() {
        use crate::sim::CrashEvent;
        // MDS needs exactly K = 3 of N = 4; two mid-round crashes leave
        // only 2 possible results. The wait must fail immediately (the
        // deadline is far away) with the "too many down" variant.
        let mut cfg = base_cfg(SchemeKind::Mds);
        cfg.workers = 4;
        cfg.stragglers = 0;
        cfg.colluders = 0;
        cfg.security = TransportSecurity::Plain;
        cfg.round_deadline_s = 60.0;
        let plan = Arc::new(FaultPlan::new(
            vec![
                CrashEvent { worker: 1, round: 1, respawn_after: None },
                CrashEvent { worker: 2, round: 1, respawn_after: None },
            ],
            0.0,
            cfg.seed,
        ));
        let mut master = MasterBuilder::new(cfg).faults(plan).build().unwrap();
        let t0 = Instant::now();
        let err = master
            .run(CodedTask::block_map(WorkerOp::Identity, Matrix::ones(12, 4)))
            .unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(10), "must not ride the deadline");
        assert!(err.to_string().contains("too many workers are down"), "got: {err}");
        assert_eq!(
            err.inner().downcast_ref::<RoundError>(),
            Some(&RoundError::Hopeless { round: 1, possible: 2, need: 3 })
        );
    }

    #[test]
    fn manual_crash_and_respawn_walk_the_lifecycle() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let x = Matrix::ones(12, 4);
        master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        // Graceful wire kill: worker 3 is gone from the next round on.
        master.crash_worker(3).unwrap();
        assert_eq!(master.worker_states()[3], WorkerState::Crashed);
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        assert_eq!(out.results_used, 10); // policy N − S, 11 dispatched
        // Rejoin: re-keyed, re-registered, serving again.
        master.respawn_worker(3).unwrap();
        assert_eq!(master.worker_states()[3], WorkerState::Alive);
        assert_eq!(master.worker_generations()[3], 1);
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        assert_eq!(out.results_used, 10);
        assert!(master.respawn_worker(3).is_err(), "respawning a live worker is refused");
    }

    #[test]
    fn planned_forgery_is_dropped_and_recovered_by_redispatch() {
        // Worker 2 forges every round; speculation re-dispatches its
        // share to an honest proxy; the collector's commitment check
        // drops the forged copy, so the decode is clean even though the
        // forged frame and the honest frame race for the same slot.
        let mut cfg = base_cfg(SchemeKind::Spacdc);
        cfg.stragglers = 0;
        cfg.speculate = true;
        let plan = Arc::new(FaultPlan::new(vec![], 0.0, cfg.seed).with_forgers(vec![2], 1.0));
        let mut master = MasterBuilder::new(cfg).faults(plan).build().unwrap();
        let mut rng = rng_from_seed(90);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        let v = Arc::new(Matrix::random_gaussian(8, 4, 0.0, 1.0, &mut rng));
        let out = master
            .run(CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone()))
            .unwrap();
        // All 12 shares decoded, none of them the −1.375-scaled forgery.
        assert_eq!(out.results_used, 12);
        let (blocks, _) = split_rows(&x, 3);
        for (d, b) in out.blocks.iter().zip(&blocks) {
            let err = d.rel_error(&matmul(b, &v));
            assert!(err < 0.5, "forged result poisoned the decode: err={err}");
        }
        let m = master.metrics();
        assert_eq!(m.get(names::VERIFY_FORGED_DETECTED), 1, "one forgery booked");
        assert!(m.get(names::SPEC_REDISPATCHED) >= 1, "forged share was re-dispatched");
        // Every buffered result passed the commitment check.
        assert!(m.get(names::VERIFY_CHECKED) >= 12);
    }

    #[test]
    fn unrecoverable_forgery_fails_typed_never_silently_wrong() {
        // MDS needs exactly K = 3 of N = 4; two forgers at rate 1.0 with
        // speculation off leave only 2 verifiable results. The wait must
        // fail with the Forged variant — not Hopeless, and above all not
        // a silently wrong decode.
        let mut cfg = base_cfg(SchemeKind::Mds);
        cfg.workers = 4;
        cfg.stragglers = 0;
        cfg.colluders = 0;
        cfg.security = TransportSecurity::Plain;
        cfg.round_deadline_s = 60.0;
        cfg.speculate = false;
        let plan =
            Arc::new(FaultPlan::new(vec![], 0.0, cfg.seed).with_forgers(vec![0, 1], 1.0));
        let mut master = MasterBuilder::new(cfg).faults(plan).build().unwrap();
        let t0 = Instant::now();
        let err = master
            .run(CodedTask::block_map(WorkerOp::Identity, Matrix::ones(12, 4)))
            .unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(10), "must not ride the deadline");
        assert_eq!(
            err.inner().downcast_ref::<RoundError>(),
            Some(&RoundError::Forged { round: 1, forged: 2 }),
            "got: {err}"
        );
        assert_eq!(master.metrics().get(names::VERIFY_FORGED_DETECTED), 2);
    }

    #[test]
    fn exact_scheme_holds_a_surplus_result_under_a_forger_plan() {
        // With an active forger plan, MDS waits for K+1 results so the
        // decode residual check has redundancy to bite on; the decode
        // itself still consumes exactly K.
        let mut cfg = base_cfg(SchemeKind::Mds);
        cfg.stragglers = 0;
        cfg.security = TransportSecurity::Plain;
        cfg.speculate = true;
        let plan = Arc::new(FaultPlan::new(vec![], 0.0, cfg.seed).with_forgers(vec![5], 1.0));
        let mut master = MasterBuilder::new(cfg).faults(plan).build().unwrap();
        let mut rng = rng_from_seed(92);
        let x = Matrix::random_gaussian(24, 6, 0.0, 1.0, &mut rng);
        let v = Arc::new(Matrix::random_gaussian(6, 5, 0.0, 1.0, &mut rng));
        let out = master
            .run(CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone()))
            .unwrap();
        assert_eq!(out.results_used, 3, "decode still consumes exactly K");
        let (blocks, _) = split_rows(&x, 3);
        for (d, b) in out.blocks.iter().zip(&blocks) {
            assert!(d.rel_error(&matmul(b, &v)) < 1e-2);
        }
    }

    #[test]
    fn surplus_prediction_matches_honest_results_and_flags_forged_ones() {
        // The decode residual core: an honest surplus share re-encoded
        // from the decoded blocks matches to round-off; a forged one is
        // off by orders of magnitude; private schemes are unpredictable.
        let code = crate::coding::EvalCode::mds(CodeParams::new(8, 3, 0));
        let mut rng = rng_from_seed(91);
        let x = Matrix::random_gaussian(12, 5, 0.0, 1.0, &mut rng);
        let enc = code.encode_blocks(&x, 1, &mut rng).unwrap();
        // f = identity: the results are the shares themselves.
        let results: Vec<(usize, Matrix)> =
            (0..3).map(|i| (i, enc.shares[i].clone())).collect();
        let decoded = code.decode_blocks(&enc.ctx, &results).unwrap();
        let honest = enc.shares[5].clone();
        let predicted = predict_share_result(&enc.ctx, &decoded, 5).unwrap();
        assert!(predicted.rel_error(&honest) < RESIDUAL_TOL);
        let forged = honest.scale(-1.375);
        assert!(predicted.rel_error(&forged) > RESIDUAL_TOL);
        // Privacy masks make the surplus unpredictable — the commitment
        // layer owns verification there.
        let priv_code = crate::coding::EvalCode::secpoly(CodeParams::new(8, 3, 2));
        let enc2 = priv_code.encode_blocks(&x, 1, &mut rng).unwrap();
        let r2: Vec<(usize, Matrix)> =
            (0..5).map(|i| (i, enc2.shares[i].clone())).collect();
        let d2 = priv_code.decode_blocks(&enc2.ctx, &r2).unwrap();
        assert!(predict_share_result(&enc2.ctx, &d2, 6).is_none());
    }

    #[test]
    fn quarantined_workers_earn_no_proxy_work_until_rehabilitated() {
        // pick_executor must skip a suspect; after rehabilitation it is
        // eligible again. Exercised directly against the directory and
        // the load book (the end-to-end path is covered by the forgers
        // scenario in the engine tests).
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let x = Matrix::ones(12, 4);
        master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        // All loads equal → least-loaded tie breaks to lowest index.
        assert_eq!(master.pick_executor(1, 5), Some(0));
        master.directory.mark_suspected(0);
        assert_eq!(master.pick_executor(1, 5), Some(1), "suspect must be skipped");
        master.directory.rehabilitate(0);
        assert_eq!(master.pick_executor(1, 5), Some(0), "rehabilitated worker is back");
    }

    #[test]
    fn successive_rounds_reuse_pool() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(7);
        let x = Matrix::random_gaussian(12, 4, 0.0, 1.0, &mut rng);
        for _ in 0..3 {
            let out = master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
            assert_eq!(out.blocks.len(), 3);
        }
        // Late results from earlier rounds may or may not have landed,
        // but the master must still be consistent.
        assert!(master.metrics().get(names::TASKS_DISPATCHED) >= 36);
    }
}
